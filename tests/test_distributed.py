"""Distributed-execution equivalence on forced multi-device CPU.

These tests spawn SUBPROCESSES with ``--xla_force_host_platform_device_
count=8`` (jax fixes the device count at first init, so the main pytest
process stays single-device) and assert that the sharded mesh execution
matches the single-device reference numerically — params FSDP/TP-sharded,
batch data-parallel, MoE expert-parallel with all_to_all."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str) -> dict:
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        env={**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    res = run_sub("""
        from repro.configs import get_config
        from repro.models.api import build_model
        from repro.runtime import ShardingRules, TrainOptions
        from repro.runtime.steps import build_train_step, make_train_state
        from jax.sharding import Mesh
        import numpy as _np

        cfg = get_config("qwen2-0.5b").reduced()
        model = build_model(cfg)
        batch = model.make_batch(jax.random.PRNGKey(1), batch=8, seq=32)
        opts = TrainOptions(total_steps=10, remat=False)

        # single device
        step1, _ = build_train_step(model, None, None, opts)
        s1 = make_train_state(model, jax.random.PRNGKey(0))
        s1, m1 = step1(s1, batch)

        # 4x2 mesh (data x model)
        mesh = Mesh(_np.asarray(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        step2, sh = build_train_step(model, mesh, ShardingRules(), opts)
        s2 = make_train_state(model, jax.random.PRNGKey(0))
        s2, m2 = step2(s2, batch)

        d = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(s1.params),
                                jax.tree.leaves(s2.params)))
        print(json.dumps({
            "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
            "max_param_diff": d}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 5e-2, res
    assert res["max_param_diff"] < 5e-2, res


@pytest.mark.slow
def test_moe_ep_matches_local():
    """EP all_to_all / psum vs the local reference.  Root cause of the
    historical xfail: the `jax.shard_map` top-level API does not exist on
    jax 0.4.x, so the subprocess died with AttributeError before computing
    anything — not numerics.  With the `models/_compat.shard_map` shim the
    drift is well inside 2e-3 (float32 dispatch order only)."""
    res = run_sub("""
        import dataclasses
        from repro.configs import get_config
        from repro.models.moe import MoEOptions, moe_ep_a2a, moe_ep_psum, \\
            moe_local, moe_specs
        from repro.models.params import init_params
        from repro.runtime import ShardingRules, use_sharding
        from jax.sharding import Mesh
        import numpy as _np

        cfg = get_config("qwen3-moe-30b-a3b").reduced()
        specs = moe_specs(cfg, 1)
        p = jax.tree.map(lambda a: a[0],
                         init_params(specs, jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32)
        opts = MoEOptions(capacity_factor=16.0)
        y_ref, aux_ref = moe_local(p, x, cfg, opts)

        mesh = Mesh(_np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        with use_sharding(mesh, ShardingRules()):
            y_a2a, aux_a2a = jax.jit(
                lambda p, x: moe_ep_a2a(p, x, cfg, opts))(p, x)
            y_psum, aux_psum = jax.jit(
                lambda p, x: moe_ep_psum(p, x, cfg, opts))(p, x)
        print(json.dumps({
            "d_a2a": float(jnp.abs(y_a2a - y_ref).max()),
            "d_psum": float(jnp.abs(y_psum - y_ref).max()),
            "aux_ref": float(aux_ref), "aux_a2a": float(aux_a2a)}))
    """)
    assert res["d_a2a"] < 2e-3, res
    assert res["d_psum"] < 2e-3, res


@pytest.mark.slow
def test_flash_decoding_shard_map_combine():
    """Explicit sequence-sharded decode: shard_map partial softmax + psum
    log-sum-exp combine equals the dense reference.  Root cause of the
    historical xfail: `jax.shard_map` is absent on jax 0.4.x (AttributeError
    in the subprocess), not combine-dtype drift; the float32 log-sum-exp
    combine is stable to <1e-4 once run through the compat shim."""
    res = run_sub("""
        from repro.kernels.decode_attention import ops as da
        from repro.kernels.decode_attention import ref as dref
        from repro.models._compat import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        import numpy as _np

        b, smax, h, kvh, d = 2, 64, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
        ck = jax.random.normal(ks[1], (b, smax, kvh, d), jnp.float32)
        cv = jax.random.normal(ks[2], (b, smax, kvh, d), jnp.float32)
        valid = jnp.asarray([40, 64])
        want = dref.decode_reference(q, ck, cv, valid)

        mesh = Mesh(_np.asarray(jax.devices()[:8]).reshape(8,), ("model",))
        pos = jnp.arange(smax)

        def shard_fn(q, ck, cv, valid, pos):
            mask = pos[None, :] < valid[:, None]
            acc, m, l = da.partial_decode(q, ck, cv, mask)
            return da.combine_partials(acc, m, l, "model")

        out = jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(None, "model"), P(None, "model"), P(),
                      P("model")),
            out_specs=P(), check_vma=False))(q, ck, cv, valid, pos)
        print(json.dumps(
            {"diff": float(jnp.abs(out.reshape(b, h, d) - want).max())}))
    """)
    assert res["diff"] < 1e-4, res

"""The analytic makespan model vs every number the paper publishes."""

import pytest

from repro.core import (cdg_dag, cdg_sequential_stage_tx, ddmd_stage_tx,
                        deepdrivemd_dag, doa_res, fig2b_with_paper_tx,
                        maskable_stages, relative_improvement,
                        sequential_ttx, sequential_ttx_grouped,
                        staggered_async_ttx, summit_pool, wla)
from repro.core.model import async_ttx, predict


def test_masking_example_section_5_3():
    """t0=500, t1=t2=1000, 2*t3=2*t5=t4=4000 -> 7500 sequential,
    5500 asynchronous, I ~ 26%."""
    g = fig2b_with_paper_tx()
    t_seq = sequential_ttx(g)
    t_async, tails = async_ttx(g)
    assert t_seq == pytest.approx(7500.0)
    assert t_async == pytest.approx(5500.0)
    assert sorted(tails, reverse=True)[0] == pytest.approx(5000.0)
    assert relative_improvement(t_seq, t_async) == pytest.approx(0.2667, abs=1e-3)


def test_ddmd_sequential_ttx_eqn2():
    # 3 * (340 + 85 + 63 + 38) = 1578 s (§7.1)
    assert sequential_ttx_grouped(ddmd_stage_tx(), n_iterations=3) == \
        pytest.approx(1578.0)


def test_ddmd_maskable_stages():
    dd = deepdrivemd_dag(3)
    pool = summit_pool()
    sets = [dd.node(n) for n in ("simul0", "aggre0", "train0", "infer0")]
    # Sim and Infer demand all 96 GPUs -> ineligible; Aggr/Train maskable.
    assert maskable_stages(sets, pool) == [False, True, True, False]


def test_ddmd_eqn6_staggered():
    # t_async = 3 t_seq - 2 t_Aggr - 1 t_Train = 1345 s (§7.1)
    mask = [False, True, True, False]
    t = staggered_async_ttx(ddmd_stage_tx(), 3, mask)
    assert t == pytest.approx(1345.0)


def test_ddmd_predicted_async_with_overheads():
    # Table 3 Pred. t_async = 1399 (= 1345 * 1.04)
    t = staggered_async_ttx(ddmd_stage_tx(), 3, [False, True, True, False])
    assert t * 1.04 == pytest.approx(1399, abs=1.0)
    assert 1 - (t * 1.04) / 1578 == pytest.approx(0.113, abs=2e-3)


def test_ddmd_masking_condition():
    # t_Sim >= t_Aggr + t_Train is what lets both stages be masked (§7.1)
    tx = ddmd_stage_tx()
    assert tx[0] >= tx[1] + tx[2]


@pytest.mark.parametrize("which,t_async_base,t_pred", [
    ("c-DG1", 1860.0, 1972.0),
    ("c-DG2", 1300.0, 1378.0),
])
def test_cdg_async_ttx_eqn3(which, t_async_base, t_pred):
    g = cdg_dag(which)
    t, _ = async_ttx(g)
    assert t == pytest.approx(t_async_base, abs=1.0)
    # Table 3 Pred. includes EnTK 4% and async-enablement 2%
    assert t * 1.04 * 1.02 == pytest.approx(t_pred, abs=2.0)


def test_cdg_sequential_2000():
    for which in ("c-DG1", "c-DG2"):
        assert sequential_ttx_grouped(cdg_sequential_stage_tx(which)) == \
            pytest.approx(2000.0, abs=25.0)  # c-DG1 fractions round to 0.99


def test_cdg_predicted_improvement_signs():
    # c-DG1 ~no benefit; c-DG2 ~0.31 predicted before overheads (§7.3)
    t1, _ = async_ttx(cdg_dag("c-DG1"))
    t2, _ = async_ttx(cdg_dag("c-DG2"))
    assert relative_improvement(2000.0, t1) < 0.08
    assert relative_improvement(2000.0, t2) == pytest.approx(0.35, abs=0.05)


def test_wla_table3():
    pool = summit_pool()
    dd = deepdrivemd_dag(3)
    assert dd.doa_dep() == 2
    assert doa_res(dd, pool, "full_set") == 1
    assert wla(dd, pool, "full_set") == 1          # Table 3 row 1
    for which in ("c-DG1", "c-DG2"):
        g = cdg_dag(which)
        assert doa_res(g, pool, "minimal") == 2
        assert wla(g, pool, "minimal") == 2        # Table 3 rows 2-3


def test_predict_end_to_end():
    pool = summit_pool()
    p = predict(cdg_dag("c-DG2"), pool)
    assert p.wla == 2
    assert p.t_async < p.t_seq
    assert 0.1 < p.improvement < 0.4


def test_predict_sequential_dg_gains_nothing():
    from repro.core import fig2a_chain
    pool = summit_pool()
    p = predict(fig2a_chain(5), pool)
    assert p.wla == 0
    assert p.improvement <= 0.0  # only overheads remain

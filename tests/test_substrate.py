"""Substrate tests: optimizer, schedules, compression, data pipeline,
checkpointing, fault tolerance, sharding rules."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_pytree, save_pytree)
from repro.data import SyntheticTokenDataset
from repro.optim import (GradAccumulator, adamw_init, adamw_update,
                         clip_by_global_norm, compress_init,
                         cosine_schedule, topk_compress_update, wsd_schedule)
from repro.runtime import ShardingRules
from repro.runtime.fault import FailureInjector, NodeFailure, StragglerMonitor


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_schedules_shape():
    s = jnp.arange(0, 1000)
    cos = cosine_schedule(s, peak_lr=1e-3, warmup=100, total=1000)
    wsd = wsd_schedule(s, peak_lr=1e-3, warmup=100, total=1000)
    assert float(cos[0]) == 0.0 and float(cos[100]) == pytest.approx(1e-3)
    # WSD: stable plateau then decay
    assert float(wsd[500]) == pytest.approx(1e-3)
    assert float(wsd[999]) < 2e-4
    assert float(wsd[950]) < float(wsd[890])


def test_grad_accumulation_matches_full_batch():
    def loss(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (8, 4))}
    batch = {"x": jax.random.normal(key, (16, 8)),
             "y": jax.random.normal(key, (16, 4))}
    l1, g1 = jax.value_and_grad(loss)(p, batch)
    l2, g2 = GradAccumulator(4).grads(loss, p, batch)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_topk_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    st = compress_init(g)
    sparse, st = topk_compress_update(g, st, ratio=0.1)
    nz = int(jnp.sum(sparse["w"] != 0))
    assert nz <= 8 + 1
    # lossless bookkeeping: sparse + residual == original
    np.testing.assert_allclose(
        np.asarray(sparse["w"] + st.residual["w"]), np.asarray(g["w"]),
        rtol=1e-6, atol=1e-6)
    # second step re-injects the residual
    sparse2, st2 = topk_compress_update(
        {"w": jnp.zeros_like(g["w"])}, st, ratio=0.1)
    assert float(jnp.abs(sparse2["w"]).sum()) > 0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    ds = SyntheticTokenDataset(vocab_size=1000, seq_len=16, global_batch=8,
                               seed=3)
    a = ds.host_batch(5)
    b = ds.host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    full = ds.batch_slice(5, 0, 8)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # per-rank slices agree with the full batch
    lo = ds.batch_slice(5, 2, 4)
    np.testing.assert_array_equal(lo["tokens"], a["tokens"][2:4])
    assert (a["tokens"] < 1000).all() and (a["tokens"] >= 0).all()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    save_pytree(tree, d, 7)
    assert latest_step(d) == 7
    out = restore_pytree(tree, d, 7)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, interval=2, max_keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in range(10):
        mgr.maybe_save({"w": tree["w"] + s}, s)
    mgr.close()
    steps = sorted(int(f[5:13]) for f in os.listdir(d)
                   if f.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == 8
    out = restore_pytree(tree, d, 8)
    assert float(out["w"][0]) == 8.0


def test_checkpoint_atomicity(tmp_path):
    """tmp files never count as a restorable checkpoint."""
    d = str(tmp_path / "ck")
    os.makedirs(d)
    with open(os.path.join(d, "tmp.3.npz"), "w") as f:
        f.write("partial")
    assert latest_step(d) is None


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_failure_injector_deterministic():
    inj1 = FailureInjector(rate=0.3, seed=9)
    inj2 = FailureInjector(rate=0.3, seed=9)
    fails1, fails2 = [], []
    for s in range(50):
        for inj, out in ((inj1, fails1), (inj2, fails2)):
            try:
                inj.check(s)
            except NodeFailure:
                out.append(s)
    assert fails1 == fails2 and len(fails1) > 5


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for _ in range(16):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)
    assert mon.flagged == 1


def test_elastic_mesh_rebuild():
    from repro.runtime.fault import ElasticMesh
    em = ElasticMesh(model_axis=1)
    mesh = em.make()
    assert mesh.shape["model"] == 1
    assert em.usable(5) == (5, 1)
    with pytest.raises(RuntimeError):
        em.usable(0)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_sharding_rules_divisibility():
    import numpy as _np
    from jax.sharding import Mesh
    devs = _np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    rules = ShardingRules()
    # 14 heads on a 1-way model axis: fine; on >1 it must drop
    spec = rules.spec_for(("embed", "heads"), (896, 14 * 64), mesh)
    assert spec is not None


def test_sharding_rules_override():
    rules = ShardingRules().override(seq="model", ffn=None)
    assert rules.table["seq"] == ("model",)
    assert rules.table["ffn"] == ()

"""DG structure + DOA_dep (paper §5.1, Fig. 2/3)."""

import pytest

from repro.core import (DAG, TaskSet, cdg_dag, deepdrivemd_dag, fig2a_chain,
                        fig2b_fork, fig2d_independent)


def test_fig2a_chain_doa_dep_zero():
    assert fig2a_chain(4).doa_dep() == 0


def test_fig2b_fork_doa_dep_one():
    assert fig2b_fork().doa_dep() == 1


@pytest.mark.parametrize("n", [1, 3, 5, 17])
def test_fig2d_independent_doa_dep_n(n):
    assert fig2d_independent(n).doa_dep() == n


def test_ddmd_staggered_doa_dep_two():
    # "three independent chains beginning at rank 1 means DOA_dep = 2" (§7.1)
    assert deepdrivemd_dag(3).doa_dep() == 2


def test_ddmd_more_iterations_scale_doa():
    assert deepdrivemd_dag(5).doa_dep() == 4


def test_cdg_doa_dep_two():
    # Table 3: DOA_dep = 2 for both c-DG1 and c-DG2
    assert cdg_dag("c-DG1").doa_dep() == 2
    assert cdg_dag("c-DG2").doa_dep() == 2


def test_diamond_collapses_to_one_branch():
    g = DAG()
    for n in "ABCD":
        g.add(TaskSet(n, 1, 1, 0, 1.0))
    g.add_edge("A", "B")
    g.add_edge("A", "C")
    g.add_edge("B", "D")
    g.add_edge("C", "D")
    assert g.doa_dep() == 0  # converging paths are not independent branches


def test_ranks_breadth_first():
    g = cdg_dag("c-DG1")
    r = g.ranks()
    assert r == {"T0": 0, "T1": 1, "T2": 1, "T3": 2, "T4": 2, "T5": 2,
                 "T6": 2, "T7": 3}


def test_cycle_rejected():
    g = DAG()
    g.add(TaskSet("A", 1, 1, 0, 1.0))
    g.add(TaskSet("B", 1, 1, 0, 1.0))
    g.add_edge("A", "B")
    with pytest.raises(ValueError):
        g.add_edge("B", "A")


def test_sequential_barriers_linearise_ranks():
    g = cdg_dag("c-DG2").with_sequential_barriers()
    # after barriers every rank-r set precedes every rank-r+1 set
    r = g.ranks()
    assert r["T7"] == 3
    assert set(g.parents("T7")) == {"T3", "T4", "T5", "T6"}


def test_critical_path_bounds_total():
    g = cdg_dag("c-DG2")
    assert g.critical_path_tx() <= g.total_tx()


def test_branch_ids_merge_at_join():
    g = cdg_dag("c-DG1")
    b = g.branch_ids()
    assert b["T4"] == b["T5"] == b["T7"]      # converge at T7
    assert b["T3"] != b["T4"]
    assert len(set(b.values())) == 3

"""Discrete-event simulator: paper-workload runs + invariants."""

import pytest

from repro.core import (CDG_SEQUENTIAL_GROUPS, SimOptions, cdg_dag,
                        ddmd_sequential_stage_groups, deepdrivemd_dag,
                        fig2a_chain, simulate, summit_pool, tpu_pod_pool)

POOL = summit_pool()
OPTS = SimOptions(seed=1, launch_latency=0.5)


def _no_noise():
    return SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)


def test_ddmd_async_beats_sequential():
    dd = deepdrivemd_dag(3)
    rs = simulate(dd, POOL, "sequential", options=OPTS,
                  sequential_stage_groups=ddmd_sequential_stage_groups(3))
    ra = simulate(dd, POOL, "async", options=OPTS)
    improvement = 1 - ra.makespan / rs.makespan
    # paper: measured I = 0.196; our simulator lands in the same band
    assert 0.14 < improvement < 0.25
    assert ra.gpu_utilization > rs.gpu_utilization


def test_ddmd_matches_paper_measured_within_6pct():
    dd = deepdrivemd_dag(3)
    rs = simulate(dd, POOL, "sequential", options=OPTS,
                  sequential_stage_groups=ddmd_sequential_stage_groups(3))
    ra = simulate(dd, POOL, "async", options=OPTS)
    assert rs.makespan == pytest.approx(1707, rel=0.06)   # paper measured
    assert ra.makespan == pytest.approx(1373, rel=0.06)


def test_cdg1_no_meaningful_benefit():
    g = cdg_dag("c-DG1")
    rs = simulate(g, POOL, "sequential", options=OPTS,
                  sequential_stage_groups=CDG_SEQUENTIAL_GROUPS)
    ra = simulate(g, POOL, "async", options=OPTS)
    assert abs(1 - ra.makespan / rs.makespan) < 0.07  # paper: I = -0.015
    assert ra.makespan == pytest.approx(1975, rel=0.06)  # paper measured


def test_cdg2_strong_benefit():
    g = cdg_dag("c-DG2")
    rs = simulate(g, POOL, "sequential", options=OPTS,
                  sequential_stage_groups=CDG_SEQUENTIAL_GROUPS)
    ra = simulate(g, POOL, "async", options=OPTS)
    assert 1 - ra.makespan / rs.makespan > 0.15       # paper: I = 0.261


def test_chain_modes_equal_without_noise():
    g = fig2a_chain(4)
    opts = _no_noise()
    rs = simulate(g, POOL, "sequential", options=opts)
    ra = simulate(g, POOL, "async", options=opts)
    assert rs.makespan == pytest.approx(ra.makespan)


def test_dependencies_respected():
    g = cdg_dag("c-DG2")
    res = simulate(g, POOL, "async", options=_no_noise())
    end_of_set = {}
    for r in res.records:
        end_of_set[r.set_name] = max(end_of_set.get(r.set_name, 0.0), r.end)
    start_of_set = {}
    for r in res.records:
        start_of_set[r.set_name] = min(start_of_set.get(r.set_name, 1e18),
                                       r.start)
    for u, v in g.edges():
        assert start_of_set[v] >= end_of_set[u] - 1e-9


def test_gpus_never_oversubscribed():
    g = cdg_dag("c-DG2")
    res = simulate(g, POOL, "async", options=_no_noise())
    events = []
    for r in res.records:
        events.append((r.start, r.gpus))
        events.append((r.end, -r.gpus))
    events.sort()
    in_use = 0
    for _, d in events:
        in_use += d
        assert in_use <= res.pool_gpus


def test_task_level_at_least_as_fast():
    dd = deepdrivemd_dag(3)
    opts = _no_noise()
    ra = simulate(dd, POOL, "async", options=opts)
    rt = simulate(dd, POOL, "async", options=opts, task_level=True)
    assert rt.makespan <= ra.makespan * 1.02


def test_straggler_mitigation_reduces_makespan():
    g = deepdrivemd_dag(2)
    base = SimOptions(seed=3, straggler_prob=0.05, straggler_factor=6.0,
                      launch_latency=0.0)
    mit = SimOptions(seed=3, straggler_prob=0.05, straggler_factor=6.0,
                     launch_latency=0.0, mitigate_stragglers=True,
                     mitigation_threshold=1.5)
    r0 = simulate(g, POOL, "async", options=base)
    r1 = simulate(g, POOL, "async", options=mit)
    assert r1.makespan < r0.makespan
    assert r1.duplicates > 0


def test_scales_to_thousand_node_pool():
    import time
    pool = tpu_pod_pool(num_pods=16)  # 1024 hosts
    g = deepdrivemd_dag(8)
    t0 = time.perf_counter()
    res = simulate(g, pool, "async", options=SimOptions(seed=0))
    assert time.perf_counter() - t0 < 30.0
    assert res.tasks_total == 8 * (96 + 16 + 1 + 96)


def test_utilization_trace_shape():
    res = simulate(cdg_dag("c-DG2"), POOL, "async", options=OPTS)
    ts, cpu, gpu = res.utilization_trace(resolution=64)
    assert len(ts) == len(cpu) == len(gpu) == 64
    assert max(gpu) <= res.pool_gpus
    assert max(gpu) > 0

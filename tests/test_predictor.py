"""Predictive control plane: the shared Eqn. 2-6 implementation (tx
lookup), the online makespan predictor (residual bound, hazard-aware
remaining time), and the mid-run re-prediction traces both substrates
record."""

import pytest

from repro.core import (DAG, FeedbackOptions, MakespanPredictor, NodeSpec,
                        PoolSpec, RealExecutor, SimOptions, TaskSet,
                        async_ttx, sequential_ttx, simulate)


def _chain():
    g = DAG()
    g.add(TaskSet("a", 4, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("b", 4, 1, 0, tx_mean=20.0, tx_sigma=0.0))
    g.add_edge("a", "b")
    return g


# ---------------------------------------------------------------------------
# one shared Eqn. 2-6 implementation: the tx lookup parameter
# ---------------------------------------------------------------------------

def test_tx_lookup_overrides_static_means():
    g = _chain()
    assert sequential_ttx(g) == 30.0
    # mapping override (missing keys fall back to the static tx_mean)
    assert sequential_ttx(g, tx={"a": 100.0}) == 120.0
    # callable override
    assert sequential_ttx(g, tx=lambda n: 1.0) == 2.0
    t_async, _ = async_ttx(g, tx={"a": 100.0})
    assert t_async == 120.0  # chain: async == sequential


def test_predictor_live_model_matches_offline_equations():
    g = _chain()
    pred = MakespanPredictor(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0)))
    live = {"a": 5.0, "b": 40.0}
    t_seq, t_async, improvement = pred.live_model(live.__getitem__)
    assert t_seq == sequential_ttx(g, tx=live)
    assert t_async == async_ttx(g, tx=live)[0]
    assert improvement == pytest.approx(1.0 - t_async / t_seq)


def test_predictor_live_staggered_eqn6():
    g = _chain()
    pred = MakespanPredictor(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0)))
    # 3 staggered iterations, second stage (k=1) maskable: (n - k) = 2 of
    # its 3 instances hide behind later iterations' pacing stages
    t = pred.live_staggered(["a", "b"], 3, [False, True],
                            {"a": 10.0, "b": 20.0}.__getitem__)
    assert t == pytest.approx(3 * 30.0 - 2 * 20.0)


# ---------------------------------------------------------------------------
# residual bound + hazard-aware expected remaining time
# ---------------------------------------------------------------------------

def test_expected_remaining_degenerates_without_dispersion():
    pred = MakespanPredictor(_chain(), PoolSpec("p", 1, NodeSpec(8, 0)))
    assert pred.expected_remaining(10.0, 0.0, 0.0) == 10.0
    assert pred.expected_remaining(10.0, 0.0, 4.0) == 6.0
    assert pred.expected_remaining(10.0, 0.0, 15.0) == 0.0


def test_expected_remaining_hazard_grows_with_elapsed():
    """Heavy tails: a task that outlived its mean is expected to keep
    running, and the expectation grows with elapsed time."""
    pred = MakespanPredictor(_chain(), PoolSpec("p", 1, NodeSpec(8, 0)))
    r1 = pred.expected_remaining(10.0, 5.0, 12.0)
    r2 = pred.expected_remaining(10.0, 5.0, 30.0)
    assert r1 > 0.0
    assert r2 > r1
    # and always at least the dispersion-free remainder
    assert pred.expected_remaining(10.0, 5.0, 2.0) >= 8.0


def test_residual_bound_full_and_empty():
    g = _chain()
    pool = PoolSpec("p", 1, NodeSpec(cpus=2, gpus=0))  # 2 slots per set
    pred = MakespanPredictor(g, pool)
    tx = lambda n: g.node(n).tx_mean
    # nothing started: both sets pending in 2 waves each
    p0 = pred.predict(tx, 0.0, {"a": 4, "b": 4}, {})
    assert p0.remaining == pytest.approx(2 * 10.0 + 2 * 20.0)
    assert p0.total == p0.remaining
    # everything finished: remaining is zero, total == now
    p1 = pred.predict(tx, 123.0, {"a": 0, "b": 0}, {}, done_fraction=1.0)
    assert p1.remaining == 0.0
    assert p1.total == 123.0


def test_residual_bound_counts_running_tasks():
    g = _chain()
    pool = PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0))
    pred = MakespanPredictor(g, pool)
    tx = lambda n: g.node(n).tx_mean
    # all of "a" running for 4 s, "b" fully pending (one wave of 4)
    p = pred.predict(tx, 4.0, {"a": 0, "b": 4},
                     {("a", i): 4.0 for i in range(4)})
    assert p.remaining == pytest.approx(6.0 + 20.0)


# ---------------------------------------------------------------------------
# mid-run re-prediction traces (both substrates)
# ---------------------------------------------------------------------------

def test_sim_records_prediction_trace_and_converges():
    g = DAG()
    g.add(TaskSet("s", 64, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=16, gpus=0))
    res = simulate(g, pool, "async",
                   options=SimOptions(seed=5, tx_distribution="lognormal",
                                      lognormal_sigma=0.5),
                   feedback=FeedbackOptions(migrate=False))
    assert len(res.predictions) > 4
    fractions = [p.done_fraction for p in res.predictions]
    assert fractions == sorted(fractions)
    assert res.predictions[0].now == 0.0
    # late predictions must beat the blind prior-based first one
    first_err = abs(res.predictions[0].total - res.makespan)
    late = res.predictions[int(len(res.predictions) * 0.8)]
    assert abs(late.total - res.makespan) < first_err


def test_sim_no_feedback_records_no_predictions():
    g = _chain()
    res = simulate(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0)), "async")
    assert res.predictions == []


def test_executor_records_prediction_trace():
    g = _chain()
    pool = PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0))
    res = RealExecutor(pool, tx_scale=2e-3).run(
        g, "async", feedback=FeedbackOptions())
    assert res.tasks_total == 8
    assert len(res.predictions) >= 1
    assert res.predictions[-1].done_fraction >= \
        res.predictions[0].done_fraction

"""Scenario engine determinism (``core/scenarios.py``).

The contract under test: a :class:`Scenario` is a frozen spec, and
(spec, seed) fully determines the workload and the dispatch — across
reruns, across ``RunConfig.incremental`` modes, and (conservation-wise)
across both substrates."""

import dataclasses

import pytest

from repro.core import (SCENARIOS, RealExecutor, ScenarioGenerator,
                        run_scenario, simulate)

#: >= 3 generated scenarios + the SWF-derived campaign (satellite
#: coverage matrix); fragmenting exercises node-level placement,
#: failure-storm exercises fault-schedule seeding
GENERATED = ("steady-mix", "bursty-heavytail", "fragmenting-footprints",
             "failure-storm")


def test_registry_shape():
    assert len(SCENARIOS) >= 6
    assert sum(1 for s in SCENARIOS.values() if s.arrival == "swf") >= 1
    assert sum(1 for s in SCENARIOS.values()
               if "adversarial" in s.description) >= 2
    for name, s in SCENARIOS.items():
        assert s.name == name
    with pytest.raises(dataclasses.FrozenInstanceError):
        SCENARIOS["steady-mix"].rate = 1.0


def test_spec_validation():
    with pytest.raises(ValueError, match="arrival"):
        dataclasses.replace(SCENARIOS["steady-mix"], arrival="weibull")
    with pytest.raises(ValueError, match="palette"):
        dataclasses.replace(SCENARIOS["steady-mix"], palette="nope")


def test_workload_is_pure_function_of_spec_and_seed():
    gen = ScenarioGenerator("diurnal-serving", seed=9)
    a = gen.workload()
    b = ScenarioGenerator("diurnal-serving", seed=9).workload()
    assert [(e.name, e.arrival) for e in a.entries] \
        == [(e.name, e.arrival) for e in b.entries]
    c = ScenarioGenerator("diurnal-serving", seed=10).workload()
    assert [(e.name, e.arrival) for e in a.entries] \
        != [(e.name, e.arrival) for e in c.entries]


def test_failure_storm_schedule_deterministic():
    f = ScenarioGenerator("failure-storm", seed=4).faults()
    g = ScenarioGenerator("failure-storm", seed=4).faults()
    assert f is not None and f.node_failure_trace == g.node_failure_trace
    assert len(f.node_failure_trace) == 2
    assert all(p == "sc" for _t, p, _n in f.node_failure_trace)
    assert ScenarioGenerator("steady-mix", seed=4).faults() is None


@pytest.mark.parametrize("name", GENERATED + ("swf-hpc2n",))
def test_scenario_dispatch_bit_identical(name):
    """Same spec + seed => bit-identical dispatch, rerun-to-rerun and
    across the engine's incremental/brute-force pass structures."""
    a = run_scenario(name, seed=3)
    b = run_scenario(name, seed=3)
    assert a.records == b.records
    assert a.makespan == b.makespan
    assert a.workflows == b.workflows
    c = run_scenario(name, seed=3, incremental=False)
    assert a.records == c.records and a.makespan == c.makespan
    d = run_scenario(name, seed=4)
    assert d.records != a.records  # the seed genuinely re-draws


def test_scenario_cross_substrate_conservation():
    # wall clocks cannot give bit-identical timestamps, so the
    # cross-substrate pin is structural: both substrates execute exactly
    # the scenario's task population and finish every workflow
    spec = dataclasses.replace(SCENARIOS["steady-mix"], horizon=420.0,
                               rate=1.0 / 70.0, pool_nodes=4)
    gen = ScenarioGenerator(spec, seed=2)
    sim = simulate(gen.workload(), gen.pool(), options=gen.sim_options(),
                   config=gen.run_config(policy="fifo"))
    key = lambda r: (r.workflow, r.set_name, r.index)
    ex_maps = []
    for incremental in (True, False):
        ex = RealExecutor(gen.pool(), tx_scale=0.002, seed=2)
        er = ex.run(gen.workload(),
                    config=gen.run_config(policy="fifo",
                                          incremental=incremental))
        assert {key(r) for r in er.records} \
            == {key(r) for r in sim.records}
        assert set(er.workflows) == set(sim.workflows)
        ex_maps.append({key(r): r.pool for r in er.records})
    assert ex_maps[0] == ex_maps[1]


def test_swf_scenario_executor_replay():
    spec = dataclasses.replace(SCENARIOS["swf-hpc2n"], swf_max_jobs=8,
                               swf_time_scale=120.0)
    gen = ScenarioGenerator(spec, seed=0)
    sim = simulate(gen.workload(), gen.pool(), options=gen.sim_options(),
                   config=gen.run_config())
    ex = RealExecutor(gen.pool(), tx_scale=0.002, seed=0)
    er = ex.run(gen.workload(), config=gen.run_config())
    key = lambda r: (r.workflow, r.set_name, r.index)
    assert {key(r) for r in er.records} == {key(r) for r in sim.records}
    assert set(er.workflows) == set(sim.workflows) == {
        f"job{i}" for i in (1, 2, 3, 5, 6, 8, 9, 10)}

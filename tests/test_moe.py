"""MoE dispatch correctness: the capacity-based scatter dispatch must
reproduce a brute-force dense mixture when capacity is ample, count drops
when it is not, and the EP (shard_map) paths must match the local path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import MoEOptions, moe_block, moe_local, moe_specs
from repro.models.params import init_params


def tiny_cfg(**kw):
    base = get_config("qwen3-moe-30b-a3b").reduced()
    return dataclasses.replace(base, **kw) if kw else base


def brute_force(p, x, cfg):
    """Dense mixture: every expert on every token, mask to top-k."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d).astype(jnp.float32)
    logits = xt @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, e = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) * \
        jnp.einsum("td,edf->tef", xt, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])   # [T, E, D]
    picked = jnp.take_along_axis(y_all, e[..., None], axis=1)
    return (picked * w[..., None]).sum(1).reshape(b, s, d)


def test_moe_local_matches_brute_force():
    cfg = tiny_cfg()
    specs = moe_specs(cfg, 1)
    p = jax.tree.map(lambda a: a[0],
                     init_params(specs, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    # capacity factor huge -> nothing dropped -> exact match
    y, aux = moe_local(p, x, cfg, MoEOptions(capacity_factor=16.0))
    want = brute_force(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = tiny_cfg()
    specs = moe_specs(cfg, 1)
    p = jax.tree.map(lambda a: a[0],
                     init_params(specs, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_small, _ = moe_local(p, x, cfg, MoEOptions(capacity_factor=0.1))
    want = brute_force(p, x, cfg)
    # with capacity 0.1 most assignments are dropped -> outputs differ
    assert float(jnp.abs(y_small - want).max()) > 1e-3


def test_moe_block_adds_shared_expert():
    cfg = tiny_cfg(shared_expert=True)
    specs = moe_specs(cfg, 1)
    p = jax.tree.map(lambda a: a[0],
                     init_params(specs, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32)
    y, _ = moe_block(p, x, cfg, opts=MoEOptions(capacity_factor=16.0))
    y_no_shared, _ = moe_local(p, x, cfg, MoEOptions(capacity_factor=16.0))
    assert float(jnp.abs(y - y_no_shared).max()) > 1e-4


def test_moe_grads_flow_to_router():
    cfg = tiny_cfg()
    specs = moe_specs(cfg, 1)
    p = jax.tree.map(lambda a: a[0],
                     init_params(specs, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, aux = moe_local(p, x, cfg, MoEOptions(capacity_factor=4.0))
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0

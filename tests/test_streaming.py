"""Streaming service tenancy: open arrival streams, SLO-aware admission,
preemptive revocation, elastic capacity leases, and the unified RunConfig
run API.

Invariants locked down here:

- stream conservation: every arrived workflow is exactly one of
  finished / admitted / deferred / queued at the end of a run, and a
  run-to-completion finishes everything that arrived;
- revocation never un-admits a workflow with a launched task;
- lease expiry never strands a placed task: busy lease nodes drain and
  retire only on their last release, with the incremental indexes
  consistent (``check_index_integrity``) across grow / drain / retire;
- a closed campaign wrapped as a ``CampaignStream`` is bit-identical to
  passing the campaign directly, on both substrates;
- a legacy-kwarg call and its ``RunConfig`` equivalent are bit-identical,
  and mixing the two forms raises ``TypeError``;
- ``GeneratedStream`` is a pure function of its arguments.
"""

import warnings

import pytest

from repro.core import (AdmissionOptions, Campaign, CampaignStream, DAG,
                        ElasticOptions, GeneratedStream, NodeSpec, PoolSpec,
                        RealExecutor, RunConfig, SchedEngine, SimOptions,
                        StreamTemplate, TaskSet, WorkflowEntry,
                        WorkflowStream, prefix_view, simulate)


def two_stage(n_sim=3, tx=40.0, gpus=1):
    g = DAG()
    g.add(TaskSet("sim", n_sim, 2, 0, tx, tx_sigma=0.0))
    g.add(TaskSet("train", 1, 2, gpus, tx, tx_sigma=0.0))
    g.add_edge("sim", "train")
    return g


def node_pool(num_nodes=4):
    return PoolSpec("p", num_nodes, NodeSpec(cpus=32, gpus=4),
                    node_level=True)


def open_stream(seed=0, rate=1 / 90.0, horizon=1200.0, **kw):
    tmpl = StreamTemplate("inf", two_stage, deadline_slack=500.0,
                          reference_makespan=130.0)
    return GeneratedStream([tmpl], rate=rate, horizon=horizon, seed=seed,
                           **kw)


# ---------------------------------------------------------------------------
# stream sources
def test_generated_stream_deterministic():
    for kind in ("poisson", "diurnal", "bursty"):
        a = open_stream(seed=7, kind=kind)
        b = open_stream(seed=7, kind=kind)
        assert [(e.name, e.arrival) for e in a.entries] \
            == [(e.name, e.arrival) for e in b.entries]
        assert all(0.0 < e.arrival < 1200.0 for e in a.entries)
        assert a.entries != open_stream(seed=8, kind=kind).entries


def test_generated_stream_periodic_and_deadlines():
    t_train = StreamTemplate("train", lambda: two_stage(1), priority=-1)
    st = GeneratedStream([StreamTemplate("inf", two_stage,
                                         deadline_slack=300.0)],
                        rate=1 / 200.0, horizon=1000.0, seed=1,
                        periodic=[(t_train, 400.0)])
    trains = [e for e in st.entries if e.name.startswith("train")]
    assert [e.arrival for e in trains] == [400.0, 800.0]
    infs = [e for e in st.entries if e.name.startswith("inf")]
    assert all(e.deadline == e.arrival + 300.0 for e in infs)
    assert all(e.deadline is None for e in trains)


def test_stream_consumption_protocol():
    st = open_stream(seed=3)
    n = len(st)
    assert n > 0
    first = st.next_arrival()
    assert st.take_until(first - 1e-9) == []
    got = st.take_until(float("inf"))
    assert len(got) == n and st.next_arrival() is None
    st.reset()
    assert st.next_arrival() == first


def test_prefix_view_empty_and_merge():
    v = prefix_view([], "s")
    assert len(v.workflow_of) == 0
    e = WorkflowEntry("w0", two_stage(), arrival=5.0, deadline=50.0)
    v = prefix_view([e], "s")
    assert v.workflow_of["w0/sim"] == "w0"
    assert v.deadline_of["w0/train"] == 50.0


# ---------------------------------------------------------------------------
# open-stream runs: conservation
def test_open_stream_conservation_simulator():
    st = open_stream(seed=11)
    r = simulate(st, node_pool(),
                 config=RunConfig(admission=AdmissionOptions()))
    s = r.stream
    assert s["arrived"] == len(st.entries)
    assert s["arrived"] == (s["finished"] + s["admitted"]
                            + s["deferred"] + s["queued"])
    assert s["finished"] == s["arrived"]  # run to completion drains all
    assert len(r.workflows) == s["arrived"]
    # every workflow's tasks all completed exactly once
    per_wf = {}
    for rec in r.records:
        per_wf[rec.workflow] = per_wf.get(rec.workflow, 0) + 1
    assert all(n == 4 for n in per_wf.values())  # 3 sim + 1 train
    assert r.slo_attainment() is not None


def test_open_stream_conservation_executor():
    st = open_stream(seed=11, rate=1 / 150.0, horizon=600.0)
    ex = RealExecutor(node_pool(2), tx_scale=0.002)
    r = ex.run(st, config=RunConfig(admission=AdmissionOptions()))
    s = r.stream
    assert s["arrived"] == len(st.entries)
    assert s["finished"] == s["arrived"]
    assert len(r.workflows) == s["arrived"]


def test_open_stream_without_admission():
    # streams work with the admission controller off too
    st = open_stream(seed=2, rate=1 / 300.0, horizon=900.0)
    r = simulate(st, node_pool(), config=RunConfig())
    assert r.stream["finished"] == r.stream["arrived"] == len(st.entries)


# ---------------------------------------------------------------------------
# closed adapter + run API equivalence
def small_campaign():
    c = Campaign(name="c")
    c.add("w0", two_stage(), arrival=0.0, reference_makespan=130.0)
    c.add("w1", two_stage(2), arrival=60.0, priority=1,
          reference_makespan=90.0)
    c.add("w2", two_stage(4), arrival=120.0, reference_makespan=170.0)
    return c


def test_campaign_stream_bit_identical_simulator():
    camp = small_campaign()
    a = simulate(camp, node_pool(),
                 config=RunConfig(admission=AdmissionOptions()))
    b = simulate(CampaignStream(camp), node_pool(),
                 config=RunConfig(admission=AdmissionOptions()))
    assert a.records == b.records
    assert a.makespan == b.makespan
    assert a.workflows == b.workflows
    assert b.stream is None  # closed path: no open-stream accounting


def test_campaign_stream_bit_identical_executor():
    camp = small_campaign()
    ex = RealExecutor(node_pool(2), tx_scale=0.002)
    a = ex.run(camp, config=RunConfig(admission=AdmissionOptions()))
    b = ex.run(CampaignStream(camp),
               config=RunConfig(admission=AdmissionOptions()))
    # wall-clock substrate: the schedule (placements, per-task pools) must
    # agree even though wall timestamps jitter
    key = lambda r: (r.set_name, r.index)
    pa = {key(r): (r.pool, r.workflow) for r in a.records}
    pb = {key(r): (r.pool, r.workflow) for r in b.records}
    assert pa == pb
    assert sorted(a.workflows) == sorted(b.workflows)


# ---------------------------------------------------------------------------
# arrival-boundary inclusivity: an arrival landing EXACTLY on a
# completion's timestamp must be admitted in the same scheduling pass on
# every path (executor dispatcher, coalesced simulator, per-event
# simulator) — regression for the pre-fix per-event path, where the
# completion's pass handed the freed node to queued work before the
# ``_STREAM`` sentinel (popping second at the equal heap timestamp)
# admitted the colliding higher-priority arrival
def _collision_entries(t_collide):
    a = DAG()
    a.add(TaskSet("a1", 1, 3, 0, 50.0, tx_sigma=0.0))
    a.add(TaskSet("a2", 1, 3, 0, 20.0, tx_sigma=0.0))
    a.add_edge("a1", "a2")
    tiny = DAG()
    tiny.add(TaskSet("t", 1, 1, 0, 1.0, tx_sigma=0.0))
    b = DAG()
    b.add(TaskSet("b", 1, 3, 0, 20.0, tx_sigma=0.0))
    return [
        WorkflowEntry("low", a, priority=0, arrival=0.0),
        # an early second arrival forces the sentinel to be RE-pushed, so
        # at the collision its heap seq exceeds the completion's
        WorkflowEntry("early", tiny, priority=5, arrival=1.0),
        WorkflowEntry("hi", b, priority=5, arrival=t_collide),
    ]


def _collision_time(pool):
    # probe run: where does low/a1 actually complete (overheads included)?
    probe = simulate(WorkflowStream(_collision_entries(1e9), "probe"),
                     pool, config=RunConfig(scheduling="priority"))
    return next(r.end for r in probe.records if r.set_name == "low/a1")


def test_stream_arrival_collision_same_pass_simulator():
    pool = PoolSpec("p", 1, NodeSpec(cpus=4, gpus=0))
    t = _collision_time(pool)
    runs = {}
    for co in (False, True):
        res = simulate(
            WorkflowStream(_collision_entries(t), "collide"), pool,
            config=RunConfig(scheduling="priority", coalesce_events=co))
        runs[co] = res
        # the colliding high-priority arrival wins the freed node in the
        # completion's own pass; the low-priority child waits behind it
        hi = next(r for r in res.records if r.set_name == "hi/b")
        a2 = next(r for r in res.records if r.set_name == "low/a2")
        assert hi.start == t, (co, hi.start, t)
        assert a2.start >= hi.end, (co, a2.start, hi.end)
    # bit-identity: coalescing must not change dispatch on collisions
    assert runs[False].records == runs[True].records
    assert runs[False].makespan == runs[True].makespan


def test_stream_arrival_collision_same_pass_executor():
    # the executor's dispatcher drains take_until(now) before startable()
    # in the same iteration; wall clocks cannot reproduce an exact float
    # collision, so pin the shared contract with a margin: the arrival
    # lands just before the completion and must win the freed node
    pool = PoolSpec("p", 1, NodeSpec(cpus=4, gpus=0))
    t = _collision_time(pool)
    ex = RealExecutor(pool, tx_scale=0.002)
    res = ex.run(WorkflowStream(_collision_entries(t * 0.9), "collide"),
                 config=RunConfig(scheduling="priority"))
    hi = next(r for r in res.records if r.set_name == "hi/b")
    a2 = next(r for r in res.records if r.set_name == "low/a2")
    assert a2.start >= hi.start


def test_runconfig_equals_legacy_kwargs_simulator():
    camp = small_campaign()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        a = simulate(camp, node_pool(), scheduling="lpt",
                     admission=AdmissionOptions())
    b = simulate(camp, node_pool(),
                 config=RunConfig(scheduling="lpt",
                                  admission=AdmissionOptions()))
    assert a.records == b.records and a.makespan == b.makespan


def test_runconfig_equals_legacy_kwargs_executor():
    g = two_stage()
    ex = RealExecutor(node_pool(2), tx_scale=0.002)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        a = ex.run(g, scheduling="lpt")
    b = ex.run(g, config=RunConfig(scheduling="lpt"))
    key = lambda r: (r.set_name, r.index)
    assert ({key(r): r.pool for r in a.records}
            == {key(r): r.pool for r in b.records})


def test_mixing_config_and_legacy_raises():
    camp = small_campaign()
    with pytest.raises(TypeError, match="not both"):
        simulate(camp, node_pool(), config=RunConfig(),
                 admission=AdmissionOptions())
    with pytest.raises(TypeError, match="not both"):
        RealExecutor(node_pool(2)).run(two_stage(), config=RunConfig(),
                                       scheduling="lpt")


def test_legacy_kwargs_warn_once_per_call_site():
    # regression (scenario-engine PR): the warn-once state was one
    # module-level bool, so only the FIRST legacy call site in the process
    # warned — RealExecutor.run() below stayed silent whenever any earlier
    # test had already tripped simulate()'s warning, and test order decided
    # which assertion passed.  Keyed by call site, each entry point warns
    # exactly once.
    import repro.core.runconfig as rc
    old = set(rc._warned_sites)
    try:
        rc.reset_legacy_warnings()
        with pytest.warns(DeprecationWarning, match="simulate.*RunConfig"):
            simulate(small_campaign(), node_pool(),
                     admission=AdmissionOptions())
        # second legacy call through the SAME site: silent
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            simulate(small_campaign(), node_pool(),
                     admission=AdmissionOptions())
        # a DIFFERENT call site still warns (failed pre-fix)
        with pytest.warns(DeprecationWarning,
                          match="RealExecutor.*RunConfig"):
            RealExecutor(node_pool(2), tx_scale=0.002).run(
                two_stage(), scheduling="lpt")
        # ... and only once
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            RealExecutor(node_pool(2), tx_scale=0.002).run(
                two_stage(), scheduling="lpt")
    finally:
        rc._warned_sites.clear()
        rc._warned_sites.update(old)


# ---------------------------------------------------------------------------
# deadline-aware admission + revocation
def test_deadline_aware_admission_admits_at_risk_workflow():
    # saturate the pool with a big workflow, then stream in a
    # deadline-carrying workflow whose slack is tight: the deadline-blind
    # controller defers it (strict floor, no aging), the deadline-aware
    # one must override the defer once the slack is within the margin
    def scenario(adm):
        c = Campaign(name="c")
        # bulk outranks urgent so the priority fast-path cannot admit it
        c.add("bulk", two_stage(24, tx=80.0), arrival=0.0, priority=1)
        c.add("urgent", two_stage(2, tx=30.0), arrival=10.0,
              deadline=260.0, reference_makespan=95.0)
        return simulate(c, node_pool(1), config=RunConfig(admission=adm))

    strict = dict(i_floor=0.99, hold_ratio=0.0, backfill_fraction=0.0,
                  max_defer_time=1e9)
    blind = scenario(AdmissionOptions(**strict))
    aware = scenario(AdmissionOptions(**strict, deadline_aware=True,
                                      deadline_margin=5.0))
    assert aware.workflows["urgent"].met_deadline
    # the deadline override actually changed the schedule: urgent starts
    # strictly earlier than under the blind controller
    assert (aware.workflows["urgent"].start
            < blind.workflows["urgent"].start)


def test_revocation_engine_level():
    # engine-level: a started workflow is never revocable, a queued one is
    c = Campaign(name="c")
    c.add("lo", two_stage(24), arrival=0.0, priority=0)
    c.add("hi", two_stage(2), arrival=0.0, priority=5)
    view = c.view()
    eng = SchedEngine(view.dag, node_pool(1), campaign=view,
                      admission=AdmissionOptions(revoke=True))
    launched = eng.startable(0.0)
    started_wfs = {eng.workflow_of[n] for n, _i, _k in launched}
    assert "hi" in started_wfs  # priority order: hi launches first
    for wf in started_wfs:
        assert eng.revoke_workflow(wf, 1.0) is False
    not_started = {"lo", "hi"} - started_wfs
    for wf in sorted(not_started):
        assert eng.revoke_workflow(wf, 1.0) is True
        for m in eng.order:
            if eng.workflow_of[m] == wf:
                assert m in eng.deferred and m not in eng.admitted
    assert eng.admission_revocations == len(not_started)
    st = eng.stream_accounting()
    assert st["arrived"] == 2
    assert st["revoked"] == len(not_started)


def test_revocation_in_stream_run_never_touches_started():
    # integration: drive a loaded stream with revocation on; every revoked
    # workflow must still finish (revocation defers, never cancels) and
    # conservation must hold
    tmpl_lo = StreamTemplate("batch", lambda: two_stage(6, tx=60.0),
                             priority=0, share=3.0,
                             reference_makespan=200.0)
    tmpl_hi = StreamTemplate("rt", lambda: two_stage(1, tx=20.0),
                             priority=4, deadline_slack=90.0,
                             reference_makespan=50.0, share=1.0)
    st = GeneratedStream([tmpl_lo, tmpl_hi], rate=1 / 45.0, horizon=900.0,
                         seed=13, kind="bursty")
    r = simulate(st, node_pool(1),
                 config=RunConfig(admission=AdmissionOptions(
                     i_floor=0.6, max_defer_time=600.0,
                     deadline_aware=True, revoke=True)))
    s = r.stream
    assert s["arrived"] == len(st.entries)
    assert s["finished"] == s["arrived"]  # revocation loses no work
    per_wf_tasks = {}
    for rec in r.records:
        per_wf_tasks.setdefault(rec.workflow, set()).add(
            (rec.set_name, rec.index))
    # every arrived workflow ran all its tasks exactly once
    for e in st.entries:
        n = 7 if e.name.startswith("batch") else 2  # 6+1 / 1+1 tasks
        assert len(per_wf_tasks[e.name]) == n, e.name


# ---------------------------------------------------------------------------
# elastic capacity
def test_elastic_engine_grow_drain_retire_integrity():
    c = Campaign(name="c")
    for i in range(6):
        c.add(f"w{i}", two_stage(8, tx=100.0), arrival=0.0)
    view = c.view()
    eng = SchedEngine(view.dag, node_pool(1), campaign=view,
                      elastic=ElasticOptions(max_lease_nodes=2,
                                             lease_term=300.0,
                                             grow_threshold=0.5,
                                             check_interval=50.0))
    launched = list(eng.startable(0.0))
    eng.check_index_integrity()
    assert eng.elastic_pass(50.0) is True  # queued demand -> grant
    eng.check_index_integrity()
    assert eng.leases_granted == 1
    leased = eng.lease_log[-1][2]
    more = list(eng.startable(50.0))
    assert any(k == 0 for _n, _i, k in more)
    eng.check_index_integrity()
    # some placements land on the leased node while it is up
    on_lease = [(n, i) for n, i, _k in more
                if eng.node_placement(n, i) == leased]
    # expire while busy: the node must drain, not die
    eng.elastic_pass(400.0)
    eng.check_index_integrity()
    if on_lease:
        assert eng.leases_expired == 0  # still draining
        assert (400.0, "drain", leased) in eng.lease_log
    # completing everything releases the node -> retire on last release
    for n, i, _k in launched + more:
        eng.complete(n, i)
    eng.check_index_integrity()
    if on_lease:
        assert eng.leases_expired == 1
        assert eng.lease_log[-1] == (eng._now, "expire", leased)
    # a retired node is never offered again
    eng.elastic_pass(500.0)
    eng.check_index_integrity()


def test_elastic_stream_run_no_stranded_tasks():
    tmpl = StreamTemplate("inf", lambda: two_stage(6, tx=80.0),
                          deadline_slack=700.0, reference_makespan=250.0)
    st = GeneratedStream([tmpl], rate=1 / 60.0, horizon=1200.0, seed=5,
                         kind="diurnal", period=1200.0, peak_ratio=6.0)
    r = simulate(st, node_pool(2),
                 config=RunConfig(
                     admission=AdmissionOptions(),
                     elastic=ElasticOptions(max_lease_nodes=3,
                                            lease_term=300.0,
                                            grow_threshold=1.0,
                                            check_interval=60.0)))
    assert r.leases_granted > 0  # the load swing actually grew the pool
    assert r.leases_expired > 0  # ... and leases lapsed again
    assert r.stream["finished"] == r.stream["arrived"]  # nothing stranded
    base = simulate(st, node_pool(2),
                    config=RunConfig(admission=AdmissionOptions()))
    # elastic capacity must not slow the stream down
    assert r.makespan <= base.makespan * 1.0001


def test_elastic_rejects_faults_and_aggregate_pools():
    from repro.runtime.fault import FaultOptions
    g = two_stage()
    with pytest.raises(ValueError, match="fault"):
        SchedEngine(g, node_pool(1),
                    elastic=ElasticOptions(max_lease_nodes=1),
                    faults=FaultOptions(node_failure_rate=1e-4))
    agg = PoolSpec("agg", 2, NodeSpec(cpus=32, gpus=4))
    with pytest.raises(ValueError, match="node_level"):
        SchedEngine(g, agg, elastic=ElasticOptions(max_lease_nodes=1))


def test_elastic_disabled_options_noop():
    g = two_stage()
    eng = SchedEngine(g, node_pool(1),
                      elastic=ElasticOptions(max_lease_nodes=0))
    assert eng.elastic is None
    assert eng.elastic_pass(100.0) is False


# ---------------------------------------------------------------------------
# per-workflow predicted finishes in the trace
def test_prediction_trace_has_workflow_finishes():
    c = Campaign(name="c")
    for i in range(5):
        c.add(f"w{i}", two_stage(3), arrival=30.0 * i,
              reference_makespan=130.0)
    from repro.core import FeedbackOptions
    r = simulate(c, node_pool(2),
                 config=RunConfig(
                     feedback=FeedbackOptions(),
                     admission=AdmissionOptions()))
    with_wf = [p for p in r.predictions if p.wf_finish]
    assert with_wf, "no prediction carried per-workflow finishes"
    for p in with_wf:
        fins = dict(p.wf_finish)
        assert all(f >= 0.0 for f in fins.values())
        for wf, f in fins.items():
            assert p.predicted_finish(wf) == f
        assert p.predicted_finish("nonexistent") is None

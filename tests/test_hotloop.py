"""Trace-scale hot loop: prediction epochs, coalesced event passes,
streaming-summary record policy, and perf attribution.

The contracts locked down here:

- ``SchedEngine.repredict`` dedupes back-to-back calls at an identical
  clock + state (trace length and values preserved, evaluation skipped)
  and ``PredictOptions`` throttling *thins* the trace without ever
  moving a placement (seeded port of the hypothesis invariant, so it
  runs in tier-1 even without hypothesis installed);
- ``coalesce_events=True`` drains same-timestamp heap batches into one
  scheduling pass and is bit-identical on collision-free streams;
- ``record_policy="summary"`` reproduces the full-trace metric surface
  from bounded sketches (SLO attainment and percentiles exact below
  sketch capacity);
- ``perf_counters=True`` fills ``RunResult.perf``; off costs nothing
  and leaves it None.
"""

import dataclasses

import pytest

from repro.core import (AdmissionOptions, DAG, ElasticOptions,
                        FeedbackOptions, GeneratedStream, MakespanPredictor,
                        NodeSpec, PoolSpec, PredictOptions, RealExecutor,
                        RunConfig, SchedEngine, SimOptions, StreamTemplate,
                        TaskSet, simulate)


def two_stage(n_sim=3, tx=40.0, sigma=0.0):
    g = DAG()
    g.add(TaskSet("sim", n_sim, 2, 0, tx, tx_sigma=sigma))
    g.add(TaskSet("train", 1, 2, 1, tx, tx_sigma=sigma))
    g.add_edge("sim", "train")
    return g


def node_pool(num_nodes=4):
    return PoolSpec("p", num_nodes, NodeSpec(cpus=32, gpus=4),
                    node_level=True)


def agg_pool(cpus=64, gpus=8):
    return PoolSpec("agg", 1, NodeSpec(cpus=cpus, gpus=gpus))


def open_stream(seed=0, rate=1 / 60.0, horizon=900.0, sigma=0.0, **kw):
    tmpl = StreamTemplate("inf", lambda: two_stage(sigma=sigma),
                          deadline_slack=500.0, reference_makespan=130.0)
    return GeneratedStream([tmpl], rate=rate, horizon=horizon, seed=seed,
                           **kw)


def record_key(r):
    return (r.set_name, r.index, r.start, r.end, r.pool, r.node,
            r.workflow, r.duplicate, r.migrated)


# ---------------------------------------------------------------------------
# repredict dedupe (engine level) + call-count spy
# ---------------------------------------------------------------------------

def predict_spy(monkeypatch):
    calls = {"n": 0}
    orig = MakespanPredictor.predict

    def spy(self, *a, **k):
        calls["n"] += 1
        return orig(self, *a, **k)

    monkeypatch.setattr(MakespanPredictor, "predict", spy)
    return calls


def test_repredict_dedupes_identical_instant(monkeypatch):
    calls = predict_spy(monkeypatch)
    eng = SchedEngine(two_stage(), node_pool(), feedback=FeedbackOptions())
    eng.startable(0.0)
    before = calls["n"]
    p1 = eng.repredict(10.0, {})
    p2 = eng.repredict(10.0, {})  # same clock, same state: no re-eval
    assert calls["n"] == before + 1
    assert p2 is p1
    # ... but the trace keeps both entries (length and values identical
    # to the pre-dedupe behaviour)
    assert eng.predictions[-2:] == [p1, p1]
    # any state movement re-evaluates, even at the same clock
    eng.complete("sim", 0)
    p3 = eng.repredict(10.0, {})
    assert calls["n"] == before + 2 and p3 is not p1
    # a later clock with untouched state re-evaluates too (dedupe only
    # guards the identical instant; time itself moves the model)
    eng.repredict(50.0, {})
    assert calls["n"] == before + 3


def test_simulator_dedupes_same_timestamp_passes(monkeypatch):
    """Watchdog + campaign-arrival sentinels colliding on one timestamp
    used to trigger two full predictor evaluations; the dedupe guard
    collapses them (trace length unchanged — strictly fewer evaluations
    than trace entries proves the guard fired)."""
    calls = predict_spy(monkeypatch)
    from repro.core import Campaign
    c = Campaign(name="c")
    # w0's sim wave saturates the node (16 x 2 cpus), so w1's arrival at
    # t=100 — the same instant as the watchdog — can launch nothing: the
    # two sentinels hit repredict with an identical clock and stamp
    c.add("w0", two_stage(16, tx=150.0), arrival=0.0)
    c.add("w1", two_stage(16, tx=150.0), arrival=100.0)
    r = simulate(c, node_pool(1), "async",
                 options=SimOptions(seed=0, sample_tx=False,
                                    launch_latency=0.0),
                 config=RunConfig(feedback=FeedbackOptions(
                     speculate=True, watchdog_interval=100.0)))
    assert r.tasks_total == 34
    assert calls["n"] < len(r.predictions)


# ---------------------------------------------------------------------------
# PredictOptions throttle semantics (engine level)
# ---------------------------------------------------------------------------

def test_throttle_min_interval_and_dirty_gating(monkeypatch):
    calls = predict_spy(monkeypatch)
    eng = SchedEngine(two_stage(), node_pool(), feedback=FeedbackOptions(),
                      predict=PredictOptions(min_interval=100.0))
    p1 = eng.repredict(0.0, {})  # first call always evaluates
    assert calls["n"] == 1 and len(eng.predictions) == 1
    eng.startable(0.0)  # dirties the stamp
    p2 = eng.repredict(50.0, {})  # dirty, but inside min_interval
    assert p2 is p1 and calls["n"] == 1
    assert len(eng.predictions) == 1  # throttled: nothing appended
    p3 = eng.repredict(150.0, {})  # dirty and interval elapsed
    assert p3 is not p1 and calls["n"] == 2 and len(eng.predictions) == 2
    p4 = eng.repredict(400.0, {})  # clean stamp: dirty_only holds it
    assert p4 is p3 and calls["n"] == 2 and len(eng.predictions) == 2
    eng.complete("sim", 0)
    p5 = eng.repredict(500.0, {})  # dirty again, interval elapsed
    assert p5 is not p3 and calls["n"] == 3


def test_throttle_dirty_only_off_reevaluates_on_interval():
    eng = SchedEngine(two_stage(), node_pool(), feedback=FeedbackOptions(),
                      predict=PredictOptions(min_interval=100.0,
                                             dirty_only=False))
    p1 = eng.repredict(0.0, {})
    p2 = eng.repredict(250.0, {})  # clean state, but interval elapsed
    assert p2 is not p1 and len(eng.predictions) == 2


# ---------------------------------------------------------------------------
# placement neutrality (seeded port of the hypothesis invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("node_level", [False, True])
@pytest.mark.parametrize("policy", ["fifo", "lpt", "gpu_bestfit",
                                    "priority"])
def test_throttle_is_placement_neutral(policy, node_level):
    st = open_stream(seed=3, sigma=6.0)
    pool = node_pool() if node_level else agg_pool()
    fb = FeedbackOptions(migrate=False)
    base = simulate(st, pool, options=SimOptions(seed=1),
                    config=RunConfig(scheduling=policy, feedback=fb))
    thr = simulate(st, pool, options=SimOptions(seed=1),
                   config=RunConfig(scheduling=policy, feedback=fb,
                                    predict=PredictOptions(
                                        min_interval=200.0)))
    assert [record_key(r) for r in thr.records] \
        == [record_key(r) for r in base.records]
    assert thr.makespan == base.makespan
    assert thr.workflows == base.workflows
    # the whole point: the throttled trace is actually thinner
    assert len(thr.predictions) < len(base.predictions)


def test_throttle_neutral_under_admission_and_elastic():
    st = open_stream(seed=5, rate=1 / 45.0)
    cfg = RunConfig(admission=AdmissionOptions(deadline_aware=True),
                    feedback=FeedbackOptions(migrate=False),
                    elastic=ElasticOptions(max_lease_nodes=2,
                                           lease_term=300.0,
                                           grow_threshold=1.0,
                                           check_interval=60.0))
    base = simulate(st, node_pool(2), options=SimOptions(seed=2), config=cfg)
    thr = simulate(st, node_pool(2), options=SimOptions(seed=2),
                   config=dataclasses.replace(
                       cfg, predict=PredictOptions(min_interval=150.0)))
    assert thr.records == base.records
    assert thr.makespan == base.makespan
    assert (thr.leases_granted, thr.leases_expired) \
        == (base.leases_granted, base.leases_expired)
    assert thr.stream == base.stream


# ---------------------------------------------------------------------------
# coalesced event passes
# ---------------------------------------------------------------------------

def test_coalesce_bit_identical_on_continuous_stream():
    """Sampled (continuous) durations: same-timestamp collisions are
    measure-zero, so draining per-timestamp batches in one pass must
    reproduce the per-event dispatch sequence bit for bit."""
    st = open_stream(seed=7, sigma=8.0)
    for coalesce_cfg in (
            RunConfig(admission=AdmissionOptions(),
                      feedback=FeedbackOptions(migrate=False)),
            RunConfig()):
        base = simulate(st, node_pool(), options=SimOptions(seed=3),
                        config=coalesce_cfg)
        co = simulate(st, node_pool(), options=SimOptions(seed=3),
                      config=dataclasses.replace(coalesce_cfg,
                                                 coalesce_events=True))
        assert co.records == base.records
        assert co.makespan == base.makespan
        assert co.workflows == base.workflows
        assert co.stream == base.stream


def test_coalesce_conserves_under_timestamp_collisions():
    """Deterministic durations make completion bursts genuinely
    simultaneous — the coalesced pass may legitimately reorder intra-batch
    dispatch, but conservation and totals must hold."""
    st = open_stream(seed=9, sigma=0.0, rate=1 / 40.0)
    base = simulate(st, node_pool(), options=SimOptions(seed=0),
                    config=RunConfig(admission=AdmissionOptions()))
    co = simulate(st, node_pool(), options=SimOptions(seed=0),
                  config=RunConfig(admission=AdmissionOptions(),
                                   coalesce_events=True))
    assert co.stream["finished"] == co.stream["arrived"] \
        == base.stream["arrived"]
    assert co.tasks_total == base.tasks_total
    assert {(r.workflow, r.set_name, r.index) for r in co.records} \
        == {(r.workflow, r.set_name, r.index) for r in base.records}


# ---------------------------------------------------------------------------
# record_policy="summary"
# ---------------------------------------------------------------------------

def test_summary_mode_reproduces_full_metric_surface():
    st = open_stream(seed=11)
    cfg = RunConfig(admission=AdmissionOptions(), slo_window=300.0)
    full = simulate(st, node_pool(), options=SimOptions(seed=4), config=cfg)
    summ = simulate(st, node_pool(), options=SimOptions(seed=4),
                    config=dataclasses.replace(cfg,
                                               record_policy="summary"))
    assert summ.records == [] and summ.workflows is None
    assert summ.metrics is not None
    assert summ.metrics.workflows == len(full.workflows)
    assert summ.makespan == full.makespan
    assert summ.tasks_total == full.tasks_total
    assert summ.cpu_utilization == pytest.approx(full.cpu_utilization,
                                                 rel=1e-12)
    assert summ.stream == full.stream
    assert summ.slo_attainment() == full.slo_attainment()
    # below sketch capacity the percentile walk is bit-identical
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        assert summ.slowdown_percentile(q) == full.slowdown_percentile(q)
    assert summ.weighted_slowdown() == pytest.approx(
        full.weighted_slowdown(), rel=1e-12)
    assert summ.window_stats(300.0) == full.window_stats(300.0)
    with pytest.raises(ValueError, match="window"):
        summ.window_stats(250.0)


def test_summary_mode_same_dispatch_as_full():
    """Dropping the trace must not change what the engine does: a full
    run and a summary run share the dispatch sequence (pinned through
    identical makespan / totals / stream accounting / prediction trace,
    since the summary run keeps no records to compare)."""
    st = open_stream(seed=13, sigma=5.0)
    cfg = RunConfig(feedback=FeedbackOptions(migrate=False))
    full = simulate(st, node_pool(), options=SimOptions(seed=5), config=cfg)
    summ = simulate(st, node_pool(), options=SimOptions(seed=5),
                    config=dataclasses.replace(cfg,
                                               record_policy="summary"))
    assert summ.makespan == full.makespan
    assert summ.tasks_total == full.tasks_total
    assert summ.stream == full.stream
    assert len(summ.predictions) == len(full.predictions)
    assert [p.total for p in summ.predictions] \
        == [p.total for p in full.predictions]


def test_record_policy_validation():
    with pytest.raises(ValueError, match="record_policy"):
        simulate(two_stage(), node_pool(),
                 config=RunConfig(record_policy="bogus"))
    with pytest.raises(ValueError, match="simulator-only"):
        RealExecutor(node_pool(1), tx_scale=0.002).run(
            two_stage(), config=RunConfig(record_policy="summary"))


# ---------------------------------------------------------------------------
# perf counters + executor integration
# ---------------------------------------------------------------------------

def test_perf_counters_populated():
    st = open_stream(seed=2)
    cfg = RunConfig(feedback=FeedbackOptions(migrate=False),
                    perf_counters=True, coalesce_events=True,
                    predict=PredictOptions(min_interval=120.0))
    r = simulate(st, node_pool(), options=SimOptions(seed=0), config=cfg)
    p = r.perf
    assert p is not None
    assert p.total_s > 0.0 and p.passes > 0 and p.events > 0
    assert p.predicts >= 1
    assert p.predicts <= len(r.predictions)
    # the buckets partition the loop
    assert p.engine_s + p.predict_s + p.metrics_s + p.events_s \
        == pytest.approx(p.total_s, rel=1e-6)
    off = simulate(st, node_pool(), options=SimOptions(seed=0),
                   config=dataclasses.replace(cfg, perf_counters=False))
    assert off.perf is None


def test_executor_accepts_predict_options():
    g = two_stage()
    ex = RealExecutor(node_pool(2), tx_scale=0.002)
    r = ex.run(g, config=RunConfig(
        feedback=FeedbackOptions(migrate=False),
        predict=PredictOptions(min_interval=5.0)))
    assert len({(rec.set_name, rec.index) for rec in r.records}) == 4
    assert len(r.predictions) >= 1


# ---------------------------------------------------------------------------
# tools/profile_run.py smoke (satellite: CI / tooling)
# ---------------------------------------------------------------------------

def test_profile_run_smoke(capsys):
    import os
    import sys
    tools = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools")
    sys.path.insert(0, tools)
    try:
        from profile_run import main
    finally:
        sys.path.remove(tools)
    assert main(["--horizon", "120", "--predict-interval", "60",
                 "--coalesce", "--summary", "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "arrivals" in out and "perf:" in out
    assert "cumulative" in out  # the pstats table made it to stdout

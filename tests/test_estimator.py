"""Runtime-feedback layer: online TX estimation (EWMA mean/variance,
prior fallback, straggler detection) and its wiring into the shared
scheduling engine (observed-TX priority re-ranking, preemption +
migration edge cases)."""

import pytest

from repro.core import (DAG, Allocation, FeedbackOptions, NodeSpec, PoolSpec,
                        SchedEngine, SimOptions, TaskSet, TxEstimator,
                        simulate)


def _two_pools(transfer=2.0):
    return Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=4, gpus=0)),
    ), transfer_cost=((0.0, transfer), (transfer, 0.0)))


# ---------------------------------------------------------------------------
# TxEstimator: EWMA mean + variance
# ---------------------------------------------------------------------------

def test_estimator_converges_on_constant_stream():
    est = TxEstimator(alpha=0.3)
    for _ in range(50):
        est.observe("s", 10.0)
    assert est.mean("s") == pytest.approx(10.0)
    assert est.std("s") == pytest.approx(0.0, abs=1e-9)
    assert est.count("s") == 50


def test_estimator_tracks_drifting_durations():
    """A 10 s -> 20 s drift: the EWMA must re-converge to the new regime
    (this is exactly what static tx_mean cannot do)."""
    est = TxEstimator(alpha=0.25)
    for _ in range(30):
        est.observe("s", 10.0)
    assert est.mean("s") == pytest.approx(10.0)
    for _ in range(30):
        est.observe("s", 20.0)
    assert est.mean("s") == pytest.approx(20.0, rel=0.01)
    # mid-drift the variance must have spiked, then decayed again
    assert est.std("s") < 1.0


def test_estimator_variance_on_noisy_stream():
    est = TxEstimator(alpha=0.2)
    for k in range(200):
        est.observe("s", 10.0 + (1.0 if k % 2 else -1.0))
    assert est.mean("s") == pytest.approx(10.0, abs=0.5)
    assert 0.5 < est.std("s") < 1.5


def test_estimator_prior_fallback_and_validation():
    est = TxEstimator(prior={"s": 42.0})
    assert est.mean("s") == 42.0          # no observations yet
    assert est.mean("other", default=7.0) == 7.0
    est.observe("s", 10.0)
    assert est.mean("s") == 10.0          # first observation replaces prior
    with pytest.raises(ValueError, match="alpha"):
        TxEstimator(alpha=0.0)


def test_straggler_detection_arms_after_min_samples():
    fb = FeedbackOptions(min_samples=3, straggler_k=3.0,
                         straggler_min_ratio=1.5)
    est = TxEstimator(alpha=0.25)
    est.observe("s", 10.0)
    est.observe("s", 10.0)
    assert not est.is_straggler("s", 1e9, fb)   # not armed yet
    est.observe("s", 10.0)
    assert est.is_straggler("s", 100.0, fb)
    # within mean + k*sigma (sigma ~ 0, but min_ratio guards the boundary)
    assert not est.is_straggler("s", 10.0, fb)
    assert not est.is_straggler("s", 14.9, fb)  # < 1.5x mean


# ---------------------------------------------------------------------------
# engine wiring: observed estimates drive tx_estimate and priority
# ---------------------------------------------------------------------------

def _engine(feedback=FeedbackOptions(), policy="lpt"):
    g = DAG()
    g.add(TaskSet("a", 4, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("b", 4, 1, 0, tx_mean=20.0, tx_sigma=0.0))
    return SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0)),
                       policy=policy, feedback=feedback)


def test_tx_estimate_static_until_min_samples():
    eng = _engine(FeedbackOptions(min_samples=3))
    assert eng.tx_estimate("a") == 10.0
    eng.observe("a", 99.0)
    eng.observe("a", 99.0)
    assert eng.tx_estimate("a") == 10.0     # still the static prior
    eng.observe("a", 99.0)
    assert eng.tx_estimate("a") == pytest.approx(99.0)


def test_observed_tx_rerank_lpt_priority():
    """LPT ranks b (tx=20) first statically; once observations show a is
    actually the long set, the next dispatch pass re-ranks a first."""
    eng = _engine(FeedbackOptions(min_samples=1))
    assert eng.priority.index("b") < eng.priority.index("a")
    for _ in range(3):
        eng.observe("a", 100.0)
        eng.observe("b", 1.0)
    eng.startable()   # rebuilds the dirty priority order
    assert eng.priority.index("a") < eng.priority.index("b")


def test_no_feedback_means_static_estimates_and_no_stragglers():
    g = DAG()
    g.add(TaskSet("a", 2, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=4, gpus=0)))
    eng.observe("a", 500.0)   # no estimator: a no-op
    assert eng.tx_estimate("a") == 10.0
    assert eng.stragglers({("a", 0): 0.0}, 1e9) == []
    assert eng.try_migrate("a", 0) is None


# ---------------------------------------------------------------------------
# migration edge cases
# ---------------------------------------------------------------------------

def _migration_engine(alloc, feedback=FeedbackOptions(min_samples=1)):
    g = DAG()
    g.add(TaskSet("s", 2, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, feedback=feedback)
    for _ in range(3):
        eng.observe("s", 10.0)
    return eng


def test_migration_moves_resources_between_pools():
    eng = _migration_engine(_two_pools(transfer=2.0))
    started = eng.startable()
    assert len(started) == 2
    (name, i, src) = started[0]
    free_src, free_dst = eng.free_cpus[src], eng.free_cpus[1 - src]
    mig = eng.try_migrate(name, i)
    assert mig is not None
    dst, cost = mig
    assert dst != src and cost == pytest.approx(2.0)
    assert eng.free_cpus[src] == free_src + 2      # source released
    assert eng.free_cpus[dst] == free_dst - 2      # target acquired
    assert eng.pool_of[(name, i)] == dst
    assert eng.migrations == 1
    # per-task migration cap: a second attempt is a no-op
    assert eng.try_migrate(name, i) is None
    # completion after migration releases the *target* pool
    eng.complete(name, i)
    assert eng.free_cpus[dst] == free_dst


def test_migration_noop_when_straggler_completed_at_detection_tick():
    eng = _migration_engine(_two_pools())
    (name, i, _), _ = eng.startable()
    eng.complete(name, i)
    assert eng.try_migrate(name, i) is None
    # the straggler scan also skips it
    assert (name, i) not in eng.stragglers({(name, i): 0.0}, 1e9)


def test_migration_noop_without_eligible_target_pool():
    single = PoolSpec("only", 1, NodeSpec(cpus=4, gpus=0))
    eng = _migration_engine(single)
    (name, i, _), _ = eng.startable()
    assert eng.try_migrate(name, i) is None        # nowhere to go
    assert eng.migrations == 0


def test_migration_noop_when_cost_exceeds_benefit():
    """Transfer cost 1000 s vs an estimated 10 s TX: rerunning elsewhere
    cannot pay for the data movement -> no-op."""
    eng = _migration_engine(_two_pools(transfer=1000.0))
    (name, i, _), _ = eng.startable()
    assert eng.try_migrate(name, i) is None
    assert eng.migrations == 0


def test_migration_respects_target_capacity():
    """The other pool is full -> no candidates -> no-op."""
    alloc = Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=2, gpus=0)),
    ), transfer_cost=((0.0, 1.0), (1.0, 0.0)))
    g = DAG()
    g.add(TaskSet("s", 3, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, feedback=FeedbackOptions(min_samples=1))
    for _ in range(3):
        eng.observe("s", 10.0)
    started = eng.startable()          # fills both pools (2+1 tasks fit)
    assert len(started) == 3
    for name, i, _k in started:
        assert eng.try_migrate(name, i) is None


# ---------------------------------------------------------------------------
# end-to-end: feedback in the simulator
# ---------------------------------------------------------------------------

def test_sim_migration_rescues_stragglers():
    """One big set with injected 20x stragglers on a two-pool allocation:
    migration-enabled runs must beat the static schedule and count > 0
    migrations, and every task must still complete exactly once."""
    g = DAG()
    g.add(TaskSet("s", 24, 2, 0, tx_mean=10.0, tx_sigma=0.5))
    alloc = Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=8, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=8, gpus=0)),
    ), transfer_cost=((0.0, 1.0), (1.0, 0.0)))
    opts = SimOptions(seed=2, launch_latency=0.0, straggler_prob=0.15,
                      straggler_factor=20.0)
    base = simulate(g, alloc, "async", options=opts)
    fed = simulate(g, alloc, "async", options=opts,
                   feedback=FeedbackOptions(straggler_k=2.0))
    assert fed.tasks_total == base.tasks_total == 24
    assert fed.migrations > 0
    assert fed.makespan < base.makespan
    assert sum(1 for r in fed.records if r.migrated) > 0
    # exactly-once completion despite preemption/requeue
    assert len({(r.set_name, r.index) for r in fed.records}) == 24


def test_sim_feedback_noop_without_stragglers():
    """Clean durations: feedback must not change the schedule at all."""
    g = DAG()
    g.add(TaskSet("s", 8, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0))
    opts = SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)
    a = simulate(g, pool, "async", options=opts)
    b = simulate(g, pool, "async", options=opts, feedback=FeedbackOptions())
    assert b.makespan == pytest.approx(a.makespan)
    assert b.migrations == 0


def test_per_pool_estimates_split_and_fallback():
    """Pool-tagged observations feed a per-(set, pool) split; pool-aware
    queries prefer it once it has samples, then fall back set-level, then
    to the prior."""
    est = TxEstimator(alpha=0.5, prior={"s": 7.0})
    assert est.mean("s", pool="fast") == 7.0     # nothing yet: prior
    est.observe("s", 10.0, pool="fast")
    est.observe("s", 10.0, pool="fast")
    assert est.mean("s", pool="fast") == pytest.approx(10.0)
    assert est.count("s", pool="fast") == 2
    # a pool with no observations of its own falls back to the blend
    assert est.mean("s", pool="slow") == pytest.approx(10.0)
    assert est.count("s", pool="slow") == 0


def test_slow_pool_does_not_pollute_sibling_pool_estimate():
    """A uniformly slow pool must raise only its own estimate — the fast
    pool's split stays on the fast regime even as slow observations
    stream in (set-wide drift is exactly what per-pool splits prevent)."""
    est = TxEstimator(alpha=0.25)
    for _ in range(10):
        est.observe("s", 10.0, pool="fast")
    for _ in range(40):
        est.observe("s", 40.0, pool="slow")
    assert est.mean("s", pool="fast") == pytest.approx(10.0)
    assert est.mean("s", pool="slow") == pytest.approx(40.0, rel=0.01)
    # the set-level blend did drift -- that is what pool queries bypass
    assert est.mean("s") > 30.0


def test_pool_aware_straggler_detection():
    """Runtime 35 s: a straggler by the polluted set-level estimate, but
    perfectly normal for the slow pool once its split is armed."""
    fb = FeedbackOptions(min_samples=3, straggler_k=2.0)
    est = TxEstimator(alpha=0.25)
    for _ in range(10):
        est.observe("s", 10.0, pool="fast")
    for _ in range(10):
        est.observe("s", 40.0, pool="slow")
    assert est.is_straggler("s", 35.0, fb, pool="fast")
    assert not est.is_straggler("s", 35.0, fb, pool="slow")
    # but a genuine outlier on the slow pool is still flagged
    assert est.is_straggler("s", 90.0, fb, pool="slow")


def test_engine_tx_estimate_is_pool_aware():
    g = DAG()
    g.add(TaskSet("s", 8, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, _two_pools(), feedback=FeedbackOptions(min_samples=2))
    for _ in range(3):
        eng.observe("s", 12.0, pool=0)
        eng.observe("s", 48.0, pool=1)
    assert eng.tx_estimate("s", pool=0) == pytest.approx(12.0)
    assert eng.tx_estimate("s", pool=1) == pytest.approx(48.0)
    # set-level estimate blends both pools
    assert 12.0 < eng.tx_estimate("s") < 48.0


def test_engine_per_pool_disabled_keeps_single_estimate():
    g = DAG()
    g.add(TaskSet("s", 8, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, _two_pools(),
                      feedback=FeedbackOptions(min_samples=2,
                                               per_pool=False))
    for _ in range(3):
        eng.observe("s", 12.0, pool=0)
        eng.observe("s", 48.0, pool=1)
    assert eng.tx_estimate("s", pool=0) == eng.tx_estimate("s", pool=1) \
        == eng.tx_estimate("s")


def test_lognormal_durations_have_heavier_tail_same_mean():
    g = DAG()
    g.add(TaskSet("s", 400, 1, 0, tx_mean=10.0, tx_sigma=0.05))
    pool = PoolSpec("p", 1, NodeSpec(cpus=400, gpus=0))
    opts = dict(seed=4, entk_overhead=0.0, async_overhead=0.0,
                launch_latency=0.0)
    rn = simulate(g, pool, "async", options=SimOptions(**opts))
    rl = simulate(g, pool, "async",
                  options=SimOptions(tx_distribution="lognormal",
                                     lognormal_sigma=0.6, **opts))
    dn = [r.duration for r in rn.records]
    dl = [r.duration for r in rl.records]
    mean = lambda xs: sum(xs) / len(xs)
    assert mean(dl) == pytest.approx(mean(dn), rel=0.15)   # same mean mu
    assert max(dl) > max(dn) * 1.5                         # heavy tail


# ---------------------------------------------------------------------------
# degenerate-history edges: 1-sample windows, identical durations,
# zero means — tail/winsorize paths must fall back, never divide or pin
# ---------------------------------------------------------------------------

def test_tail_ratio_single_sample_window_returns_none():
    """A 1-sample raw window cannot define a tail — even when the caller
    lowers ``min_count`` to 1 the floor of 2 holds."""
    est = TxEstimator()
    est.observe("s", 10.0)
    assert est.tail_ratio("s") is None
    assert est.tail_ratio("s", min_count=1) is None
    assert est.tail_ratio("s", min_count=0) is None
    assert est.tail_ratio("missing") is None


def test_tail_ratio_two_samples_rounds_index_up():
    """The quantile index rounds UP, so a 2-sample window reads the max —
    a lone outlier must not be ignored merely because history is short."""
    est = TxEstimator(alpha=0.5)
    est.observe("s", 10.0)
    est.observe("s", 40.0)      # EWMA mean = 25.0
    assert est.tail_ratio("s", q=0.95, min_count=2) == 40.0 / 25.0
    # even a mid quantile hits the last slot on 2 samples: ceil(0.5) = 1
    assert est.tail_ratio("s", q=0.5, min_count=2) == 40.0 / 25.0


def test_tail_ratio_identical_durations_clamps_to_one():
    """All-identical history: the observed tail IS the mean, clamped to
    1.0 — and the sigma-underflow straggler guard still requires the
    min-ratio excess before flagging."""
    est = TxEstimator()
    for _ in range(5):
        est.observe("s", 10.0)
    assert est.tail_ratio("s") == 1.0
    assert est.std("s") == 0.0
    fb = FeedbackOptions(min_samples=3, straggler_k=3.0,
                         straggler_min_ratio=1.5)
    # sigma collapsed to 0: mean + k*sigma == mean, so ANY runtime above
    # the mean passes the first test — the ratio guard must hold the line
    assert not est.is_straggler("s", 10.0 + 1e-9, fb)
    assert not est.is_straggler("s", 14.9, fb)
    assert est.is_straggler("s", 15.1, fb)


def test_tail_ratio_zero_mean_returns_none():
    """All-zero durations: mean is 0, the ratio is undefined — None, not
    a ZeroDivisionError."""
    est = TxEstimator()
    for _ in range(4):
        est.observe("s", 0.0)
    assert est.mean("s") == 0.0
    assert est.tail_ratio("s") is None


def test_engine_tail_ratio_degenerate_calibration_falls_back():
    """Engine-level ``tail_ratio`` with online calibration on: before the
    window arms it returns the static default; with an all-identical
    window the observed 1.0 is floored at ``straggler_min_ratio``."""
    g = DAG()
    g.add(TaskSet("s", 4, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    fb = FeedbackOptions(min_samples=3, calibrate_tail=True,
                         straggler_tail_ratio=4.0, straggler_min_ratio=1.5)
    eng = SchedEngine(g, _two_pools(), feedback=fb)
    eng.observe("s", 10.0)
    assert eng.tail_ratio("s") == 4.0          # window not armed yet
    for _ in range(4):
        eng.observe("s", 10.0)
    assert eng.tail_ratio("s") == 1.5          # 1.0 floored at min ratio


def test_winsorize_zero_mean_does_not_pin_estimates():
    """An armed all-zero mean must not clip later observations to zero:
    without the guard every subsequent duration would winsorize to
    ``ratio * 0 = 0`` and the estimate could never leave the floor."""
    g = DAG()
    g.add(TaskSet("s", 8, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    fb = FeedbackOptions(min_samples=1, winsorize_ratio=4.0, per_pool=False)
    eng = SchedEngine(g, _two_pools(), feedback=fb)
    eng.observe("s", 0.0)                      # arms the estimate at 0.0
    eng.observe("s", 100.0)                    # must enter unclipped
    assert eng.tx_estimate("s") > 0.0
    # the same guard holds on the per-pool split
    fb2 = FeedbackOptions(min_samples=1, winsorize_ratio=4.0, per_pool=True)
    eng2 = SchedEngine(g, _two_pools(), feedback=fb2)
    eng2.observe("s", 0.0, pool=0)
    eng2.observe("s", 100.0, pool=0)
    assert eng2.tx_estimate("s", pool=0) > 0.0


def test_expected_remaining_degenerate_inputs():
    """The PR-5 div-by-zero fix must cover every degenerate input the
    tail-ratio path can feed: zero mean, zero sigma, zero elapsed."""
    from repro.core import MakespanPredictor
    g = DAG()
    g.add(TaskSet("s", 1, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    pred = MakespanPredictor(g, PoolSpec("p", 1, NodeSpec(cpus=4, gpus=0)))
    assert pred.expected_remaining(0.0, 0.0, 5.0) == 0.0
    assert pred.expected_remaining(0.0, 3.0, 5.0) == 0.0
    assert pred.expected_remaining(10.0, 0.0, 4.0) == 6.0
    assert pred.expected_remaining(10.0, 3.0, 0.0) == 10.0
    # far in the tail: finite, never below the heavy-tail linear floor
    far = pred.expected_remaining(10.0, 3.0, 1e6)
    assert far == 3.0
    # the arbiter's baseline uses tail_ratio * mean: degenerate means
    # price to zero cleanly rather than raising
    assert pred.straggler_baseline(0.0, 5.0, 4.0) == 0.0

"""Snapshot of the curated public API (``repro.core.__all__``).

The core package used to export "whatever ``dir()`` found", so surface
changes were invisible in review.  ``__all__`` is now an explicit,
curated list; this snapshot makes any addition or removal show up as a
one-line test diff.  Additions are deliberate API growth (update the
snapshot); removals are breaking changes (think twice)."""

import repro.core as core

EXPECTED = {
    # structure + workloads
    "DAG", "TaskSet", "Pipeline", "Stage", "pipelines_to_dag",
    "fig2a_chain", "fig2b_fork", "fig2b_with_paper_tx", "fig2d_independent",
    "deepdrivemd_dag", "cdg_dag", "ddmd_stage_tx", "cdg_sequential_stage_tx",
    "ddmd_sequential_stage_groups", "DDMD_TABLE1", "CDG_TABLE2",
    "CDG_SEQUENTIAL_GROUPS",
    # resources
    "Resources", "NodeSpec", "NodeState", "PoolSpec", "Allocation",
    "ElasticOptions", "as_allocation", "node_states", "summit_pool",
    "hybrid_pool", "tpu_pod_pool", "doa_res", "wla",
    # analytic model + prediction
    "ENTK_OVERHEAD", "ASYNC_OVERHEAD", "Prediction", "predict",
    "async_ttx", "sequential_ttx", "sequential_ttx_grouped",
    "staggered_async_ttx", "relative_improvement", "maskable_stages",
    "tx_lookup_fn", "BatchEqns", "jax_available",
    "staggered_async_ttx_batch", "MakespanPrediction", "MakespanPredictor",
    # scheduling engine
    "SchedEngine", "SchedulingPolicy", "SCHEDULING_POLICIES",
    "get_scheduling_policy", "SetInfo", "FifoBackfill", "LargestTxFirst",
    "GpuAwareBestFit", "LocalityAware", "NodePackTopology",
    "CampaignPriority", "AdmissionOptions", "FailureEvent", "PredictOptions",
    # estimator / feedback
    "TxEstimator", "SetEstimate", "FeedbackOptions",
    # faults
    "FaultOptions", "FailureSchedule",
    # tenancy: campaigns + streams
    "Campaign", "CampaignView", "WorkflowEntry", "WorkflowStats",
    "campaign_stats", "weighted_slowdown", "WorkflowStream",
    "CampaignStream", "GeneratedStream", "StreamTemplate", "prefix_view",
    # trace replay + scenario engine
    "SWFJob", "SWFTrace", "SWFMapOptions", "parse_swf", "load_swf",
    "swf_entries", "swf_campaign", "swf_stream", "Scenario",
    "ScenarioGenerator", "SCENARIOS", "run_scenario",
    # run API (both substrates)
    "RunConfig", "resolve_run_config", "reset_legacy_warnings",
    "RunResult", "TaskRecord",
    "per_pool_task_counts", "simulate", "SimOptions", "SimResult",
    "RealExecutor", "ExecResult", "PerfCounters",
    # streaming metric sketches
    "QuantileSketch", "StreamMetrics",
    # execution policies / comparison
    "ExecutionPolicy", "async_policy", "sequential_policy",
    "adaptive_policy", "adaptive_observed_policy", "arbitrated_policy",
    "priority_policy", "lpt_policy", "gpu_bestfit_policy",
    "locality_policy", "nodepack_policy", "PolicyComparison",
    "compare_policies",
}


def test_public_api_snapshot():
    got = set(core.__all__)
    added = sorted(got - EXPECTED)
    removed = sorted(EXPECTED - got)
    assert not added and not removed, (
        f"public API changed — added {added}, removed {removed}; "
        f"update tests/test_public_api.py if deliberate")


def test_public_api_resolves():
    for name in core.__all__:
        assert getattr(core, name, None) is not None, name


def test_results_are_runresults():
    from repro.core import ExecResult, RunResult, SimResult
    assert issubclass(SimResult, RunResult)
    assert issubclass(ExecResult, RunResult)

"""Streaming metric sketches (``core/metrics.py``) and the
``RunResult`` metric-surface edge cases they must agree with.

The contract under test: below its compaction threshold the sketch is
*bit-identical* to ``RunResult.slowdown_percentile`` over the same
weighted population; past it, quantile rank error stays within the
largest centroid's weight share; the extremes (q=0 / q=1) are exact
forever."""

import random

import pytest

from repro.core import QuantileSketch, RunResult, StreamMetrics
from repro.core.workflow import WorkflowStats


def full_result(stats):
    return RunResult(makespan=0.0, records=[],
                     workflows={w.name: w for w in stats})


def wf(name, finish, *, ref=1.0, weight=1.0, deadline=None, arrival=0.0,
       tasks=1):
    return WorkflowStats(name=name, arrival=arrival, start=arrival,
                         finish=finish, tasks=tasks, weight=weight,
                         deadline=deadline, reference_makespan=ref)


# -- QuantileSketch ---------------------------------------------------------

def test_sketch_empty_and_validation():
    s = QuantileSketch()
    assert s.query(0.5) is None
    assert s.exact and len(s) == 0
    with pytest.raises(ValueError):
        QuantileSketch(max_points=1)


def test_sketch_ignores_nonpositive_weight():
    s = QuantileSketch()
    s.add(5.0, 0.0)
    s.add(7.0, -1.0)
    assert len(s) == 0 and s.n_added == 0
    s.add(3.0)
    assert s.query(0.5) == 3.0


def test_sketch_exact_fallback_matches_runresult_bitwise():
    rng = random.Random(7)
    pop = [(rng.uniform(1.0, 40.0), rng.choice([0.5, 1.0, 2.0, 4.0]))
           for _ in range(300)]
    s = QuantileSketch(max_points=512)  # 300 < 2*512 -> never compacts
    stats = []
    for i, (v, w) in enumerate(pop):
        s.add(v, w)
        stats.append(wf(f"w{i}", finish=v, ref=1.0, weight=w))
    assert s.exact
    r = full_result(stats)
    for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0]:
        assert s.query(q) == r.slowdown_percentile(q)


def test_sketch_extremes_exact_after_compaction():
    rng = random.Random(11)
    vals = [rng.uniform(0.0, 100.0) for _ in range(5000)]
    s = QuantileSketch(max_points=32)
    for v in vals:
        s.add(v)
    assert not s.exact and s.compactions > 0
    assert len(s) <= 2 * s.max_points
    assert s.query(0.0) == min(vals)
    assert s.query(1.0) == max(vals)
    assert s.total_weight() == pytest.approx(len(vals))


def test_sketch_rank_error_within_documented_bound():
    """Documented bound: the rank of ``query(q)`` is within the largest
    centroid's weight share of ``q`` (module docstring)."""
    rng = random.Random(3)
    vals = sorted(rng.lognormvariate(0.0, 1.0) for _ in range(8000))
    s = QuantileSketch(max_points=64)
    for v in vals:
        s.add(v)
    assert not s.exact
    bound = max(w for _v, w in s._pts) / s.total_weight()
    n = len(vals)
    for q in [0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99]:
        got = s.query(q)
        # exact rank interval of the returned value in the population
        lo = sum(1 for v in vals if v < got) / n
        hi = sum(1 for v in vals if v <= got) / n
        assert lo - bound <= q <= hi + bound, (q, got, lo, hi, bound)


def test_sketch_weighted_mass_pulls_quantile():
    s = QuantileSketch()
    s.add(1.0, 9.0)
    s.add(100.0, 1.0)
    assert s.query(0.5) == 1.0
    assert s.query(0.95) == 100.0


# -- StreamMetrics ----------------------------------------------------------

def make_population(seed, n=400):
    rng = random.Random(seed)
    stats = []
    for i in range(n):
        finish = rng.uniform(0.0, 5000.0)
        stats.append(WorkflowStats(
            name=f"w{i}", arrival=finish - rng.uniform(1.0, 50.0),
            start=finish - rng.uniform(0.5, 20.0), finish=finish,
            tasks=rng.choice([0, 1, 3]),
            weight=rng.choice([0.5, 1.0, 2.0]),
            deadline=(finish + rng.uniform(-5.0, 5.0)
                      if rng.random() < 0.6 else None),
            reference_makespan=(rng.uniform(0.5, 10.0)
                                if rng.random() < 0.8 else None)))
    return stats


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_metrics_parity_with_full_result(seed):
    stats = make_population(seed)
    m = StreamMetrics(window=900.0)
    for w in stats:
        m.observe_workflow(w)
    r = full_result(stats)
    assert m.workflows == len(stats)
    assert m.slo_attainment() == r.slo_attainment()
    assert m.weighted_slowdown() == pytest.approx(
        r.weighted_slowdown(), rel=1e-12)
    for q in [0.0, 0.5, 0.9, 0.99, 1.0]:  # below capacity -> bit-exact
        assert m.slowdown_percentile(q) == r.slowdown_percentile(q)
    assert m.window_stats() == r.window_stats(900.0)


def test_stream_metrics_empty_and_validation():
    m = StreamMetrics()
    assert m.slo_attainment() is None
    assert m.weighted_slowdown() is None
    assert m.slowdown_percentile(0.5) is None
    assert m.window_stats() == []
    with pytest.raises(ValueError):
        StreamMetrics(window=0.0)


# -- RunResult metric edge cases (satellite: results coverage) --------------

def test_runresult_empty_records():
    r = RunResult(makespan=0.0, records=[])
    assert r.slo_attainment() is None
    assert r.weighted_slowdown() is None
    assert r.slowdown_percentile(0.5) is None
    assert r.window_stats(900.0) == []
    assert r.throughput() == 0.0
    assert r.per_pool_task_counts() == {}


def test_runresult_all_zero_weights():
    stats = [wf(f"w{i}", finish=10.0 * i, ref=2.0, weight=0.0)
             for i in range(1, 4)]
    r = full_result(stats)
    # zero-weight workflows carry no percentile mass ...
    assert r.slowdown_percentile(0.5) is None
    # ... and no weighted-mean mass either
    assert r.weighted_slowdown() is None


def test_runresult_single_record_window():
    r = full_result([wf("only", finish=950.0, ref=10.0)])
    ws = r.window_stats(900.0)
    assert len(ws) == 1
    (w,) = ws
    assert w["t0"] == 900.0 and w["t1"] == 1800.0 and w["finished"] == 1
    sd = 950.0 / 10.0
    assert w["p50_slowdown"] == sd and w["p99_slowdown"] == sd
    assert w["slo_attainment"] is None  # no deadline carried


def test_runresult_percentile_endpoints():
    stats = [wf("a", finish=2.0), wf("b", finish=5.0), wf("c", finish=9.0)]
    r = full_result(stats)
    assert r.slowdown_percentile(0.0) == 2.0
    assert r.slowdown_percentile(1.0) == 9.0
    with pytest.raises(ValueError):
        r.window_stats(0.0)


def test_runresult_metric_queries_are_memoized():
    stats = make_population(5)
    r = full_result(stats)
    r.slowdown_percentile(0.5)
    view = r.__dict__["_slow_view"]
    r.slowdown_percentile(0.99)
    assert r.__dict__["_slow_view"] is view  # sorted once, reused
    first = r.window_stats(900.0)
    assert r.window_stats(900.0) is first  # memoized per window
    assert r.window_stats(600.0) is not first


def test_summary_result_rejects_foreign_window():
    m = StreamMetrics(window=900.0)
    m.observe_workflow(wf("w", finish=10.0))
    r = RunResult(makespan=0.0, records=[], metrics=m)
    assert r.window_stats(900.0) == m.window_stats()
    with pytest.raises(ValueError):
        r.window_stats(600.0)
    assert r.slowdown_percentile(0.5) == m.slowdown_percentile(0.5)
    assert r.weighted_slowdown() == m.weighted_slowdown()

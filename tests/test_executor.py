"""Real concurrent executor: actual asynchronous execution on this host."""

import threading

import jax
import jax.numpy as jnp
import pytest

from repro.core import (DAG, PoolSpec, NodeSpec, RealExecutor, TaskSet,
                        cdg_dag, deepdrivemd_dag)

SMALL_POOL = PoolSpec("local", num_nodes=1, node=NodeSpec(cpus=8, gpus=4),
                      oversubscribe_cpus=True)


def _scaled(dag, scale=2e-4):
    g = dag.copy()
    for name, ts in dag.nodes.items():
        g.replace(name, tx_mean=ts.tx_mean * scale / 2e-4 * 2e-4,
                  tx_sigma=0.0)
    return g


def test_async_faster_than_sequential_wallclock():
    # two independent chains of sleeps: async must overlap them
    g = DAG()
    g.add(TaskSet("A", 2, 1, 1, tx_mean=0.15, tx_sigma=0.0))
    g.add(TaskSet("B", 2, 1, 1, tx_mean=0.15, tx_sigma=0.0))
    ex = RealExecutor(SMALL_POOL, tx_scale=1.0)
    ra = ex.run(g, "async")
    rs = ex.run(g, "sequential", sequential_stage_groups=[["A"], ["B"]])
    assert ra.makespan < rs.makespan * 0.8
    assert ra.tasks_total == rs.tasks_total == 4


def test_dependencies_respected_wallclock():
    g = DAG()
    g.add(TaskSet("A", 1, 1, 0, tx_mean=0.05, tx_sigma=0.0))
    g.add(TaskSet("B", 1, 1, 0, tx_mean=0.05, tx_sigma=0.0))
    g.add_edge("A", "B")
    res = RealExecutor(SMALL_POOL).run(g, "async")
    rec = {r.set_name: r for r in res.records}
    assert rec["B"].start >= rec["A"].end - 1e-3


def test_jax_payloads_execute():
    """Heterogeneous payloads: a jitted train-ish step and an inference-ish
    step genuinely run and produce finite numbers."""
    results = {}
    lock = threading.Lock()

    @jax.jit
    def heavy(x):
        return jnp.tanh(x @ x.T).sum()

    def sim_payload(i):
        v = float(heavy(jnp.ones((64, 64)) * (i + 1)))
        with lock:
            results[("sim", i)] = v

    def ml_payload(i):
        v = float(heavy(jnp.eye(32)))
        with lock:
            results[("ml", i)] = v

    g = DAG()
    g.add(TaskSet("sim", 3, 1, 1, tx_mean=0.0, payload=sim_payload,
                  kind="simulation"))
    g.add(TaskSet("ml", 2, 1, 1, tx_mean=0.0, payload=ml_payload,
                  kind="training"))
    g.add_edge("sim", "ml")
    res = RealExecutor(SMALL_POOL).run(g, "async")
    assert res.tasks_total == 5
    assert len(results) == 5
    assert all(jnp.isfinite(v) for v in results.values())
    # dependency: every ml record starts after all sim records end
    sim_end = max(r.end for r in res.records if r.set_name == "sim")
    ml_start = min(r.start for r in res.records if r.set_name == "ml")
    assert ml_start >= sim_end - 1e-3


def test_gpu_slots_limit_concurrency():
    """4 GPU slots, 8 single-GPU tasks of 0.1 s -> at least two waves."""
    g = DAG()
    g.add(TaskSet("T", 8, 1, 1, tx_mean=0.1, tx_sigma=0.0))
    res = RealExecutor(SMALL_POOL).run(g, "async")
    assert res.makespan >= 0.19


def test_ddmd_shape_runs_at_laptop_scale():
    dd = _scaled(deepdrivemd_dag(2))
    for name, ts in dd.nodes.items():
        dd.replace(name, tx_mean=0.02, num_tasks=min(ts.num_tasks, 6))
    ex = RealExecutor(SMALL_POOL)
    ra = ex.run(dd, "async")
    rs = ex.run(dd, "sequential")
    assert ra.tasks_total == rs.tasks_total
    assert ra.makespan <= rs.makespan * 1.05


def test_task_level_executor():
    g = cdg_dag("c-DG2")
    for name, ts in g.nodes.items():
        g.replace(name, tx_mean=0.01, num_tasks=min(ts.num_tasks, 4),
                  tx_sigma=0.0)
    res = RealExecutor(SMALL_POOL).run(g, "async", task_level=True)
    assert res.tasks_total == sum(ts.num_tasks for ts in g.nodes.values())

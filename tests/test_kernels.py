"""Per-kernel allclose validation: Pallas (interpret mode on CPU) and the
jnp chunked fallbacks against the pure-jnp oracles, swept over shapes and
dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import ops as da
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.rwkv6 import ops as rk
from repro.kernels.rwkv6 import ref as rk_ref
from repro.kernels.ssm import ops as sk
from repro.kernels.ssm import ref as sk_ref

TOL = dict(rtol=2e-2, atol=2e-2)    # bf16-friendly
TOL32 = dict(rtol=2e-4, atol=2e-4)


def _tol(dtype):
    return TOL if dtype == jnp.bfloat16 else TOL32


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,h,kvh,d", [
    (2, 128, 128, 4, 2, 32),
    (1, 256, 256, 4, 4, 64),
    (2, 96, 96, 2, 1, 16),       # non-multiple-of-block seq
])
@pytest.mark.parametrize("mask", ["causal", "window", "chunk"])
def test_flash_attention(b, sq, skv, h, kvh, d, dtype, mask):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, sq, h, d), dtype)
    k = _rand(ks[1], (b, skv, kvh, d), dtype)
    v = _rand(ks[2], (b, skv, kvh, d), dtype)
    kw = dict(causal=True,
              window=48 if mask == "window" else None,
              chunk=64 if mask == "chunk" else None)
    want = fa_ref.mha_reference(q, k, v, **kw)
    got_jnp = fa.flash_attention(q, k, v, impl="jnp", **kw)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    got_pl = fa.flash_attention(q, k, v, impl="pallas", interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_q_offset():
    """Prefill continuation: q block positioned mid-sequence."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 192, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 192, 2, 32), jnp.float32)
    want = fa_ref.mha_reference(q, k, v, causal=True, q_offset=128)
    got = fa.flash_attention(q, k, v, causal=True, q_offset=128, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,smax,h,kvh,d", [
    (2, 128, 4, 2, 32),
    (3, 64, 2, 2, 64),
])
def test_decode_attention(b, smax, h, kvh, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (b, h, d), dtype)
    ck = _rand(ks[1], (b, smax, kvh, d), dtype)
    cv = _rand(ks[2], (b, smax, kvh, d), dtype)
    valid = jnp.asarray([smax // 2, smax, smax - 7][:b] or [smax // 2])
    valid = valid[:b]
    want = da_ref.decode_reference(q, ck, cv, valid)
    got = da.decode_attention(q, ck, cv, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,k,bt", [
    (2, 64, 3, 16, 16),
    (1, 128, 2, 32, 32),
    (2, 32, 1, 64, 32),
])
def test_rwkv6(b, t, h, k, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = _rand(ks[0], (b, t, h, k), dtype)
    kk = _rand(ks[1], (b, t, h, k), dtype)
    v = _rand(ks[2], (b, t, h, k), dtype)
    w = jax.nn.sigmoid(_rand(ks[3], (b, t, h, k), jnp.float32)
                       ).astype(dtype) * 0.98 + 0.01
    u = _rand(ks[4], (h, k), jnp.float32)
    want, _ = rk_ref.rwkv6_reference(r, kk, v, w, u)
    got_jnp = rk.rwkv6(r, kk, v, w, u, impl="jnp", block_t=bt)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    got_pl = rk.rwkv6(r, kk, v, w, u, impl="pallas", block_t=bt,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_rwkv6_strong_decay_stability():
    """Near-zero decays (w -> 0) must not overflow the chunked form."""
    b, t, h, k = 1, 64, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    r = _rand(ks[0], (b, t, h, k), jnp.float32)
    kk = _rand(ks[1], (b, t, h, k), jnp.float32)
    v = _rand(ks[2], (b, t, h, k), jnp.float32)
    w = jnp.full((b, t, h, k), 1e-30, jnp.float32)
    u = _rand(ks[3], (h, k), jnp.float32)
    want, _ = rk_ref.rwkv6_reference(r, kk, v, w, u)
    got = rk.rwkv6(r, kk, v, w, u, impl="jnp", block_t=16)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_rwkv6_decode_matches_scan():
    b, t, h, k = 2, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = _rand(ks[0], (b, t, h, k), jnp.float32)
    kk = _rand(ks[1], (b, t, h, k), jnp.float32)
    v = _rand(ks[2], (b, t, h, k), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (b, t, h, k), jnp.float32))
    u = _rand(ks[4], (h, k), jnp.float32)
    want, _ = rk_ref.rwkv6_reference(r, kk, v, w, u)
    state = jnp.zeros((b, h, k, k))
    outs = []
    for i in range(t):
        o, state = rk.rwkv6_decode_step(state, r[:, i], kk[:, i], v[:, i],
                                        w[:, i], u)
        outs.append(o)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,p,n,bt", [
    (2, 64, 3, 8, 16, 16),
    (1, 128, 2, 16, 32, 32),
])
def test_ssd(b, t, h, p, n, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = _rand(ks[0], (b, t, h, p), dtype)
    dt = jnp.abs(_rand(ks[1], (b, t, h), jnp.float32)) * 0.5
    a_log = _rand(ks[2], (h,), jnp.float32) * 0.5
    bb = _rand(ks[3], (b, t, n), dtype)
    cc = _rand(ks[4], (b, t, n), dtype)
    d = _rand(ks[5], (h,), jnp.float32)
    want, _ = sk_ref.ssd_reference(x, dt, a_log, bb, cc, d)
    got_jnp = sk.ssd(x, dt, a_log, bb, cc, d, impl="jnp", block_t=bt)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    got_pl = sk.ssd(x, dt, a_log, bb, cc, d, impl="pallas", block_t=bt,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ssd_decode_matches_scan():
    b, t, h, p, n = 2, 8, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = _rand(ks[0], (b, t, h, p), jnp.float32)
    dt = jnp.abs(_rand(ks[1], (b, t, h), jnp.float32)) * 0.5
    a_log = _rand(ks[2], (h,), jnp.float32) * 0.5
    bb = _rand(ks[3], (b, t, n), jnp.float32)
    cc = _rand(ks[4], (b, t, n), jnp.float32)
    d = _rand(ks[5], (h,), jnp.float32)
    want, _ = sk_ref.ssd_reference(x, dt, a_log, bb, cc, d)
    state = jnp.zeros((b, h, n, p))
    outs = []
    for i in range(t):
        y, state = sk.ssd_decode_step(state, x[:, i], dt[:, i], a_log,
                                      bb[:, i], cc[:, i], d)
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)

"""Node-level topology: NVLink group structure, per-node occupancy,
fragmentation-honest placement, the ``nodepack`` packing policy,
topology-derived transfer distances, node-granular straggler
migration/speculation, sim-vs-executor node-placement equivalence, and
the satellites riding along (multi-pool DOA_res, online tail
calibration)."""

import dataclasses

import pytest

from repro.core import (DAG, Allocation, FeedbackOptions, NodeSpec, NodeState,
                        PoolSpec, RealExecutor, SchedEngine, SimOptions,
                        TaskSet, TxEstimator, cdg_dag, doa_res, hybrid_pool,
                        node_states, simulate, summit_pool, wla)

ALL_POLICIES = ("fifo", "lpt", "gpu_bestfit", "locality", "nodepack")


def _no_noise():
    return SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)


# ---------------------------------------------------------------------------
# NodeSpec / NodeState primitives
# ---------------------------------------------------------------------------

def test_nodespec_nvlink_validation():
    assert NodeSpec(48, 6, nvlink_groups=2).gpus_per_group == 3
    assert NodeSpec(48, 0).gpus_per_group == 0
    with pytest.raises(ValueError, match="divide evenly"):
        NodeSpec(48, 6, nvlink_groups=4)
    with pytest.raises(ValueError, match="nvlink_groups"):
        NodeSpec(48, 6, nvlink_groups=0)


def test_nodestate_single_group_acquire_release():
    ns = NodeState(NodeSpec(8, 6, nvlink_groups=2), cpus=8)
    takes = ns.acquire(2, 3)          # exactly one full NVLink group
    assert takes == [(0, 3)]
    assert ns.free_gpus == 3 and ns.group_free == [0, 3]
    assert ns.largest_block() == 3
    # tightest-fit: a 2-GPU ask prefers... only group 1 fits now
    assert ns.best_group(2) == 1
    ns.release(2, takes)
    assert ns.free_gpus == 6 and ns.group_free == [3, 3]


def test_nodestate_spans_groups_when_no_single_group_fits():
    ns = NodeState(NodeSpec(8, 6, nvlink_groups=2), cpus=8)
    ns.acquire(0, 2)                  # group 0 down to 1 free
    takes = ns.acquire(0, 4)          # must span: no group has 4 free
    assert sorted(takes) == [(0, 1), (1, 3)]
    assert ns.free_gpus == 0
    with pytest.raises(ValueError):
        ns.acquire(0, 1)


def test_node_states_spread_reserved_cpus():
    pool = PoolSpec("p", 4, NodeSpec(cpus=48, gpus=6), reserved_cpus=6)
    caps = [ns.cpus for ns in node_states(pool)]
    assert sorted(caps) == [46, 46, 47, 47]
    assert sum(caps) == pool.total.cpus


# ---------------------------------------------------------------------------
# fragmentation honesty: node-granular acceptance + candidacy
# ---------------------------------------------------------------------------

def test_task_wider_than_node_rejected_at_construction():
    g = DAG()
    g.add(TaskSet("wide", 1, 1, 8, tx_mean=1.0))
    pool = PoolSpec("p", 4, NodeSpec(cpus=8, gpus=6), node_level=True)
    assert pool.total.gpus == 24  # aggregate would happily "fit" it
    with pytest.raises(ValueError, match="fits no pool"):
        SchedEngine(g, pool)
    # the same task on the aggregate view of the same hardware is accepted
    SchedEngine(g, PoolSpec("p", 4, NodeSpec(cpus=8, gpus=6)))


def test_aggregate_fit_without_node_fit_waits():
    """Two 1-GPU tasks spread over two 2-GPU nodes leave 2 GPUs free in
    aggregate, but no node can host a 2-GPU task: the node-level pool
    honestly defers it, the aggregate pool dishonestly starts it."""
    def build():
        g = DAG()
        g.add(TaskSet("narrow", 2, 1, 1, tx_mean=10.0, tx_sigma=0.0))
        g.add(TaskSet("wide", 1, 1, 2, tx_mean=10.0, tx_sigma=0.0))
        return g

    honest = SchedEngine(build(), PoolSpec("p", 2, NodeSpec(4, 2),
                                           node_level=True))
    started = honest.startable()
    assert [(n, i) for n, i, _k in started] == [("narrow", 0), ("narrow", 1)]
    # default node choice spreads: one narrow task per node
    assert {honest.node_placement("narrow", 0),
            honest.node_placement("narrow", 1)} == {0, 1}
    assert honest.free_gpus[0] == 2 and not honest.startable()
    assert list(honest.ready["wide"]) == [0]
    # once a narrow task completes, a whole node frees up and wide starts
    honest.complete("narrow", 0)
    assert [(n, i) for n, i, _k in honest.startable()] == [("wide", 0)]

    naive = SchedEngine(build(), PoolSpec("p", 2, NodeSpec(4, 2)))
    assert len(naive.startable()) == 3  # aggregate co-fit: all start


def test_per_node_capacity_never_exceeded_all_policies():
    """Reconstruct per-(pool, node) concurrent GPU usage from the records:
    no node may ever exceed its own GPUs, under every policy."""
    g = DAG()
    g.add(TaskSet("w2", 8, 1, 2, tx_mean=7.0, tx_sigma=0.0))
    g.add(TaskSet("w1", 8, 1, 1, tx_mean=3.0, tx_sigma=0.0))
    g.add(TaskSet("w3", 4, 1, 3, tx_mean=5.0, tx_sigma=0.0, kind="train"))
    alloc = Allocation("two", (
        PoolSpec("a", 2, NodeSpec(cpus=16, gpus=4), node_level=True),
        PoolSpec("b", 2, NodeSpec(cpus=16, gpus=6,
                                  nvlink_groups=2), node_level=True),
    ))
    caps = {"a": 4, "b": 6}
    for policy in ALL_POLICIES:
        res = simulate(g, alloc, "async", options=_no_noise(),
                       scheduling=policy)
        assert res.tasks_total == 20
        per_node: dict = {}
        for r in res.records:
            assert r.node >= 0, (policy, r)
            per_node.setdefault((r.pool, r.node), []).append(r)
        for (pool_name, _node), rs in per_node.items():
            events = []
            for r in rs:
                events.append((r.start, r.gpus))
                events.append((r.end, -r.gpus))
            events.sort()
            in_use = 0
            for _, d in events:
                in_use += d
                assert in_use <= caps[pool_name], (policy, pool_name)


# ---------------------------------------------------------------------------
# nodepack: single-node / single-NVLink-group packing
# ---------------------------------------------------------------------------

def test_nodepack_keeps_multi_gpu_task_in_one_nvlink_group():
    g = DAG()
    g.add(TaskSet("t", 4, 1, 3, tx_mean=5.0, tx_sigma=0.0))
    pool = PoolSpec("p", 2, NodeSpec(cpus=16, gpus=6, nvlink_groups=2),
                    node_level=True)
    eng = SchedEngine(g, pool, policy="nodepack")
    started = eng.startable()
    assert len(started) == 4
    for name, i, _k in started:
        node, takes = eng._node_alloc[(name, i)]
        assert len(takes) == 1 and takes[0][1] == 3, (i, node, takes)


def test_nodepack_packs_narrow_tasks_default_spreads():
    """One 1-GPU task is already running on node 0.  The next 1-GPU task:
    nodepack packs it next to the first (tightest group), the default
    spread policy sends it to the empty node."""
    def build():
        g = DAG()
        g.add(TaskSet("s", 2, 1, 1, tx_mean=5.0, tx_sigma=0.0))
        return g
    pool = PoolSpec("p", 2, NodeSpec(cpus=8, gpus=2), node_level=True)

    packed = SchedEngine(build(), pool, policy="nodepack")
    nodes = [packed.node_placement(n, i) for n, i, _ in packed.startable()]
    assert nodes == [0, 0]

    spread = SchedEngine(build(), pool, policy="fifo")
    nodes = [spread.node_placement(n, i) for n, i, _ in spread.startable()]
    assert sorted(nodes) == [0, 1]


def test_nodepack_preserves_contiguous_blocks_for_wide_tasks():
    """Fillers first, then a wide task: packing keeps a whole node free so
    the wide task starts immediately; spreading fragments and the wide
    task must wait for a completion."""
    def build():
        g = DAG()
        g.add(TaskSet("fill", 2, 1, 1, tx_mean=50.0, tx_sigma=0.0))
        g.add(TaskSet("wide", 1, 1, 4, tx_mean=50.0, tx_sigma=0.0))
        return g
    pool = PoolSpec("p", 2, NodeSpec(cpus=8, gpus=4), node_level=True)
    res_pack = simulate(build(), pool, "async", options=_no_noise(),
                        scheduling="nodepack")
    res_fifo = simulate(build(), pool, "async", options=_no_noise(),
                        scheduling="fifo")
    assert res_pack.makespan < res_fifo.makespan
    start_wide = {r.set_name: r.start for r in res_pack.records}["wide"]
    assert start_wide == 0.0


def test_largest_free_block_and_occupancy():
    g = DAG()
    g.add(TaskSet("t", 1, 1, 4, tx_mean=5.0, tx_sigma=0.0))
    pool = PoolSpec("p", 2, NodeSpec(cpus=8, gpus=6, nvlink_groups=2),
                    node_level=True)
    eng = SchedEngine(g, pool, policy="nodepack")
    assert eng.largest_free_block(0) == 3
    eng.startable()  # the 4-GPU task spans both groups of one node
    assert eng.largest_free_block(0) == 3
    occ = eng.node_occupancy()["p"]
    assert occ is not None and len(occ) == 2
    used = [o for o in occ if o["free_gpus"] == 2]
    assert len(used) == 1 and sorted(used[0]["group_free"]) == [0, 2]


# ---------------------------------------------------------------------------
# topology-derived transfer distances
# ---------------------------------------------------------------------------

def test_transfer_distance_ordering():
    alloc = Allocation("t", (
        PoolSpec("a", 2, NodeSpec(8, 2), node_level=True),
        PoolSpec("b", 2, NodeSpec(8, 2), node_level=True),
    ), transfer_cost=((0.0, 9.0), (9.0, 0.0)),
        same_group_cost=0.5, same_node_cost=1.0, intra_pool_cost=4.0)
    same_group = alloc.transfer(0, 0, 0, 0, 0, 0)
    same_node = alloc.transfer(0, 0, 0, 0, 0, 1)
    intra_pool = alloc.transfer(0, 0, 0, 1)
    cross_pool = alloc.transfer(0, 1)
    assert same_group <= same_node <= intra_pool < cross_pool
    assert (same_group, same_node, intra_pool, cross_pool) == \
        (0.5, 1.0, 4.0, 9.0)
    # aggregate (node-less) calls keep the legacy semantics
    assert alloc.transfer(0, 0) == 0.0
    with pytest.raises(ValueError, match="topology costs"):
        Allocation("bad", (PoolSpec("a", 1, NodeSpec(8, 2)),),
                   same_node_cost=1.0, intra_pool_cost=0.5)


# ---------------------------------------------------------------------------
# migration / speculation land on concrete nodes
# ---------------------------------------------------------------------------

def _fed_engine(alloc, num_tasks=1, **fb_kw):
    g = DAG()
    g.add(TaskSet("s", num_tasks, 2, 1, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, feedback=FeedbackOptions(min_samples=1,
                                                         **fb_kw))
    for _ in range(3):
        eng.observe("s", 10.0)
    return eng


def test_migration_lands_on_concrete_node_cross_pool():
    alloc = Allocation("two", (
        PoolSpec("a", 1, NodeSpec(8, 2), node_level=True),
        PoolSpec("b", 2, NodeSpec(8, 2), node_level=True),
    ), transfer_cost=((0.0, 1.0), (1.0, 0.0)))
    eng = _fed_engine(alloc)
    (name, i, src), = eng.startable()
    assert src == 0 and eng.node_placement(name, i) == 0
    out = eng.try_migrate(name, i)
    assert out is not None
    dst, cost = out
    assert dst == 1 and cost == 1.0
    assert eng.node_placement(name, i) in (0, 1)
    # source node's GPU is back, target node's is taken
    assert eng.node_states[0][0].free_gpus == 2
    landed = eng.node_placement(name, i)
    assert eng.node_states[1][landed].free_gpus == 1
    eng.complete(name, i)
    assert all(ns.free_gpus == 2 for ns in eng.node_states[1])


def test_same_pool_cross_node_migration_charges_intra_pool_cost():
    """Node-level pools unlock migration WITHIN a pool: the straggler
    moves to a different node, charged the topology's intra-pool hop."""
    alloc = Allocation("one", (
        PoolSpec("a", 2, NodeSpec(8, 2), node_level=True),),
        intra_pool_cost=2.0, same_node_cost=1.0, same_group_cost=0.0)
    eng = _fed_engine(alloc)
    (name, i, src), = eng.startable()
    src_node = eng.node_placement(name, i)
    out = eng.try_migrate(name, i)
    assert out is not None
    dst, cost = out
    assert dst == 0 and cost == 2.0
    assert eng.node_placement(name, i) != src_node
    # aggregate single pool can never migrate (no second pool, no nodes)
    agg = _fed_engine(Allocation("agg", (PoolSpec("a", 2, NodeSpec(8, 2)),)))
    (n2, i2, _), = agg.startable()
    assert agg.try_migrate(n2, i2) is None


def test_speculation_lands_on_concrete_node_and_frees_it():
    alloc = Allocation("one", (
        PoolSpec("a", 2, NodeSpec(8, 2), node_level=True),),
        intra_pool_cost=2.0, same_node_cost=1.0)
    eng = _fed_engine(alloc, speculate=True, migrate=False)
    (name, i, _src), = eng.startable()
    src_node = eng.node_placement(name, i)
    out = eng.try_speculate(name, i)
    assert out is not None
    dst, cost = out
    assert dst == 0
    dup_node = eng.spec_node(name, i)
    assert dup_node >= 0
    # spread default picks the other node -> intra-pool hop priced
    assert dup_node != src_node and cost == 2.0
    assert eng.node_states[0][dup_node].free_gpus == 1
    eng.complete(name, i)  # winner frees BOTH node slots
    assert all(ns.free_gpus == 2 for ns in eng.node_states[0])
    assert eng.spec_node(name, i) == -1


def test_node_level_migration_end_to_end_sim():
    """Injected stragglers on a node-level split allocation: the full
    simulate() loop migrates onto concrete nodes and every record carries
    one."""
    g = DAG()
    g.add(TaskSet("s", 16, 2, 1, tx_mean=20.0, tx_sigma=0.5))
    alloc = Allocation("two", (
        PoolSpec("a", 2, NodeSpec(8, 4), node_level=True),
        PoolSpec("b", 2, NodeSpec(8, 4), node_level=True),
    ), transfer_cost=((0.0, 1.0), (1.0, 0.0)))
    res = simulate(g, alloc, "async",
                   options=SimOptions(seed=5, launch_latency=0.0,
                                      straggler_prob=0.2,
                                      straggler_factor=20.0),
                   feedback=FeedbackOptions(straggler_k=2.0))
    assert res.tasks_total == 16
    assert res.migrations > 0
    assert all(r.node >= 0 for r in res.records)


# ---------------------------------------------------------------------------
# sim-vs-executor equivalence at node granularity
# ---------------------------------------------------------------------------

def test_simulator_matches_executor_node_placements():
    """Deterministic single-pass workload: both substrates must place
    every task on the SAME (pool, node) through the shared engine."""
    g = DAG()
    g.add(TaskSet("s", 6, 1, 2, tx_mean=30.0, tx_sigma=0.0))
    pool = PoolSpec("p", 3, NodeSpec(cpus=8, gpus=4), node_level=True)
    sim = simulate(g, pool, "async", options=_no_noise(),
                   scheduling="nodepack")
    real = RealExecutor(pool, tx_scale=1e-3).run(g, "async",
                                                 scheduling="nodepack")
    sim_nodes = {(r.set_name, r.index): (r.pool, r.node)
                 for r in sim.records}
    real_nodes = {(r.set_name, r.index): (r.pool, r.node)
                  for r in real.records}
    assert sim_nodes == real_nodes
    assert sorted(n for _p, n in sim_nodes.values()) == [0, 0, 1, 1, 2, 2]


def test_node_level_strict_summit_matches_aggregate_makespan():
    """1-GPU workloads can never fragment a 6-GPU node, so the node-level
    strict Summit schedule must reproduce the aggregate one exactly."""
    opts = SimOptions(seed=3, tx_distribution="lognormal")
    agg = simulate(cdg_dag("c-DG2"), summit_pool(), "async", options=opts)
    node = simulate(cdg_dag("c-DG2"), summit_pool(node_level=True), "async",
                    options=opts)
    assert agg.makespan == node.makespan
    assert {r.node for r in agg.records} == {-1}
    assert all(r.node >= 0 for r in node.records)


# ---------------------------------------------------------------------------
# satellite: DOA_res / WLA over multi-pool Allocations
# ---------------------------------------------------------------------------

def test_doa_res_accepts_allocation():
    dag = cdg_dag("c-DG2")
    # hybrid GPU+CPU allocation computes instead of raising
    alloc = hybrid_pool()
    assert doa_res(dag, alloc) >= 1
    assert wla(dag, alloc) == min(dag.doa_dep(), doa_res(dag, alloc))
    # full_set strategy honours the combined aggregate footprint
    assert doa_res(dag, alloc, strategy="full_set") >= 0


def test_wla_allocation_matches_equivalent_single_pool():
    """An Allocation wrapping one pool must give the single-pool answer."""
    from repro.core import Allocation as Alloc, deepdrivemd_dag
    dag = deepdrivemd_dag(3)
    pool = summit_pool()
    assert doa_res(dag, Alloc("w", (pool,))) == doa_res(dag, pool)
    assert wla(dag, Alloc("w", (pool,))) == wla(dag, pool)


# ---------------------------------------------------------------------------
# satellite: online tail-ratio calibration
# ---------------------------------------------------------------------------

def test_estimator_tail_ratio_tracks_observed_quantile():
    est = TxEstimator(alpha=0.5)
    assert est.tail_ratio("s") is None
    for _ in range(19):
        est.observe("s", 10.0)
    # winsorized-for-the-EWMA straggler, raw tail recorded unclipped
    est.observe("s", 10.0, raw=80.0)
    r = est.tail_ratio("s", q=0.95, min_count=3)
    assert r is not None and r > 4.0  # the 80 s outlier IS the tail
    # raw (un-winsorized) durations feed the quantile even when the EWMA
    # input was clipped
    est2 = TxEstimator(alpha=0.5)
    for _ in range(19):
        est2.observe("s", 10.0)
    est2.observe("s", 10.0, raw=200.0)   # clipped to 10 for the EWMA
    assert est2.tail_ratio("s") > 10.0
    assert est2.mean("s") == pytest.approx(10.0)


def test_engine_tail_ratio_calibration_flag():
    g = DAG()
    g.add(TaskSet("s", 4, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=16, gpus=0))
    static = SchedEngine(g, pool, feedback=FeedbackOptions(min_samples=2))
    calib = SchedEngine(g, pool,
                        feedback=FeedbackOptions(min_samples=2,
                                                 calibrate_tail=True))
    for eng in (static, calib):
        for d in (10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0,
                  10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0,
                  10.0, 90.0):
            eng.observe("s", d)
    # fixed default vs the observed ~9x tail
    assert static.tail_ratio("s") == 4.0
    assert calib.tail_ratio("s") == pytest.approx(90.0 / static.estimator
                                                  .mean("s"), rel=0.3)
    assert calib.tail_ratio("s") > 4.0


def test_calibrated_tail_changes_arbiter_baseline():
    """A workload whose observed tail is MILD (2x): the calibrated
    arbiter declines a costly migration the 4x default would have taken,
    because the expected remainder no longer justifies the move."""
    g = DAG()
    g.add(TaskSet("s", 1, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    alloc = Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=4, gpus=0)),
    ), transfer_cost=((0.0, 8.0), (8.0, 0.0)))

    def build(calibrate):
        eng = SchedEngine(g, alloc, feedback=FeedbackOptions(
            min_samples=1, speculate=True, max_speculations_per_task=0,
            calibrate_tail=calibrate, max_cost_ratio=2.0))
        for _ in range(10):
            eng.observe("s", 10.0)
        eng.observe("s", 20.0)   # observed tail ratio ~2x
        return eng

    default = build(False)
    (n, i, _), = default.startable()
    # 4x default: baseline at elapsed=12 is max(10, 40-12)=28 > cost 8 +
    # rerun ~10 -> migrate
    assert default.arbitrate(n, i, elapsed=12.0) is not None

    calib = build(True)
    (n2, i2, _), = calib.startable()
    # calibrated ~2x: baseline max(10, ~22-12) ~= 10.9 < 8 + rerun -> no-op
    assert calib.arbitrate(n2, i2, elapsed=12.0) is None


# ---------------------------------------------------------------------------
# locality: node-granular data-movement scoring (ROADMAP PR-4 follow-up)
# ---------------------------------------------------------------------------

def _locality_node_pool(same_node=1.0, intra=5.0):
    return Allocation("loc", (
        PoolSpec("p", 2, NodeSpec(cpus=8, gpus=0), node_level=True),
    ), same_node_cost=same_node, intra_pool_cost=intra)


def _blocker_parent_child():
    """blocker + parent fill the two nodes; the child's data then lives
    on the parent's node only."""
    g = DAG()
    g.add(TaskSet("blocker", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("parent", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("child", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add_edge("parent", "child")
    return g


def test_data_cost_is_node_granular_on_node_level_pools():
    """``SchedEngine.data_cost`` prices same-pool pulls at the topology
    distances (same node < intra-pool fabric) when given a destination
    node, while the legacy pool-level call still reads zero — and the
    parent's placement survives its completion (``node_of``)."""
    eng = SchedEngine(_blocker_parent_child(), _locality_node_pool(),
                      policy="locality")
    started = eng.startable()
    placed = {n: eng.node_placement(n, 0) for n, _i, _k in started}
    assert placed["blocker"] == 0 and placed["parent"] == 1  # spread
    for n, i, _k in started:
        eng.complete(n, i)
    assert eng.node_of[("parent", 0)] == 1    # persists past completion
    assert eng.data_cost("child", 0, node=1) == 1.0   # same node
    assert eng.data_cost("child", 0, node=0) == 5.0   # intra-pool hop
    assert eng.best_data_cost("child", 0) == 1.0
    assert eng.data_cost("child", 0) == 0.0   # legacy pool-level view


def test_locality_places_child_on_parents_node():
    """Regression: the ``locality`` node choice must follow the data.
    Both nodes are free and the RM-default spread tie-break would pick
    node 0; the parent's outputs live on node 1, so locality lands the
    child there."""
    eng = SchedEngine(_blocker_parent_child(), _locality_node_pool(),
                      policy="locality")
    for n, i, _k in eng.startable():
        eng.complete(n, i)
    (name, i, k), = eng.startable()
    assert name == "child"
    assert eng.node_placement(name, i) == 1

    # control: fifo keeps the spread default and lands on node 0
    eng2 = SchedEngine(_blocker_parent_child(), _locality_node_pool(),
                       policy="fifo")
    for n, i, _k in eng2.startable():
        eng2.complete(n, i)
    (name2, i2, _k2), = eng2.startable()
    assert eng2.node_placement(name2, i2) == 0


def test_locality_node_granular_end_to_end_sim():
    """Full simulate(): every child task follows its parents' node under
    ``locality`` on a node-level pool (aggregate pools unchanged)."""
    g = DAG()
    g.add(TaskSet("blocker", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("parent", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("child", 2, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add_edge("parent", "child")
    res = simulate(g, _locality_node_pool(), "async", options=_no_noise(),
                   scheduling="locality")
    nodes = {(r.set_name, r.index): r.node for r in res.records}
    parent_node = nodes[("parent", 0)]
    # the first child task lands with the data; the second finds the
    # parent's node full (its sibling) only if capacities force it
    assert nodes[("child", 0)] == parent_node

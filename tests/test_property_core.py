"""Hypothesis property tests over the core invariants."""

import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency "
                    "(pip install -r requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import DAG, PoolSpec, NodeSpec, SimOptions, TaskSet, simulate
from repro.core.model import async_ttx, sequential_ttx


@st.composite
def random_dags(draw, max_nodes=10):
    n = draw(st.integers(2, max_nodes))
    g = DAG()
    for i in range(n):
        g.add(TaskSet(
            name=f"N{i}",
            num_tasks=draw(st.integers(1, 6)),
            cpus_per_task=draw(st.integers(1, 8)),
            gpus_per_task=draw(st.integers(0, 2)),
            tx_mean=float(draw(st.integers(1, 50))),
            tx_sigma=0.0,
        ))
    # edges only i -> j with i < j keeps it acyclic
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()) and draw(st.integers(0, 2)) == 0:
                g.add_edge(f"N{i}", f"N{j}")
    return g


POOL = PoolSpec("test", num_nodes=4, node=NodeSpec(cpus=16, gpus=4),
                oversubscribe_cpus=True)
NO_NOISE = SimOptions(sample_tx=False, entk_overhead=0.0, async_overhead=0.0,
                      launch_latency=0.0)


@settings(max_examples=40, deadline=None)
@given(random_dags())
def test_async_model_never_worse_than_sequential(g):
    t_seq = sequential_ttx(g)
    t_async, _ = async_ttx(g)
    assert t_async <= t_seq + 1e-6


@settings(max_examples=40, deadline=None)
@given(random_dags())
def test_doa_dep_bounds(g):
    d = g.doa_dep()
    assert 0 <= d <= len(g) - 1


@settings(max_examples=30, deadline=None)
@given(random_dags(max_nodes=8))
def test_simulated_dependencies_and_resources(g):
    res = simulate(g, POOL, "async", options=NO_NOISE)
    # every task ran exactly once
    assert res.tasks_total == sum(ts.num_tasks for ts in g.nodes.values())
    # set-level dependency: child sets start after parent sets end
    end_of, start_of = {}, {}
    for r in res.records:
        end_of[r.set_name] = max(end_of.get(r.set_name, 0.0), r.end)
        start_of[r.set_name] = min(start_of.get(r.set_name, 1e18), r.start)
    for u, v in g.edges():
        assert start_of[v] >= end_of[u] - 1e-9
    # GPU capacity respected at every instant
    events = sorted([(r.start, r.gpus) for r in res.records] +
                    [(r.end, -r.gpus) for r in res.records])
    use = 0
    for _, d in events:
        use += d
        assert use <= POOL.total.gpus


@settings(max_examples=30, deadline=None)
@given(random_dags(max_nodes=8))
def test_async_sim_not_slower_than_sequential_sim(g):
    ra = simulate(g, POOL, "async", options=NO_NOISE)
    rs = simulate(g, POOL, "sequential", options=NO_NOISE)
    # async relaxes barrier constraints; with deterministic durations and
    # backfill it can't lose by more than scheduling-anomaly noise
    assert ra.makespan <= rs.makespan * 1.15 + 1e-6


@settings(max_examples=30, deadline=None)
@given(random_dags(max_nodes=8))
def test_makespan_lower_bounds(g):
    res = simulate(g, POOL, "async", options=NO_NOISE)
    assert res.makespan + 1e-6 >= g.critical_path_tx()


@settings(max_examples=20, deadline=None)
@given(random_dags(max_nodes=7), st.integers(0, 3))
def test_sim_deterministic_given_seed(g, seed):
    a = simulate(g, POOL, "async", options=SimOptions(seed=seed))
    b = simulate(g, POOL, "async", options=SimOptions(seed=seed))
    assert a.makespan == b.makespan

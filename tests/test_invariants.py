"""Property-based invariant suite over the grown scheduling engine.

Hypothesis properties over random DAGs x all registered policies x
aggregate/node-level pools x feedback on/off, locking down the invariants
every layer must preserve no matter how the control plane grows:

- no pool / node / NVLink-group over-subscription at any event;
- every task runs exactly once (speculation losers cancelled, migrations
  idempotent);
- trace timestamps monotone (and the prediction trace's clock too);
- sim-vs-executor schedule equality through the shared engine;
- campaign conservation: every workflow's tasks complete, arrivals gate
  starts, per-workflow traces partition the record set, and admission
  deferral never loses work (deferred != lost).
"""

import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency "
                    "(pip install -r requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (SCHEDULING_POLICIES, AdmissionOptions, Allocation,
                        Campaign, DAG, FeedbackOptions, NodeSpec, NodeState,
                        PoolSpec, RealExecutor, SimOptions, TaskSet, simulate)

ALL_POLICIES = tuple(sorted(SCHEDULING_POLICIES))
POOL_MODES = ("aggregate", "node_level")
FEEDBACK = (None, "feedback")


def _feedback(arg):
    if arg is None:
        return None
    return FeedbackOptions(straggler_k=2.0, min_samples=2, speculate=True)


def make_pool(mode: str) -> Allocation:
    """Two strict pools (no oversubscription — capacity is a hard bound);
    node-level mode switches both to node-granular accounting with two
    NVLink groups per node."""
    nl = mode == "node_level"
    return Allocation("inv", (
        PoolSpec("p0", 2, NodeSpec(cpus=16, gpus=4, nvlink_groups=2),
                 node_level=nl),
        PoolSpec("p1", 1, NodeSpec(cpus=32, gpus=2, nvlink_groups=2),
                 node_level=nl),
    ), transfer_cost=((0.0, 2.0), (2.0, 0.0)))


@st.composite
def random_dags(draw, max_nodes=7, max_tasks=5):
    """Random task-set DGs whose tasks fit one node of ``make_pool``."""
    n = draw(st.integers(2, max_nodes))
    g = DAG()
    for i in range(n):
        g.add(TaskSet(
            name=f"N{i}",
            num_tasks=draw(st.integers(1, max_tasks)),
            cpus_per_task=draw(st.integers(1, 8)),
            gpus_per_task=draw(st.integers(0, 2)),
            tx_mean=float(draw(st.integers(5, 50))),
            tx_sigma=0.0,
        ))
    for j in range(1, n):
        for i in range(j):
            if draw(st.integers(0, 3)) == 0:
                g.add_edge(f"N{i}", f"N{j}")
    return g


@st.composite
def random_campaigns(draw, max_workflows=3):
    c = Campaign()
    for w in range(draw(st.integers(2, max_workflows))):
        c.add(f"wf{w}", draw(random_dags(max_nodes=4, max_tasks=3)),
              priority=draw(st.integers(0, 3)),
              arrival=float(draw(st.integers(0, 3)) * 40),
              weight=float(draw(st.integers(1, 4))))
    return c


def straggler_opts(seed: int) -> SimOptions:
    return SimOptions(seed=seed, launch_latency=0.0, straggler_prob=0.15,
                      straggler_factor=12.0)


def usage_events(records, key):
    """(time, +/- usage) event list per ``key(record)`` bucket."""
    out = {}
    for r in records:
        k = key(r)
        out.setdefault(k, []).append((r.start, r.cpus, r.gpus))
        out.setdefault(k, []).append((r.end, -r.cpus, -r.gpus))
    for evs in out.values():
        evs.sort()
    return out


# ---------------------------------------------------------------------------
# 1+2: no pool / node over-subscription at any event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=8, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_no_pool_oversubscription(policy, mode, g, seed):
    alloc = make_pool(mode)
    res = simulate(g, alloc, "async", options=SimOptions(seed=seed),
                   scheduling=policy)
    caps = {p.name: p.total for p in alloc.pools}
    for pool, evs in usage_events(res.records, lambda r: r.pool).items():
        c = gpu = 0
        for _t, dc, dg in evs:
            c += dc
            gpu += dg
            assert c <= caps[pool].cpus, (policy, mode, pool)
            assert gpu <= caps[pool].gpus, (policy, mode, pool)
        assert c == 0 and gpu == 0  # everything released


@pytest.mark.parametrize("fb", FEEDBACK)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_no_node_oversubscription(policy, fb, g, seed):
    """Node-level pools: per-(pool, node) usage never exceeds the node's
    own capacity — aggregate co-fit alone is never accepted.  Feedback
    here is estimator-only (no migration: a migrated record charges its
    final node for the whole task span, so per-node reconstruction from
    the trace is only exact for unmigrated runs — the engine-level
    accounting under mitigation is covered below)."""
    alloc = make_pool("node_level")
    node_caps = {"p0": (16, 4), "p1": (32, 2)}
    res = simulate(g, alloc, "async", options=SimOptions(seed=seed),
                   scheduling=policy,
                   feedback=None if fb is None
                   else FeedbackOptions(migrate=False))
    assert all(r.node >= 0 for r in res.records)
    for (pool, _node), evs in usage_events(
            res.records, lambda r: (r.pool, r.node)).items():
        c = gpu = 0
        cap_c, cap_g = node_caps[pool]
        for _t, dc, dg in evs:
            c += dc
            gpu += dg
            assert c <= cap_c and gpu <= cap_g, (policy, fb, pool)


@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(max_nodes=5), seed=st.integers(0, 5))
def test_engine_node_accounting_under_mitigation(policy, g, seed):
    """Drive the engine directly through random complete / migrate /
    speculate / arbitrate sequences: the per-node and per-NVLink-group
    occupancy stays within capacity at EVERY step, the aggregate
    counters remain the derived view of the node states, and everything
    is released at the end."""
    import random as _random
    rng = _random.Random(seed)
    alloc = make_pool("node_level")
    from repro.core import SchedEngine
    eng = SchedEngine(g, alloc, policy=policy,
                      feedback=FeedbackOptions(straggler_k=2.0,
                                               min_samples=1,
                                               speculate=True))
    for n in g.nodes:
        eng.observe(n, g.node(n).tx_mean)

    def check():
        for k, p in enumerate(eng.pools):
            states = eng.node_states[k]
            assert 0 <= eng.free_cpus[k] <= p.total.cpus
            assert 0 <= eng.free_gpus[k] <= p.total.gpus
            assert eng.free_cpus[k] == sum(ns.free_cpus for ns in states)
            assert eng.free_gpus[k] == sum(ns.free_gpus for ns in states)
            for ns in states:
                assert 0 <= ns.free_cpus and 0 <= ns.free_gpus
                assert all(0 <= f <= ns.spec.gpus_per_group
                           for f in ns.group_free)

    running = []
    guard = 0
    while not eng.done() and guard < 2000:
        guard += 1
        for name, i, _k in eng.startable():
            running.append((name, i))
        check()
        if not running:
            break
        idx = rng.randrange(len(running))
        name, i = running[idx]
        op = rng.randint(0, 3)
        if op == 1:
            eng.try_migrate(name, i)
        elif op == 2:
            eng.try_speculate(name, i)
        elif op == 3:
            eng.arbitrate(name, i, elapsed=rng.uniform(0, 100))
        else:
            running.pop(idx)
            eng.complete(name, i)
        check()
    for (name, i) in running:
        eng.complete(name, i)
    check()
    assert eng.done()
    for k, p in enumerate(eng.pools):
        assert eng.free_cpus[k] == p.total.cpus
        assert eng.free_gpus[k] == p.total.gpus


# ---------------------------------------------------------------------------
# 3: NVLink-group accounting (NodeState acquire/release round-trip)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 4), st.integers(0, 6)),
                    min_size=1, max_size=40),
       groups=st.integers(1, 3))
def test_nvlink_group_accounting_roundtrip(ops, groups):
    """Random acquire/release sequences: group frees stay within
    [0, gpus_per_group], the aggregate view is their sum, and releasing
    everything restores full capacity."""
    spec = NodeSpec(cpus=24, gpus=6 * groups, nvlink_groups=groups)
    ns = NodeState(spec, cpus=24)
    held = []
    for need_c, need_g in ops:
        if ns.fits(need_c, need_g):
            held.append((need_c, ns.acquire(need_c, need_g)))
        elif held:
            need_c2, takes = held.pop()
            ns.release(need_c2, takes)
        assert 0 <= ns.free_cpus <= 24
        assert 0 <= ns.free_gpus <= spec.gpus
        assert all(0 <= f <= spec.gpus_per_group for f in ns.group_free)
        assert ns.free_gpus == sum(ns.group_free)
        assert ns.largest_block() == max(ns.group_free)
    for need_c, takes in held:
        ns.release(need_c, takes)
    assert ns.free_cpus == 24 and ns.free_gpus == spec.gpus
    assert ns.group_free == [spec.gpus_per_group] * groups


# ---------------------------------------------------------------------------
# 4: every task runs exactly once (mitigation cannot lose or double work)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_every_task_runs_exactly_once(policy, mode, g, seed):
    """Under injected stragglers with migration + speculation enabled,
    the winner's record is the only one per task (the losing duplicate is
    cancelled) and no task is lost."""
    res = simulate(g, make_pool(mode), "async", options=straggler_opts(seed),
                   scheduling=policy, feedback=_feedback("feedback"))
    total = sum(ts.num_tasks for ts in g.nodes.values())
    assert res.tasks_total == total
    assert len({(r.set_name, r.index) for r in res.records}) == total


# ---------------------------------------------------------------------------
# 5: trace timestamps monotone
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fb", FEEDBACK)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_trace_timestamps_monotone(policy, fb, g, seed):
    res = simulate(g, make_pool("aggregate"), "async",
                   options=straggler_opts(seed), scheduling=policy,
                   feedback=_feedback(fb))
    for r in res.records:
        assert 0.0 <= r.start <= r.end, (policy, fb, r)
    assert res.makespan == max(r.end for r in res.records)
    clocks = [p.now for p in res.predictions]
    assert clocks == sorted(clocks)
    for p in res.predictions:
        assert p.total >= p.now and p.remaining >= 0.0


# ---------------------------------------------------------------------------
# 6: sim-vs-executor schedule equality (the shared-engine guarantee)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_sim_matches_executor_schedule(data):
    """Deterministic workloads with well-separated durations: both
    substrates must produce the same task -> pool placement through the
    shared engine (and at node granularity on node-level pools)."""
    g = data.draw(random_dags(max_nodes=4, max_tasks=3))
    # distinct, well-separated durations so thread completion order
    # cannot race the simulator's event order
    for j, name in enumerate(sorted(g.nodes)):
        g.replace(name, tx_mean=40.0 + 25.0 * j, tx_sigma=0.0)
    policy = data.draw(st.sampled_from(("fifo", "gpu_bestfit", "nodepack")))
    mode = data.draw(st.sampled_from(POOL_MODES))
    alloc = make_pool(mode)
    opts = SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)
    sim = simulate(g, alloc, "async", options=opts, scheduling=policy)
    real = RealExecutor(alloc, tx_scale=1e-3).run(g, "async",
                                                  scheduling=policy)
    sim_place = {(r.set_name, r.index): (r.pool, r.node)
                 for r in sim.records}
    real_place = {(r.set_name, r.index): (r.pool, r.node)
                  for r in real.records}
    assert sim_place == real_place


# ---------------------------------------------------------------------------
# 7-11: campaign conservation + tenancy invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("admission", (None, AdmissionOptions()))
@settings(max_examples=10, deadline=None)
@given(c=random_campaigns(), seed=st.integers(0, 3))
def test_campaign_conservation(admission, c, seed):
    """Every admitted workflow eventually completes in full (deferred !=
    lost), no task starts before its workflow's arrival, and the
    per-workflow traces partition the record set."""
    res = simulate(c, make_pool("aggregate"), "async",
                   options=SimOptions(seed=seed), scheduling="priority",
                   admission=admission)
    total = sum(ts.num_tasks for w in c.workflows
                for ts in w.dag.nodes.values())
    assert res.tasks_total == total
    assert len({(r.set_name, r.index) for r in res.records}) == total
    arrivals = {w.name: w.arrival for w in c.workflows}
    for r in res.records:
        assert r.workflow in arrivals
        assert r.start >= arrivals[r.workflow] - 1e-9
    partition = [len(res.workflow_records(w.name)) for w in c.workflows]
    assert sum(partition) == total
    assert set(res.workflows) == set(arrivals)
    if admission is None:
        assert res.admission_deferrals == 0


@settings(max_examples=12, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 5))
def test_single_workflow_campaign_matches_plain_run(g, seed):
    """A one-workflow campaign with admission off is the plain run:
    identical makespan and identical schedule modulo the name prefix."""
    opts = SimOptions(seed=seed)
    plain = simulate(g, make_pool("aggregate"), "async", options=opts)
    c = Campaign()
    c.add("solo", g)
    camp = simulate(c, make_pool("aggregate"), "async", options=opts)
    assert camp.makespan == plain.makespan
    strip = {(r.set_name.split("/", 1)[1], r.index): (r.start, r.end, r.pool)
             for r in camp.records}
    assert strip == {(r.set_name, r.index): (r.start, r.end, r.pool)
                     for r in plain.records}


@settings(max_examples=8, deadline=None)
@given(c=random_campaigns(), seed=st.integers(0, 3))
def test_campaign_deterministic_given_seed(c, seed):
    a = simulate(c, make_pool("aggregate"), "async",
                 options=SimOptions(seed=seed), scheduling="priority",
                 admission=AdmissionOptions())
    b = simulate(c, make_pool("aggregate"), "async",
                 options=SimOptions(seed=seed), scheduling="priority",
                 admission=AdmissionOptions())
    assert a.makespan == b.makespan
    assert a.admission_deferrals == b.admission_deferrals
    assert [(r.set_name, r.index, r.pool) for r in a.records] == \
        [(r.set_name, r.index, r.pool) for r in b.records]


@settings(max_examples=20, deadline=None)
@given(p_hi=st.integers(1, 5), p_lo=st.integers(0, 5), tx=st.integers(5, 50))
def test_priority_policy_orders_by_workflow_priority(p_hi, p_lo, tx):
    """Two single-set workflows on one slot: the higher-priority one
    always starts first under the ``priority`` policy."""
    if p_hi <= p_lo:
        p_hi = p_lo + 1
    c = Campaign()
    for name, pri in (("lo", p_lo), ("hi", p_hi)):
        g = DAG()
        g.add(TaskSet("only", 1, 2, 0, tx_mean=float(tx), tx_sigma=0.0))
        c.add(name, g, priority=pri)
    pool = PoolSpec("one", 1, NodeSpec(cpus=2, gpus=0))
    res = simulate(c, pool, "async",
                   options=SimOptions(seed=0, sample_tx=False,
                                      launch_latency=0.0),
                   scheduling="priority")
    starts = {r.workflow: r.start for r in res.records}
    assert starts["hi"] < starts["lo"]


@settings(max_examples=10, deadline=None)
@given(c=random_campaigns(), seed=st.integers(0, 3))
def test_workflow_stats_consistent_with_records(c, seed):
    """Per-workflow stats are exactly the fold of the trace, and the
    weighted slowdown recomputes from them."""
    # give every workflow a reference so slowdown is defined
    c.workflows = [dataclasses.replace(w, reference_makespan=100.0)
                   for w in c.workflows]
    res = simulate(c, make_pool("aggregate"), "async",
                   options=SimOptions(seed=seed))
    num = den = 0.0
    for w in c.workflows:
        recs = res.workflow_records(w.name)
        s = res.workflows[w.name]
        assert s.tasks == len(recs)
        assert s.start == min(r.start for r in recs)
        assert s.finish == max(r.end for r in recs)
        assert s.makespan == s.finish - s.start
        assert abs(s.turnaround - (s.finish - w.arrival)) < 1e-9
        num += s.weight * s.slowdown
        den += s.weight
    assert abs(res.weighted_slowdown() - num / den) < 1e-9


# ---------------------------------------------------------------------------
# 12-13: feedback bookkeeping + admission progress
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", POOL_MODES)
@settings(max_examples=8, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_feedback_preserves_task_accounting(mode, g, seed):
    """Feedback on vs off: identical task totals, and the estimator saw
    exactly one observation per completed task (no double counting)."""
    opts = SimOptions(seed=seed)
    base = simulate(g, make_pool(mode), "async", options=opts)
    fed = simulate(g, make_pool(mode), "async", options=opts,
                   feedback=FeedbackOptions(migrate=False))
    assert fed.tasks_total == base.tasks_total
    per_set = {}
    for r in fed.records:
        per_set[r.set_name] = per_set.get(r.set_name, 0) + 1
    assert per_set == {n: g.node(n).num_tasks for n in g.nodes}


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 9), arrival=st.integers(0, 4))
def test_admission_deferral_conserves_and_completes(seed, arrival):
    """A wide, long low-priority set behind a high-priority stream is
    deferred (the admission mechanism engages) yet still completes —
    deferral may reorder work but never strand it."""
    stream = DAG()
    prev = None
    for i in range(4):
        stream.add(TaskSet(f"S{i}", 4, 2, 1, tx_mean=10.0, tx_sigma=0.0))
        if prev is not None:
            stream.add_edge(prev, f"S{i}")
        prev = f"S{i}"
    wide = DAG()
    wide.add(TaskSet("W", 3, 2, 4, tx_mean=200.0, tx_sigma=0.0))
    c = Campaign()
    c.add("stream", stream, priority=1)
    c.add("wide", wide, priority=0, arrival=float(arrival * 5))
    pool = PoolSpec("p", 1, NodeSpec(cpus=16, gpus=4))
    res = simulate(c, pool, "async", options=SimOptions(seed=seed),
                   scheduling="priority", admission=AdmissionOptions())
    assert res.tasks_total == 19
    assert res.admission_deferrals >= 1
    # the wide set ran only after the stream's last wave began
    wide_start = min(r.start for r in res.workflow_records("wide"))
    stream_last = max(r.start for r in res.workflow_records("stream"))
    assert wide_start >= stream_last - 1e-9


# ---------------------------------------------------------------------------
# 14-15: incremental engine — indexes equal brute force, and the fast path
# is bit-identical to the scan path
# ---------------------------------------------------------------------------

def _drive_random_ops(engines, rng, after_step, max_steps=2000):
    """Drive engines in lockstep through random startable / migrate /
    speculate / arbitrate / complete sequences, calling ``after_step``
    after every mutation.  Returns once every engine is drained."""
    running = []
    for _ in range(max_steps):
        outs = [eng.startable() for eng in engines]
        assert all(o == outs[0] for o in outs[1:]), outs
        for name, i, _k in outs[0]:
            running.append((name, i))
        after_step()
        if not running:
            break
        idx = rng.randrange(len(running))
        name, i = running[idx]
        op = rng.randint(0, 3)
        rets = []
        for eng in engines:
            if op == 1:
                rets.append(eng.try_migrate(name, i))
            elif op == 2:
                rets.append(eng.try_speculate(name, i))
            elif op == 3:
                rets.append(eng.arbitrate(name, i, elapsed=13.7))
            else:
                rets.append(eng.complete(name, i))
        if op == 0:
            running.pop(idx)
        assert all(r == rets[0] for r in rets[1:]), (op, rets)
        after_step()
        if engines[0].done() and not running:
            break
    for (name, i) in running:
        rets = [eng.complete(name, i) for eng in engines]
        assert all(r == rets[0] for r in rets[1:]), rets
    after_step()
    for eng in engines:
        assert eng.done()


def _mitigation_engine(g, mode, policy, incremental=True):
    from repro.core import SchedEngine
    eng = SchedEngine(g, make_pool(mode), policy=policy,
                      feedback=FeedbackOptions(straggler_k=2.0,
                                               min_samples=1,
                                               speculate=True),
                      incremental=incremental)
    for n in g.nodes:
        eng.observe(n, g.node(n).tx_mean)
    return eng


@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(max_nodes=5), seed=st.integers(0, 5))
def test_incremental_indexes_match_brute_force(policy, mode, g, seed):
    """The incremental fit classes, free-block buckets, spread heap, and
    blocked-set tracking must equal a brute-force recount after EVERY
    mutation of a random acquire/release/migrate/speculate/complete
    sequence (``SchedEngine.check_index_integrity`` does the recount)."""
    import random as _random
    rng = _random.Random(seed)
    eng = _mitigation_engine(g, mode, policy)
    eng.check_index_integrity()
    _drive_random_ops([eng], rng, eng.check_index_integrity)


@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(max_nodes=5), seed=st.integers(0, 5))
def test_incremental_engine_bit_identical_to_scan(policy, mode, g, seed):
    """Incremental and brute-force-scan engines driven in lockstep emit
    the same dispatch decisions, mitigation outcomes, and placements at
    every step — the indexes change the cost of a pass, never its
    result."""
    import random as _random
    rng = _random.Random(seed)
    engines = [_mitigation_engine(g, mode, policy, incremental=inc)
               for inc in (True, False)]

    def same_placements():
        assert engines[0].node_of == engines[1].node_of
        assert engines[0].pool_of == engines[1].pool_of

    _drive_random_ops(engines, rng, same_placements)


# ---------------------------------------------------------------------------
# 16-19: fault tolerance — exactly-once under failure/recovery
# interleavings, no slot leak after node loss, conservation (failed is
# never lost), and faults-off bit-identity
# ---------------------------------------------------------------------------

from repro.core import FaultOptions, SchedEngine  # noqa: E402


def fault_storm(seed: int, replicate: bool = False) -> FaultOptions:
    """Stochastic node losses with recovery + software failures +
    checkpointing — every recovery mechanism can engage."""
    return FaultOptions(node_failure_rate=0.004, node_recovery_time=60.0,
                        task_failure_prob=0.15, seed=seed,
                        checkpoint_interval=5.0, checkpoint_write_cost=0.5,
                        checkpoint_read_cost=1.0, replicate=replicate)


@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3), replicate=st.booleans())
def test_exactly_once_under_faults(policy, mode, g, seed, replicate):
    """Random failure/recovery interleavings (node losses, software
    faults, promotions, checkpointed restarts): every task completes
    effectively exactly once — one non-duplicate record per task."""
    res = simulate(g, make_pool(mode), "async",
                   options=SimOptions(seed=seed), scheduling=policy,
                   faults=fault_storm(seed, replicate))
    total = sum(ts.num_tasks for ts in g.nodes.values())
    assert res.tasks_total == total
    prim = [(r.set_name, r.index) for r in res.records if not r.duplicate]
    assert len(prim) == total and len(set(prim)) == total
    for r in res.records:
        assert 0.0 <= r.start <= r.end


@pytest.mark.parametrize("mode", POOL_MODES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(max_nodes=5), seed=st.integers(0, 5),
       ops=st.lists(st.integers(0, 5), min_size=10, max_size=120))
def test_no_slot_leak_after_node_loss(mode, g, seed, ops):
    """Drive the engine through random dispatch / completion / node loss
    / recovery / software-failure / replication interleavings: the
    incremental indexes equal a brute-force recount after EVERY mutation
    (``check_index_integrity``), and once the DAG drains and every node
    is restored the pools are back to full capacity — no slot leaks."""
    import random as _random
    rng = _random.Random(seed)
    eng = SchedEngine(g, make_pool(mode), policy="gpu_bestfit",
                      faults=FaultOptions(node_failure_rate=1e-12,
                                          replicate=True,
                                          checkpoint_interval=5.0,
                                          checkpoint_write_cost=0.5,
                                          checkpoint_read_cost=1.0))
    for n in g.nodes:
        eng.observe(n, g.node(n).tx_mean)
    running: list = []
    down: list = []
    now = 0.0
    for op in ops:
        if eng.done():
            break
        now += 1.0
        for name, i, _k in eng.startable(now):
            running.append((name, i))
        eng.check_index_integrity()
        if op <= 1 and running:
            name, i = running.pop(rng.randrange(len(running)))
            eng.complete(name, i)
        elif op == 2:
            k = rng.randrange(len(eng.pools))
            node = rng.randrange(eng.pools[k].num_nodes)
            if eng.fail_node(k, node, now=now,
                             started=dict.fromkeys(running, 0.0)):
                down.append((k, node))
                running = [key for key in running if key in eng.launched]
        elif op == 3 and down:
            k, node = down.pop(rng.randrange(len(down)))
            eng.recover_node(k, node, now=now)
        elif op == 4 and running:
            name, i = running[rng.randrange(len(running))]
            ev = eng.fail_task(name, i, now=now,
                               elapsed=rng.uniform(0.0, 20.0))
            if ev is not None and ev.failed:
                running.remove((name, i))
        elif op == 5 and running:
            name, i = running[rng.randrange(len(running))]
            eng.try_replicate(name, i)
        eng.check_index_integrity()
    for name, i in running:
        eng.complete(name, i)
    for _ in range(2000):
        if eng.done():
            break
        started = eng.startable(now)
        assert started, "unfinished work with nothing startable"
        for name, i, _k in started:
            eng.complete(name, i)
    assert eng.done()
    for k, node in down:
        eng.recover_node(k, node, now=now)
    eng.check_index_integrity()
    for k, p in enumerate(eng.pools):
        assert eng.free_cpus[k] == p.total.cpus
        assert eng.free_gpus[k] == p.total.gpus


@settings(max_examples=8, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_conservation_failed_is_never_lost(g, seed):
    """Permanent (no-recovery) trace-driven node losses: the conservation
    guard refuses any loss that would strand work, so every task still
    completes — failed != lost, even when nodes never come back."""
    trace = tuple((10.0 * (j + 1), "p0", j % 2) for j in range(3)) \
        + ((25.0, "p1", 0),)
    res = simulate(g, make_pool("node_level"), "async",
                   options=SimOptions(seed=seed), scheduling="gpu_bestfit",
                   faults=FaultOptions(node_failure_trace=trace,
                                       task_failure_prob=0.1, seed=seed))
    total = sum(ts.num_tasks for ts in g.nodes.values())
    prim = {(r.set_name, r.index) for r in res.records if not r.duplicate}
    assert len(prim) == total
    assert res.node_failures <= len(trace)


@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=6, deadline=None)
@given(g=random_dags(), seed=st.integers(0, 3))
def test_disabled_faults_bit_identity(policy, g, seed):
    """``FaultOptions()`` (all rates zero) is indistinguishable from
    ``faults=None`` — bit-identical record tuples under stragglers +
    mitigation, and every fault counter zero."""
    opts = straggler_opts(seed)
    fb = _feedback("feedback")

    def trace(res):
        return [(r.set_name, r.index, r.start, r.end, r.pool, r.node)
                for r in res.records]

    plain = simulate(g, make_pool("node_level"), "async", options=opts,
                     scheduling=policy, feedback=fb)
    off = simulate(g, make_pool("node_level"), "async", options=opts,
                   scheduling=policy, feedback=fb, faults=FaultOptions())
    assert trace(off) == trace(plain)
    assert off.makespan == plain.makespan
    assert off.node_failures == 0 and off.task_failures == 0
    assert off.recoveries_restart == 0 and off.recoveries_rerun == 0


# ---------------------------------------------------------------------------
# streaming tenancy (PR 8): open streams, revocation, elastic leases
from repro.core import (CampaignStream, ElasticOptions, GeneratedStream,
                       RunConfig, StreamTemplate)


@st.composite
def random_streams(draw):
    """Seeded generated streams over small random-template workflows."""
    kind = draw(st.sampled_from(["poisson", "diurnal", "bursty"]))
    tmpls = []
    for t in range(draw(st.integers(1, 2))):
        g = draw(random_dags(max_nodes=3, max_tasks=3))
        tmpls.append(StreamTemplate(
            f"T{t}", g, priority=draw(st.integers(0, 2)),
            deadline_slack=draw(st.sampled_from([None, 300.0, 900.0])),
            reference_makespan=100.0))
    return GeneratedStream(tmpls, rate=1 / 80.0,
                           horizon=float(draw(st.integers(200, 600))),
                           seed=draw(st.integers(0, 9)), kind=kind)


@settings(max_examples=10, deadline=None)
@given(stream=random_streams(), seed=st.integers(0, 3),
       revoke=st.booleans())
def test_stream_conservation_and_exactly_once(stream, seed, revoke):
    """Open-stream runs conserve work: arrived == finished at the end,
    the stream partition sums, every arrived workflow's tasks run exactly
    once — revocation (which re-defers queued workflows) included."""
    stream.reset()
    r = simulate(stream, make_pool("node_level"),
                 options=SimOptions(seed=seed),
                 config=RunConfig(admission=AdmissionOptions(
                     deadline_aware=True, revoke=revoke)))
    s = r.stream
    assert s["arrived"] == len(stream.entries)
    assert s["arrived"] == (s["finished"] + s["admitted"]
                            + s["deferred"] + s["queued"])
    assert s["finished"] == s["arrived"]
    seen = {}
    for rec in r.records:
        key = (rec.workflow, rec.set_name, rec.index)
        seen[key] = seen.get(key, 0) + 1
    assert all(n == 1 for n in seen.values())
    for e in stream.entries:
        want = sum(ts.num_tasks for ts in e.dag.nodes.values())
        got = sum(1 for (wf, _n, _i) in seen if wf == e.name)
        assert got == want, e.name


@settings(max_examples=8, deadline=None)
@given(stream=random_streams(), seed=st.integers(0, 3))
def test_closed_stream_adapter_bit_identity(stream, seed):
    """Wrapping the same entries as a closed campaign and streaming it
    through ``CampaignStream`` reproduces the direct-campaign run
    bit-identically (records, makespan, per-workflow stats)."""
    entries = stream.entries
    if not entries:
        return
    camp = Campaign(entries, name="c")
    a = simulate(camp, make_pool("node_level"),
                 options=SimOptions(seed=seed),
                 config=RunConfig(admission=AdmissionOptions()))
    b = simulate(CampaignStream(camp), make_pool("node_level"),
                 options=SimOptions(seed=seed),
                 config=RunConfig(admission=AdmissionOptions()))
    assert a.records == b.records
    assert a.makespan == b.makespan
    assert a.workflows == b.workflows


@settings(max_examples=8, deadline=None)
@given(stream=random_streams(), seed=st.integers(0, 3),
       lease_term=st.sampled_from([120.0, 400.0]))
def test_elastic_leases_never_strand_or_lose_work(stream, seed, lease_term):
    """Under elastic capacity every arrived workflow still finishes
    (drain-before-retire: expiry never kills a placed task) and the lease
    ledger is consistent (expired <= granted, log events balanced)."""
    stream.reset()
    r = simulate(stream, make_pool("node_level"),
                 options=SimOptions(seed=seed),
                 config=RunConfig(
                     admission=AdmissionOptions(),
                     elastic=ElasticOptions(max_lease_nodes=2,
                                            lease_term=lease_term,
                                            grow_threshold=1.0,
                                            check_interval=40.0)))
    assert r.stream["finished"] == r.stream["arrived"]
    assert r.leases_expired <= r.leases_granted
    kinds = [ev for _t, ev, _n in r.lease_log]
    assert kinds.count("expire") == r.leases_expired
    assert kinds.count("grant") == r.leases_granted


# ---------------------------------------------------------------------------
# trace-scale hot loop (PR 9): the repredict throttle is placement-neutral
from repro.core import PredictOptions  # noqa: E402


@pytest.mark.parametrize("mode", POOL_MODES)
@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=4, deadline=None)
@given(stream=random_streams(), seed=st.integers(0, 2),
       min_interval=st.sampled_from([60.0, 500.0]),
       admission=st.booleans())
def test_prediction_throttle_is_placement_neutral(policy, mode, stream, seed,
                                                  min_interval, admission):
    """``PredictOptions`` throttling thins the prediction *trace* only:
    across every policy x pool mode (admission on/off, stragglers +
    speculation active), the dispatch sequence, makespan and per-workflow
    stats are bit-identical to the unthrottled run."""
    adm = AdmissionOptions() if admission else None
    fb = FeedbackOptions(straggler_k=2.0, min_samples=2, speculate=True)
    opts = straggler_opts(seed)
    base = simulate(stream, make_pool(mode), options=opts,
                    config=RunConfig(scheduling=policy, feedback=fb,
                                     admission=adm))
    thr = simulate(stream, make_pool(mode), options=opts,
                   config=RunConfig(scheduling=policy, feedback=fb,
                                    admission=adm,
                                    predict=PredictOptions(
                                        min_interval=min_interval)))
    assert thr.records == base.records
    assert thr.makespan == base.makespan
    assert thr.workflows == base.workflows
    assert len(thr.predictions) <= len(base.predictions)


@pytest.mark.parametrize("policy", ALL_POLICIES)
@settings(max_examples=4, deadline=None)
@given(g=random_dags(max_nodes=5), seed=st.integers(0, 3))
def test_runconfig_bit_identical_to_legacy_kwargs(policy, g, seed):
    """The RunConfig call form is purely mechanical sugar: legacy kwargs
    and the equivalent config produce bit-identical runs."""
    import warnings as _w
    opts = straggler_opts(seed)
    fb = _feedback("feedback")
    with _w.catch_warnings():
        _w.simplefilter("ignore", DeprecationWarning)
        a = simulate(g, make_pool("node_level"), "async", options=opts,
                     scheduling=policy, feedback=fb)
    b = simulate(g, make_pool("node_level"), "async", options=opts,
                 config=RunConfig(scheduling=policy, feedback=fb))
    assert a.records == b.records
    assert a.makespan == b.makespan

"""Edges of the fault-injection layer (``runtime/fault.py``) and the
checkpoint store (``checkpoint/store.py``) that the end-to-end suites
don't reach: the deterministic failure streams both substrates share,
FaultOptions validation, elastic-mesh shrink limits, crash-mid-write
artifacts, corrupt-archive fallback, and async-save completion ordering.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_latest,
                              save_pytree)
from repro.runtime.fault import (ElasticMesh, FailureSchedule, FaultOptions,
                                 ckpt_manager_latest)


# ---------------------------------------------------------------------------
# FaultOptions: validation + the enabled/disabled contract
# ---------------------------------------------------------------------------

def test_fault_options_rejects_unknown_recovery_policy():
    with pytest.raises(ValueError, match="unknown recovery policy"):
        FaultOptions(recovery="pray")


def test_fault_options_enabled_iff_something_can_fail():
    assert not FaultOptions().enabled
    assert not FaultOptions(checkpoint_interval=10.0,
                            node_recovery_time=5.0).enabled
    assert FaultOptions(node_failure_rate=0.1).enabled
    assert FaultOptions(task_failure_prob=0.1).enabled
    assert FaultOptions(node_failure_trace=((1.0, "p", 0),)).enabled


# ---------------------------------------------------------------------------
# FailureSchedule: the seeded substrate-independent failure streams
# ---------------------------------------------------------------------------

SITES = [(0, 2), (1, 1)]
NAMES = ["p0", "p1"]


def _drain(schedule, n=20):
    out = []
    for _ in range(n):
        ev = schedule.next_node_failure()
        if ev is None:
            break
        out.append(ev)
    return out


def test_node_failure_stream_deterministic():
    opts = FaultOptions(node_failure_rate=0.01, seed=7)
    a = _drain(FailureSchedule(opts, SITES, NAMES))
    b = _drain(FailureSchedule(opts, SITES, NAMES))
    assert a == b and len(a) == 20
    assert [t for t, _k, _n in a] == sorted(t for t, _k, _n in a)
    assert all((k, n) in [(0, 0), (0, 1), (1, 0)] for _t, k, n in a)
    # a different seed is a different stream
    c = _drain(FailureSchedule(
        FaultOptions(node_failure_rate=0.01, seed=8), SITES, NAMES))
    assert c != a


def test_trace_merged_with_stochastic_stream_in_time_order():
    trace = ((5.0, "p1", 0), (1e9, "p0", 1))
    opts = FaultOptions(node_failure_rate=0.001, seed=3,
                        node_failure_trace=trace)
    evs = _drain(FailureSchedule(opts, SITES, NAMES), n=50)
    assert [t for t, _k, _n in evs] == sorted(t for t, _k, _n in evs)
    assert (5.0, 1, 0) in evs  # pool name resolved to its index
    # trace-only schedule: exactly the trace, then exhausted
    only = FailureSchedule(FaultOptions(node_failure_trace=trace),
                           SITES, NAMES)
    assert _drain(only) == [(5.0, 1, 0), (1e9, 0, 1)]
    assert only.next_node_failure() is None


def test_trace_with_unknown_pool_rejected():
    opts = FaultOptions(node_failure_trace=((1.0, "nope", 0),))
    with pytest.raises(ValueError, match="unknown pool"):
        FailureSchedule(opts, SITES, NAMES)


def test_attempt_failure_draws_deterministic_and_bounded():
    opts = FaultOptions(task_failure_prob=0.5, seed=11)
    s1 = FailureSchedule(opts, SITES, NAMES)
    s2 = FailureSchedule(opts, SITES, NAMES)
    draws = [(name, i, a, s1.attempt_failure(name, i, a))
             for name in ("T0", "T36") for i in range(8) for a in range(3)]
    # substrate-independent: a second schedule (any call order) agrees
    for name, i, a, frac in reversed(draws):
        assert s2.attempt_failure(name, i, a) == frac
    fracs = [f for _n, _i, _a, f in draws if f is not None]
    assert fracs and all(0.05 <= f <= 0.95 for f in fracs)
    assert any(f is None for _n, _i, _a, f in draws)


def test_attempt_failure_runaway_guard_and_off_switch():
    opts = FaultOptions(task_failure_prob=1.0, max_task_retries=3, seed=0)
    s = FailureSchedule(opts, SITES, NAMES)
    # certain failure up to the retry cap, certain success past it
    assert all(s.attempt_failure("T", 0, a) is not None for a in range(3))
    assert s.attempt_failure("T", 0, 3) is None
    off = FailureSchedule(FaultOptions(node_failure_rate=0.1), SITES, NAMES)
    assert off.attempt_failure("T", 0, 0) is None


# ---------------------------------------------------------------------------
# ElasticMesh: shrink limits
# ---------------------------------------------------------------------------

def test_elastic_mesh_refuses_partial_model_replica():
    em = ElasticMesh(model_axis=4, devices=tuple(range(8)))
    assert em.usable(8) == (2, 4)
    assert em.usable(7) == (1, 4)  # a partial data row is dropped
    with pytest.raises(RuntimeError, match="not enough devices"):
        em.usable(3)  # survivors < model_axis: no full parameter shard set


# ---------------------------------------------------------------------------
# checkpoint store: crash artifacts, corruption fallback, async ordering
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": np.full(4, float(v)), "opt": {"m": np.arange(3.0) + v}}


def test_restore_latest_missing_and_empty_dir(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
    assert restore_latest(_tree(0), str(tmp_path / "nope")) is None
    os.makedirs(tmp_path / "empty")
    assert restore_latest(_tree(0), str(tmp_path / "empty")) is None


def test_crash_mid_write_artifact_never_restored(tmp_path):
    """A crash between the tmp write and the rename leaves ``tmp.<step>``
    (and possibly a complete ``tmp.<step>.npz`` never renamed): neither
    counts as a restorable checkpoint."""
    d = str(tmp_path / "ck")
    save_pytree(_tree(1), d, 1)
    with open(os.path.join(d, "tmp.2"), "wb") as f:
        f.write(b"partial")
    # a finished-but-unrenamed tmp archive with DIFFERENT content
    np.savez(os.path.join(d, "tmp.3"), leaf_0=np.zeros(4))
    assert latest_step(d) == 1
    step, tree = restore_latest(_tree(0), d)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), _tree(1)["w"])


def test_corrupt_newest_archive_falls_back_to_older_step(tmp_path):
    d = str(tmp_path / "ck")
    save_pytree(_tree(1), d, 1)
    save_pytree(_tree(2), d, 2)
    # step 3 finalized but truncated on disk (e.g. the node died during
    # fsync): restore must skip it and land on step 2
    with open(os.path.join(d, "step_00000003.npz"), "wb") as f:
        f.write(b"\x00" * 16)
    assert latest_step(d) == 3  # it *looks* newest...
    step, tree = restore_latest(_tree(0), d)  # ...but cannot be read
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["opt"]["m"]),
                                  _tree(2)["opt"]["m"])


def test_all_archives_corrupt_returns_none(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    with open(os.path.join(d, "step_00000005.npz"), "wb") as f:
        f.write(b"junk")
    assert restore_latest(_tree(0), d) is None


def test_async_save_completes_before_restore(tmp_path):
    """The manager's background save must be awaited before a restore:
    ``ckpt_manager_latest`` (the restart loop's lookup) calls ``wait()``,
    so the step it reports is always fully on disk."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, interval=1, max_keep=2)
    assert ckpt_manager_latest(mgr) is None
    for s in range(4):
        assert mgr.maybe_save(_tree(s), s)
    latest = ckpt_manager_latest(mgr)  # waits for the in-flight save
    assert latest == 3
    step, tree = restore_latest(_tree(0), d)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(tree["w"]), _tree(3)["w"])
    mgr.close()
    # max_keep GC ran inside the worker thread
    steps = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(steps) == 2


def test_maybe_save_skips_off_interval_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), interval=5)
    assert not mgr.maybe_save(_tree(1), 3)
    assert mgr.maybe_save(_tree(2), 5)
    mgr.close()
    assert latest_step(str(tmp_path / "ck")) == 5


# ---------------------------------------------------------------------------
# run_resilient: the generic restart loop end-to-end
# ---------------------------------------------------------------------------

def test_run_resilient_restarts_from_latest_checkpoint(tmp_path):
    """Seeded failures mid-loop: the loop rebuilds, restores the newest
    complete snapshot, and still reaches exactly ``total_steps`` effective
    steps (restarts re-pay only the work since the last checkpoint)."""
    from repro.checkpoint import restore_pytree
    from repro.runtime.fault import FailureInjector, run_resilient

    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, interval=2, max_keep=3)
    template = {"w": np.zeros(1)}

    def step_fn(state, s):
        return {"w": state["w"] + 1.0}

    def build(lost):
        return step_fn, None  # re-lowering is a no-op on this toy state

    state, history = run_resilient(
        total_steps=30, build=build,
        step_fn_state=(step_fn, {"w": np.zeros(1)}),
        injector=FailureInjector(rate=0.3, seed=9),
        ckpt_manager=mgr,
        restore=lambda step: restore_pytree(template, d, step),
        start_step=0)
    mgr.close()
    # bit-deterministic across restarts: exactly 30 effective steps
    assert float(np.asarray(state["w"])[0]) == 30.0
    assert history["failures"] > 0
    assert len(history["restarts"]) == history["failures"]

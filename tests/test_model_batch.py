"""Batch Eqn. 2-6 evaluation (``core/model_batch.py``) vs the scalar
reference implementations in ``core/model.py``.

The NumPy backend must be *bit-identical* to the scalar evaluators (same
float64 operations in the same order); the jax backend is the same index
program at jax's configured precision, so it gets a float32-scale
tolerance.  Both are checked over the repo's whole workflow zoo and over
randomized TX batches.
"""

import numpy as np
import pytest

from repro.core import (BatchEqns, async_ttx, cdg_dag, deepdrivemd_dag,
                        fig2a_chain, fig2b_fork, fig2b_with_paper_tx,
                        fig2d_independent, jax_available, sequential_ttx,
                        staggered_async_ttx, staggered_async_ttx_batch)

ZOO = {
    "fig2a": fig2a_chain,
    "fig2b": fig2b_fork,
    "fig2b_paper": fig2b_with_paper_tx,
    "fig2d": fig2d_independent,
    "cdg1": lambda: cdg_dag("c-DG1"),
    "cdg2": lambda: cdg_dag("c-DG2"),
    "ddmd": deepdrivemd_dag,
}


def _tx_batch(g, rows=8, seed=0):
    """Static priors + ``rows`` random perturbations of them."""
    rng = np.random.default_rng(seed)
    return [None] + [
        {n: g.node(n).tx_mean * float(rng.uniform(0.5, 2.0))
         for n in g.topological_order()}
        for _ in range(rows)]


@pytest.mark.parametrize("name", sorted(ZOO))
def test_numpy_backend_bit_identical(name):
    g = ZOO[name]()
    be = BatchEqns(g)
    assert be.backend == "numpy"
    txs = _tx_batch(g)
    t_seq, t_async, imp = be.evaluate(be.pack(txs))
    ref_seq = np.array([sequential_ttx(g, tx=tx) for tx in txs])
    ref_async = np.array([async_ttx(g, tx=tx)[0] for tx in txs])
    assert np.array_equal(t_seq, ref_seq)
    assert np.array_equal(t_async, ref_async)
    assert np.array_equal(imp, 1.0 - ref_async / ref_seq)


@pytest.mark.parametrize("name", sorted(ZOO))
def test_jax_backend_matches(name):
    if not jax_available():
        pytest.skip("jax not importable in this environment")
    g = ZOO[name]()
    be = BatchEqns(g, backend="jax")
    txs = _tx_batch(g)
    t_seq, t_async, _ = be.evaluate(be.pack(txs))
    ref_seq = np.array([sequential_ttx(g, tx=tx) for tx in txs])
    ref_async = np.array([async_ttx(g, tx=tx)[0] for tx in txs])
    assert np.allclose(t_seq, ref_seq, rtol=1e-5)
    assert np.allclose(t_async, ref_async, rtol=1e-5)


def test_auto_backend_resolves():
    g = fig2b_fork()
    be = BatchEqns(g, backend="auto")
    assert be.backend == ("jax" if jax_available() else "numpy")
    with pytest.raises(ValueError):
        BatchEqns(g, backend="tpu")


def test_single_branch_falls_back_to_sequential():
    g = fig2a_chain()
    be = BatchEqns(g)
    assert be.n_branches == 1
    txs = be.pack(_tx_batch(g, rows=4, seed=1))
    t_seq, t_async, imp = be.evaluate(txs)
    assert np.array_equal(t_seq, t_async)
    assert np.array_equal(imp, np.zeros_like(imp))


def test_overheads_and_iterations():
    g = fig2b_fork()
    be = BatchEqns(g)
    txs = be.pack([None])
    assert be.sequential_ttx(txs, overhead_c=7.0, n_iterations=3)[0] == (
        sequential_ttx(g, overhead_c=0.0, n_iterations=3) + 7.0)
    assert be.async_ttx(txs, overhead_c=5.0)[0] == (
        async_ttx(g, overhead_c=5.0)[0])


def test_pack_column_order_covers_every_set():
    g = cdg_dag("c-DG2")
    be = BatchEqns(g)
    assert sorted(be.names) == sorted(g.topological_order())
    # pack accepts mappings, callables, and None interchangeably
    fn_row = be.pack([lambda n: 2.0])[0]
    assert np.array_equal(fn_row, np.full(len(be.names), 2.0))


def test_shape_validation():
    be = BatchEqns(fig2b_fork())
    with pytest.raises(ValueError):
        be.evaluate(np.zeros((2, len(be.names) + 1)))


def test_staggered_batch_matches_scalar():
    rng = np.random.default_rng(3)
    st = rng.uniform(1, 10, size=(16, 4))
    mask = [False, True, True, False]
    got = staggered_async_ttx_batch(st, 3, mask, overhead_c=1.5)
    ref = np.array([staggered_async_ttx(list(r), 3, mask, overhead_c=1.5)
                    for r in st])
    assert np.allclose(got, ref, rtol=0, atol=1e-9)
    with pytest.raises(ValueError):
        staggered_async_ttx_batch(st, 3, [True])

"""Shared scheduling engine: policy semantics, heterogeneous multi-pool
placement, and simulator-vs-RealExecutor equivalence (both substrates
dispatch through the same SchedEngine, so their schedules must agree)."""

import pytest

from repro.core import (DAG, Allocation, ExecutionPolicy, NodeSpec, PoolSpec,
                        RealExecutor, SchedEngine, SimOptions, TaskSet,
                        fig2a_chain, fig2b_fork, fig2d_independent,
                        get_scheduling_policy, gpu_bestfit_policy, lpt_policy,
                        simulate)

ALL_POLICIES = ("fifo", "lpt", "gpu_bestfit")


def _no_noise():
    return SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)


def _hybrid():
    return Allocation("hyb", (
        PoolSpec("gpu", num_nodes=1, node=NodeSpec(cpus=8, gpus=4),
                 oversubscribe_cpus=True),
        PoolSpec("cpu", num_nodes=1, node=NodeSpec(cpus=16, gpus=0)),
    ))


# ---------------------------------------------------------------------------
# policy registry + priority-order semantics
# ---------------------------------------------------------------------------

def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_scheduling_policy("nope")
    with pytest.raises(ValueError):
        simulate(fig2a_chain(2), PoolSpec("p", 1, NodeSpec(4, 0)),
                 scheduling="nope")


def test_unplaceable_task_set_rejected():
    g = DAG()
    g.add(TaskSet("huge", 1, 1, 99, tx_mean=1.0))
    with pytest.raises(ValueError, match="fits no pool"):
        SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=4)))


def test_fifo_runs_in_rank_order():
    """Two independent single-GPU sets on one GPU slot: fifo keeps topo
    (alphabetical-source) order regardless of duration."""
    g = DAG()
    g.add(TaskSet("ashort", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("blong", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("one-gpu", 1, NodeSpec(cpus=8, gpus=1))
    res = simulate(g, pool, "async", options=_no_noise(), scheduling="fifo")
    starts = {r.set_name: r.start for r in res.records}
    assert starts["ashort"] < starts["blong"]


def test_lpt_runs_largest_tx_first():
    g = DAG()
    g.add(TaskSet("ashort", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("blong", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("one-gpu", 1, NodeSpec(cpus=8, gpus=1))
    res = simulate(g, pool, "async", options=_no_noise(), scheduling="lpt")
    starts = {r.set_name: r.start for r in res.records}
    assert starts["blong"] < starts["ashort"]
    assert res.policy == "lpt"


def test_gpu_bestfit_prioritises_gpu_sets():
    """One free GPU + one free CPU slot, a GPU set and a CPU set both
    ready: gpu_bestfit offers resources to the GPU set first."""
    g = DAG()
    g.add(TaskSet("acpu", 1, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("bgpu", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    engine = SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=1)),
                         policy="gpu_bestfit")
    order = [name for name, _, _ in engine.startable()]
    assert order == ["bgpu", "acpu"]


# ---------------------------------------------------------------------------
# heterogeneous multi-pool placement
# ---------------------------------------------------------------------------

def test_gpu_bestfit_packs_cpu_tasks_on_cpu_pool():
    g = DAG()
    g.add(TaskSet("gputask", 4, 1, 1, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("cputask", 4, 4, 0, tx_mean=5.0, tx_sigma=0.0))
    res = simulate(g, _hybrid(), "async", options=_no_noise(),
                   scheduling="gpu_bestfit")
    by_set = {}
    for r in res.records:
        by_set.setdefault(r.set_name, set()).add(r.pool)
    assert by_set["gputask"] == {"gpu"}
    assert by_set["cputask"] == {"cpu"}


def test_per_pool_gpu_capacity_respected():
    """Reconstruct per-pool concurrent GPU usage from the schedule: no
    pool may ever exceed its own capacity (aggregate fit is not enough)."""
    g = DAG()
    g.add(TaskSet("gputask", 16, 1, 1, tx_mean=5.0, tx_sigma=0.0))
    alloc = Allocation("two", (
        PoolSpec("g1", 1, NodeSpec(cpus=8, gpus=2)),
        PoolSpec("g2", 1, NodeSpec(cpus=8, gpus=3)),
    ))
    for policy in ALL_POLICIES:
        res = simulate(g, alloc, "async", options=_no_noise(),
                       scheduling=policy)
        cap = {"g1": 2, "g2": 3}
        for pool_name in cap:
            events = []
            for r in res.records:
                if r.pool == pool_name:
                    events.append((r.start, r.gpus))
                    events.append((r.end, -r.gpus))
            events.sort()
            in_use = 0
            for _, d in events:
                in_use += d
                assert in_use <= cap[pool_name], (policy, pool_name)
        assert res.tasks_total == 16


def test_only_kinds_constraint_restricts_placement():
    alloc = Allocation("constrained", (
        PoolSpec("anykind", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("aggonly", 1, NodeSpec(cpus=16, gpus=0),
                 only_kinds=("aggregation",)),
    ))
    g = DAG()
    g.add(TaskSet("agg", 4, 4, 0, tx_mean=2.0, tx_sigma=0.0,
                  kind="aggregation"))
    g.add(TaskSet("gen", 4, 4, 0, tx_mean=2.0, tx_sigma=0.0))
    res = simulate(g, alloc, "async", options=_no_noise())
    for r in res.records:
        if r.set_name == "gen":
            assert r.pool == "anykind"  # generic work may not use aggonly
    # generic tasks only fit one at a time -> they serialise
    gen = sorted(r.start for r in res.records if r.set_name == "gen")
    assert gen == sorted(set(gen))


def test_hybrid_allocation_end_to_end_executor():
    g = DAG()
    g.add(TaskSet("gputask", 3, 1, 1, tx_mean=0.05, tx_sigma=0.0))
    g.add(TaskSet("cputask", 3, 4, 0, tx_mean=0.05, tx_sigma=0.0))
    res = RealExecutor(_hybrid()).run(g, "async", scheduling="gpu_bestfit")
    counts = res.per_pool_task_counts()
    assert counts.get("cpu") == 3 and counts.get("gpu") == 3


# ---------------------------------------------------------------------------
# async vs sequential invariants (Fig. 2 DGs, every policy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("build", [fig2a_chain, fig2b_fork,
                                   fig2d_independent])
def test_async_never_slower_than_sequential_fig2(build, policy):
    g = build()
    pool = PoolSpec("p", 4, NodeSpec(cpus=16, gpus=0))
    opts = _no_noise()
    rs = simulate(g, pool, "sequential", options=opts, scheduling=policy)
    ra = simulate(g, pool, "async", options=opts, scheduling=policy)
    assert ra.makespan <= rs.makespan * (1 + 1e-9)
    assert ra.tasks_total == rs.tasks_total


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_dependencies_respected_under_all_policies(policy):
    from repro.core import cdg_dag, summit_pool
    g = cdg_dag("c-DG2")
    res = simulate(g, summit_pool(), "async", options=_no_noise(),
                   scheduling=policy)
    end_of_set, start_of_set = {}, {}
    for r in res.records:
        end_of_set[r.set_name] = max(end_of_set.get(r.set_name, 0.0), r.end)
        start_of_set[r.set_name] = min(start_of_set.get(r.set_name, 1e18),
                                       r.start)
    for u, v in g.edges():
        assert start_of_set[v] >= end_of_set[u] - 1e-9, (policy, u, v)


# ---------------------------------------------------------------------------
# simulator vs RealExecutor equivalence (the shared-engine guarantee)
# ---------------------------------------------------------------------------

def _equiv_dag():
    """Two branches + a join; enough structure for order to matter."""
    g = DAG()
    g.add(TaskSet("a0", 2, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    g.add(TaskSet("b1", 2, 1, 1, tx_mean=150.0, tx_sigma=0.0))
    g.add(TaskSet("b2", 2, 2, 0, tx_mean=100.0, tx_sigma=0.0))
    g.add(TaskSet("c3", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    g.add_edge("a0", "b1")
    g.add_edge("a0", "b2")
    g.add_edge("b1", "c3")
    g.add_edge("b2", "c3")
    return g


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_simulator_matches_real_executor(policy):
    """Same DAG + same policy through both substrates: the real executor's
    wall-clock makespan (at tx_scale) must agree with the simulated one."""
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    tx_scale = 1.5e-3  # 100 modelled s -> 0.15 wall s
    sim = simulate(g, pool, "async", options=_no_noise(), scheduling=policy)
    ex = RealExecutor(pool, tx_scale=tx_scale)
    real = ex.run(g, "async", scheduling=policy)
    assert real.tasks_total == sim.tasks_total
    expected = sim.makespan * tx_scale
    # thread wakeup/dispatch overhead only ever lengthens the real run
    assert real.makespan >= expected * 0.9
    assert real.makespan <= expected * 1.35 + 0.15, (policy, real.makespan,
                                                     expected)


def test_execution_policy_carries_scheduling_to_both_substrates():
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    pol = lpt_policy()
    sim = pol.simulate(g, pool, options=_no_noise())
    assert sim.policy == "lpt"
    real = pol.execute(g, RealExecutor(pool, tx_scale=1e-4))
    assert real.policy == "lpt"
    assert sim.tasks_total == real.tasks_total
    pol2 = ExecutionPolicy().with_scheduling("gpu_bestfit")
    assert pol2.simulate(g, pool, options=_no_noise()).policy == "gpu_bestfit"
    assert gpu_bestfit_policy().scheduling == "gpu_bestfit"

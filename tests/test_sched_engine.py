"""Shared scheduling engine: policy semantics, heterogeneous multi-pool
placement, locality-aware placement + bounded work stealing, and
simulator-vs-RealExecutor equivalence (both substrates dispatch through
the same SchedEngine, so their schedules must agree — with and without
runtime feedback)."""

import pytest

from repro.core import (DAG, AdmissionOptions, Allocation, Campaign,
                        ExecutionPolicy, FeedbackOptions, LocalityAware,
                        NodeSpec, PoolSpec, RealExecutor, SchedEngine,
                        SimOptions, TaskSet, fig2a_chain, fig2b_fork,
                        fig2d_independent, get_scheduling_policy,
                        gpu_bestfit_policy, lpt_policy, priority_policy,
                        simulate)

ALL_POLICIES = ("fifo", "lpt", "gpu_bestfit", "locality")


def _no_noise():
    return SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)


def _hybrid():
    return Allocation("hyb", (
        PoolSpec("gpu", num_nodes=1, node=NodeSpec(cpus=8, gpus=4),
                 oversubscribe_cpus=True),
        PoolSpec("cpu", num_nodes=1, node=NodeSpec(cpus=16, gpus=0)),
    ))


# ---------------------------------------------------------------------------
# policy registry + priority-order semantics
# ---------------------------------------------------------------------------

def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_scheduling_policy("nope")
    with pytest.raises(ValueError):
        simulate(fig2a_chain(2), PoolSpec("p", 1, NodeSpec(4, 0)),
                 scheduling="nope")


def test_unplaceable_task_set_rejected():
    g = DAG()
    g.add(TaskSet("huge", 1, 1, 99, tx_mean=1.0))
    with pytest.raises(ValueError, match="fits no pool"):
        SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=4)))


def test_fifo_runs_in_rank_order():
    """Two independent single-GPU sets on one GPU slot: fifo keeps topo
    (alphabetical-source) order regardless of duration."""
    g = DAG()
    g.add(TaskSet("ashort", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("blong", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("one-gpu", 1, NodeSpec(cpus=8, gpus=1))
    res = simulate(g, pool, "async", options=_no_noise(), scheduling="fifo")
    starts = {r.set_name: r.start for r in res.records}
    assert starts["ashort"] < starts["blong"]


def test_lpt_runs_largest_tx_first():
    g = DAG()
    g.add(TaskSet("ashort", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("blong", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("one-gpu", 1, NodeSpec(cpus=8, gpus=1))
    res = simulate(g, pool, "async", options=_no_noise(), scheduling="lpt")
    starts = {r.set_name: r.start for r in res.records}
    assert starts["blong"] < starts["ashort"]
    assert res.policy == "lpt"


def test_gpu_bestfit_prioritises_gpu_sets():
    """One free GPU + one free CPU slot, a GPU set and a CPU set both
    ready: gpu_bestfit offers resources to the GPU set first."""
    g = DAG()
    g.add(TaskSet("acpu", 1, 1, 0, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("bgpu", 1, 1, 1, tx_mean=10.0, tx_sigma=0.0))
    engine = SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=1)),
                         policy="gpu_bestfit")
    order = [name for name, _, _ in engine.startable()]
    assert order == ["bgpu", "acpu"]


# ---------------------------------------------------------------------------
# heterogeneous multi-pool placement
# ---------------------------------------------------------------------------

def test_gpu_bestfit_packs_cpu_tasks_on_cpu_pool():
    g = DAG()
    g.add(TaskSet("gputask", 4, 1, 1, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("cputask", 4, 4, 0, tx_mean=5.0, tx_sigma=0.0))
    res = simulate(g, _hybrid(), "async", options=_no_noise(),
                   scheduling="gpu_bestfit")
    by_set = {}
    for r in res.records:
        by_set.setdefault(r.set_name, set()).add(r.pool)
    assert by_set["gputask"] == {"gpu"}
    assert by_set["cputask"] == {"cpu"}


def test_per_pool_gpu_capacity_respected():
    """Reconstruct per-pool concurrent GPU usage from the schedule: no
    pool may ever exceed its own capacity (aggregate fit is not enough)."""
    g = DAG()
    g.add(TaskSet("gputask", 16, 1, 1, tx_mean=5.0, tx_sigma=0.0))
    alloc = Allocation("two", (
        PoolSpec("g1", 1, NodeSpec(cpus=8, gpus=2)),
        PoolSpec("g2", 1, NodeSpec(cpus=8, gpus=3)),
    ))
    for policy in ALL_POLICIES:
        res = simulate(g, alloc, "async", options=_no_noise(),
                       scheduling=policy)
        cap = {"g1": 2, "g2": 3}
        for pool_name in cap:
            events = []
            for r in res.records:
                if r.pool == pool_name:
                    events.append((r.start, r.gpus))
                    events.append((r.end, -r.gpus))
            events.sort()
            in_use = 0
            for _, d in events:
                in_use += d
                assert in_use <= cap[pool_name], (policy, pool_name)
        assert res.tasks_total == 16


def test_only_kinds_constraint_restricts_placement():
    alloc = Allocation("constrained", (
        PoolSpec("anykind", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("aggonly", 1, NodeSpec(cpus=16, gpus=0),
                 only_kinds=("aggregation",)),
    ))
    g = DAG()
    g.add(TaskSet("agg", 4, 4, 0, tx_mean=2.0, tx_sigma=0.0,
                  kind="aggregation"))
    g.add(TaskSet("gen", 4, 4, 0, tx_mean=2.0, tx_sigma=0.0))
    res = simulate(g, alloc, "async", options=_no_noise())
    for r in res.records:
        if r.set_name == "gen":
            assert r.pool == "anykind"  # generic work may not use aggonly
    # generic tasks only fit one at a time -> they serialise
    gen = sorted(r.start for r in res.records if r.set_name == "gen")
    assert gen == sorted(set(gen))


def test_hybrid_allocation_end_to_end_executor():
    g = DAG()
    g.add(TaskSet("gputask", 3, 1, 1, tx_mean=0.05, tx_sigma=0.0))
    g.add(TaskSet("cputask", 3, 4, 0, tx_mean=0.05, tx_sigma=0.0))
    res = RealExecutor(_hybrid()).run(g, "async", scheduling="gpu_bestfit")
    counts = res.per_pool_task_counts()
    assert counts.get("cpu") == 3 and counts.get("gpu") == 3


# ---------------------------------------------------------------------------
# async vs sequential invariants (Fig. 2 DGs, every policy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("build", [fig2a_chain, fig2b_fork,
                                   fig2d_independent])
def test_async_never_slower_than_sequential_fig2(build, policy):
    g = build()
    pool = PoolSpec("p", 4, NodeSpec(cpus=16, gpus=0))
    opts = _no_noise()
    rs = simulate(g, pool, "sequential", options=opts, scheduling=policy)
    ra = simulate(g, pool, "async", options=opts, scheduling=policy)
    assert ra.makespan <= rs.makespan * (1 + 1e-9)
    assert ra.tasks_total == rs.tasks_total


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_dependencies_respected_under_all_policies(policy):
    from repro.core import cdg_dag, summit_pool
    g = cdg_dag("c-DG2")
    res = simulate(g, summit_pool(), "async", options=_no_noise(),
                   scheduling=policy)
    end_of_set, start_of_set = {}, {}
    for r in res.records:
        end_of_set[r.set_name] = max(end_of_set.get(r.set_name, 0.0), r.end)
        start_of_set[r.set_name] = min(start_of_set.get(r.set_name, 1e18),
                                       r.start)
    for u, v in g.edges():
        assert start_of_set[v] >= end_of_set[u] - 1e-9, (policy, u, v)


# ---------------------------------------------------------------------------
# locality policy: data-movement-aware placement + bounded work stealing
# ---------------------------------------------------------------------------

def _transfer_alloc(transfer=50.0, cpus0=4, cpus1=4, pin_parents=False):
    """Two CPU pools with a symmetric transfer cost.  ``pin_parents``
    restricts p1 to kind="child" tasks so "parent" sets must run on p0
    (giving the children a definite data-local pool)."""
    return Allocation("tc", (
        PoolSpec("p0", 1, NodeSpec(cpus=cpus0, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=cpus1, gpus=0),
                 only_kinds=("child",) if pin_parents else None),
    ), transfer_cost=((0.0, transfer), (transfer, 0.0)))


def _parent_child(child_tasks=2):
    g = DAG()
    g.add(TaskSet("parent", 2, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add(TaskSet("child", child_tasks, 2, 0, tx_mean=5.0, tx_sigma=0.0,
                  kind="child"))
    g.add_edge("parent", "child")
    return g


def _run_parents(eng):
    done = 0
    while done < 2:
        for name, i, k in eng.startable():
            assert (name, k) == ("parent", 0)
            eng.complete(name, i)
            done += 1


def test_locality_places_child_with_parent_data():
    """Both pools free, parents ran on p0, transfer cost is steep: every
    child task must land on p0 (a steal would pay 50 s for nothing)."""
    g = _parent_child(child_tasks=2)
    eng = SchedEngine(g, _transfer_alloc(transfer=50.0, pin_parents=True),
                      policy="locality")
    _run_parents(eng)
    started = eng.startable()
    assert [(n, k) for n, _i, k in started] == [("child", 0), ("child", 0)]
    assert eng.data_cost("child", 0) == 0.0
    assert eng.data_cost("child", 1) == 50.0


def test_locality_steals_within_budget_then_defers():
    """p0 holds the parents' data but fits one child at a time; with
    steal_budget=1 exactly one child may be stolen by idle p1 per pass,
    the rest defer."""
    g = _parent_child(child_tasks=4)
    alloc = _transfer_alloc(transfer=50.0, cpus0=2, cpus1=8,
                            pin_parents=True)
    pol = LocalityAware(steal_budget=1)
    eng = SchedEngine(g, alloc, policy=pol)
    _run_parents(eng)
    started = eng.startable()
    pools = sorted(k for _n, _i, k in started)
    # one child on local p0, exactly one stolen onto p1, two deferred
    assert pools == [0, 1]
    assert len(eng.ready["child"]) == 2


def test_locality_zero_budget_waits_for_local_pool():
    g = _parent_child(child_tasks=2)
    alloc = _transfer_alloc(transfer=50.0, cpus0=2, cpus1=8,
                            pin_parents=True)
    pol = LocalityAware(steal_budget=0)
    eng = SchedEngine(g, alloc, policy=pol)
    _run_parents(eng)
    first = eng.startable()
    assert [(n, k) for n, _i, k in first] == [("child", 0)]
    assert eng.startable() == []               # second child holds for p0
    eng.complete("child", first[0][1])
    assert [(n, k) for n, _i, k in eng.startable()] == [("child", 0)]


def test_locality_without_transfer_matrix_is_load_balancing():
    """No transfer_cost: the score degenerates to queue depth, so 4
    identical tasks spread 2+2 over two equal pools."""
    g = DAG()
    g.add(TaskSet("s", 4, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    alloc = Allocation("flat", (
        PoolSpec("p0", 1, NodeSpec(cpus=4, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=4, gpus=0)),
    ))
    eng = SchedEngine(g, alloc, policy="locality")
    pools = sorted(k for _n, _i, k in eng.startable())
    assert pools == [0, 0, 1, 1]


def test_locality_end_to_end_simulation_completes():
    from repro.core import cdg_dag, summit_pool
    import dataclasses
    half = summit_pool(8)
    alloc = Allocation("split", (
        dataclasses.replace(half, name="s1"),
        dataclasses.replace(half, name="s2"),
    ), transfer_cost=((0.0, 5.0), (5.0, 0.0)))
    res = simulate(cdg_dag("c-DG2"), alloc, "async", options=_no_noise(),
                   scheduling="locality")
    # placement-constrained but complete and dependency-correct
    assert res.tasks_total == sum(
        ts.num_tasks for ts in cdg_dag("c-DG2").nodes.values())


# ---------------------------------------------------------------------------
# simulator vs RealExecutor equivalence (the shared-engine guarantee)
# ---------------------------------------------------------------------------

def _equiv_dag():
    """Two branches + a join; enough structure for order to matter."""
    g = DAG()
    g.add(TaskSet("a0", 2, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    g.add(TaskSet("b1", 2, 1, 1, tx_mean=150.0, tx_sigma=0.0))
    g.add(TaskSet("b2", 2, 2, 0, tx_mean=100.0, tx_sigma=0.0))
    g.add(TaskSet("c3", 1, 1, 1, tx_mean=100.0, tx_sigma=0.0))
    g.add_edge("a0", "b1")
    g.add_edge("a0", "b2")
    g.add_edge("b1", "c3")
    g.add_edge("b2", "c3")
    return g


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_simulator_matches_real_executor(policy):
    """Same DAG + same policy through both substrates: the real executor's
    wall-clock makespan (at tx_scale) must agree with the simulated one."""
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    tx_scale = 1.5e-3  # 100 modelled s -> 0.15 wall s
    sim = simulate(g, pool, "async", options=_no_noise(), scheduling=policy)
    ex = RealExecutor(pool, tx_scale=tx_scale)
    real = ex.run(g, "async", scheduling=policy)
    assert real.tasks_total == sim.tasks_total
    expected = sim.makespan * tx_scale
    # thread wakeup/dispatch overhead only ever lengthens the real run
    assert real.makespan >= expected * 0.9
    assert real.makespan <= expected * 1.35 + 0.15, (policy, real.makespan,
                                                     expected)


def test_simulator_matches_real_executor_with_feedback():
    """Runtime feedback on (estimator active, no stragglers to migrate):
    the two substrates must still agree through the shared engine."""
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    tx_scale = 1.5e-3
    fb = FeedbackOptions()
    sim = simulate(g, pool, "async", options=_no_noise(), feedback=fb)
    real = RealExecutor(pool, tx_scale=tx_scale).run(g, "async", feedback=fb)
    assert real.tasks_total == sim.tasks_total
    assert sim.migrations == real.migrations == 0
    expected = sim.makespan * tx_scale
    assert expected * 0.9 <= real.makespan <= expected * 1.35 + 0.15


def test_real_executor_migrates_stragglers():
    """Injected stragglers on a two-pool allocation: the executor's
    watchdog must preempt + migrate at least one task, and every task must
    still complete exactly once."""
    g = DAG()
    g.add(TaskSet("s", 12, 2, 0, tx_mean=40.0, tx_sigma=1.0))
    alloc = Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=8, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=8, gpus=0)),
    ), transfer_cost=((0.0, 1.0), (1.0, 0.0)))
    ex = RealExecutor(alloc, tx_scale=1e-3, seed=7,
                      straggler_prob=0.2, straggler_factor=50.0)
    res = ex.run(g, "async", feedback=FeedbackOptions(straggler_k=2.0,
                                                      min_samples=2))
    assert res.tasks_total == 12
    assert len({(r.set_name, r.index) for r in res.records}) == 12
    assert res.migrations > 0
    assert any(r.migrated for r in res.records)


# ---------------------------------------------------------------------------
# speculation + the migration-vs-speculation arbiter
# ---------------------------------------------------------------------------

def _spec_alloc(transfer=2.0, cpus=4):
    return Allocation("two", (
        PoolSpec("p0", 1, NodeSpec(cpus=cpus, gpus=0)),
        PoolSpec("p1", 1, NodeSpec(cpus=cpus, gpus=0)),
    ), transfer_cost=((0.0, transfer), (transfer, 0.0)))


def _spec_engine(alloc, num_tasks=1, speculate=True, migrate=True):
    g = DAG()
    g.add(TaskSet("s", num_tasks, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc,
                      feedback=FeedbackOptions(min_samples=1,
                                               migrate=migrate,
                                               speculate=speculate))
    for _ in range(3):
        eng.observe("s", 10.0)
    return eng


def test_speculation_acquires_and_complete_frees_both_slots():
    eng = _spec_engine(_spec_alloc(), num_tasks=1)
    (name, i, src), = eng.startable()
    free_before = list(eng.free_cpus)
    spec = eng.try_speculate(name, i)
    assert spec is not None
    dst, cost = spec
    # same-pool slot is free and cheapest: no data movement
    assert dst == src and cost == 0.0
    assert eng.free_cpus[src] == free_before[src] - 2
    assert eng.speculations == 1
    assert eng.speculation_pool(name, i) == dst
    # one duplicate at a time
    assert eng.try_speculate(name, i) is None
    # whichever attempt wins, complete() frees BOTH slots exactly once
    eng.complete(name, i)
    assert eng.free_cpus == [4, 4]
    assert eng.running_per_pool == [0, 0]
    assert eng.speculation_pool(name, i) is None


def test_duplicate_finishing_second_is_cancelled():
    """First finisher wins: the second completion (the losing attempt)
    must be a no-op — no double resource release, no double count."""
    eng = _spec_engine(_spec_alloc(), num_tasks=2)
    started = eng.startable()
    (name, i, _src) = started[0]
    assert eng.try_speculate(name, i) is not None
    eng.complete(name, i)          # winner
    free_after = list(eng.free_cpus)
    done_after = eng._n_done
    eng.complete(name, i)          # loser arrives late: no-op
    assert eng.free_cpus == free_after
    assert eng._n_done == done_after


def test_speculation_noop_without_free_slot():
    """Cluster saturated: no duplicate slot exists anywhere -> the
    speculation candidate is None, and the arbiter (with migration also
    impossible) declines to act."""
    eng = _spec_engine(_spec_alloc(cpus=2), num_tasks=2)
    started = eng.startable()          # one task per pool: saturated
    assert len(started) == 2
    (name, i, _k) = started[0]
    assert eng.try_speculate(name, i) is None
    assert eng.arbitrate(name, i, elapsed=50.0) is None
    assert eng.speculations == 0 and eng.migrations == 0


def test_arbiter_falls_back_to_migration_when_speculation_unavailable():
    """Any migration target is also a valid duplicate slot, so pure
    capacity can never leave only migration — but an exhausted speculation
    budget (or a dup already racing) can.  The arbiter must then fall back
    to the always-migrate path."""
    g = DAG()
    g.add(TaskSet("s", 1, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, _spec_alloc(),
                      feedback=FeedbackOptions(min_samples=1, speculate=True,
                                               max_speculations_per_task=0))
    for _ in range(3):
        eng.observe("s", 10.0)
    (name, i, _), = eng.startable()
    # elapsed 5 s of an expected ~40 s tail: migrating (cost 2 + rerun 10)
    # beats the predicted 35 s remainder, so the arbiter acts
    act = eng.arbitrate(name, i, elapsed=5.0)
    assert act is not None and act[0] == "migrate"
    assert eng.migrations == 1 and eng.speculations == 0
    # ...but with the tail nearly paid off (elapsed 50 s -> baseline is
    # one mean), a rerun cannot finish sooner and the arbiter declines
    eng2 = SchedEngine(g, _spec_alloc(),
                       feedback=FeedbackOptions(min_samples=1, speculate=True,
                                                max_speculations_per_task=0))
    for _ in range(3):
        eng2.observe("s", 10.0)
    (n2, i2, _), = eng2.startable()
    assert eng2.arbitrate(n2, i2, elapsed=50.0) is None


def test_arbiter_tie_breaks_by_slot_pressure():
    """Identical costs both ways: with queued work the duplicate's slot
    displaces it, so the arbiter migrates; with an empty queue the
    original races for free, so it speculates."""
    # pressure: 3 tasks, 2 started (one per pool), 1 queued; zero transfer
    alloc = _spec_alloc(transfer=0.0, cpus=2)
    eng = _spec_engine(alloc, num_tasks=3)
    started = eng.startable()
    assert len(started) == 2 and len(eng.ready["s"]) == 1
    (name, i, _k) = started[0]
    eng.complete(name, i)              # frees a slot; queue still has one
    (qname, qi, _qk) = started[1]
    # drain the queue? no -- it is still pending, so pressure holds
    act = eng.arbitrate(qname, qi, elapsed=50.0)
    assert act is not None and act[0] == "migrate"

    # no pressure: single task, both pools otherwise idle, zero transfer
    eng2 = _spec_engine(alloc, num_tasks=1)
    (n2, i2, _), = eng2.startable()
    act2 = eng2.arbitrate(n2, i2, elapsed=50.0)
    assert act2 is not None and act2[0] == "speculate"


def test_single_mechanism_configs_skip_arbitration():
    """speculate=False degenerates to always-migrate; migrate=False to
    always-speculate (the benchmark's pure arms)."""
    eng = _spec_engine(_spec_alloc(), num_tasks=1, speculate=False)
    (n, i, _), = eng.startable()
    act = eng.arbitrate(n, i, elapsed=50.0)
    assert act is not None and act[0] == "migrate"

    eng2 = _spec_engine(_spec_alloc(), num_tasks=1, migrate=False)
    (n2, i2, _), = eng2.startable()
    act2 = eng2.arbitrate(n2, i2, elapsed=50.0)
    assert act2 is not None and act2[0] == "speculate"


def test_sim_speculation_rescues_stragglers_single_pool():
    """Migration needs a second pool; speculation only needs a free slot,
    so it rescues stragglers even on a single-pool allocation — and every
    task still completes exactly once."""
    g = DAG()
    g.add(TaskSet("s", 24, 2, 0, tx_mean=10.0, tx_sigma=0.5))
    pool = PoolSpec("p", 1, NodeSpec(cpus=16, gpus=0))
    opts = SimOptions(seed=2, launch_latency=0.0, straggler_prob=0.15,
                      straggler_factor=20.0)
    base = simulate(g, pool, "async", options=opts)
    fed = simulate(g, pool, "async", options=opts,
                   feedback=FeedbackOptions(straggler_k=2.0, migrate=False,
                                            speculate=True))
    assert fed.tasks_total == base.tasks_total == 24
    assert fed.speculations > 0 and fed.migrations == 0
    assert fed.makespan < base.makespan
    assert len({(r.set_name, r.index) for r in fed.records}) == 24
    assert sum(1 for r in fed.records if r.duplicate) > 0


def test_real_executor_speculates_stragglers():
    """The executor's watchdog launches speculative duplicates through the
    same engine; first finisher wins and the records stay exactly-once."""
    g = DAG()
    g.add(TaskSet("s", 12, 2, 0, tx_mean=40.0, tx_sigma=1.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=16, gpus=0))
    ex = RealExecutor(pool, tx_scale=1e-3, seed=7,
                      straggler_prob=0.2, straggler_factor=50.0)
    res = ex.run(g, "async",
                 feedback=FeedbackOptions(straggler_k=2.0, min_samples=2,
                                          migrate=False, speculate=True))
    assert res.tasks_total == 12
    assert len({(r.set_name, r.index) for r in res.records}) == 12
    assert res.speculations > 0 and res.migrations == 0


# ---------------------------------------------------------------------------
# multi-workflow campaigns: equivalence, trace disjointness, admission
# ---------------------------------------------------------------------------

def _two_wf_campaign():
    """Two small workflows with a staggered arrival; deterministic TXs."""
    a = DAG()
    a.add(TaskSet("first", 2, 2, 1, tx_mean=100.0, tx_sigma=0.0))
    a.add(TaskSet("second", 2, 2, 0, tx_mean=80.0, tx_sigma=0.0))
    a.add_edge("first", "second")
    b = DAG()
    b.add(TaskSet("only", 2, 2, 1, tx_mean=60.0, tx_sigma=0.0))
    c = Campaign()
    c.add("alpha", a, priority=1, weight=2.0)
    c.add("beta", b, priority=0, arrival=50.0)
    return c


def test_campaign_sim_matches_real_executor():
    """A campaign through both substrates: same task -> pool placement,
    agreeing makespans (at tx_scale), per-workflow stats in both."""
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    tx_scale = 1.5e-3
    opts = SimOptions(seed=0, sample_tx=False, entk_overhead=0.0,
                      async_overhead=0.0, launch_latency=0.0)
    sim = simulate(_two_wf_campaign(), pool, "async", options=opts,
                   scheduling="priority")
    real = RealExecutor(pool, tx_scale=tx_scale).run(
        _two_wf_campaign(), "async", scheduling="priority")
    assert real.tasks_total == sim.tasks_total == 6
    assert {(r.set_name, r.index): r.pool for r in sim.records} == \
        {(r.set_name, r.index): r.pool for r in real.records}
    expected = sim.makespan * tx_scale
    assert expected * 0.9 <= real.makespan <= expected * 1.35 + 0.15
    assert set(sim.workflows) == set(real.workflows) == {"alpha", "beta"}
    # the executor's stats are on the modelled clock, commensurate with
    # the simulator's (beta may not start before its 50 s arrival)
    for res in (sim, real):
        assert res.workflows["beta"].start >= 50.0 - 1e-9
        assert res.workflows["alpha"].tasks == 4
        assert res.workflows["beta"].tasks == 2


def test_campaign_workflow_traces_disjoint():
    """Per-workflow traces partition the record set, and every record's
    workflow tag matches its namespaced set name."""
    res = simulate(_two_wf_campaign(), PoolSpec("p", 1, NodeSpec(8, 2)),
                   "async", options=_no_noise(), scheduling="priority")
    alpha = res.workflow_records("alpha")
    beta = res.workflow_records("beta")
    assert len(alpha) + len(beta) == res.tasks_total == len(res.records)
    assert not ({(r.set_name, r.index) for r in alpha}
                & {(r.set_name, r.index) for r in beta})
    for r in res.records:
        assert r.set_name.startswith(f"{r.workflow}/")


def test_campaign_admission_off_bit_identical_to_recorded_trace():
    """A one-workflow campaign with admission off replays the plain
    single-workflow run event for event (names modulo the namespace
    prefix) — the tenancy plumbing may not disturb a single tenant."""
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    opts = SimOptions(seed=5)  # sampled TXs: any drift would show
    plain = simulate(g, pool, "async", options=opts)
    c = Campaign()
    c.add("solo", g)
    camp = simulate(c, pool, "async", options=opts)
    assert camp.makespan == plain.makespan
    recorded = [(r.set_name, r.index, r.start, r.end, r.pool, r.node)
                for r in plain.records]
    replayed = [(r.set_name.split("/", 1)[1], r.index, r.start, r.end,
                 r.pool, r.node) for r in camp.records]
    assert replayed == recorded


def test_campaign_rejects_invalid_configurations():
    c = _two_wf_campaign()
    pool = PoolSpec("p", 1, NodeSpec(8, 2))
    with pytest.raises(ValueError, match="asynchronously"):
        simulate(c, pool, "sequential")
    with pytest.raises(ValueError, match="requires a campaign"):
        SchedEngine(_equiv_dag(), pool, admission=AdmissionOptions())
    with pytest.raises(ValueError, match="duplicate workflow"):
        c.add("alpha", _equiv_dag())
    with pytest.raises(ValueError, match="may not contain"):
        Campaign().add("bad/name", _equiv_dag())


def test_engine_gates_dispatch_on_arrival():
    view = _two_wf_campaign().view()
    eng = SchedEngine(view.dag, PoolSpec("p", 1, NodeSpec(16, 4)),
                      policy="priority", campaign=view)
    started = {n for n, _i, _k in eng.startable(now=0.0)}
    assert started == {"alpha/first"}           # beta arrives at t = 50
    assert not eng.startable(now=49.9)
    started2 = {n for n, _i, _k in eng.startable(now=50.0)}
    assert started2 == {"beta/only"}


def test_priority_policy_execution_bundle():
    pol = priority_policy()
    assert pol.scheduling == "priority"
    res = pol.simulate(_two_wf_campaign(), PoolSpec("p", 1, NodeSpec(8, 2)),
                       options=_no_noise())
    assert res.policy == "priority"


def _deferral_campaign(hot_tasks=3):
    """A high-priority set next to a wide, long low-priority one that the
    admission controller must defer (no predicted overlap, hold_ratio)."""
    a = DAG()
    a.add(TaskSet("s", hot_tasks, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    b = DAG()
    b.add(TaskSet("w", 1, 2, 0, tx_mean=1000.0, tx_sigma=0.0))
    c = Campaign()
    c.add("hot", a, priority=1)
    c.add("cold", b, priority=0)
    return c


def test_admission_deferred_sets_are_not_slot_pressure():
    """The arbiter's tie-break: queued work normally makes migration win
    (the duplicate's slot displaces it), but admission-DEFERRED queued
    work is held back ahead of disturbing running tasks — with only a
    deferred set queued, the pressure-free duplicate races instead."""
    def build(admission):
        view = _deferral_campaign(hot_tasks=2).view()
        alloc = Allocation("two", (
            PoolSpec("p0", 1, NodeSpec(cpus=2, gpus=0)),
            PoolSpec("p1", 1, NodeSpec(cpus=2, gpus=0)),
        ), transfer_cost=((0.0, 0.0), (0.0, 0.0)))
        eng = SchedEngine(view.dag, alloc, policy="priority",
                          feedback=FeedbackOptions(min_samples=1,
                                                   speculate=True),
                          campaign=view, admission=admission)
        for _ in range(3):
            eng.observe("hot/s", 10.0)
        started = eng.startable(now=0.0)
        assert [n for n, _i, _k in started] == ["hot/s", "hot/s"]
        return eng, started

    # admission on: the wide set is deferred -> its queued task is NOT
    # pressure; complete one hot task to free a slot, then arbitrate
    eng, started = build(AdmissionOptions())
    assert eng.admission_deferrals == 1 and "cold/w" in eng.deferred
    name, i, _k = started[0]
    eng.complete(name, i)
    act = eng.arbitrate(*started[1][:2], elapsed=50.0)
    assert act is not None and act[0] == "speculate"

    # admission off: the same queued wide set IS pressure -> migrate
    eng2, started2 = build(None)
    assert eng2.admission_deferrals == 0
    name, i, _k = started2[0]
    eng2.complete(name, i)
    act2 = eng2.arbitrate(*started2[1][:2], elapsed=50.0)
    assert act2 is not None and act2[0] == "migrate"


def test_admission_conservation_guard_admits_deferred_work():
    """When the admitted work drains, the idle guard admits the deferred
    set: deferred != lost, and the trace shows it ran last."""
    res = simulate(_deferral_campaign(), PoolSpec("p", 1, NodeSpec(4, 0)),
                   "async", options=_no_noise(), scheduling="priority",
                   admission=AdmissionOptions())
    assert res.tasks_total == 4
    assert res.admission_deferrals == 1
    cold = res.workflow_records("cold")
    hot = res.workflow_records("hot")
    assert len(cold) == 1 and len(hot) == 3
    assert cold[0].start >= max(r.end for r in hot) - 1e-9


def test_execution_policy_carries_scheduling_to_both_substrates():
    g = _equiv_dag()
    pool = PoolSpec("local", 1, NodeSpec(cpus=8, gpus=2))
    pol = lpt_policy()
    sim = pol.simulate(g, pool, options=_no_noise())
    assert sim.policy == "lpt"
    real = pol.execute(g, RealExecutor(pool, tx_scale=1e-4))
    assert real.policy == "lpt"
    assert sim.tasks_total == real.tasks_total
    pol2 = ExecutionPolicy().with_scheduling("gpu_bestfit")
    assert pol2.simulate(g, pool, options=_no_noise()).policy == "gpu_bestfit"
    assert gpu_bestfit_policy().scheduling == "gpu_bestfit"


# ---------------------------------------------------------------------------
# migration with no alternative node: priced no-op, never a policy crash
# ---------------------------------------------------------------------------

from repro.core import SCHEDULING_POLICIES  # noqa: E402

EVERY_POLICY = tuple(sorted(SCHEDULING_POLICIES))


def _single_node_engine(policy):
    """One node-level pool with ONE node: any same-pool migration must
    exclude the straggler's own node, leaving zero candidates."""
    alloc = Allocation("solo", (
        PoolSpec("p0", 1, NodeSpec(cpus=8, gpus=2), node_level=True),),
        transfer_cost=((0.0,),))
    g = DAG()
    g.add(TaskSet("s", 2, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, policy=policy,
                      feedback=FeedbackOptions(min_samples=1, migrate=True,
                                               speculate=False))
    eng.observe("s", 10.0)
    return eng


@pytest.mark.parametrize("policy", EVERY_POLICY)
@pytest.mark.parametrize("incremental", (True, False))
def test_migration_with_no_alternative_node_is_priced_noop(policy,
                                                           incremental):
    """``exclude`` removes the only fitting node: ``_choose_node`` must
    report -1 (not hand ``policy.choose_node`` an empty list) and the
    migration must decline cleanly for EVERY registered policy."""
    eng = _single_node_engine(policy)
    eng.incremental = incremental and eng.incremental
    started = eng.startable()
    assert started, policy
    name, i, k = started[0]
    ts = eng.g.node(name)
    src_node = eng.node_placement(name, i)
    assert src_node == 0
    # the direct query: the only node excluded -> -1, no policy call
    assert eng._choose_node(k, ts, exclude=src_node) == -1
    # the end-to-end path: migration is a priced no-op
    assert eng.try_migrate(name, i) is None
    assert eng.migrations == 0
    eng.complete(name, i)


@pytest.mark.parametrize("policy", EVERY_POLICY)
def test_choose_node_exclude_with_alternative_still_places(policy):
    """Control arm: with a second fitting node, exclusion reroutes the
    migration instead of declining it."""
    alloc = Allocation("duo", (
        PoolSpec("p0", 2, NodeSpec(cpus=8, gpus=2), node_level=True),),
        transfer_cost=((1.0,),))
    g = DAG()
    g.add(TaskSet("s", 1, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, policy=policy,
                      feedback=FeedbackOptions(min_samples=1, migrate=True,
                                               speculate=False))
    eng.observe("s", 10.0)
    (name, i, k), = eng.startable()
    src_node = eng.node_placement(name, i)
    chosen = eng._choose_node(k, eng.g.node(name), exclude=src_node)
    assert chosen >= 0 and chosen != src_node
    mig = eng.try_migrate(name, i)
    assert mig is not None
    assert eng.node_placement(name, i) != src_node
    eng.complete(name, i)


# ---------------------------------------------------------------------------
# speculation losers must not clobber the winner's node placement
# ---------------------------------------------------------------------------


def _two_node_spec_engine():
    alloc = Allocation("spec2", (
        PoolSpec("p0", 2, NodeSpec(cpus=4, gpus=0), node_level=True),),
        transfer_cost=((1.0,),))
    g = DAG()
    g.add(TaskSet("s", 1, 2, 0, tx_mean=10.0, tx_sigma=0.0))
    g.add(TaskSet("c", 1, 2, 0, tx_mean=5.0, tx_sigma=0.0))
    g.add_edge("s", "c")
    eng = SchedEngine(g, alloc,
                      feedback=FeedbackOptions(min_samples=1, migrate=False,
                                               speculate=True))
    eng.observe("s", 10.0)
    return eng


def test_spec_winner_on_other_node_updates_node_of():
    """The duplicate wins on a different node: ``node_of`` must point at
    the duplicate's node (children price data pulls from where the output
    actually lives)."""
    eng = _two_node_spec_engine()
    (name, i, _k), = eng.startable()
    orig_node = eng.node_placement(name, i)
    assert eng.try_speculate(name, i) is not None
    dup_node = eng.spec_node(name, i)
    assert dup_node >= 0 and dup_node != orig_node
    eng.complete(name, i, spec_won=True)
    assert eng.node_of[(name, i)] == dup_node
    assert (name, i) not in eng._spec_node_alloc


def test_spec_loser_does_not_overwrite_winner_placement():
    """The duplicate loses (original finishes first): the stale
    ``_spec_node_alloc`` entry must NOT leak into ``node_of`` — children
    would otherwise price pulls from a node that never produced the
    output."""
    eng = _two_node_spec_engine()
    (name, i, _k), = eng.startable()
    orig_node = eng.node_placement(name, i)
    assert eng.try_speculate(name, i) is not None
    dup_node = eng.spec_node(name, i)
    assert dup_node != orig_node
    eng.complete(name, i)          # original wins; loser cancelled
    assert eng.node_of[(name, i)] == orig_node
    assert (name, i) not in eng._spec_node_alloc
    assert eng.spec_node(name, i) == -1
    # both slots freed exactly once
    assert eng.free_cpus == [8]
    # the late loser completion stays a no-op
    done = eng._n_done
    eng.complete(name, i)
    assert eng._n_done == done and eng.node_of[(name, i)] == orig_node


# ---------------------------------------------------------------------------
# incremental indexes: seeded in-container variants of the hypothesis
# properties (tests/test_invariants.py runs the full random exploration)
# ---------------------------------------------------------------------------


def _rand_dag(rng):
    g = DAG()
    n = rng.randint(2, 6)
    for j in range(n):
        g.add(TaskSet(name=f"N{j}", num_tasks=rng.randint(1, 4),
                      cpus_per_task=rng.randint(1, 8),
                      gpus_per_task=rng.randint(0, 2),
                      tx_mean=float(rng.randint(5, 50)), tx_sigma=0.0))
    for j in range(1, n):
        for i in range(j):
            if rng.randint(0, 3) == 0:
                g.add_edge(f"N{i}", f"N{j}")
    return g


def _inv_alloc(mode):
    nl = mode == "node_level"
    return Allocation("inv", (
        PoolSpec("p0", 2, NodeSpec(cpus=16, gpus=4, nvlink_groups=2),
                 node_level=nl),
        PoolSpec("p1", 1, NodeSpec(cpus=32, gpus=2, nvlink_groups=2),
                 node_level=nl),
    ), transfer_cost=((0.0, 2.0), (2.0, 0.0)))


def _drive(engines, rng, after_step):
    running = []
    for _ in range(2000):
        outs = [eng.startable() for eng in engines]
        assert all(o == outs[0] for o in outs[1:])
        running.extend((n, i) for n, i, _k in outs[0])
        after_step()
        if not running:
            break
        idx = rng.randrange(len(running))
        name, i = running[idx]
        op = rng.randint(0, 3)
        rets = []
        for eng in engines:
            if op == 1:
                rets.append(eng.try_migrate(name, i))
            elif op == 2:
                rets.append(eng.try_speculate(name, i))
            elif op == 3:
                rets.append(eng.arbitrate(name, i, elapsed=13.7))
            else:
                rets.append(eng.complete(name, i))
        if op == 0:
            running.pop(idx)
        assert all(r == rets[0] for r in rets[1:])
        after_step()
        if engines[0].done() and not running:
            break
    for (name, i) in running:
        rets = [eng.complete(name, i) for eng in engines]
        assert all(r == rets[0] for r in rets[1:])
    after_step()
    assert all(eng.done() for eng in engines)


@pytest.mark.parametrize("mode", ("aggregate", "node_level"))
@pytest.mark.parametrize("policy", ("gpu_bestfit", "locality", "nodepack"))
def test_incremental_index_integrity_seeded(mode, policy):
    """Seeded walk: every incremental structure equals a brute-force
    recount after every engine mutation."""
    import random
    for seed in range(3):
        rng = random.Random(1000 * seed + 7)
        eng = SchedEngine(_rand_dag(rng), _inv_alloc(mode), policy=policy,
                          feedback=FeedbackOptions(straggler_k=2.0,
                                                   min_samples=1,
                                                   speculate=True))
        for n in eng.g.nodes:
            eng.observe(n, eng.g.node(n).tx_mean)
        eng.check_index_integrity()
        _drive([eng], rng, eng.check_index_integrity)


@pytest.mark.parametrize("mode", ("aggregate", "node_level"))
@pytest.mark.parametrize("policy", EVERY_POLICY)
def test_incremental_bit_identical_to_scan_seeded(mode, policy):
    """Seeded lockstep: the incremental engine and the brute-force-scan
    engine emit identical decisions and placements at every step."""
    import random
    for seed in range(2):
        rng = random.Random(1000 * seed + 13)
        g = _rand_dag(rng)
        fb = FeedbackOptions(straggler_k=2.0, min_samples=1, speculate=True)
        engines = [SchedEngine(g, _inv_alloc(mode), policy=policy,
                               feedback=fb, incremental=inc)
                   for inc in (True, False)]
        for eng in engines:
            for n in g.nodes:
                eng.observe(n, g.node(n).tx_mean)

        def same():
            assert engines[0].node_of == engines[1].node_of
            assert engines[0].pool_of == engines[1].pool_of

        _drive(engines, rng, same)


def test_scan_engine_rejects_integrity_check():
    g = fig2a_chain()
    eng = SchedEngine(g, PoolSpec("p", 1, NodeSpec(cpus=8, gpus=2)),
                      incremental=False)
    with pytest.raises(AssertionError):
        eng.check_index_integrity()


# ---------------------------------------------------------------------------
# fault tolerance: seeded in-container variants of the hypothesis fault
# properties (tests/test_invariants.py runs the full random exploration)
# ---------------------------------------------------------------------------

from repro.core import FaultOptions  # noqa: E402


def _on(**kw):
    """Enabled-but-inert FaultOptions for direct engine driving: the
    vanishing stochastic rate flips ``enabled`` without ever being drawn
    from (the engine only injects what the caller tells it to)."""
    kw.setdefault("node_failure_rate", 1e-12)
    return FaultOptions(**kw)


def _storm(seed, **kw):
    """A real failure storm for end-to-end runs: stochastic node losses
    with recovery, software task failures, and checkpointing on."""
    base = dict(node_failure_rate=0.004, node_recovery_time=60.0,
                task_failure_prob=0.15, seed=seed,
                checkpoint_interval=5.0, checkpoint_write_cost=0.5,
                checkpoint_read_cost=1.0)
    base.update(kw)
    return FaultOptions(**base)


def _two_node_fault_engine(**fault_kw):
    alloc = Allocation("ft", (
        PoolSpec("p", 2, NodeSpec(cpus=8, gpus=2), node_level=True),),
        transfer_cost=((0.0,),))
    g = DAG()
    g.add(TaskSet("s", 1, 4, 1, tx_mean=10.0, tx_sigma=0.0))
    return SchedEngine(g, alloc, faults=_on(**fault_kw))


@pytest.mark.parametrize("mode", ("aggregate", "node_level"))
@pytest.mark.parametrize("policy", EVERY_POLICY)
def test_disabled_faults_bit_identical_to_plain(mode, policy):
    """``FaultOptions()`` (all rates zero) must be indistinguishable from
    ``faults=None``: the full record tuples — starts, ends, placements —
    are bit-identical and every fault counter stays zero."""
    import random
    g = _rand_dag(random.Random(5))
    opts = SimOptions(seed=3)

    def trace(res):
        return [(r.set_name, r.index, r.start, r.end, r.pool, r.node)
                for r in res.records]

    plain = simulate(g, _inv_alloc(mode), "async", options=opts,
                     scheduling=policy)
    off = simulate(g, _inv_alloc(mode), "async", options=opts,
                   scheduling=policy, faults=FaultOptions())
    assert trace(off) == trace(plain)
    assert off.makespan == plain.makespan
    assert off.node_failures == 0 and off.task_failures == 0
    assert off.recoveries_restart == 0 and off.recoveries_rerun == 0


@pytest.mark.parametrize("mode", ("aggregate", "node_level"))
@pytest.mark.parametrize("policy", EVERY_POLICY)
def test_exactly_once_under_failure_storm_seeded(mode, policy):
    """Seeded stochastic node losses + software failures + checkpointed
    recovery: every task still completes effectively exactly once (one
    non-duplicate record per task, no extras, no losses)."""
    import random
    for seed in range(2):
        g = _rand_dag(random.Random(900 + seed))
        total = sum(ts.num_tasks for ts in g.nodes.values())
        res = simulate(g, _inv_alloc(mode), "async",
                       options=SimOptions(seed=seed), scheduling=policy,
                       faults=_storm(seed))
        assert res.tasks_total == total
        prim = [(r.set_name, r.index) for r in res.records
                if not r.duplicate]
        assert len(prim) == total and len(set(prim)) == total
        for r in res.records:
            assert 0.0 <= r.start <= r.end


def test_executor_exactly_once_under_faults():
    """The thread executor under a trace-driven node loss + software
    failures reaches the same exactly-once guarantee as the simulator."""
    import random
    g = _rand_dag(random.Random(77))
    total = sum(ts.num_tasks for ts in g.nodes.values())
    res = RealExecutor(_inv_alloc("node_level"), tx_scale=1e-3).run(
        g, "async", scheduling="gpu_bestfit",
        faults=FaultOptions(task_failure_prob=0.3, seed=1,
                            node_failure_trace=((3.0, "p0", 0),),
                            node_recovery_time=30.0))
    prim = {(r.set_name, r.index) for r in res.records if not r.duplicate}
    assert prim == {(n, i) for n in g.nodes
                    for i in range(g.node(n).num_tasks)}
    assert res.node_failures == 1
    assert res.task_failures >= 1


@pytest.mark.parametrize("mode", ("aggregate", "node_level"))
def test_no_slot_leak_after_node_loss_seeded(mode):
    """Random interleavings of dispatch / completion / node loss / node
    recovery / software failure / replication: every incremental index
    equals a brute-force recount after EVERY mutation, and full capacity
    is restored once all nodes are back and the DAG has drained."""
    import random
    for seed in range(3):
        rng = random.Random(40 + seed)
        g = _rand_dag(rng)
        eng = SchedEngine(g, _inv_alloc(mode), policy="gpu_bestfit",
                          faults=_on(replicate=True,
                                     checkpoint_interval=5.0,
                                     checkpoint_write_cost=0.5,
                                     checkpoint_read_cost=1.0))
        for n in g.nodes:
            eng.observe(n, g.node(n).tx_mean)
        running: list[tuple[str, int]] = []
        down: list[tuple[int, int]] = []
        now = 0.0
        guard = 0
        while not eng.done() and guard < 4000:
            guard += 1
            now += 1.0
            for name, i, _k in eng.startable(now):
                running.append((name, i))
            eng.check_index_integrity()
            op = rng.randint(0, 5)
            if op <= 1 and running:
                name, i = running.pop(rng.randrange(len(running)))
                eng.complete(name, i)
            elif op == 2:
                k = rng.randrange(len(eng.pools))
                node = rng.randrange(eng.pools[k].num_nodes)
                ev = eng.fail_node(k, node, now=now,
                                   started=dict.fromkeys(running, 0.0))
                if ev is not None:
                    down.append((k, node))
                    running = [key for key in running
                               if key in eng.launched]
            elif op == 3 and down:
                k, node = down.pop(rng.randrange(len(down)))
                eng.recover_node(k, node, now=now)
            elif op == 4 and running:
                name, i = running[rng.randrange(len(running))]
                ev = eng.fail_task(name, i, now=now,
                                   elapsed=rng.uniform(0.0, 20.0))
                if ev is not None and ev.failed:
                    running.remove((name, i))
            elif op == 5 and running:
                name, i = running[rng.randrange(len(running))]
                eng.try_replicate(name, i)
            eng.check_index_integrity()
        for name, i in running:
            eng.complete(name, i)
        while not eng.done() and guard < 5000:
            guard += 1
            started = eng.startable(now)
            assert started, "unfinished work with nothing startable"
            for name, i, _k in started:
                eng.complete(name, i)
        eng.check_index_integrity()
        assert eng.done()
        for k, node in down:
            eng.recover_node(k, node, now=now)
        eng.check_index_integrity()
        for k, p in enumerate(eng.pools):
            assert eng.free_cpus[k] == p.total.cpus
            assert eng.free_gpus[k] == p.total.gpus


def test_failure_refused_when_it_would_strand_work():
    """Conservation guard: a node loss that would leave an unfinished set
    with no possible placement anywhere is refused — failed must never
    become lost."""
    alloc = Allocation("c", (
        PoolSpec("p", 2, NodeSpec(cpus=8, gpus=2), node_level=True),),
        transfer_cost=((0.0,),))
    g = DAG()
    g.add(TaskSet("only", 2, 4, 1, tx_mean=10.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc, faults=_on())
    assert eng.fail_node(0, 0, now=1.0) is not None
    # the second loss would strand "only": refused
    assert eng.fail_node(0, 1, now=2.0) is None
    # ... and an already-down / unknown node is refused too
    assert eng.fail_node(0, 0, now=2.0) is None
    assert eng.fail_node(0, 99, now=2.0) is None
    assert eng.recover_node(0, 0, now=3.0)
    # with node 0 back, node 1 may now go down
    assert eng.fail_node(0, 1, now=4.0) is not None


def test_stale_completion_after_node_death_is_a_noop():
    """Regression (audit): a task whose node died between dispatch and
    completion was already released + re-enqueued by the failure path — a
    late completion report from the dead attempt must not double-free the
    slots or mark the task finished."""
    eng = _two_node_fault_engine()
    (name, i, _k), = eng.startable()
    node = eng.node_placement(name, i)
    ev = eng.fail_node(0, node, now=1.0, started={(name, i): 0.0})
    assert ev.failed == ((name, i),)
    free = (list(eng.free_cpus), list(eng.free_gpus))
    eng.complete(name, i)  # the dead attempt's thread reports in late
    assert (list(eng.free_cpus), list(eng.free_gpus)) == free
    assert (name, i) not in eng.finished and not eng.done()
    # the re-queued attempt dispatches onto the surviving node and wins
    (n2, i2, _k2), = eng.startable()
    assert (n2, i2) == (name, i)
    assert eng.node_placement(name, i) != node
    eng.complete(name, i)
    assert eng.done()


def test_spec_loser_cannot_resurrect_failed_task():
    """Regression (audit): after a software failure re-enqueues a task, a
    stale speculative-winner completion from a cancelled duplicate must
    not resurrect its placement or finish the task."""
    eng = _two_node_fault_engine(replicate=True)
    (name, i, _k), = eng.startable()
    assert eng.try_replicate(name, i) is not None
    dup_node = eng.spec_node(name, i)
    # the duplicate's node dies: duplicate cancelled, primary unharmed
    ev = eng.fail_node(0, dup_node, now=1.0, started={(name, i): 0.0})
    assert ev.cancelled == ((name, i),)
    assert (name, i) in eng.launched
    # now the primary hits a software fault: released + re-enqueued
    ev2 = eng.fail_task(name, i, now=2.0, elapsed=2.0)
    assert ev2.failed == ((name, i),)
    free = (list(eng.free_cpus), list(eng.free_gpus))
    eng.complete(name, i, spec_won=True)  # stale loser report
    assert (list(eng.free_cpus), list(eng.free_gpus)) == free
    assert (name, i) not in eng.finished
    assert (name, i) not in eng.node_of
    eng.recover_node(0, dup_node, now=3.0)
    (n2, i2, _k2), = eng.startable()
    assert (n2, i2) == (name, i)
    eng.complete(name, i)
    assert eng.done()


def test_replica_promoted_when_primary_node_dies():
    """Proactive replication: the primary's node dies, the replica on the
    other node is promoted in place — the task is never re-enqueued and
    no work is lost."""
    eng = _two_node_fault_engine(replicate=True)
    (name, i, _k), = eng.startable()
    prim = eng.node_placement(name, i)
    assert eng.try_replicate(name, i) is not None
    rep = eng.spec_node(name, i)
    assert rep != prim
    ev = eng.fail_node(0, prim, now=5.0, started={(name, i): 0.0})
    assert ev.promoted == ((name, i),)
    assert ev.failed == () and ev.cancelled == ()
    assert (name, i) in eng.launched
    assert eng.node_placement(name, i) == rep
    assert eng.replications == 1
    eng.complete(name, i)
    assert eng.done()
    eng.recover_node(0, prim)
    assert eng.free_cpus == [16] and eng.free_gpus == [4]


def test_at_risk_flags_only_long_remaining_tasks():
    """The replication risk gate: probability of losing the node before
    completion (1 - exp(-hazard x remaining)) against ``replicate_risk``
    — a long-remaining task is flagged, a nearly-done one is not."""
    alloc = Allocation("r", (
        PoolSpec("p", 2, NodeSpec(cpus=8, gpus=2), node_level=True),),
        transfer_cost=((0.0,),))
    g = DAG()
    g.add(TaskSet("along", 1, 2, 0, tx_mean=100.0, tx_sigma=0.0))
    g.add(TaskSet("bshort", 1, 2, 0, tx_mean=1.0, tx_sigma=0.0))
    eng = SchedEngine(g, alloc,
                      faults=_on(node_failure_rate=0.01, replicate=True,
                                 replicate_risk=0.35))
    started = {(name, i): 0.0 for name, i, _k in eng.startable()}
    assert len(started) == 2
    risky = eng.at_risk(started, now=0.0)
    assert risky == [("along", 0)]


def test_restart_recovery_resumes_from_checkpoint_progress():
    """Forced restart arm: a checkpointing task that failed mid-flight
    re-dispatches with the saved progress subtracted and the checkpoint
    read (plus write overheads on the remainder) added."""
    g = DAG()
    g.add(TaskSet("t", 1, 4, 0, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0))
    eng = SchedEngine(g, pool,
                      faults=_on(recovery="restart",
                                 checkpoint_interval=10.0,
                                 checkpoint_write_cost=1.0,
                                 checkpoint_read_cost=2.0))
    eng.observe("t", 100.0)
    (name, i, k), = eng.startable()
    # 100s of work snapshots 10x at 1s each
    assert eng.dispatch_duration(name, i, 100.0, k) == 110.0
    assert eng.fail_task(name, i, now=55.0, elapsed=55.0) is not None
    assert eng.recoveries_restart == 1 and eng.recoveries_rerun == 0
    (name, i, k2), = eng.startable()
    # floor(55 / (10+1)) = 5 intervals saved -> 50s of progress; the
    # remainder re-pays the read (2) and its own snapshots (5x1)
    assert eng.dispatch_duration(name, i, 100.0, k2) == 57.0
    eng.complete(name, i)
    assert eng.done()


def test_rerun_recovery_repays_everything():
    """Forced rerun arm: no checkpoints are written (dispatch durations
    unchanged) and a failed attempt re-pays its full duration."""
    g = DAG()
    g.add(TaskSet("t", 1, 4, 0, tx_mean=100.0, tx_sigma=0.0))
    pool = PoolSpec("p", 1, NodeSpec(cpus=8, gpus=0))
    eng = SchedEngine(g, pool,
                      faults=_on(recovery="rerun",
                                 checkpoint_interval=10.0,
                                 checkpoint_write_cost=1.0,
                                 checkpoint_read_cost=2.0))
    eng.observe("t", 100.0)
    (name, i, k), = eng.startable()
    assert eng.dispatch_duration(name, i, 100.0, k) == 100.0
    assert eng.fail_task(name, i, now=55.0, elapsed=55.0) is not None
    assert eng.recoveries_rerun == 1 and eng.recoveries_restart == 0
    (name, i, k2), = eng.startable()
    assert eng.dispatch_duration(name, i, 100.0, k2) == 100.0


def test_hazard_rate_tracks_observed_failures():
    """Trace-driven runs configure no stochastic rate, but the arbiter
    and predictor still need a hazard: the empirical failures/(sites x
    elapsed) estimate takes over once losses are observed."""
    eng = _two_node_fault_engine()
    assert eng.hazard_rate() == pytest.approx(1e-12)
    (name, i, _k), = eng.startable()
    node = eng.node_placement(name, i)
    eng.fail_node(0, node, now=10.0, started={(name, i): 0.0})
    # 1 failure over 2 sites x 10s
    assert eng.hazard_rate() == pytest.approx(1.0 / 20.0)

"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run one forward + one grad (train) step and a decode step on CPU,
assert output shapes and absence of NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStructs, no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model
from repro.models.params import count_params, init_params

B, S = 2, 64


def _reduced(arch):
    cfg = get_config(arch).reduced()
    return cfg


@pytest.fixture(scope="module")
def built(request):
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = _reduced(arch)
    m = build_model(cfg)
    params = init_params(m.specs(), jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), batch=B, seq=S)

    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # at least 99% of parameters receive gradient signal somewhere
    nz = sum(float(jnp.abs(g.astype(jnp.float32)).sum() > 0) for g in flat)
    assert nz >= 0.8 * len(flat), f"{arch}: {nz}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = _reduced(arch)
    m = build_model(cfg)
    params = init_params(m.specs(), jax.random.PRNGKey(0))
    cache = init_params(m.cache_specs(B, 128), jax.random.PRNGKey(2))
    batch = m.make_batch(jax.random.PRNGKey(3), batch=B, seq=S,
                         mode="decode")
    step = jax.jit(m.decode_step)
    logits, cache = step(params, cache, batch["tokens"], batch["pos"])
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    # a second step at pos+1 must also be finite and change the cache
    logits2, cache2 = step(params, cache, batch["tokens"],
                           batch["pos"] + 1)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_math(arch):
    """Full config: spec-tree construction only (no allocation) + 6ND
    bookkeeping sanity."""
    cfg = get_config(arch)
    m = build_model(cfg)
    n = count_params(m.specs())
    est = cfg.param_count_estimate()
    assert n > 0.25e9 or arch == "whisper-tiny"
    # estimate within 2x of true count (it ignores small tensors)
    assert 0.4 < n / max(est, 1) < 2.5, (arch, n, est)


def test_known_param_counts():
    """Spot-check the spec trees against published sizes."""
    import math
    checks = {
        "qwen2-0.5b": (0.35e9, 0.65e9),      # 0.49B (w/ tied emb)
        "stablelm-12b": (10e9, 14e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),  # 109B total
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "whisper-tiny": (25e6, 60e6),
    }
    for arch, (lo, hi) in checks.items():
        n = count_params(build_model(get_config(arch)).specs())
        assert lo <= n <= hi, (arch, f"{n / 1e9:.2f}B not in [{lo}, {hi}]")

"""SWF trace loader: parsing, degenerate-job validation, footprint
mapping, seeded down-sampling, and the predictor-side guarantees for
what the loader can emit (``core/swf.py``).

The committed fixture ``tests/data/hpc2n_head.swf`` is a truncated
HPC2N-shaped trace that deliberately contains the archive's warts: -1
sentinel fields, zero and -1 runtimes, cancelled/failed/unknown status
codes, a short row, and jobs wider than one node."""

import math
import os

import pytest

from repro.core import (Campaign, FeedbackOptions, MakespanPredictor,
                        NodeSpec, PoolSpec, RunConfig, SWFMapOptions,
                        TxEstimator, WorkflowStream, load_swf, parse_swf,
                        simulate, swf_campaign, swf_entries, swf_stream)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "hpc2n_head.swf")


def pool(nodes=8, cpus=32, gpus=0, **kw):
    return PoolSpec("p", nodes, NodeSpec(cpus=cpus, gpus=gpus), **kw)


# ---------------------------------------------------------------------------
# parsing
def test_parse_fixture_header_and_jobs():
    tr = load_swf(FIXTURE)
    assert len(tr) == 32
    assert tr.directive("MaxProcs") == "240"
    assert tr.directive("maxnodes") == "120"  # case-insensitive
    assert tr.directive("NoSuchKey", "dflt") == "dflt"
    by_id = {j.job_id: j for j in tr.jobs}
    assert by_id[1].submit == 0 and by_id[1].procs == 2
    assert by_id[1].run_time == 4595
    # -1 sentinels preserved at parse time
    assert by_id[7].run_time == -1 and by_id[7].status == 0
    assert by_id[17].procs == -1 and by_id[17].req_procs == 24
    # zero-runtime cancelled job
    assert by_id[4].run_time == 0 and by_id[4].status == 5
    # short row right-padded with -1
    assert by_id[28].partition == -1


def test_parse_tolerates_junk():
    tr = parse_swf([
        "; Version: 2.2",
        ";",
        "",
        "1 0 5 100 4 x -1 4 600 -1 1 1 1 -1 1 -1 -1 -1",
        "2 10 0 50 2",
    ])
    assert len(tr) == 2
    assert tr.jobs[0].run_time == 100
    assert tr.jobs[1].status == -1  # padded


# ---------------------------------------------------------------------------
# degenerate jobs: clamp / drop / error (loader validation, satellite fix)
def test_degenerate_jobs_clamped_by_default():
    tr = load_swf(FIXTURE)
    entries = swf_entries(tr, pool(),
                          SWFMapOptions(keep_statuses=None,
                                        min_runtime=7.0))
    assert len(entries) == 32  # nothing dropped: all repaired
    for e in entries:
        for ts in e.dag.nodes.values():
            assert ts.tx_mean > 0
            assert ts.num_tasks >= 1 and ts.cpus_per_task >= 1
    # the zero/-1 runtime rows got exactly the clamp floor
    tx = {e.name: next(iter(e.dag.nodes.values())).tx_mean
          for e in entries}
    assert tx["job4"] == 7.0 and tx["job7"] == 7.0 and tx["job21"] == 7.0
    # -1 procs fell back to the requested 24 cores -> one 24-wide task
    j17 = next(e for e in entries if e.name == "job17")
    ts = next(iter(j17.dag.nodes.values()))
    assert ts.num_tasks * ts.cpus_per_task >= 24


def test_degenerate_jobs_drop_and_error():
    tr = load_swf(FIXTURE)
    dropped = swf_entries(tr, pool(),
                          SWFMapOptions(keep_statuses=None,
                                        on_degenerate="drop"))
    names = {e.name for e in dropped}
    assert {"job4", "job7", "job13", "job21"}.isdisjoint(names)
    assert "job1" in names
    with pytest.raises(ValueError, match="degenerate SWF job"):
        swf_entries(tr, pool(), SWFMapOptions(keep_statuses=None,
                                              on_degenerate="error"))


def test_status_filter_default_keeps_completed_only():
    tr = load_swf(FIXTURE)
    names = {e.name for e in swf_entries(tr, pool())}
    # failed (0), cancelled (5) and out-of-spec (3) statuses are gone
    assert {"job4", "job7", "job13", "job21", "job26"}.isdisjoint(names)
    assert len(names) == 27


def test_map_options_validate():
    for bad in (dict(sample=0.0), dict(sample=1.5), dict(time_scale=0),
                dict(on_degenerate="zap"), dict(min_runtime=0),
                dict(cpus_per_proc=0)):
        with pytest.raises(ValueError):
            SWFMapOptions(**bad)


# ---------------------------------------------------------------------------
# footprint + arrival mapping
def test_footprint_splits_wide_jobs_over_nodes():
    tr = load_swf(FIXTURE)
    entries = {e.name: e for e in swf_entries(
        tr, pool(nodes=8, cpus=32, node_level=True))}
    ts = next(iter(entries["job11"].dag.nodes.values()))  # 128 procs
    assert ts.num_tasks == 4 and ts.cpus_per_task == 32
    ts = next(iter(entries["job31"].dag.nodes.values()))  # 120 procs
    assert ts.num_tasks == 4 and ts.cpus_per_task == 30
    ts = next(iter(entries["job2"].dag.nodes.values()))   # 1 proc
    assert ts.num_tasks == 1 and ts.cpus_per_task == 1


def test_arrivals_shift_and_time_scale():
    tr = load_swf(FIXTURE)
    a = swf_entries(tr, pool())
    assert a[0].arrival == 0.0
    assert all(e.arrival >= 0 for e in a)
    b = swf_entries(tr, pool(), SWFMapOptions(time_scale=10.0))
    assert b[3].arrival == pytest.approx(a[3].arrival / 10.0)
    tx_a = next(iter(a[0].dag.nodes.values())).tx_mean
    tx_b = next(iter(b[0].dag.nodes.values())).tx_mean
    assert tx_b == pytest.approx(tx_a / 10.0)


def test_gpu_fraction_and_deadlines():
    tr = load_swf(FIXTURE)
    entries = swf_entries(tr, pool(gpus=4),
                          SWFMapOptions(gpu_fraction=1.0,
                                        deadline_slack=2.0))
    assert all(next(iter(e.dag.nodes.values())).gpus_per_task >= 1
               for e in entries)
    assert all(e.deadline is not None and e.deadline > e.arrival
               for e in entries)
    # gpu draws ignored on a CPU-only pool
    cpu_only = swf_entries(tr, pool(), SWFMapOptions(gpu_fraction=1.0))
    assert all(next(iter(e.dag.nodes.values())).gpus_per_task == 0
               for e in cpu_only)


# ---------------------------------------------------------------------------
# seeded down-sampling (the documented bounded-replay knob)
def test_down_sampling_seeded_and_reproducible():
    tr = load_swf(FIXTURE)
    opt = SWFMapOptions(sample=0.5, seed=11)
    a = swf_entries(tr, pool(), opt)
    b = swf_entries(tr, pool(), opt)
    assert [(e.name, e.arrival) for e in a] \
        == [(e.name, e.arrival) for e in b]
    assert 0 < len(a) < 27
    c = swf_entries(tr, pool(), SWFMapOptions(sample=0.5, seed=12))
    assert {e.name for e in c} != {e.name for e in a}
    capped = swf_entries(tr, pool(), SWFMapOptions(max_jobs=5))
    assert len(capped) == 5


def test_down_sampling_draws_stable_under_status_filter():
    # one Bernoulli draw per TRACE job: widening the status filter must
    # not reshuffle which completed jobs survive thinning
    tr = load_swf(FIXTURE)
    base = {e.name for e in swf_entries(
        tr, pool(), SWFMapOptions(sample=0.4, seed=5))}
    wide = {e.name for e in swf_entries(
        tr, pool(), SWFMapOptions(sample=0.4, seed=5,
                                  keep_statuses=None))}
    assert base == {n for n in wide
                    if n not in {"job4", "job7", "job13", "job21",
                                 "job26"}}


# ---------------------------------------------------------------------------
# end-to-end replay + predictor-side guarantees for loader output
def test_swf_campaign_and_stream_replay():
    tr = load_swf(FIXTURE)
    opt = SWFMapOptions(max_jobs=12, time_scale=20.0)
    camp = swf_campaign(tr, pool(), opt)
    assert isinstance(camp, Campaign) and len(camp) == 12
    r = simulate(camp, pool())
    assert len(r.workflows) == 12
    assert all(w.finish >= w.start for w in r.workflows.values())
    st = swf_stream(tr, pool(), opt)
    assert isinstance(st, WorkflowStream)
    rs = simulate(st, pool())
    assert rs.stream["finished"] == 12


def test_workflow_entry_rejects_degenerate_slo_fields():
    # load-time validation backstop below the SWF mapper: entries with
    # impossible SLO / slowdown denominators never enter a campaign
    from repro.core import DAG, TaskSet, WorkflowEntry
    g = DAG()
    g.add(TaskSet("a", 1, 1, 0, 5.0))
    with pytest.raises(ValueError, match="deadline"):
        WorkflowEntry("w", g, arrival=10.0, deadline=10.0)
    with pytest.raises(ValueError, match="reference_makespan"):
        WorkflowEntry("w", g, reference_makespan=0.0)


def test_swf_empty_after_filtering_raises():
    tr = parse_swf(["1 0 0 0 0 -1 -1 0 -1 -1 5 1 1 -1 1 -1 -1 -1"])
    with pytest.raises(ValueError, match="no SWF jobs"):
        swf_campaign(tr, pool())  # status filter eats the only job


def test_clamped_minimal_jobs_safe_for_predictor_and_estimator():
    # the most degenerate workload the loader can emit: every repaired
    # job clamped to the runtime floor — prediction and estimation must
    # stay finite (regression: pre-validation, zero-TX sets reached the
    # predictor and estimator as 0-mean inputs)
    tr = load_swf(FIXTURE)
    camp = swf_campaign(tr, pool(), SWFMapOptions(
        keep_statuses=None, min_runtime=0.5, time_scale=20.0,
        max_jobs=10))
    view = camp.view()
    pred = MakespanPredictor(view.dag, pool(),
                             workflow_of=view.workflow_of)
    p = pred.predict(lambda n: view.dag.node(n).tx_mean, 0.0,
                     {n: ts.num_tasks
                      for n, ts in view.dag.nodes.items()}, {})
    assert math.isfinite(p.total) and p.total > 0
    assert math.isfinite(p.remaining) and p.remaining > 0
    r = simulate(camp, pool(),
                 config=RunConfig(feedback=FeedbackOptions()))
    assert math.isfinite(r.makespan)
    assert r.predictions and all(math.isfinite(q.total)
                                 for q in r.predictions)
    est = TxEstimator()
    for name, ts in view.dag.nodes.items():
        assert ts.tx_mean > 0  # the loader's validation guarantee
        for _ in range(3):
            est.observe(name, ts.tx_mean)
        assert est.mean(name) > 0
        assert est.tail_ratio(name) is not None

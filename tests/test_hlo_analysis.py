"""HLO analysis unit tests: collective-byte parsing and loop-weighted
multiplicity propagation on synthetic HLO text."""

import textwrap

from repro.launch.hlo_analysis import (collective_stats,
                                       computation_multiplicities,
                                       weighted_collective_stats,
                                       _shape_bytes)

HLO = textwrap.dedent("""\
    HloModule jit_step

    %body (arg: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
    }

    %cond (arg: (s32[], f32[8,128])) -> pred[] {
      %p2 = (s32[], f32[8,128]) parameter(0)
      ROOT %lt = pred[] compare(%i2, %c), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,128]) -> f32[8,128] {
      %x0 = f32[8,128]{1,0} parameter(0)
      %ag = f32[16,128]{1,0} all-gather(%x0), dimensions={0}
      %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
    }
    """)


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8


def test_flat_collective_stats():
    st = collective_stats(HLO)
    assert st.count_by_kind == {"all-reduce": 1, "all-gather": 1}
    assert st.bytes_by_kind["all-gather"] == 16 * 128 * 4


def test_multiplicity_propagation():
    m = computation_multiplicities(HLO)
    assert m["main"] == 1
    assert m["body"] == 10
    assert m["cond"] == 1      # conditions carry no collectives; weight 1
    assert m["add"] == 10      # called from body -> inherits its weight


def test_weighted_collectives():
    st = weighted_collective_stats(HLO)
    # the in-loop all-reduce counts 10x, the top-level all-gather once
    assert st.count_by_kind["all-reduce"] == 10
    assert st.count_by_kind["all-gather"] == 1
    assert st.bytes_by_kind["all-reduce"] == 10 * 8 * 128 * 4


def test_tuple_collective_with_index_comments():
    """XLA embeds /*index=N*/ comments (containing '=') inside large tuple
    types; the fused gradient all-reduce must still be counted."""
    line = ("  %all-reduce.696 = (f32[64]{0}, f32[4224]{0}, f32[4224]{0}, "
            "f32[4224]{0}, f32[4224]{0}, /*index=5*/f32[4224]{0}, "
            "f32[2048,4096]{1,0}) all-reduce(%a, %b), to_apply=%add")
    st = collective_stats(line)
    assert st.count_by_kind == {"all-reduce": 1}
    want = (64 + 4 * 4224 + 4224 + 2048 * 4096) * 4
    assert st.bytes_by_kind["all-reduce"] == want


def test_operand_reference_not_counted():
    line = ("  %gte = f32[64]{0} get-tuple-element(%all-reduce.696), "
            "index=0")
    assert collective_stats(line).total_count == 0

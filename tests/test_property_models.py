"""Hypothesis property tests on model-layer and analytic invariants."""

import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency "
                    "(pip install -r requirements-dev.txt)")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.analytic import analyse_cell, forward_flops, decode_flops
from repro.models.layers import apply_rope, cross_entropy, rms_norm
from repro.models.moe import _capacity, _positions_in_expert

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(1, 4), st.integers(2, 32), st.integers(2, 16))
@settings(**SETTINGS)
def test_rms_norm_unit_rms(b, s, d):
    x = jnp.asarray(np.random.default_rng(b * s + d).normal(
        size=(b, s, d)) * 7 + 1, jnp.float32)
    y = rms_norm(x, jnp.ones((d,)))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


@given(st.integers(0, 1000), st.integers(1, 8))
@settings(**SETTINGS)
def test_rope_preserves_norm(pos, h):
    """Rotation must preserve per-head vector norms."""
    d = 32
    x = jnp.asarray(np.random.default_rng(pos).normal(size=(1, 3, h, d)),
                    jnp.float32)
    positions = jnp.full((1, 3), pos, jnp.int32)
    y = apply_rope(x, positions, theta=10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)


@given(st.integers(2, 50))
@settings(**SETTINGS)
def test_cross_entropy_bounds(v):
    """Uniform logits -> CE == log(V); ignore-mask zeroes contributions."""
    logits = jnp.zeros((2, 3, v))
    labels = jnp.zeros((2, 3), jnp.int32)
    ce = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce), np.log(v), rtol=1e-5)
    masked = cross_entropy(logits, labels.at[:, 1:].set(-1))
    np.testing.assert_allclose(float(masked), np.log(v), rtol=1e-5)


@given(st.integers(1, 4096), st.integers(1, 128), st.integers(1, 8),
       st.floats(0.5, 4.0))
@settings(**SETTINGS)
def test_capacity_positive_and_aligned(tokens, experts, k, factor):
    c = _capacity(tokens, experts, k, factor)
    assert c >= 8 and c % 8 == 0
    assert c * experts >= tokens * k * factor * 0.5


@given(st.lists(st.integers(0, 7), min_size=1, max_size=64))
@settings(**SETTINGS)
def test_positions_in_expert_are_ranks(assign):
    e = jnp.asarray(assign, jnp.int32)
    pos = np.asarray(_positions_in_expert(e, 8))
    seen: dict[int, int] = {}
    for a, p in zip(assign, pos):
        assert p == seen.get(a, 0)
        seen[a] = seen.get(a, 0) + 1


@given(st.sampled_from(ARCH_IDS), st.integers(1, 8), st.integers(7, 12))
@settings(**SETTINGS)
def test_analytic_flops_monotone(arch, b, log_s):
    """FLOPs strictly increase with sequence length and batch."""
    cfg = get_config(arch)
    s = 1 << log_s
    f1 = forward_flops(cfg, b, s)
    f2 = forward_flops(cfg, b, 2 * s)
    f3 = forward_flops(cfg, 2 * b, s)
    assert 0 < f1 < f2
    assert f1 < f3 <= 2 * f1 + 1e-6 * f1


@given(st.sampled_from(ARCH_IDS))
@settings(**SETTINGS)
def test_analytic_cells_sane(arch):
    """Model flops never exceed analytic compiled flops; decode is far
    cheaper than prefill."""
    cfg = get_config(arch)
    n = cfg.param_count_estimate()
    na = cfg.active_param_count_estimate()
    for shape in SHAPES.values():
        cell = analyse_cell(cfg, shape, n, na, 256)
        assert cell.flops_global > 0 and cell.hbm_bytes_global > 0
        assert cell.model_flops <= cell.flops_global * 1.05, (arch, shape)
    d = decode_flops(cfg, SHAPES["decode_32k"].global_batch, 32768)
    p = forward_flops(cfg, SHAPES["prefill_32k"].global_batch, 32768)
    assert d < p


def test_workflow_dag_properties():
    """DOA_dep bounds from the paper's Fig. 2 families, property-style."""
    from repro.core import fig2a_chain, fig2d_independent
    for n in (2, 5, 9):
        assert fig2a_chain(n).doa_dep() == 0
        assert fig2d_independent(n).doa_dep() == n

"""Launch-layer tests: mesh construction, cell registry, analytic model
consistency, dry-run artifact schema."""

import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, SHAPES, all_cells, cell_status, \
    get_config
from repro.launch.analytic import analyse_cell, cache_bytes
from repro.launch.mesh import TPU_V5E, make_host_mesh

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def test_cell_matrix_is_complete():
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] == "skipped_full_attention"]
    assert len(skips) == 7
    # the skips are exactly long_500k on the pure full-attention archs
    assert all(s[1] == "long_500k" for s in skips)
    runs = {(a, s) for a, s, st in cells if st == "run"}
    assert ("rwkv6-1.6b", "long_500k") in runs
    assert ("zamba2-1.2b", "long_500k") in runs
    assert ("h2o-danube-1.8b", "long_500k") in runs


def test_host_mesh():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1


def test_hw_constants():
    assert TPU_V5E["peak_bf16_flops"] == 197e12
    assert TPU_V5E["hbm_bytes_per_s"] == 819e9
    assert TPU_V5E["ici_bytes_per_s"] == 5.0e10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_bytes_bounded_for_long_context(arch):
    """Sub-quadratic archs must have (near-)constant cache vs seq len."""
    cfg = get_config(arch)
    c32 = cache_bytes(cfg, 1, 32768)
    c500 = cache_bytes(cfg, 1, 524288)
    if cfg.sub_quadratic:
        assert c500 <= c32 * 1.01, (arch, c32, c500)
    else:
        assert c500 > c32 * 4


def test_dryrun_artifacts_schema():
    """If the sweep has run, every compiled artifact has the fields the
    roofline reads."""
    files = glob.glob(os.path.join(ART, "single_pod_16x16", "*.json"))
    files = [f for f in files if "__hc" not in f]
    if not files:
        pytest.skip("dry-run artifacts not generated")
    assert len(files) == 40
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        assert rec["status"] in ("run", "skipped_full_attention"), f
        if rec["status"] == "run":
            for key in ("cost", "collectives_weighted", "roofline",
                        "params", "devices"):
                assert key in rec, (f, key)
            assert rec["devices"] == 256

"""Per-module coverage floors: `python tools/coverage_floor.py
coverage.xml <path-suffix>=<floor> [...]`.

Reads the Cobertura XML that ``make test-cov`` writes and fails when any
named module's line coverage sits below its floor.  Matching is by path
suffix so the gate is independent of how coverage.py roots filenames
(``src/repro/...`` vs ``repro/...``).  Used by CI to pin the fault layer
(``runtime/fault.py``) and the checkpoint store (``checkpoint/store.py``)
— the modules whose failure paths only fire when things go wrong, where
untested lines stay untested in production until a real outage.
"""

from __future__ import annotations

import sys
import xml.etree.ElementTree as ET


def module_rates(xml_path: str) -> dict[str, tuple[int, int]]:
    """filename -> (covered, total) statement counts."""
    out: dict[str, tuple[int, int]] = {}
    for cls in ET.parse(xml_path).getroot().iter("class"):
        fname = cls.get("filename", "")
        covered = total = 0
        for line in cls.iter("line"):
            total += 1
            if int(line.get("hits", "0")) > 0:
                covered += 1
        c, t = out.get(fname, (0, 0))
        out[fname] = (c + covered, t + total)
    return out


def main(argv: list[str]) -> int:
    if len(argv) < 2:
        print("usage: coverage_floor.py coverage.xml suffix=floor [...]")
        return 2
    rates = module_rates(argv[0])
    problems = []
    for spec in argv[1:]:
        suffix, floor_s = spec.rsplit("=", 1)
        floor = float(floor_s)
        hits = {f: ct for f, ct in rates.items() if f.endswith(suffix)}
        if not hits:
            problems.append(f"{suffix}: not present in {argv[0]}")
            continue
        covered = sum(c for c, _t in hits.values())
        total = sum(t for _c, t in hits.values())
        rate = covered / total if total else 0.0
        status = "OK" if rate >= floor else "BELOW FLOOR"
        print(f"  {suffix}: {rate:.1%} ({covered}/{total} lines, "
              f"floor {floor:.0%}) {status}")
        if rate < floor:
            problems.append(f"{suffix}: {rate:.1%} < floor {floor:.0%}")
    if problems:
        print("coverage-floor: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("coverage-floor: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""cProfile any simulated run: `make profile` / `python
tools/profile_run.py [knobs]`.

Builds the stream-scale benchmark's diurnal open-stream scenario (small
2-CPU jobs on an aggregate slice whose diurnal peak overruns capacity),
runs it once under ``cProfile`` with the requested ``RunConfig`` knobs,
and prints the top cumulative hot spots — the first place to look when
simulated-arrivals/sec regress.  Every hot-loop knob is a flag, so the
throttled and unthrottled arms can be profiled side by side:

    python tools/profile_run.py --horizon 4000
    python tools/profile_run.py --horizon 4000 --predict-interval 900 \\
        --coalesce --summary

Exits 0; the report goes to stdout.
"""

from __future__ import annotations

import argparse
import cProfile
import os
import pstats
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core import (DAG, FeedbackOptions, GeneratedStream,  # noqa: E402
                        NodeSpec, PoolSpec, PredictOptions, RunConfig,
                        SimOptions, StreamTemplate, TaskSet, simulate)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="cProfile a simulated open-stream run")
    ap.add_argument("--horizon", type=float, default=2000.0,
                    help="stream horizon in modelled seconds")
    ap.add_argument("--rate", type=float, default=0.4,
                    help="trough arrival rate (1/s); diurnal peak is 5x")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--cpus", type=int, default=96,
                    help="aggregate pool width")
    ap.add_argument("--scheduling", default="fifo",
                    help="scheduling policy name")
    ap.add_argument("--predict-interval", type=float, default=None,
                    metavar="S", help="enable PredictOptions with this "
                    "min_interval (modelled seconds)")
    ap.add_argument("--coalesce", action="store_true",
                    help="coalesce same-timestamp event passes")
    ap.add_argument("--summary", action="store_true",
                    help='record_policy="summary" (bounded memory)')
    ap.add_argument("--top", type=int, default=20,
                    help="rows of the profile to print")
    ap.add_argument("--sort", default="cumulative",
                    choices=["cumulative", "tottime", "ncalls"])
    return ap


def build_config(args) -> RunConfig:
    return RunConfig(
        scheduling=args.scheduling,
        feedback=FeedbackOptions(migrate=False),
        predict=(PredictOptions(min_interval=args.predict_interval)
                 if args.predict_interval is not None else None),
        coalesce_events=args.coalesce,
        record_policy="summary" if args.summary else "full",
        slo_window=1800.0, perf_counters=True)


def build_scenario(args):
    g = DAG()
    g.add(TaskSet("job", 1, 2, 0, tx_mean=30.0, tx_sigma=6.0))
    tmpl = StreamTemplate("job", lambda: g, deadline_slack=600.0,
                          reference_makespan=30.0)
    stream = GeneratedStream([tmpl], rate=args.rate, horizon=args.horizon,
                             seed=args.seed, kind="diurnal", period=3600.0,
                             peak_ratio=5.0, name="profile")
    pool = PoolSpec("profile", 1, NodeSpec(cpus=args.cpus, gpus=0))
    return stream, pool


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    stream, pool = build_scenario(args)
    config = build_config(args)
    holder = {}
    pr = cProfile.Profile()
    pr.enable()
    holder["r"] = simulate(stream, pool, options=SimOptions(seed=args.seed),
                           config=config)
    pr.disable()
    r = holder["r"]
    print(f"profile_run: {r.stream['arrived']} arrivals, "
          f"makespan {r.makespan:.1f} modelled s, "
          f"{len(r.predictions)} predictions")
    if r.perf is not None:
        p = r.perf
        print(f"  perf: engine {p.engine_s:.2f}s  predict "
              f"{p.predict_s:.2f}s  events {p.events_s:.2f}s  metrics "
              f"{p.metrics_s:.2f}s  ({p.passes} passes, "
              f"{p.predicts} predicts)")
    pstats.Stats(pr).sort_stats(args.sort).print_stats(args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())

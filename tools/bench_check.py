"""Benchmark-regression gate: `make bench-check`.

Compares fresh benchmark JSON (``benchmarks/out/*.json``, written by the
bench targets) against the committed baselines
(``benchmarks/baseline/*.json``).  The baseline directory is the source
of truth for *which* benchmarks are gated: every baseline file must have
a fresh counterpart.

Failure conditions:

1. **Makespan regression**: any numeric leaf whose key contains
   ``makespan`` may not exceed its baseline value by more than
   ``THRESHOLD`` (10%).  Improvements (smaller makespans) always pass —
   the gate is one-sided.
2. **Headline guards**: the paper-level claims must hold in the fresh
   run regardless of drift —
   - the shared-GPU c-DG2 async win survives locality placement
     (``runtime_feedback.json``: ``locality_cdg2_shared.i`` >= 0.25,
     the I ~= 0.34 headline with margin);
   - the online predictor still converges
     (``predictor.json``: final mean re-prediction error < 0.10);
   - the arbiter still beats both pure mitigation arms
     (``predictor.json``: arbitrated mean <= min(always-migrate,
     always-speculate));
   - node-level packing still wins the fragmented multi-GPU mix
     (``topology.json``: nodepack mean <= gpu_bestfit mean), the
     cross-set contention term still lowers strict-GPU c-DG2 mid-run
     re-prediction error, and the aggregate (``node_level=False``)
     resource model stays bit-identical to the committed baselines;
   - admission-controlled tenancy still beats FIFO-admit-all and static
     partitioning on weighted slowdown (``admission.json``: per-seed
     dominance on the 3-workflow Summit campaign), the deferral arm
     still engages and wins, and one-workflow campaigns stay
     bit-identical to the committed single-workflow baselines;
   - the incremental engine still pays off (``engine_scale.json``:
     >= 10x decisions/sec over the brute-force-scan arm at the largest
     scale point, per-decision pass latency sublinear in node count,
     and the two arms' dispatch sequences identical).  Timing values in
     that file are machine-dependent and are NOT drift-compared (none
     of its keys contain ``makespan``); only the fresh headline flags
     gate;
   - streaming tenancy still pays off (``streaming.json``: per-seed
     deadline-aware + elastic SLO attainment >= deadline-blind static
     and P99 weighted slowdown <=, elastic leases both granted and
     expired on every seed while the static arm stays static,
     preemptive revocation exercised across the seeds, and the
     streaming run API — ``CampaignStream`` + ``RunConfig`` — stays
     bit-identical to the committed closed-campaign baselines);
   - the trace-scale hot loop still pays off (``stream_scale.json``:
     >= 5x end-to-end simulated arrivals/sec on the ~1e5-arrival
     diurnal stream for the epoch-throttled + coalesced + summary arm
     over the unthrottled prefix arm, throttled predictions leave the
     dispatch sequence bit-identical on every seed, and repeated
     summary metric queries stay O(1)-amortized — per-query latency at
     ~1e5 workflows within 3x of ~1e4).  Wall-clock values in that
     file are machine-dependent and are NOT drift-compared; the
     deterministic per-seed ``makespan_throttled`` values are;
   - the scenario matrix still selects policies (``scenarios.json``:
     the full 6-policy x admission x feedback grid ran on every named
     scenario, the adversarial compositions still separate the field
     — best arm beats worst by >= 1.2x on each — the per-scenario
     winning policy is seed-stable on most scenarios, no single policy
     sweeps the whole matrix, and fresh scenario runs stay
     bit-identical to the committed baseline — the scenario engine's
     same-spec-same-seed determinism contract).  Per-arm ``makespan``
     values are deterministic and drift-compared like any baseline;
   - priced recovery arbitration still matches-or-beats both pure
     recovery arms on every seed of the c-DG2 failure storm
     (``faults.json``: per-seed arbitrated <= min(always-rerun,
     always-restart)) while genuinely using both mechanisms, the
     hazard term still lowers mid-run re-prediction error under node
     losses, and disabled ``FaultOptions()`` stays bit-identical to
     the committed fault-free baselines.

Exits non-zero with a list of problems; wired into CI after the bench
targets.  To accept an intentional change, regenerate the baseline
(e.g. ``make bench-policies bench-feedback bench-predictor
bench-faults``) and copy the new ``benchmarks/out/*.json`` over
``benchmarks/baseline/``.
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(ROOT, "benchmarks", "baseline")
OUT_DIR = os.path.join(ROOT, "benchmarks", "out")

#: one-sided makespan-regression tolerance (fresh <= baseline * (1 + T))
THRESHOLD = 0.10


def walk_makespans(baseline, fresh, path, problems):
    """Recursively pair up makespan-keyed numeric leaves."""
    if isinstance(baseline, dict):
        if not isinstance(fresh, dict):
            problems.append(f"{path}: baseline is an object, fresh is not")
            return
        for key, bval in baseline.items():
            if key not in fresh:
                problems.append(f"{path}.{key}: missing from fresh output")
                continue
            walk_makespans(bval, fresh[key], f"{path}.{key}", problems)
        return
    if isinstance(baseline, list):
        if not isinstance(fresh, list) or len(fresh) != len(baseline):
            problems.append(f"{path}: list shape changed")
            return
        for k, (b, f) in enumerate(zip(baseline, fresh)):
            walk_makespans(b, f, f"{path}[{k}]", problems)
        return
    leaf = path.rsplit(".", 1)[-1].split("[")[0]
    if "makespan" in leaf and isinstance(baseline, (int, float)) \
            and isinstance(fresh, (int, float)) and baseline > 0:
        if fresh > baseline * (1.0 + THRESHOLD):
            problems.append(
                f"{path}: makespan regressed {baseline} -> {fresh} "
                f"(+{100 * (fresh / baseline - 1):.1f}% > "
                f"{100 * THRESHOLD:.0f}%)")


def check_identity(name, fresh, problems, what):
    """Shared bit-identity headline: every ``baseline_identity`` entry
    of ``fresh`` must report ``identical`` (topology + admission)."""
    ident = fresh.get("baseline_identity", {})
    for which, r in ident.items():
        if not r.get("identical"):
            problems.append(
                f"{name}: {which}: {what} no longer bit-identical to the "
                f"committed baseline ({r.get('fresh')!r} vs "
                f"{r.get('committed')!r})")
    if not ident:
        problems.append(f"{name}: baseline_identity section missing")


def check_headlines(name, fresh, problems):
    if name == "runtime_feedback.json":
        i = fresh.get("locality_cdg2_shared", {}).get("i")
        if i is None or i < 0.25:
            problems.append(
                f"{name}: shared-GPU c-DG2 async win lost under locality "
                f"(I = {i!r}, needs >= 0.25)")
    if name == "predictor.json":
        errs = fresh.get("convergence", {}).get("mean_errors") or []
        if not errs or errs[-1] >= 0.10:
            problems.append(
                f"{name}: predictor no longer converges (final mean "
                f"re-prediction error {errs[-1] if errs else 'missing'!r}, "
                f"needs < 0.10)")
        arms = fresh.get("arbitrage", {}).get("arms", {})
        try:
            arb = arms["arbitrated"]["makespan_mean"]
            pure = min(arms["always_migrate"]["makespan_mean"],
                       arms["always_speculate"]["makespan_mean"])
            if arb > pure * 1.0001:
                problems.append(
                    f"{name}: arbitrated mitigation ({arb}) lost to the "
                    f"best pure arm ({pure})")
        except KeyError as e:
            problems.append(f"{name}: arbitrage arm missing: {e}")
    if name == "topology.json":
        arms = fresh.get("fragmented", {}).get("arms", {})
        try:
            np_m = arms["nodepack"]["makespan_mean"]
            bf_m = arms["gpu_bestfit"]["makespan_mean"]
            if np_m > bf_m * 1.0001:
                problems.append(
                    f"{name}: nodepack ({np_m}) lost the fragmented "
                    f"multi-GPU mix to gpu_bestfit ({bf_m})")
        except KeyError as e:
            problems.append(f"{name}: fragmented arm missing: {e}")
        cont = fresh.get("contention", {})
        e_with, e_without = cont.get("err_with"), cont.get("err_without")
        if e_with is None or e_without is None or e_with >= e_without:
            problems.append(
                f"{name}: contention term no longer lowers strict-GPU "
                f"c-DG2 mid-run error (with={e_with!r}, "
                f"without={e_without!r})")
        check_identity(name, fresh, problems, "node_level=False")
    if name == "admission.json":
        per_seed = fresh.get("tenancy", {}).get("per_seed", {})
        if not per_seed:
            problems.append(f"{name}: tenancy section missing")
        for seed, r in per_seed.items():
            adm, fifo = r.get("admission_ws"), r.get("fifo_ws")
            static = r.get("static_ws")
            if adm is None or fifo is None or static is None \
                    or adm > fifo * 1.0001 or adm > static * 1.0001:
                problems.append(
                    f"{name}: tenancy seed {seed}: admission weighted "
                    f"slowdown ({adm!r}) no longer beats fifo ({fifo!r}) "
                    f"and static ({static!r})")
        de = fresh.get("deferral", {}).get("per_seed", {})
        if not de:
            problems.append(f"{name}: deferral section missing")
        for seed, r in de.items():
            if not r.get("deferrals"):
                problems.append(
                    f"{name}: deferral seed {seed}: admission controller "
                    f"no longer defers the wide training set")
            on, off = r.get("on_ws"), r.get("off_ws")
            if on is None or off is None or on > off * 1.0001:
                problems.append(
                    f"{name}: deferral seed {seed}: admission-on weighted "
                    f"slowdown ({on!r}) lost to admission-off ({off!r})")
        check_identity(name, fresh, problems, "one-workflow campaign")
    if name == "engine_scale.json":
        hl = fresh.get("headlines", {})
        speedup = hl.get("speedup_largest")
        if speedup is None or speedup < 10.0:
            problems.append(
                f"{name}: incremental engine speedup at the largest scale "
                f"point is {speedup!r} decisions/sec over the scan arm "
                f"(needs >= 10x)")
        if not hl.get("sublinear"):
            problems.append(
                f"{name}: indexed per-decision pass latency no longer "
                f"sublinear in node count (grew "
                f"{hl.get('sublinear_ratio')!r}x over 10x nodes)")
        if not hl.get("dispatch_identity"):
            problems.append(
                f"{name}: incremental and brute-force-scan arms no longer "
                f"emit identical dispatch sequences")
    if name == "stream_scale.json":
        hl = fresh.get("headlines", {})
        speedup = hl.get("speedup")
        if speedup is None or speedup < 5.0:
            problems.append(
                f"{name}: hot-loop arm end-to-end arrivals/sec speedup is "
                f"{speedup!r} over the unthrottled arm (needs >= 5x)")
        if not hl.get("dispatch_identity"):
            problems.append(
                f"{name}: throttled predictions no longer leave the "
                f"dispatch sequence bit-identical to the unthrottled arm")
        if not hl.get("metric_query_sublinear"):
            problems.append(
                f"{name}: summary metric queries no longer O(1)-amortized "
                f"(per-query latency grew {hl.get('latency_ratio')!r}x "
                f"from ~1e4 to ~1e5 workflows, needs <= 3x)")
    if name == "streaming.json":
        st = fresh.get("streaming", {})
        per_seed = st.get("per_seed", {})
        if not per_seed:
            problems.append(f"{name}: streaming section missing")
        for seed, r in per_seed.items():
            a, b = r.get("aware", {}), r.get("blind", {})
            slo_a, slo_b = a.get("slo"), b.get("slo")
            if slo_a is None or slo_b is None \
                    or slo_a * 1.0001 < slo_b:
                problems.append(
                    f"{name}: seed {seed}: deadline-aware + elastic SLO "
                    f"attainment ({slo_a!r}) lost to deadline-blind "
                    f"static ({slo_b!r})")
            p99_a, p99_b = a.get("p99_slowdown"), b.get("p99_slowdown")
            if p99_a is None or p99_b is None or p99_a > p99_b * 1.0001:
                problems.append(
                    f"{name}: seed {seed}: deadline-aware + elastic P99 "
                    f"weighted slowdown ({p99_a!r}) lost to "
                    f"deadline-blind static ({p99_b!r})")
            if not a.get("leases_granted") or not a.get("leases_expired"):
                problems.append(
                    f"{name}: seed {seed}: elastic leases not exercised "
                    f"(granted={a.get('leases_granted')!r}, "
                    f"expired={a.get('leases_expired')!r})")
            if b.get("leases_granted"):
                problems.append(
                    f"{name}: seed {seed}: the static arm leased nodes "
                    f"({b.get('leases_granted')!r}) — it must stay static")
        if not st.get("revocations_total"):
            problems.append(
                f"{name}: preemptive revocation never fired across the "
                f"seeds (revocations_total="
                f"{st.get('revocations_total')!r})")
        check_identity(name, fresh, problems, "streaming run API")
    if name == "scenarios.json":
        hl = fresh.get("headlines", {})
        if not hl.get("full_grid"):
            problems.append(
                f"{name}: policy x admission x feedback sweep grid "
                f"incomplete — some scenario is missing arms or seeds")
        if not hl.get("adversarial_separation"):
            problems.append(
                f"{name}: adversarial scenarios no longer separate the "
                f"policy field (min spread "
                f"{hl.get('adversarial_spread_min')!r}, needs >= 1.2x "
                f"on each of {hl.get('adversarial')!r})")
        stable = hl.get("winner_policy_stable_count")
        if stable is None or stable < 4:
            problems.append(
                f"{name}: per-scenario winning policy seed-stable on "
                f"only {stable!r} scenarios (needs >= 4)")
        if hl.get("single_policy_sweep"):
            problems.append(
                f"{name}: a single policy now wins every scenario — the "
                f"matrix no longer exercises policy selection")
        winners = fresh.get("winners", {})
        if len(winners) < 6:
            problems.append(
                f"{name}: policy-selection table covers only "
                f"{len(winners)} scenarios (needs >= 6)")
        check_identity(name, fresh, problems, "scenario engine run")
    if name == "faults.json":
        rec = fresh.get("recovery", {})
        arms = rec.get("arms", {})
        try:
            arb = arms["arbitrated"]["makespans"]
            rerun = arms["always_rerun"]["makespans"]
            restart = arms["always_restart"]["makespans"]
            for j, seed in enumerate(rec.get("seeds", [])):
                pure = min(rerun[j], restart[j])
                if arb[j] > pure * 1.0001:
                    problems.append(
                        f"{name}: recovery seed {seed}: arbitrated "
                        f"({arb[j]}) lost to the best pure arm ({pure})")
            if not arms["arbitrated"]["recoveries_restart"] \
                    or not arms["arbitrated"]["recoveries_rerun"]:
                problems.append(
                    f"{name}: arbitrated arm no longer exercises both "
                    f"recovery mechanisms (restarts="
                    f"{arms['arbitrated']['recoveries_restart']!r}, "
                    f"reruns={arms['arbitrated']['recoveries_rerun']!r})")
            if not arms["arbitrated"]["node_failures"]:
                problems.append(
                    f"{name}: recovery scenario injected no node failures "
                    f"— the storm is not exercising the fault layer")
        except (KeyError, IndexError) as e:
            problems.append(f"{name}: recovery arm missing: {e!r}")
        haz = fresh.get("hazard", {})
        e_with, e_without = haz.get("err_with"), haz.get("err_without")
        if e_with is None or e_without is None or e_with > e_without:
            problems.append(
                f"{name}: hazard term no longer lowers mid-run "
                f"re-prediction error under node losses "
                f"(with={e_with!r}, without={e_without!r})")
        check_identity(name, fresh, problems, "FaultOptions disabled")


def main() -> int:
    problems: list[str] = []
    baselines = sorted(f for f in os.listdir(BASELINE_DIR)
                       if f.endswith(".json")) \
        if os.path.isdir(BASELINE_DIR) else []
    if not baselines:
        print("bench-check: FAILED\n  - no baselines committed under "
              "benchmarks/baseline/")
        return 1
    checked = 0
    for name in baselines:
        fresh_path = os.path.join(OUT_DIR, name)
        if not os.path.exists(fresh_path):
            problems.append(f"{name}: no fresh output in benchmarks/out/ "
                            f"(did the bench target run?)")
            continue
        with open(os.path.join(BASELINE_DIR, name)) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        walk_makespans(baseline, fresh, name, problems)
        check_headlines(name, fresh, problems)
        checked += 1
    if problems:
        print("bench-check: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench-check: OK ({checked} baseline files, "
          f"<= {100 * THRESHOLD:.0f}% makespan drift, headlines held)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

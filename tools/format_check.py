"""Formatting gate: `make format-check` (BLOCKING in CI).

A pure-Python checker for the formatter rules this repo enforces, so the
gate runs everywhere — including the dev container, where ruff is not
installable (the historical `ruff format --check` step could only ever
run on GitHub and stayed advisory for that reason).  The one-time
cleanup pass this gate enforces landed together with it.

Checked, per file under ``src/``, ``tools/``, ``benchmarks/`` and
``tests/``:

1. no tab characters in indentation;
2. no trailing whitespace;
3. LF line endings (no CRLF);
4. file ends with exactly one newline;
5. lines <= 88 columns (the ``[tool.ruff] line-length``), with a
   ``# noqa: E501`` escape hatch for the rare unsplittable literal;
6. double-quoted strings (tokenize-based; strings whose *content*
   contains a double quote may stay single-quoted, matching the ruff
   formatter's ``quote-style = "double"`` behaviour).

Exits non-zero with a list of problems.  Run `python
tools/format_check.py --fix` to apply the mechanical fixes (1-4, 6;
long lines must be split by hand).
"""

from __future__ import annotations

import io
import os
import sys
import tokenize

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DIRS = ("src", "tools", "benchmarks", "tests")
MAX_COLS = 88


def python_files() -> list[str]:
    out = []
    for d in DIRS:
        for dirpath, _dirnames, filenames in os.walk(os.path.join(ROOT, d)):
            if "__pycache__" in dirpath:
                continue
            out += [os.path.join(dirpath, f) for f in filenames
                    if f.endswith(".py")]
    return sorted(out)


def requote(tok: str) -> "str | None":
    """The double-quoted form of a single-quoted string token, or None
    when it should be left alone (content contains a double quote)."""
    body = tok
    prefix = ""
    while body and body[0] not in "'\"":
        prefix += body[0]
        body = body[1:]
    if not body.startswith("'") or body.startswith("'''"):
        return None
    if "r" not in prefix.lower():
        # only plain/escape-processed strings are safe to requote
        inner = body[1:-1]
        if '"' in inner or "\\" in inner:
            return None
        return f'{prefix}"{inner}"'
    inner = body[1:-1]
    if '"' in inner:
        return None
    return f'{prefix}"{inner}"'


def single_quoted_strings(text: str) -> list[tuple[int, str]]:
    """(line, token) for every offending single-quoted string literal."""
    out = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in toks:
            if tok.type == tokenize.STRING and requote(tok.string):
                out.append((tok.start[0], tok.string))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        pass  # the test suite / lint job owns syntax validity
    return out


def apply_requotes(text: str) -> str:
    """Rewrite offending single-quoted strings in place, by token
    position — a global text replace would corrupt identical substrings
    inside OTHER string literals."""
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return text
    lines = text.split("\n")
    repl = []
    for tok in toks:
        if tok.type == tokenize.STRING and tok.start[0] == tok.end[0]:
            new = requote(tok.string)
            if new:
                repl.append((tok.start[0], tok.start[1], tok.end[1], new))
    for row, c0, c1, new in sorted(repl, reverse=True):
        line = lines[row - 1]
        lines[row - 1] = line[:c0] + new + line[c1:]
    return "\n".join(lines)


def multiline_string_lines(text: str) -> set[int]:
    """Line numbers lying INSIDE multi-line string literals: their
    content is data, not code — formatters never reflow it, so the
    column limit does not apply there."""
    out: set[int] = set()
    try:
        toks = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in toks:
            if tok.type == tokenize.STRING and tok.end[0] > tok.start[0]:
                out.update(range(tok.start[0], tok.end[0] + 1))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        pass
    return out


def check_file(path: str, fix: bool = False) -> list[str]:
    rel = os.path.relpath(path, ROOT)
    with open(path, newline="") as f:
        raw = f.read()
    problems = []
    text = raw
    if "\r" in text:
        problems.append(f"{rel}: CRLF line endings")
        text = text.replace("\r\n", "\n").replace("\r", "\n")
    lines = text.split("\n")
    in_string = multiline_string_lines(text)
    for k, line in enumerate(lines, 1):
        indent = line[:len(line) - len(line.lstrip())]
        if "\t" in indent:
            problems.append(f"{rel}:{k}: tab in indentation")
        if line != line.rstrip() and k not in in_string:
            problems.append(f"{rel}:{k}: trailing whitespace")
        if (len(line) > MAX_COLS and k not in in_string
                and "noqa: E501" not in line):
            problems.append(f"{rel}:{k}: line is {len(line)} cols "
                            f"(> {MAX_COLS})")
    if text and not text.endswith("\n"):
        problems.append(f"{rel}: missing trailing newline")
    while text.endswith("\n\n"):
        problems.append(f"{rel}: extra blank lines at EOF")
        text = text[:-1]
    for k, tok in single_quoted_strings(text):
        problems.append(f"{rel}:{k}: single-quoted string {tok!r}")
    if fix and problems:
        out_lines = []
        for k, line in enumerate(lines, 1):
            if k in in_string:
                out_lines.append(line)  # string contents are data
                continue
            stripped = line.lstrip()
            indent = line[:len(line) - len(stripped)].expandtabs(4)
            out_lines.append((indent + stripped).rstrip())
        fixed = "\n".join(out_lines)
        if fixed and not fixed.endswith("\n"):
            fixed += "\n"
        while fixed.endswith("\n\n"):
            fixed = fixed[:-1]
        fixed = apply_requotes(fixed)
        with open(path, "w", newline="") as f:
            f.write(fixed)
    return problems


def main() -> int:
    fix = "--fix" in sys.argv[1:]
    files = python_files()
    problems: list[str] = []
    for path in files:
        problems += check_file(path, fix=fix)
    if problems:
        verb = "fixed where mechanical" if fix else "FAILED"
        print(f"format-check: {verb}")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"format-check: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Docs sanity checker: `make docs-check`.

Verifies that README.md and DESIGN.md only reference things that exist:

1. every backtick-quoted repo path (``src/...``, ``benchmarks/...py``,
   ``examples/...``, ``experiments/...``, glob patterns allowed) resolves
   to at least one real file/directory;
2. every scheduling-policy name in `SCHEDULING_POLICIES` is documented in
   BOTH files, and every policy name the DESIGN.md policy table lists is
   actually registered (docs and registry cannot drift);
3. the run-API knob dataclasses (`RunConfig`, `ElasticOptions`,
   `AdmissionOptions`, `FaultOptions`, `FeedbackOptions`, `SimOptions`,
   `SWFMapOptions`, `Scenario`) stay documented field-by-field: every
   field must be mentioned in README.md or DESIGN.md, so adding a knob
   without documenting it fails CI.

Exits non-zero with a list of problems; run by CI on every push.
"""

from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

DOCS = ("README.md", "DESIGN.md")

#: backticked tokens that look like repo paths: contain a "/" or end in a
#: known suffix, and start with a known top-level dir or file
PATH_RE = re.compile(r"`([A-Za-z0-9_./*{}-]+?)`")
TOP_LEVEL = ("src/", "benchmarks/", "examples/", "experiments/", "tests/",
             "tools/", ".github/", "core/", "models/", "kernels/",
             "launch/", "runtime/", "configs/")
FILE_SUFFIXES = (".py", ".md", ".csv", ".yml", ".json", ".txt")


def looks_like_path(tok: str) -> bool:
    if tok.startswith(TOP_LEVEL):
        return True
    return "/" not in tok and tok.endswith(FILE_SUFFIXES) and "*" not in tok


def resolve(tok: str) -> bool:
    """True if the token matches at least one real path.

    Handles: bare filenames (`dag.py` -> searched recursively), module
    paths relative to src/repro (`core/sched_engine.py`), dotted member
    references (`core/adaptive.compare_policies` -> core/adaptive.py),
    `{a,b}` alternation and `*` globs."""
    candidates = [tok,
                  os.path.join("src", "repro", tok),
                  os.path.join("**", tok)]
    if not tok.endswith(FILE_SUFFIXES):
        # `core/adaptive.compare_policies` -> the module file
        base = tok.split(".")[0]
        candidates += [base + ".py",
                       os.path.join("src", "repro", base + ".py")]
    out = []
    for c in candidates:
        m = re.match(r"(.*)\{([^}]*)\}(.*)", c)
        if m:
            out += [m.group(1) + alt + m.group(3)
                    for alt in m.group(2).split(",")]
        else:
            out.append(c)
    for c in out:
        if glob.glob(os.path.join(ROOT, c), recursive=True):
            return True
    return False


def main() -> int:
    problems: list[str] = []

    texts = {}
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            problems.append(f"{doc}: missing")
            continue
        texts[doc] = open(path).read()

    # 1. every path-looking backtick reference exists
    for doc, text in texts.items():
        for tok in PATH_RE.findall(text):
            if looks_like_path(tok) and not resolve(tok):
                problems.append(f"{doc}: references `{tok}` "
                                f"but no such path exists")

    # 2. policy registry <-> docs agreement
    try:
        from repro.core import SCHEDULING_POLICIES
        registered = set(SCHEDULING_POLICIES)
    except Exception as e:  # pragma: no cover - import environment broken
        problems.append(f"cannot import SCHEDULING_POLICIES: {e}")
        registered = set()
    for doc, text in texts.items():
        for name in registered:
            if f"`{name}`" not in text and f'"{name}"' not in text:
                problems.append(
                    f"{doc}: scheduling policy {name!r} is registered but "
                    f"undocumented")
    # the DESIGN policy table rows: | `name` | ... | — scan only the
    # "Scheduling policies" section so other tables don't false-positive
    design = texts.get("DESIGN.md", "")
    m = re.search(r"### Scheduling policies(.*?)(?:\n#|\Z)", design,
                  re.S)
    for row_name in re.findall(r"^\| `([a-z_]+)` +\|",
                               m.group(1) if m else "", re.M):
        if row_name not in registered:
            problems.append(
                f"DESIGN.md: policy table lists {row_name!r} which is not "
                f"in SCHEDULING_POLICIES")

    # 3. run-API knob dataclasses <-> docs agreement: every field of the
    # public options classes must be documented somewhere
    n_knobs = 0
    try:
        import dataclasses as _dc

        from repro.core import (AdmissionOptions, ElasticOptions,
                                FaultOptions, FeedbackOptions,
                                PredictOptions, RunConfig, Scenario,
                                SimOptions, SWFMapOptions)
        knob_classes = (RunConfig, ElasticOptions, AdmissionOptions,
                        FaultOptions, FeedbackOptions, PredictOptions,
                        SimOptions, SWFMapOptions, Scenario)
    except Exception as e:  # pragma: no cover - import environment broken
        problems.append(f"cannot import run-API knob classes: {e}")
        knob_classes = ()
    everywhere = "\n".join(texts.values())
    for cls in knob_classes:
        if f"`{cls.__name__}" not in everywhere:
            problems.append(f"run-API class {cls.__name__!r} is public "
                            f"but undocumented in README.md/DESIGN.md")
        for field in _dc.fields(cls):
            n_knobs += 1
            if f"`{field.name}" not in everywhere:
                problems.append(
                    f"{cls.__name__}.{field.name}: knob is public but "
                    f"undocumented in README.md/DESIGN.md")

    if problems:
        print("docs-check: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_refs = sum(1 for t in texts.values() for tok in PATH_RE.findall(t)
                 if looks_like_path(tok))
    print(f"docs-check: OK ({n_refs} path references, "
          f"{len(registered)} policies, {n_knobs} run-API knobs "
          f"cross-checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Engine scaling: pass cost proportional to change, not cluster size.

Drives ``SchedEngine`` directly (no simulator clock, no substrate) over
a synthetic campaign-scale workload — 10^4-10^5 tasks on 10^2-10^3
node-level nodes — and measures the dispatch loop itself:

- **decisions/sec** — tasks placed per second of wall time across the
  whole drive loop (startable + complete churn), per engine arm:
  ``incremental=True`` (the indexed fast path: per-pool fit classes,
  bucket-counted free blocks, lazy spread heap, blocked-set skipping)
  vs ``incremental=False`` (the pre-index brute-force scans);
- **per-decision pass latency** — steady-state ``startable()`` time per
  placement.  The scan arm rescans every node per candidate check, so
  its per-decision cost grows linearly with node count; the indexed arm
  touches only what changed and must stay sublinear;
- **dispatch identity** — at the smallest scale point both arms are
  driven to completion in lockstep and must emit the SAME placement
  sequence (the indexes change the cost of a pass, never its result).

The scan arm is *sampled* at the larger points (a fixed decision
budget, recorded in the output) — driving 10^5 tasks through an
O(nodes)-per-decision scan would take minutes for no extra
information; its per-decision cost is stationary after warm-up.

Headlines asserted here and gated by ``tools/bench_check.py`` against
``benchmarks/baseline/engine_scale.json``:

- speedup (decisions/sec, indexed over scan) >= 10 at the largest
  scale point;
- indexed per-decision pass latency sublinear in node count: growing
  node count 10x (and tasks with it) must grow it < 4x;
- dispatch identity between the arms.

Timing fields vary across machines and are NOT compared against the
committed baseline (no key here contains "makespan"); the gate runs on
the fresh headline flags + the drift/identity checks of the four
existing benchmark baselines.

Writes ``benchmarks/out/engine_scale.json``.
"""

from __future__ import annotations

import json
import os
import time
from collections import deque

from repro.core import DAG, NodeSpec, PoolSpec, SchedEngine, TaskSet

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

#: (total tasks, nodes): 100 tasks per node, Summit-like 4-GPU nodes
SCALE_POINTS = ((10_000, 100), (30_000, 300), (100_000, 1_000))
#: decision budget for the sampled brute-force-scan arm
SCAN_BUDGET = 2_000
#: steady-state window: skip the cold first passes (index build, first
#: giant wave) when averaging pass latency
WARMUP_PASSES = 2
#: the indexed arm must grow per-decision latency < this factor while
#: node count grows 10x (linear rescans grow ~10x)
SUBLINEAR_LIMIT = 4.0


def scale_workload(tasks_total: int, nodes: int) -> tuple[DAG, PoolSpec]:
    """A 4-layer x 5-set campaign slice: wide waves with cross-layer
    dependencies, every task 4 CPUs + 1 GPU (4 per 4-GPU node)."""
    layers, width = 4, 5
    per_set = tasks_total // (layers * width)
    g = DAG()
    for layer in range(layers):
        for w in range(width):
            g.add(TaskSet(f"L{layer}W{w}", per_set, 4, 1,
                          tx_mean=100.0, tx_sigma=0.0))
            if layer:
                g.add_edge(f"L{layer - 1}W{w}", f"L{layer}W{w}")
    pool = PoolSpec("hpc", nodes, NodeSpec(cpus=32, gpus=4,
                                           nvlink_groups=2),
                    node_level=True)
    return g, pool


def drive(eng: SchedEngine, max_decisions: "int | None" = None,
          trace: "list | None" = None) -> dict:
    """Run the engine's dispatch loop to completion (or to a decision
    budget): launch everything startable, then complete the oldest
    quarter of the running queue to churn occupancy.  Deterministic —
    no RNG, no clock — so two arms driven this way emit identical
    placement sequences."""
    running: deque = deque()
    decisions = 0
    pass_times: list[float] = []
    t_begin = time.perf_counter()
    while True:
        t0 = time.perf_counter()
        started = eng.startable()
        pass_times.append(time.perf_counter() - t0)
        for name, i, k in started:
            if trace is not None:
                trace.append((name, i, k, eng.node_placement(name, i)))
            running.append((name, i))
        decisions += len(started)
        if max_decisions is not None and decisions >= max_decisions:
            break
        if not running:
            break
        for _ in range(max(1, len(running) // 4)):
            name, i = running.popleft()
            eng.complete(name, i)
    elapsed = time.perf_counter() - t_begin
    steady = pass_times[WARMUP_PASSES:] or pass_times
    return dict(
        decisions=decisions,
        elapsed_s=round(elapsed, 4),
        decisions_per_sec=round(decisions / elapsed, 1),
        passes=len(pass_times),
        steady_pass_ms=round(1e3 * sum(steady) / len(steady), 4),
        per_decision_us=round(1e6 * sum(pass_times) / max(1, decisions),
                              3),
    )


def run_point(tasks_total: int, nodes: int, largest: bool) -> dict:
    g, pool = scale_workload(tasks_total, nodes)
    inc = drive(SchedEngine(g, pool, incremental=True))
    assert inc["decisions"] == sum(ts.num_tasks for ts in g.nodes.values())
    g2, pool2 = scale_workload(tasks_total, nodes)
    scan = drive(SchedEngine(g2, pool2, incremental=False),
                 max_decisions=SCAN_BUDGET)
    scan["sampled"] = scan["decisions"] < inc["decisions"]
    return dict(
        tasks=tasks_total, nodes=nodes,
        incremental=inc, scan=scan,
        speedup=round(inc["decisions_per_sec"]
                      / scan["decisions_per_sec"], 2),
    )


def run_identity(tasks_total: int, nodes: int) -> dict:
    """Both arms driven to completion: same placement sequence."""
    traces = []
    for incremental in (True, False):
        g, pool = scale_workload(tasks_total, nodes)
        trace: list = []
        drive(SchedEngine(g, pool, incremental=incremental), trace=trace)
        traces.append(trace)
    return dict(tasks=tasks_total, nodes=nodes,
                decisions=len(traces[0]),
                identical=traces[0] == traces[1])


def main() -> dict:
    print("== engine scaling: indexed (incremental) vs brute-force-scan "
          "dispatch ==")
    points = []
    for tasks_total, nodes in SCALE_POINTS:
        largest = (tasks_total, nodes) == SCALE_POINTS[-1]
        pt = run_point(tasks_total, nodes, largest)
        points.append(pt)
        print(f"  {tasks_total:7d} tasks / {nodes:5d} nodes: "
              f"indexed {pt['incremental']['decisions_per_sec']:>10.1f}/s "
              f"(pass {pt['incremental']['steady_pass_ms']:.2f} ms)  "
              f"scan {pt['scan']['decisions_per_sec']:>9.1f}/s"
              f"{' [sampled]' if pt['scan']['sampled'] else ''}  "
              f"speedup {pt['speedup']:.1f}x")

    print("== dispatch identity (both arms driven to completion) ==")
    ident = run_identity(*SCALE_POINTS[0])
    print(f"  {ident['tasks']} tasks / {ident['nodes']} nodes: "
          f"{ident['decisions']} decisions identical={ident['identical']}")
    assert ident["identical"], ident

    speedup_largest = points[-1]["speedup"]
    # nodes grew 10x smallest -> largest; indexed per-decision latency
    # must not follow (the scan arm's does — that is the whole point)
    lat = [p["incremental"]["per_decision_us"] for p in points]
    sublinear_ratio = round(lat[-1] / lat[0], 2)
    headlines = dict(
        speedup_largest=speedup_largest,
        sublinear_ratio=sublinear_ratio,
        sublinear=sublinear_ratio < SUBLINEAR_LIMIT,
        dispatch_identity=ident["identical"],
    )
    print(f"== headlines: speedup@largest={speedup_largest:.1f}x  "
          f"per-decision growth over 10x nodes={sublinear_ratio:.2f}x "
          f"(sublinear={headlines['sublinear']}) ==")
    assert speedup_largest >= 10.0, headlines
    assert headlines["sublinear"], headlines

    out = {"scale_points": points, "identity": ident,
           "headlines": headlines,
           "config": dict(scan_budget=SCAN_BUDGET,
                          warmup_passes=WARMUP_PASSES,
                          sublinear_limit=SUBLINEAR_LIMIT)}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "engine_scale.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  engine_scale: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

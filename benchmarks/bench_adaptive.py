"""BEYOND PAPER: adaptive (task-level) asynchronicity — the paper's own
future work (§6.1 fn. 3, §8).

Set-level async (the paper) makes a child task wait for its WHOLE parent
set; task-level async releases each child task as soon as its matching
parent task finishes.  We quantify the additional makespan/throughput gain
on the paper's own workloads and on a scaled 1024-node allocation."""

from __future__ import annotations

from repro.core import (SimOptions, cdg_dag, compare_policies,
                        deepdrivemd_dag, summit_pool)


def main():
    print("== adaptive (task-level) asynchronicity ==")
    rows = []
    workloads = {
        "DeepDriveMD": deepdrivemd_dag(3),
        "c-DG2": cdg_dag("c-DG2"),
    }
    pools = {
        "summit-16": summit_pool(16),
        "summit-1024": summit_pool(1024),
    }
    for wname, dag in workloads.items():
        for pname, pool in pools.items():
            cmp = compare_policies(dag, pool, options=SimOptions(seed=5))
            rows.append(dict(
                workload=wname, pool=pname,
                t_seq=round(cmp.sequential.makespan, 1),
                t_async=round(cmp.asynchronous.makespan, 1),
                t_adaptive=round(cmp.adaptive.makespan, 1),
                t_observed=round(cmp.adaptive_observed.makespan, 1),
                i_async=round(cmp.improvement_async, 3),
                i_adaptive=round(cmp.improvement_adaptive, 3),
                adaptive_gain=round(cmp.adaptive_gain_over_async, 3),
                observed_gain=round(cmp.observed_gain_over_adaptive, 3)))
    for r in rows:
        print("  " + "  ".join(f"{k}={v}" for k, v in r.items()))
    # adaptive must never be slower than set-level async
    for r in rows:
        assert r["t_adaptive"] <= r["t_async"] * 1.02, r
    small = [r for r in rows if r["pool"] == "summit-16"]
    assert any(r["adaptive_gain"] > 0.01 for r in small), \
        "task-level release should help at least one workload"
    return rows


if __name__ == "__main__":
    main()

"""Paper Table 3: the full summary — DOA_dep, DOA_res, WLA, t_seq,
t_async (predicted vs simulated) and I for all three experiments, plus the
model-vs-simulation agreement check (the paper reports <= 6% disagreement
modulo constant overheads)."""

from __future__ import annotations

from benchmarks import bench_cdg, bench_deepdrivemd


def main():
    rows = []
    d = bench_deepdrivemd.run(write_csv=False)
    rows.append(dict(
        experiment="DeepDriveMD", doa_dep=d["doa_dep"], doa_res=d["doa_res"],
        wla=d["wla"], t_seq_pred=d["t_seq_pred"], t_seq_meas=d["t_seq_sim"],
        t_async_pred=d["t_async_pred"], t_async_meas=d["t_async_sim"],
        i_pred=d["i_pred"], i_meas=d["i_sim"],
        paper_i_meas=d["paper"]["i_meas"]))
    for which in ("c-DG1", "c-DG2"):
        c = bench_cdg.run(which, write_csv=False)
        rows.append(dict(
            experiment=which, doa_dep=c["doa_dep"], doa_res=c["paper"]["doa_res"],
            wla=c["wla"], t_seq_pred=c["t_seq_model"],
            t_seq_meas=c["t_seq_sim"], t_async_pred=c["t_async_pred"],
            t_async_meas=c["t_async_sim_shared"],
            i_pred=c["i_pred"], i_meas=c["i_sim_shared"],
            paper_i_meas=c["paper"]["i_meas"]))

    hdr = ("experiment", "doa_dep", "doa_res", "wla", "t_seq_pred",
           "t_seq_meas", "t_async_pred", "t_async_meas", "i_pred", "i_meas",
           "paper_i_meas")
    print("== Table 3 (predicted vs simulated vs paper) ==")
    print("  " + "  ".join(f"{h:>12s}" for h in hdr))
    for r in rows:
        print("  " + "  ".join(f"{str(r[h]):>12s}" for h in hdr))

    # the paper's agreement claim: model predicts measured TTX within ~6%
    for r in rows:
        err = abs(r["t_async_pred"] - r["t_async_meas"]) / r["t_async_meas"]
        assert err < 0.06, (r["experiment"], err)
    print("  model-vs-simulated async TTX agreement: < 6% everywhere (OK)")
    return rows


if __name__ == "__main__":
    main()

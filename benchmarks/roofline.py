"""Deliverable (g): roofline analysis per (arch x shape x mesh).

Three terms per cell, all per-device seconds for one step:

  compute    = analytic matmul/scan FLOPs   / peak bf16 FLOP/s
  memory     = analytic HBM traffic floor   / HBM bandwidth
  collective = loop-weighted HLO collective bytes / ICI link bandwidth

METHODOLOGY (full discussion in EXPERIMENTS.md §Roofline):
- collective bytes come from the COMPILED partitioned HLO (dry-run
  artifact), with while-loop bodies weighted by their known trip counts
  (XLA's own cost_analysis counts a scanned layer once; a 40-layer scan
  would otherwise be 40x under-counted).
- compute/memory come from the structural model in launch/analytic.py:
  XLA:CPU's flop counter has the same while-body blindness, and its
  'bytes accessed' reflects CPU fusion, not TPU VMEM reuse.  The raw XLA
  numbers are still recorded in the artifacts for reference.
- roofline_fraction = (MODEL_FLOPS/dev / peak) / max(terms): the fraction
  of peak the step achieves if it hits this roofline (MFU bound).
- useful_ratio = MODEL_FLOPS / analytic FLOPs: how much compiled compute
  is 6ND-useful (remat + attention + routing overhead shows up here).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.analytic import analyse_cell
from repro.launch.mesh import TPU_V5E

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def analyse(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec.get("devices", 256)
    cell = analyse_cell(cfg, shape, rec.get("params", 0),
                        rec.get("active_params", rec.get("params", 0)),
                        batch_axes_size=n_dev)
    flops_dev, hbm_dev, model_dev = cell.per_device(n_dev)
    coll = rec.get("collectives_weighted", rec.get("collectives", {}))
    coll_bytes = float(coll.get("total_bytes", 0.0))

    compute_s = flops_dev / TPU_V5E["peak_bf16_flops"]
    memory_s = hbm_dev / TPU_V5E["hbm_bytes_per_s"]
    collective_s = coll_bytes / TPU_V5E["ici_bytes_per_s"]
    step = max(compute_s, memory_s, collective_s)
    dominant = ("compute" if step == compute_s else
                "memory" if step == memory_s else "collective")
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, step_time_s=step,
        model_flops=cell.model_flops,
        useful_ratio=cell.model_flops / max(cell.flops_global, 1.0),
        roofline_fraction=(model_dev / TPU_V5E["peak_bf16_flops"]) / step
        if step else 0.0,
        collective_bytes=coll_bytes,
        xla_flops_per_dev=rec.get("cost", {}).get("flops"),
        tokens_per_s_roofline=(
            shape.seq_len * shape.global_batch / step
            if shape.mode != "decode" else shape.global_batch / step)
        if step else 0.0,
    )


def load_cells(mesh: str = "single_pod_16x16", tag: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRY_DIR, mesh, f"*{tag}.json"))):
        if tag == "" and "__hc" in os.path.basename(p):
            continue
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "run" and "roofline" in rec:
            out.append(rec)
    return out


def table(mesh: str = "single_pod_16x16", tag: str = "") -> list[dict]:
    return [analyse(rec) for rec in load_cells(mesh, tag)]


def main():
    print("== Roofline (single-pod 16x16, per-device terms, seconds) ==")
    rows = table()
    for a in rows:
        print(f"  {a['arch']:24s}{a['shape']:13s}"
              f"c={a['compute_s']:.3e} m={a['memory_s']:.3e} "
              f"x={a['collective_s']:.3e}  {a['dominant']:10s} "
              f"useful={a['useful_ratio']:.2f} "
              f"RF={a['roofline_fraction']:.3f}")
    assert len(rows) >= 33, f"expected >= 33 compiled cells, got {len(rows)}"
    for a in rows:
        assert a["step_time_s"] > 0, a
        assert 0 < a["useful_ratio"] <= 1.05, (
            a["arch"], a["shape"], a["useful_ratio"])
    worst = sorted(rows, key=lambda a: a["roofline_fraction"])[:3]
    print("  worst roofline fractions:",
          [(w["arch"], w["shape"], round(w["roofline_fraction"], 3))
           for w in worst])
    by_dom = {}
    for a in rows:
        by_dom[a["dominant"]] = by_dom.get(a["dominant"], 0) + 1
    print("  dominant-term histogram:", by_dom)
    return rows


if __name__ == "__main__":
    main()

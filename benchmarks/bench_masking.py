"""Paper §5.3 worked example + masking sensitivity sweep.

The fixed example (Fig. 2b with t0=500, t1=t2=1000, t3=t5=2000, t4=4000)
must give t_seq=7500, t_async=5500, I ~= 26%.  The sweep then varies the
branch imbalance to chart when asynchronicity pays — the decision surface
a workflow designer actually needs (the paper's §8 design guidance)."""

from __future__ import annotations

from repro.core import (SimOptions, async_ttx, fig2b_with_paper_tx,
                        relative_improvement, sequential_ttx, simulate,
                        summit_pool)


def worked_example():
    g = fig2b_with_paper_tx()
    t_seq = sequential_ttx(g)
    t_async, tails = async_ttx(g)
    i = relative_improvement(t_seq, t_async)
    print(f"  §5.3 example: t_seq={t_seq:.0f}s t_async={t_async:.0f}s "
          f"I={i:.3f} (paper: 7500 / 5500 / ~0.26)")
    assert t_seq == 7500 and t_async == 5500
    assert abs(i - 0.2667) < 1e-3
    return dict(t_seq=t_seq, t_async=t_async, i=i)


def sweep(points: int = 9):
    """Vary t4 (the masking branch) from 0.25x to 4x its paper value."""
    pool = summit_pool(16)
    rows = []
    for k in range(points):
        f = 0.25 * (4.0 / 0.25) ** (k / (points - 1))
        g = fig2b_with_paper_tx()
        g.replace("T4", tx_mean=4000.0 * f)
        t_seq = sequential_ttx(g)
        t_async, _ = async_ttx(g)
        sim_seq = simulate(g, pool, "sequential",
                           options=SimOptions(seed=3)).makespan
        sim_asy = simulate(g, pool, "async",
                           options=SimOptions(seed=3)).makespan
        rows.append(dict(
            t4_scale=round(f, 3),
            i_model=round(relative_improvement(t_seq, t_async), 3),
            i_sim=round(relative_improvement(sim_seq, sim_asy), 3)))
    print("  masking sweep (t4 x):",
          " ".join(f"{r['t4_scale']}->{r['i_model']:+.2f}/{r['i_sim']:+.2f}"
                   for r in rows))
    # model and simulation must agree on the trend
    for r in rows:
        assert abs(r["i_model"] - r["i_sim"]) < 0.08, r
    return rows


def main():
    print("== §5.3 TX masking ==")
    out = worked_example()
    rows = sweep()
    return dict(example=out, sweep=rows)


if __name__ == "__main__":
    main()

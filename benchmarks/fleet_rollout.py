import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf fleet rollout: apply the confirmed pure-DP recipe (hillclimb
cell 1) to the remaining small-model collective-bound train cells and
measure the generalisation across families (linear-attention, hybrid
SSM, SWA dense, encoder-decoder).

Run:  PYTHONPATH=src:. python -m benchmarks.fleet_rollout
"""

import json

from repro.launch.dryrun import run_cell
from repro.runtime import ShardingRules

from benchmarks.roofline import analyse

#: the confirmed recipe: batch over every mesh axis, nothing else sharded
PURE_DP = dict(batch=("pod", "data", "model"), embed=None, ffn=None,
               heads=None, kv_heads=None, vocab=None, act_ffn=None,
               act_heads=None, act_vocab=None)

ARCHS = ("rwkv6-1.6b", "zamba2-1.2b", "h2o-danube-1.8b", "whisper-tiny")


def main():
    rows = []
    for arch in ARCHS:
        base_path = os.path.join(
            os.path.dirname(__file__), "..", "experiments", "dryrun",
            "single_pod_16x16", f"{arch}__train_4k.json")
        with open(base_path) as f:
            base = json.load(f)
        rec = run_cell(arch, "train_4k", multi_pod=False,
                       rules=ShardingRules().override(**PURE_DP),
                       tag="__hc_dp256", verbose=False)
        if rec.get("status") == "error":
            print(arch, "FAIL", rec.get("error", "")[:300])
            continue
        b, v = analyse(base), analyse(rec)
        rows.append((arch, b, v))
        print(f"  {arch:18s} collective {b['collective_s']:.3e} -> "
              f"{v['collective_s']:.3e}  RF {b['roofline_fraction']:.3f} -> "
              f"{v['roofline_fraction']:.3f}  dominant {b['dominant']} -> "
              f"{v['dominant']}")
    # the recipe must decisively win on every rollout target
    for arch, b, v in rows:
        assert v["roofline_fraction"] > 5 * b["roofline_fraction"], arch
        assert v["dominant"] == "compute", arch
    return rows


if __name__ == "__main__":
    main()

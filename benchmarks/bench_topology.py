"""Node-level topology: NVLink-aware packing + contention-aware
prediction (``core/resources.py`` node model, ``nodepack`` policy,
``core/predictor.py`` cross-set contention term).

Three claims, all asserted (CI gates on them via
``benchmarks/baseline/topology.json`` + ``make bench-check``):

(a) **Packing** — on a fragmented multi-GPU mix (an ML-serving stream of
    1-GPU tasks next to periodic 6-GPU training tasks that each need a
    whole node), ``nodepack`` — which packs narrow tasks into the
    tightest NVLink groups, preserving contiguous free blocks — beats
    pool-aggregate-minded ``gpu_bestfit`` (RM-default *spread* node
    choice) on mean makespan: spreading leaves every node partially
    busy, so the wide tasks wait for whole nodes to drain.

(b) **Contention-aware prediction** — on strict-GPU c-DG2 (the paper's
    Summit allocation WITHOUT GPU sharing, where rank-2 task sets demand
    112 GPUs on 96), the mid-run re-prediction error is strictly lower
    with the cross-set contention term (node-level occupancy feeding
    ``MakespanPredictor._effective_slots``) than without: T3/T6 waves
    serialize behind T4/T5's GPUs, which the per-set path bound cannot
    see.  The schedules themselves are identical (1-GPU tasks cannot
    fragment a 6-GPU node), so the error delta is pure predictor.

(c) **Aggregate bit-identity** — with ``node_level=False`` (the default)
    nothing changes: re-running one seed of each committed baseline
    configuration (``predictor.json`` convergence seed 3,
    ``runtime_feedback.json`` c-DG2 migration arm seed 3) reproduces the
    committed makespans exactly.

Writes ``benchmarks/out/topology.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (DAG, Allocation, FeedbackOptions, NodeSpec, PoolSpec,
                        SimOptions, TaskSet, cdg_dag, simulate, summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

FRAG_SEEDS = tuple(range(1, 9))
CONTENTION_SEEDS = (3, 7, 11, 13, 17)
#: heavy-tailed durations, as in bench_predictor's convergence run
LOGNORMAL = dict(tx_distribution="lognormal", lognormal_sigma=0.5)


def frag_pool() -> PoolSpec:
    """4 GPU nodes, 6 GPUs each in 2 NVLink groups of 3 (Summit-like),
    node-granular accounting."""
    return PoolSpec("gpu", 4, NodeSpec(cpus=32, gpus=6, nvlink_groups=2),
                    node_level=True)


def frag_dag() -> DAG:
    """The fragmented multi-GPU mix: a 1-GPU inference stream occupying
    the cluster when 6-GPU (whole-node) training tasks arrive mid-run,
    with more 1-GPU serving work backfilling around them."""
    g = DAG()
    g.add(TaskSet("stage_a", 12, 2, 1, tx_mean=100.0, tx_sigma=15.0,
                  kind="inference"))
    g.add(TaskSet("trigger", 1, 2, 0, tx_mean=50.0, tx_sigma=2.0))
    g.add(TaskSet("train", 2, 4, 6, tx_mean=400.0, tx_sigma=10.0,
                  kind="training"))
    g.add(TaskSet("serve", 16, 2, 1, tx_mean=80.0, tx_sigma=10.0,
                  kind="inference"))
    g.add_edge("trigger", "train")
    g.add_edge("trigger", "serve")
    return g


def run_fragmented() -> dict:
    out: dict = {"seeds": list(FRAG_SEEDS), "arms": {}}
    for policy in ("gpu_bestfit", "nodepack"):
        ms = []
        for seed in FRAG_SEEDS:
            res = simulate(frag_dag(), frag_pool(), "async",
                           options=SimOptions(seed=seed), scheduling=policy)
            assert res.tasks_total == 31
            assert all(r.node >= 0 for r in res.records)
            ms.append(res.makespan)
        out["arms"][policy] = dict(
            makespan_mean=round(sum(ms) / len(ms), 1),
            makespans=[round(m, 1) for m in ms])
    return out


def midrun_error(res, lo: float = 0.1, hi: float = 0.9) -> float:
    """Mean |predicted total - realized| / realized over the mid-run
    prediction window (done fraction in [lo, hi])."""
    errs = [abs(p.total - res.makespan) / res.makespan
            for p in res.predictions if lo <= p.done_fraction <= hi]
    return sum(errs) / len(errs)


def run_contention() -> dict:
    fb = FeedbackOptions(straggler_k=2.0)
    per_seed = {}
    sum_with = sum_without = 0.0
    for seed in CONTENTION_SEEDS:
        opts = SimOptions(seed=seed, **LOGNORMAL)
        base = simulate(cdg_dag("c-DG2"), summit_pool(), "async",
                        options=opts, feedback=fb)
        node = simulate(cdg_dag("c-DG2"), summit_pool(node_level=True),
                        "async", options=opts, feedback=fb)
        # same schedule — the error delta is pure predictor
        assert base.makespan == node.makespan, (seed, base.makespan,
                                                node.makespan)
        e_without, e_with = midrun_error(base), midrun_error(node)
        per_seed[seed] = dict(makespan=round(base.makespan, 1),
                              err_without=round(e_without, 4),
                              err_with=round(e_with, 4))
        sum_without += e_without
        sum_with += e_with
    n = len(CONTENTION_SEEDS)
    return dict(seeds=list(CONTENTION_SEEDS),
                err_without=round(sum_without / n, 4),
                err_with=round(sum_with / n, 4),
                per_seed=per_seed)


def run_baseline_identity() -> dict:
    """Recompute one seed of each committed-baseline configuration with
    the (default) aggregate resource model and compare bit-exactly."""
    out: dict = {}

    # predictor.json convergence, seed 3: c-DG2 shared-GPU + lognormal
    shared = dataclasses.replace(summit_pool(), oversubscribe_gpus=True)
    res = simulate(cdg_dag("c-DG2"), shared, "async",
                   options=SimOptions(seed=3, **LOGNORMAL),
                   feedback=FeedbackOptions(straggler_k=2.0, speculate=True))
    with open(os.path.join(BASELINE_DIR, "predictor.json")) as f:
        committed = json.load(f)["convergence"]["per_seed"]["3"]["makespan"]
    out["predictor_seed3"] = dict(fresh=round(res.makespan, 1),
                                  committed=committed,
                                  identical=round(res.makespan, 1)
                                  == committed)

    # runtime_feedback.json c-DG2 migration arm, seed 3: split Summit +
    # lognormal + 10% x16 stragglers, lpt + full feedback
    half = summit_pool(8)
    split = Allocation(
        "summit-split",
        (dataclasses.replace(half, name="summit-a"),
         dataclasses.replace(half, name="summit-b")),
        transfer_cost=((0.0, 10.0), (10.0, 0.0)))
    res = simulate(cdg_dag("c-DG2"), split, "async",
                   options=SimOptions(seed=3, straggler_prob=0.1,
                                      straggler_factor=16.0, **LOGNORMAL),
                   scheduling="lpt",
                   feedback=FeedbackOptions(straggler_k=2.0))
    with open(os.path.join(BASELINE_DIR, "runtime_feedback.json")) as f:
        wl = next(w for w in json.load(f)["workloads"]
                  if w["workload"] == "c-DG2")
    committed = wl["arms"]["migration"]["makespans"][0]
    out["feedback_seed3"] = dict(fresh=round(res.makespan, 1),
                                 committed=committed,
                                 identical=round(res.makespan, 1)
                                 == committed)
    return out


def main() -> dict:
    print("== (a) nodepack vs gpu_bestfit, fragmented multi-GPU mix "
          "(4x6-GPU nodes, 2 NVLink groups each) ==")
    frag = run_fragmented()
    for arm, r in frag["arms"].items():
        print(f"  {arm:12s} mean={r['makespan_mean']:8.1f}  "
              f"{r['makespans']}")
    a = frag["arms"]
    assert a["nodepack"]["makespan_mean"] <= \
        a["gpu_bestfit"]["makespan_mean"], frag
    # every seed, not just the mean: packing must never lose here
    for np_m, bf_m in zip(a["nodepack"]["makespans"],
                          a["gpu_bestfit"]["makespans"]):
        assert np_m <= bf_m, frag

    print("== (b) contention-aware prediction, strict-GPU c-DG2 "
          "(112-GPU rank-2 demand on 96 GPUs) ==")
    cont = run_contention()
    print(f"  mid-run mean |err|: without={cont['err_without']:.4f}  "
          f"with={cont['err_with']:.4f}")
    assert cont["err_with"] < cont["err_without"], cont
    for seed, r in cont["per_seed"].items():
        assert r["err_with"] < r["err_without"], (seed, cont)

    print("== (c) node_level=False stays bit-identical to the committed "
          "baselines ==")
    ident = run_baseline_identity()
    for which, r in ident.items():
        print(f"  {which:16s} fresh={r['fresh']} committed={r['committed']}"
              f" identical={r['identical']}")
        assert r["identical"], (which, ident)

    out = {"fragmented": frag, "contention": cont,
           "baseline_identity": ident}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "topology.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  topology: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower the three chosen cells with variant
sharding rules / perf flags / mesh aspect ratios and report
hypothesis -> before -> after.  The full hypothesis log (confirmed AND
refuted) is in EXPERIMENTS.md §Perf; by default this re-runs only the
final confirmed variant per cell (--all re-runs every iteration).

Cells (selection rule from the assignment):
  qwen2-0.5b x train_4k           worst roofline fraction (0.047)
  llama4-scout-17b-a16e x train_4k    most collective-bound (21.3 s/step)
  qwen3-moe-30b-a3b x decode_32k      serving path (the paper's async focus)

Run:  PYTHONPATH=src:. python -m benchmarks.hillclimb [--all] [--cell N]
"""

import argparse
import json

from repro.launch.dryrun import OUT_DIR, run_cell
from repro.runtime import ShardingRules

from benchmarks.roofline import analyse


def load(arch, shape, tag=""):
    p = os.path.join(OUT_DIR, "single_pod_16x16",
                     f"{arch}__{shape}{tag}.json")
    with open(p) as f:
        return json.load(f)


def report(title, base, var):
    b, v = analyse(base), analyse(var)
    print(f"\n  -- {title}")
    for k in ("compute_s", "memory_s", "collective_s", "step_time_s",
              "roofline_fraction"):
        delta = (v[k] / b[k] - 1) * 100 if b[k] else 0.0
        print(f"     {k:18s} {b[k]:.4e} -> {v[k]:.4e}  ({delta:+.1f}%)")
    print(f"     dominant           {b['dominant']} -> {v['dominant']}")
    return b, v


def V(tag, hypothesis, *, rules=None, flags=None, mesh=None, final=False):
    return dict(tag=tag, hypothesis=hypothesis, rules=rules, flags=flags,
                mesh=mesh, final=final)


#: full iteration history per cell (see EXPERIMENTS.md §Perf for outcomes)
VARIANTS = [
    ("qwen2-0.5b", "train_4k", [
        V("__hc_dp", "H1 (refuted, -0.3%): drop FSDP 'embed' sharding",
          rules=lambda: ShardingRules().override(embed=None)),
        V("__hc_dp_seq", "H2 (refuted, +37%): add sequence parallelism",
          rules=lambda: ShardingRules().override(embed=None, seq="model")),
        # H3 (refuted, +197%): pin flash-scan shardings — code-level, reverted
        V("__hc_dp256", "H4 (CONFIRMED, collective -96.2%, RF 0.046 -> "
          "0.849): 0.5B params need no model parallelism on 256 chips — "
          "pure DP, batch over (data x model), params replicated "
          "(opt state 6 GB/dev fits); only the gradient all-reduce remains",
          rules=lambda: ShardingRules().override(
              batch=("pod", "data", "model"), embed=None, ffn=None,
              heads=None, kv_heads=None, vocab=None, act_ffn=None,
              act_heads=None, act_vocab=None),
          final=True),
    ]),
    ("llama4-scout-17b-a16e", "train_4k", [
        V("__hc_bf16", "H1 (refuted, +100%): bf16-cast expert stacks before "
          "the shard_map boundary", flags={"moe_gather_bf16": True}),
        V("__hc_mesh32x8", "H3 (refuted, -0.5%): mesh 32x8 so the model "
          "axis divides 40 heads", mesh=(32, 8)),
        V("__hc_hp32x8", "H4 (refuted, +0.1%): + explicit head-parallel "
          "shard_map attention", mesh=(32, 8),
          flags={"headparallel_attn": True}),
        # H5 (refuted, +0.0%): + ZeRO-3 model-keeping gathers
        # H5b (refuted, +58%): remat=False ablation (14.7s)
        V("__hc_dp_ep", "H6 (CONFIRMED, collective -77.9%, RF 0.100 -> "
          "0.454): full-DP dense path (batch over data x model, dense "
          "weights gathered bf16 per layer, ZeRO-3) + experts EP over "
          "'model' with bf16 gathers; remaining ~236GB all-gather is "
          "~1.8x the ZeRO bf16 weight-gather floor",
          rules=lambda: ShardingRules().override(
              batch=("pod", "data", "model")),
          flags={"zero3_gather": True, "zero3_full": True,
                 "moe_gather_bf16": True},
          final=True),
    ]),
    ("qwen3-moe-30b-a3b", "decode_32k", [
        V("__hc_flashdec", "H1 (confirmed direction, -87.1%): explicit "
          "shard_map flash-decoding — partial softmax per sequence shard, "
          "psum log-sum-exp combine, local cache scatter",
          flags={"sharded_decode": True}),
        V("__hc_flashdec_resident", "H2 (CONFIRMED, collective -99.96%, "
          "step 1.18s -> 2.5ms, now memory-bound = at the decode "
          "bandwidth roofline): + serving weights resident per model rank "
          "(no ZeRO 'data' sharding to re-gather at every layer)",
          rules=lambda: ShardingRules().override(embed=None),
          flags={"sharded_decode": True},
          final=True),
    ]),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=None)
    ap.add_argument("--all", action="store_true",
                    help="re-run every iteration, not just the finals")
    args = ap.parse_args()
    cells = VARIANTS if args.cell is None else [VARIANTS[args.cell]]

    results = []
    for arch, shape, variants in cells:
        base = load(arch, shape)
        print(f"\n== hillclimb: {arch} x {shape} "
              f"(baseline dominant: {analyse(base)['dominant']}) ==")
        for var in variants:
            if not args.all and not var["final"]:
                print(f"  [skip non-final] {var['tag']}: "
                      f"{var['hypothesis'][:72]}")
                continue
            print(f"\n  hypothesis: {var['hypothesis']}")
            rec = run_cell(
                arch, shape, multi_pod=False,
                rules=var["rules"]() if var["rules"] else ShardingRules(),
                flags=var["flags"], tag=var["tag"],
                mesh_shape=var["mesh"], verbose=False)
            if rec.get("status") == "error":
                print("  FAILED:", rec.get("error"))
                continue
            results.append(report(var["tag"], base, rec))
    return results


if __name__ == "__main__":
    main()

"""Streaming service tenancy: open arrival streams with SLOs + elastic
capacity (``core/stream.WorkflowStream``, ``RunConfig``,
``ElasticOptions``, deadline-aware admission).

The 1-hour open-stream scenario (per seed): a node-level 8-node Summit
slice serves a diurnal batch-inference arrival process
(`examples/serve_batch.py`-shaped decode jobs with per-arrival SLOs),
interleaved with mid-priority multi-GPU analysis jobs on tight
deadlines and a fixed-cadence low-priority training job — the serving
fleet's recurring fine-tune.  The day/night swing saturates the slice
around the diurnal peak and leaves it half-idle off-peak.

Two arms, asserted per seed (CI gates on them via
``benchmarks/baseline/streaming.json`` + ``make bench-check``):

(a) **SLO headline** — deadline-aware admission + preemptive revocation
    + elastic node leases (``aware_elastic``) attains at least the SLO
    fraction of deadline-blind admission on the static slice
    (``blind_static``) and no worse a P99 weighted slowdown, on every
    seed.  The deadline-blind arm defers the wide analysis jobs on
    price alone (no masking win, long device pinning) until they age
    out — turning likely SLO misses into certain ones; the aware arm's
    deadline override admits them while they still fit, revoking an
    admitted-but-unstarted training job when one is in the way, and the
    diurnal peak is absorbed by leased burst nodes that drain and
    retire off-peak.

(b) **Mechanism coverage** — revocation fires (aggregate across seeds)
    and never kills a started workflow (engine invariant), and elastic
    leases are both granted and expired on every seed; stream
    conservation (arrived == finished) holds everywhere.

(c) **Bit-identity** — wrapping the committed 3-workflow admission
    campaign in a ``CampaignStream`` and passing admission via
    ``RunConfig`` reproduces ``admission.json``'s seed-1 makespan
    exactly, and the frozen-``RunConfig`` call form reproduces
    ``predictor.json``'s convergence seed 3 exactly: the streaming API
    may not disturb a closed-campaign schedule by a single event.

Writes ``benchmarks/out/streaming.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (DAG, AdmissionOptions, ElasticOptions,
                        CampaignStream, FeedbackOptions, GeneratedStream,
                        RunConfig, SimOptions, StreamTemplate, TaskSet,
                        cdg_dag, simulate, summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

SEEDS = (1, 2, 3, 4, 5)
LOGNORMAL = dict(tx_distribution="lognormal", lognormal_sigma=0.5)
#: the 1-hour open-stream horizon (modelled seconds)
HORIZON = 3600.0
#: steady-state reporting window (modelled seconds)
WINDOW = 900.0


def service_pool():
    """The static slice: 8 node-level Summit nodes (48 GPUs)."""
    return summit_pool(8, node_level=True)


def infer_dag() -> DAG:
    """One batch-decode job (`examples/serve_batch.py` shape): a prefill
    wave pacing a decode wave, 4 x 1-GPU tasks each."""
    g = DAG()
    g.add(TaskSet("prefill", 4, 4, 1, tx_mean=40.0, kind="inference"))
    g.add(TaskSet("decode", 4, 4, 1, tx_mean=60.0, kind="inference"))
    g.add_edge("prefill", "decode")
    return g


def analysis_dag() -> DAG:
    """A deadline-carrying analysis job: 2 whole-node 6-GPU tasks."""
    g = DAG()
    g.add(TaskSet("ana", 2, 8, 6, tx_mean=240.0, kind="analysis"))
    return g


def train_dag() -> DAG:
    """The recurring low-priority fine-tune: 4 x 6-GPU x 500 s tasks."""
    g = DAG()
    g.add(TaskSet("tune", 4, 8, 6, tx_mean=500.0, kind="training"))
    return g


def references(seed: int) -> dict[str, float]:
    """Dedicated single-tenant makespans (slowdown denominators)."""
    opts = SimOptions(seed=seed, **LOGNORMAL)
    return {name: simulate(dag(), service_pool(), "async",
                           options=opts).makespan
            for name, dag in (("infer", infer_dag),
                              ("analysis", analysis_dag),
                              ("train", train_dag))}


def build_stream(seed: int, refs: dict[str, float]) -> GeneratedStream:
    """The per-seed arrival process (identical for both arms: all
    randomness comes from the stream seed, fixed at construction)."""
    infer = StreamTemplate("infer", infer_dag, priority=2, weight=4.0,
                           deadline_slack=600.0,
                           reference_makespan=refs["infer"], share=6.0)
    analysis = StreamTemplate("analysis", analysis_dag, priority=1,
                              weight=1.0, deadline_slack=450.0,
                              reference_makespan=refs["analysis"],
                              share=1.0)
    train = StreamTemplate("train", train_dag, priority=0, weight=0.25,
                           reference_makespan=refs["train"])
    return GeneratedStream(
        [infer, analysis], rate=1 / 40.0, horizon=HORIZON, seed=seed,
        kind="diurnal", period=HORIZON, peak_ratio=5.0,
        periodic=[(train, 1200.0)], name="serve")


#: shared (deadline-blind) admission knobs, both arms: an aggressive
#: price floor so wide jobs defer while the slice is saturated, a low
#: hold ratio so the rule keeps biting once other wide sets are in
#: flight, and a 400 s age-out so deferred work is never stranded
ADMISSION = dict(i_floor=0.3, hold_ratio=0.1, max_defer_time=400.0)


def blind_static_config() -> RunConfig:
    return RunConfig(scheduling="priority",
                     admission=AdmissionOptions(**ADMISSION))


def aware_elastic_config() -> RunConfig:
    return RunConfig(
        scheduling="priority",
        admission=AdmissionOptions(deadline_aware=True, revoke=True,
                                   **ADMISSION),
        elastic=ElasticOptions(max_lease_nodes=4, lease_term=600.0,
                               check_interval=60.0))


def arm_metrics(r) -> dict:
    return dict(
        slo=round(r.slo_attainment(), 4),
        p50_slowdown=round(r.slowdown_percentile(0.50), 4),
        p99_slowdown=round(r.slowdown_percentile(0.99), 4),
        weighted_slowdown=round(r.weighted_slowdown(), 4),
        deferrals=r.admission_deferrals,
        revocations=r.admission_revocations,
        leases_granted=r.leases_granted,
        leases_expired=r.leases_expired)


def run_streaming() -> dict:
    per_seed = {}
    for seed in SEEDS:
        refs = references(seed)
        opts = SimOptions(seed=seed, **LOGNORMAL)
        blind = simulate(build_stream(seed, refs), service_pool(),
                         options=opts, config=blind_static_config())
        aware = simulate(build_stream(seed, refs), service_pool(),
                         options=opts, config=aware_elastic_config())
        for r in (blind, aware):
            s = r.stream
            assert s["finished"] == s["arrived"], (seed, s)  # conservation
        per_seed[seed] = dict(
            arrived=blind.stream["arrived"],
            blind=arm_metrics(blind),
            aware=arm_metrics(aware),
            windows=aware.window_stats(WINDOW))
    mean = lambda arm, key: round(  # noqa: E731 - tiny reduction helper
        sum(r[arm][key] for r in per_seed.values()) / len(per_seed), 4)
    return dict(seeds=list(SEEDS), horizon=HORIZON, per_seed=per_seed,
                blind_slo_mean=mean("blind", "slo"),
                aware_slo_mean=mean("aware", "slo"),
                blind_p99_mean=mean("blind", "p99_slowdown"),
                aware_p99_mean=mean("aware", "p99_slowdown"),
                revocations_total=sum(r["aware"]["revocations"]
                                      for r in per_seed.values()))


def run_baseline_identity() -> dict:
    """The streaming API wrappers must reproduce committed closed-
    campaign baselines bit-exactly."""
    out: dict = {}

    # admission.json tenancy seed 1, replayed through CampaignStream +
    # RunConfig (was: bare Campaign + legacy kwargs)
    from bench_admission import build_campaign
    from bench_admission import references as adm_references
    adm = simulate(CampaignStream(build_campaign(adm_references(1))),
                   summit_pool(), "async",
                   options=SimOptions(seed=1, **LOGNORMAL),
                   config=RunConfig(scheduling="priority",
                                    admission=AdmissionOptions()))
    with open(os.path.join(BASELINE_DIR, "admission.json")) as f:
        committed = json.load(f)["tenancy"]["per_seed"]["1"][
            "makespan_admission"]
    out["campaign_stream_seed1"] = dict(
        fresh=round(adm.makespan, 1), committed=committed,
        identical=round(adm.makespan, 1) == committed)

    # predictor.json convergence seed 3 through the frozen-RunConfig
    # call form (was: legacy feedback= kwarg)
    shared = dataclasses.replace(summit_pool(), oversubscribe_gpus=True)
    res = simulate(cdg_dag("c-DG2"), shared, "async",
                   options=SimOptions(seed=3, **LOGNORMAL),
                   config=RunConfig(feedback=FeedbackOptions(
                       straggler_k=2.0, speculate=True)))
    with open(os.path.join(BASELINE_DIR, "predictor.json")) as f:
        committed2 = json.load(f)["convergence"]["per_seed"]["3"]["makespan"]
    out["runconfig_predictor_seed3"] = dict(
        fresh=round(res.makespan, 1), committed=committed2,
        identical=round(res.makespan, 1) == committed2)
    return out


def main() -> dict:
    print("== (a) open stream: deadline-aware + elastic vs "
          "deadline-blind static ==")
    st = run_streaming()
    for seed, r in st["per_seed"].items():
        b, a = r["blind"], r["aware"]
        print(f"  seed {seed}: slo {b['slo']:.3f} -> {a['slo']:.3f}  "
              f"p99 {b['p99_slowdown']:.2f} -> {a['p99_slowdown']:.2f}  "
              f"revocations={a['revocations']}  "
              f"leases +{a['leases_granted']}/-{a['leases_expired']}  "
              f"({r['arrived']} workflows)")
        assert a["slo"] >= b["slo"], (seed, st)
        assert a["p99_slowdown"] <= b["p99_slowdown"], (seed, st)
        assert a["leases_granted"] > 0, (seed, st)    # burst absorbed...
        assert a["leases_expired"] > 0, (seed, st)    # ...and returned
        assert b["leases_granted"] == 0, (seed, st)   # static arm is static
    print(f"  means: slo {st['blind_slo_mean']:.3f} -> "
          f"{st['aware_slo_mean']:.3f}  p99 {st['blind_p99_mean']:.2f} "
          f"-> {st['aware_p99_mean']:.2f}")
    assert st["revocations_total"] > 0, st  # revocation exercised

    print("== (b) streaming API stays bit-identical to committed "
          "baselines ==")
    ident = run_baseline_identity()
    for which, r in ident.items():
        print(f"  {which:28s} fresh={r['fresh']} "
              f"committed={r['committed']} identical={r['identical']}")
        assert r["identical"], (which, ident)

    out = {"streaming": st, "baseline_identity": ident}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "streaming.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  streaming: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

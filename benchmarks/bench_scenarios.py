"""Scenario matrix: policy selection over trace replay + adversarial
generation (``core/scenarios.py``).

Sweeps every scheduling policy x admission(on/off) x feedback(on/off)
over the named :data:`repro.core.SCENARIOS` matrix — the three service
mixes, the three adversarial compositions, and the committed HPC2N SWF
replay — at two seeds each, and emits the policy-selection table: which
policy wins each scenario, by how much, and whether the winner is
stable across seeds.

The point of the matrix is that no single policy wins everywhere: the
adversarial scenarios are built to separate the field (fragmentation
rewards packing-aware placement, heavy tails reward size-aware orders),
so the table is the reproduction's answer to "which knobs for which
workload".  Headlines gated by ``make bench-check``:

- every scenario ran the full 6 x 2 x 2 arm grid at both seeds with
  finite metrics and stream/campaign conservation;
- the policy spread on the adversarial scenarios is real (best arm
  materially beats the worst arm);
- scenario runs stay bit-identical to the committed baseline
  (``baseline_identity`` rows — the scenario engine's determinism
  contract, same spec + seed => same makespan, held across commits).

Per-arm ``makespan`` values are drift-gated (10%, one-sided) like every
other baseline; they are deterministic here, so any drift is a real
behaviour change.  Writes ``benchmarks/out/scenarios.json``.
"""

from __future__ import annotations

import json
import math
import os

from repro.core import SCENARIOS, ScenarioGenerator

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

POLICIES = ("fifo", "lpt", "gpu_bestfit", "locality", "nodepack",
            "priority")
#: (admission, feedback) toggles — the full cross
COMBOS = ((False, False), (True, False), (False, True), (True, True))
SEEDS = (1, 2)


def arm_key(policy: str, admission: bool, feedback: bool) -> str:
    return policy + ("+adm" if admission else "") \
        + ("+fb" if feedback else "")


def run_arm(scenario: str, policy: str, admission: bool, feedback: bool,
            seed: int) -> dict:
    r = ScenarioGenerator(scenario, seed).run(
        policy=policy, admission=admission, feedback=feedback)
    if r.stream is not None:  # open arrivals: conservation must hold
        assert r.stream["finished"] == r.stream["arrived"], \
            (scenario, policy, seed, r.stream)
    ws = r.weighted_slowdown()
    assert math.isfinite(r.makespan), (scenario, policy, seed)
    out = dict(makespan=round(r.makespan, 1))
    if ws is not None:
        assert math.isfinite(ws), (scenario, policy, seed)
        out["ws"] = round(ws, 4)
    return out


def sweep() -> dict:
    """The policy-selection table: scenario -> arm -> per-seed metrics.

    The selection metric is fairness-weighted slowdown when the scenario
    carries reference makespans (all of them do), with raw makespan kept
    alongside for the drift gate."""
    table: dict = {}
    for scenario in SCENARIOS:
        arms: dict = {}
        for policy in POLICIES:
            for admission, feedback in COMBOS:
                per_seed = {s: run_arm(scenario, policy, admission,
                                       feedback, s) for s in SEEDS}
                key = "ws" if "ws" in per_seed[SEEDS[0]] else "makespan"
                arms[arm_key(policy, admission, feedback)] = dict(
                    metric=key, per_seed=per_seed,
                    mean=round(sum(r[key] for r in per_seed.values())
                               / len(SEEDS), 4))
        table[scenario] = arms
    return table


def winners(table: dict) -> dict:
    """Per scenario: the arm with the best (lowest) mean metric, its
    margin over the worst arm, and per-seed winner stability."""
    out = {}
    for scenario, arms in table.items():
        means = {k: a["mean"] for k, a in arms.items()}
        best = min(means, key=means.get)
        worst = max(means, key=means.get)
        per_seed_best = {
            s: min(arms, key=lambda k: arms[k]["per_seed"][s][
                arms[k]["metric"]]) for s in SEEDS}
        out[scenario] = dict(
            winner=best, mean=means[best],
            worst=worst, worst_mean=means[worst],
            spread=round(means[worst] / means[best], 3)
            if means[best] > 0 else None,
            per_seed_winner={s: per_seed_best[s] for s in SEEDS},
            winner_policy_stable=len(
                {per_seed_best[s].split("+")[0] for s in SEEDS}) == 1)
    return out


def run_baseline_identity() -> dict:
    """Scenario-engine determinism across commits: fresh single runs of
    three scenario/seed pairs must reproduce the makespans committed in
    ``benchmarks/baseline/scenarios.json`` bit-exactly (on the first
    generation, before a baseline exists, the fresh value seeds the
    row)."""
    committed: dict = {}
    path = os.path.join(BASELINE_DIR, "scenarios.json")
    if os.path.exists(path):
        with open(path) as f:
            committed = json.load(f).get("baseline_identity", {})
    rows = {"swf_replay_seed1": ("swf-hpc2n", 1),
            "bursty_heavytail_seed2": ("bursty-heavytail", 2),
            "failure_storm_seed1": ("failure-storm", 1)}
    out = {}
    for key, (scenario, seed) in rows.items():
        fresh = round(ScenarioGenerator(scenario, seed).run().makespan, 1)
        comm = committed.get(key, {}).get("committed", fresh)
        out[key] = dict(fresh=fresh, committed=comm,
                        identical=fresh == comm)
    return out


def headlines(table: dict, win: dict) -> dict:
    adversarial = [n for n, s in SCENARIOS.items()
                   if "adversarial" in s.description]
    full_grid = all(
        len(table[s]) == len(POLICIES) * len(COMBOS)
        and all(len(a["per_seed"]) == len(SEEDS)
                for a in table[s].values())
        for s in SCENARIOS)
    spreads = {n: win[n]["spread"] for n in adversarial}
    return dict(
        scenarios=len(table), adversarial=adversarial,
        full_grid=full_grid,
        # the adversarial compositions must actually separate the field
        adversarial_spread_min=min(spreads.values()),
        adversarial_separation=all(sp is not None and sp >= 1.2
                                   for sp in spreads.values()),
        winner_policy_stable_count=sum(
            1 for w in win.values() if w["winner_policy_stable"]),
        single_policy_sweep=len({w["winner"].split("+")[0]
                                 for w in win.values()}) == 1)


def main() -> dict:
    print(f"== policy-selection sweep: {len(SCENARIOS)} scenarios x "
          f"{len(POLICIES)} policies x {len(COMBOS)} admission/feedback "
          f"combos x {len(SEEDS)} seeds ==")
    table = sweep()
    win = winners(table)
    for scenario, w in win.items():
        metric = table[scenario][w["winner"]]["metric"]
        stable = "stable" if w["winner_policy_stable"] else "UNSTABLE"
        print(f"  {scenario:24s} winner {w['winner']:16s} "
              f"{metric}={w['mean']:<9g} spread {w['spread']:.2f}x "
              f"({stable} across seeds)")
    hl = headlines(table, win)
    assert hl["full_grid"], "sweep grid incomplete"
    print(f"  adversarial spread >= {hl['adversarial_spread_min']:.2f}x "
          f"on {hl['adversarial']}")
    print(f"  winner policy stable on {hl['winner_policy_stable_count']}"
          f"/{hl['scenarios']} scenarios; single policy sweeps all: "
          f"{hl['single_policy_sweep']}")

    print("== scenario engine determinism vs committed baseline ==")
    ident = run_baseline_identity()
    for which, r in ident.items():
        print(f"  {which:26s} fresh={r['fresh']} "
              f"committed={r['committed']} identical={r['identical']}")
        assert r["identical"], (which, ident)

    out = {"policies": list(POLICIES), "seeds": list(SEEDS),
           "selection": table, "winners": win, "headlines": hl,
           "baseline_identity": ident}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "scenarios.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  scenarios: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

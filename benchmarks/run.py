"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure + the roofline deliverable:

  bench_deepdrivemd   Table 1 / Fig. 4  (sync vs async DeepDriveMD)
  bench_cdg           Table 2 / Figs. 5-6 (c-DG1 negative, c-DG2 positive)
  bench_table3        Table 3 summary (model vs simulated vs paper)
  bench_masking       §5.3 worked example + masking sensitivity sweep
  bench_adaptive      beyond paper: task-level adaptive asynchronicity
  bench_scaling       beyond paper: 16 -> 4096 nodes + straggler healing
  roofline            deliverable (g): per-(arch x shape) roofline terms
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (bench_adaptive, bench_cdg, bench_deepdrivemd,
                        bench_masking, bench_scaling, bench_table3, roofline)


def _hillclimb_summary():
    """Report the confirmed §Perf variants from their saved artifacts
    (re-lowering takes ~5 min on 1 CPU core; `python -m
    benchmarks.hillclimb` re-runs them live)."""
    from benchmarks.hillclimb import VARIANTS, load, report
    for arch, shape, variants in VARIANTS:
        base = load(arch, shape)
        for var in variants:
            if not var["final"]:
                continue
            try:
                rec = load(arch, shape, var["tag"])
            except FileNotFoundError:
                print(f"  [missing artifact] {arch} {shape} {var['tag']} — "
                      "run `python -m benchmarks.hillclimb` first")
                continue
            print(f"\n  {arch} x {shape}:\n  {var['hypothesis'][:100]}")
            report(var["tag"], base, rec)
    # fleet rollout of the pure-DP recipe (benchmarks/fleet_rollout.py)
    from benchmarks.fleet_rollout import ARCHS
    from benchmarks.roofline import analyse
    print("\n  fleet rollout (pure-DP recipe, train_4k):")
    for arch in ARCHS:
        try:
            b = analyse(load(arch, "train_4k"))
            v = analyse(load(arch, "train_4k", "__hc_dp256"))
        except FileNotFoundError:
            print(f"    [missing artifact] {arch} — run "
                  "`python -m benchmarks.fleet_rollout` first")
            continue
        print(f"    {arch:18s} RF {b['roofline_fraction']:.3f} -> "
              f"{v['roofline_fraction']:.3f}  "
              f"({b['dominant']} -> {v['dominant']})")


SUITES = [
    ("deepdrivemd", bench_deepdrivemd.main),
    ("cdg", bench_cdg.main),
    ("table3", bench_table3.main),
    ("masking", bench_masking.main),
    ("adaptive", bench_adaptive.main),
    ("scaling", bench_scaling.main),
    ("roofline", roofline.main),
    ("hillclimb-summary", _hillclimb_summary),
]


def main() -> int:
    failures = []
    for name, fn in SUITES:
        print(f"\n{'=' * 72}\n== benchmark: {name}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            fn()
            print(f"-- {name}: OK ({time.perf_counter() - t0:.1f}s)")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"-- {name}: FAILED")
    print(f"\n{'=' * 72}")
    if failures:
        print(f"benchmarks FAILED: {failures}")
        return 1
    print(f"all {len(SUITES)} benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Predictive control plane: online makespan re-prediction + the
speculation-vs-migration arbiter (``core/predictor.py``).

Two claims, both asserted (CI gates on them):

(a) **Convergence** — on the paper's headline c-DG2 configuration
    (shared-GPU Summit pool) under lognormal durations, the mid-run
    re-predicted makespan (``SimResult.predictions``, Eqns. 2-6 evaluated
    on the live EWMA estimates + the residual wave/tail bound) converges
    onto the realized one: the mean absolute error across seeds shrinks
    monotonically over completion checkpoints and ends below 10%.  Early
    predictions only know the static ``tx_mean`` priors — no dispersion,
    no overheads — so they underpredict heavy-tailed runs badly; the
    error collapse IS the estimator feeding the analytic model.

(b) **Arbitrage** — on the split Summit allocation under lognormal +
    10% x16 injected stragglers, arbitrated mitigation (the engine picks
    migration or speculation per straggler by the predictor's
    marginal-makespan delta) beats BOTH pure arms on mean makespan:
    always-migrate and always-speculate.

Writes ``benchmarks/out/predictor.json`` (compared against the committed
``benchmarks/baseline/predictor.json`` by ``make bench-check``).
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (Allocation, FeedbackOptions, SimOptions, cdg_dag,
                        simulate, summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

#: heavy-tailed durations (mean preserved, lognormal right tail)
LOGNORMAL = dict(tx_distribution="lognormal", lognormal_sigma=0.5)
#: ... plus 10% of tasks stretched 16x (the arbitrage regime)
HEAVY = dict(**LOGNORMAL, straggler_prob=0.1, straggler_factor=16.0)
#: detection at mean + 2 sigma; speculation enabled next to migration
ARBITRATED = FeedbackOptions(straggler_k=2.0, speculate=True)

#: completion-fraction checkpoints the convergence claim is measured at
CHECKPOINTS = (0.0, 0.25, 0.5, 0.75, 0.9, 0.95, 0.995)
CONVERGENCE_SEEDS = (3, 7, 11, 13, 17)
ARBITRAGE_SEEDS = (1, 2, 3, 4, 5, 6, 7, 8)


def shared_summit(num_nodes: int = 16):
    """The paper's headline c-DG2 configuration: 16 Summit nodes with
    GPU sharing (DESIGN.md, 'GPU sharing for c-DG2')."""
    return dataclasses.replace(summit_pool(num_nodes),
                               oversubscribe_gpus=True)


def split_summit(num_nodes: int = 16, transfer: float = 10.0) -> Allocation:
    """Two equal Summit partitions with a symmetric transfer cost — the
    smallest topology where migration vs speculation is a real choice."""
    half = summit_pool(num_nodes // 2)
    return Allocation(
        "summit-split",
        (dataclasses.replace(half, name="summit-a"),
         dataclasses.replace(half, name="summit-b")),
        transfer_cost=((0.0, transfer), (transfer, 0.0)),
    )


def checkpoint_errors(res) -> list[float]:
    """|predicted total - realized| / realized at the first prediction at
    or past each completion-fraction checkpoint."""
    out = []
    for c in CHECKPOINTS:
        p = next((p for p in res.predictions if p.done_fraction >= c),
                 res.predictions[-1])
        out.append(abs(p.total - res.makespan) / res.makespan)
    return out


def run_convergence() -> dict:
    pool = shared_summit()
    per_seed = {}
    sums = [0.0] * len(CHECKPOINTS)
    for seed in CONVERGENCE_SEEDS:
        res = simulate(cdg_dag("c-DG2"), pool, "async",
                       options=SimOptions(seed=seed, **LOGNORMAL),
                       feedback=ARBITRATED)
        errs = checkpoint_errors(res)
        per_seed[seed] = dict(makespan=round(res.makespan, 1),
                              errors=[round(e, 4) for e in errs])
        sums = [a + b for a, b in zip(sums, errs)]
    mean_errors = [s / len(CONVERGENCE_SEEDS) for s in sums]
    return dict(checkpoints=list(CHECKPOINTS),
                seeds=list(CONVERGENCE_SEEDS),
                mean_errors=[round(e, 4) for e in mean_errors],
                per_seed=per_seed)


def run_arbitrage() -> dict:
    alloc = split_summit()
    arms = {
        "always_migrate": dataclasses.replace(ARBITRATED, speculate=False),
        "always_speculate": dataclasses.replace(ARBITRATED, migrate=False),
        "arbitrated": ARBITRATED,
    }
    out: dict = {"seeds": list(ARBITRAGE_SEEDS), "arms": {}}
    for arm, fb in arms.items():
        makespans, migrations, speculations = [], 0, 0
        for seed in ARBITRAGE_SEEDS:
            res = simulate(cdg_dag("c-DG2"), alloc, "async",
                           options=SimOptions(seed=seed, **HEAVY),
                           feedback=fb)
            makespans.append(res.makespan)
            migrations += res.migrations
            speculations += res.speculations
        out["arms"][arm] = dict(
            makespan_mean=round(sum(makespans) / len(makespans), 1),
            makespans=[round(m, 1) for m in makespans],
            migrations=migrations, speculations=speculations)
    return out


def main() -> dict:
    print("== (a) online makespan re-prediction, c-DG2 shared-GPU, "
          "lognormal ==")
    conv = run_convergence()
    print("  done-fraction : " +
          " ".join(f"{c:>6.2f}" for c in conv["checkpoints"]))
    print("  mean |err|    : " +
          " ".join(f"{e:6.3f}" for e in conv["mean_errors"]))
    errs = conv["mean_errors"]
    # re-prediction error shrinks monotonically and ends < 10%
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-9, errs
    assert errs[-1] < 0.10, errs

    print("== (b) speculation-vs-migration arbitrage, c-DG2 split "
          "Summit, lognormal + 10% x16 stragglers ==")
    arb = run_arbitrage()
    for arm, r in arb["arms"].items():
        print(f"  {arm:17s} mean={r['makespan_mean']:8.1f} "
              f"migr={r['migrations']:3d} spec={r['speculations']:3d}")
    a = arb["arms"]
    best_pure = min(a["always_migrate"]["makespan_mean"],
                    a["always_speculate"]["makespan_mean"])
    # the arbiter must not lose to either pure arm...
    assert a["arbitrated"]["makespan_mean"] <= best_pure * 1.0001, arb
    # ...and must genuinely use both mechanisms to get there
    assert a["arbitrated"]["migrations"] > 0, arb
    assert a["arbitrated"]["speculations"] > 0, arb

    out = {"convergence": conv, "arbitrage": arb}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "predictor.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  predictor: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

"""Paper §7.1 / Table 1 / Fig. 4: DeepDriveMD sequential vs asynchronous.

Reproduces (on the discrete-event simulator configured exactly as the
paper's 16-node Summit allocation):

- sequential TTX   (paper: predicted 1578 s, measured 1707 s)
- asynchronous TTX (paper: predicted 1399 s, measured 1373 s)
- relative improvement I (paper: predicted 0.113, measured 0.196)
- Eqn. 6 staggered-masking prediction (1345 s, within 2%)
- the Fig. 4 utilisation traces (CSV artifact).
"""

from __future__ import annotations

import csv
import os

from repro.core import (ENTK_OVERHEAD, ASYNC_OVERHEAD, SimOptions,
                        deepdrivemd_dag, ddmd_sequential_stage_groups,
                        ddmd_stage_tx, maskable_stages, predict,
                        relative_improvement, sequential_ttx_grouped,
                        simulate, staggered_async_ttx, summit_pool, wla)
from repro.core.workflow import DDMD_STAGE_ORDER, ddmd_task_sets

PAPER = dict(t_seq_pred=1578.0, t_seq_meas=1707.0, t_async_pred=1399.0,
             t_async_meas=1373.0, i_pred=0.113, i_meas=0.196,
             doa_dep=2, doa_res=1, wla=1)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "paper")


def run(n_iterations: int = 3, write_csv: bool = True,
        policy: str = "fifo") -> dict:
    pool = summit_pool(16)
    dag = deepdrivemd_dag(n_iterations)

    # --- analytic model -----------------------------------------------------
    stage_tx = ddmd_stage_tx()
    t_seq_model = sequential_ttx_grouped(stage_tx,
                                         n_iterations=n_iterations)
    sets = [ddmd_task_sets(0)[k] for k in DDMD_STAGE_ORDER]
    mask = maskable_stages(sets, pool)
    t_async_model = staggered_async_ttx(stage_tx, n_iterations, mask)
    # Table 3 'Pred.' columns include the overhead corrections
    t_async_pred = t_async_model * (1 + ENTK_OVERHEAD) * (1 + ASYNC_OVERHEAD)
    t_seq_pred = t_seq_model

    # Table 1 sets execute all-tasks-concurrently ("all Simulation tasks
    # run at the same time"), so resource eligibility uses full-set
    # footprints — the paper's DOA_res = 1 reasoning (§7.1).
    p = predict(dag, pool, strategy="full_set")

    # --- simulated execution (the framework's 'measured') ------------------
    seq = simulate(dag, pool, "sequential",
                   sequential_stage_groups=ddmd_sequential_stage_groups(
                       n_iterations),
                   options=SimOptions(seed=7), scheduling=policy)
    asy = simulate(dag, pool, "async", options=SimOptions(seed=7),
                   scheduling=policy)

    i_model = relative_improvement(t_seq_pred, t_async_pred)
    i_sim = relative_improvement(seq.makespan, asy.makespan)

    out = dict(
        policy=policy,
        doa_dep=dag.doa_dep(), doa_res=p.doa_res,
        wla=wla(dag, pool, "full_set"),
        t_seq_model=round(t_seq_model, 1),
        t_async_model_eqn6=round(t_async_model, 1),
        t_seq_pred=round(t_seq_pred, 1),
        t_async_pred=round(t_async_pred, 1),
        t_seq_sim=round(seq.makespan, 1),
        t_async_sim=round(asy.makespan, 1),
        i_pred=round(i_model, 3), i_sim=round(i_sim, 3),
        gpu_util_seq=round(seq.gpu_utilization, 3),
        gpu_util_async=round(asy.gpu_utilization, 3),
        cpu_util_seq=round(seq.cpu_utilization, 3),
        cpu_util_async=round(asy.cpu_utilization, 3),
        paper=PAPER,
    )

    if write_csv and policy == "fifo":
        # fig4_*.csv is the paper's figure; only the fifo schedule writes it
        os.makedirs(ART_DIR, exist_ok=True)
        for tag, res in (("seq", seq), ("async", asy)):
            ts, cpu, gpu = res.utilization_trace()
            with open(os.path.join(ART_DIR, f"fig4_{tag}.csv"), "w",
                      newline="") as f:
                w = csv.writer(f)
                w.writerow(["t", "cpus", "gpus"])
                w.writerows(zip(ts, cpu, gpu))
    return out


def main(policy: str = "fifo"):
    out = run(policy=policy)
    paper = out.pop("paper")
    print("== DeepDriveMD (Table 1 workload, 16 Summit nodes) ==")
    for k, v in out.items():
        print(f"  {k:18s} {v}")
    print("  -- paper reference --")
    for k, v in paper.items():
        print(f"  {k:18s} {v}")
    # agreement assertions (documented tolerances)
    assert out["doa_dep"] == paper["doa_dep"]
    assert out["wla"] == paper["wla"]
    if policy == "fifo":
        assert abs(out["t_seq_sim"] - paper["t_seq_meas"]) \
            / paper["t_seq_meas"] < 0.08, "sequential sim vs paper-measured"
        assert abs(out["t_async_sim"] - paper["t_async_meas"]) \
            / paper["t_async_meas"] < 0.08, "async sim vs paper-measured"
        assert out["i_sim"] > 0.12, "async must clearly beat sequential"
        print("  agreement: OK (within 8% of the paper's measured TTX)")
    else:
        print(f"  (paper-agreement asserts skipped for policy={policy})")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="fifo",
                    help="scheduling policy: fifo | lpt | gpu_bestfit")
    main(policy=ap.parse_args().policy)

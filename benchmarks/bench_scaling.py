"""BEYOND PAPER: scale-out study — the middleware at 1000+ nodes.

The paper runs 16 Summit nodes; a production deployment must sustain the
async advantage at three orders of magnitude more resources and tasks.
We scale the DeepDriveMD workload proportionally (tasks x nodes/16) from
16 to 4096 nodes and check that (a) the simulator handles ~10^5 tasks,
(b) the async improvement I is stable, (c) straggler mitigation
(duplicate-dispatch) recovers most of the injected tail latency."""

from __future__ import annotations

import time

from repro.core import (DDMD_TABLE1, SimOptions, deepdrivemd_dag,
                        ddmd_sequential_stage_groups, relative_improvement,
                        simulate, summit_pool)


def scaled_table(factor: int) -> dict:
    t = {k: dict(v) for k, v in DDMD_TABLE1.items()}
    for k in t:
        t[k]["n"] = t[k]["n"] * factor
    return t


def main():
    print("== scale-out: DeepDriveMD x N nodes ==")
    rows = []
    for nodes in (16, 128, 1024, 4096):
        factor = nodes // 16
        dag = deepdrivemd_dag(3, table=scaled_table(factor))
        pool = summit_pool(nodes)
        t0 = time.perf_counter()
        seq = simulate(dag, pool, "sequential",
                       sequential_stage_groups=ddmd_sequential_stage_groups(),
                       options=SimOptions(seed=2))
        asy = simulate(dag, pool, "async", options=SimOptions(seed=2))
        wall = time.perf_counter() - t0
        i = relative_improvement(seq.makespan, asy.makespan)
        rows.append(dict(nodes=nodes, tasks=seq.tasks_total,
                         t_seq=round(seq.makespan, 1),
                         t_async=round(asy.makespan, 1),
                         i=round(i, 3), sim_wall_s=round(wall, 2)))
        print(f"  nodes={nodes:5d} tasks={seq.tasks_total:6d} "
              f"I={i:+.3f}  (sim wall {wall:.2f}s)")
    assert all(r["i"] > 0.1 for r in rows), "async advantage must persist"
    assert rows[-1]["sim_wall_s"] < 60, "simulator must scale"

    # straggler mitigation at 1024 nodes.  Set-level barriers AMPLIFY
    # stragglers (any 4x-slow task in a 6k-task set stalls its stage), so
    # we measure three remedies: duplicate-dispatch, task-level (adaptive)
    # release, and both.
    dag = deepdrivemd_dag(3, table=scaled_table(64))
    pool = summit_pool(1024)
    slow_opt = SimOptions(seed=2, straggler_prob=0.02, straggler_factor=4.0)
    heal_opt = SimOptions(seed=2, straggler_prob=0.02, straggler_factor=4.0,
                          mitigate_stragglers=True,
                          mitigation_threshold=1.5)
    base = simulate(dag, pool, "async", options=SimOptions(seed=2)).makespan
    slow = simulate(dag, pool, "async", options=slow_opt).makespan
    heal = simulate(dag, pool, "async", options=heal_opt).makespan
    adap = simulate(dag, pool, "async", options=slow_opt,
                    task_level=True).makespan
    both = simulate(dag, pool, "async", options=heal_opt,
                    task_level=True).makespan
    rec = lambda x: (slow - x) / max(slow - base, 1e-9)  # noqa: E731
    print(f"  stragglers @1024 nodes: clean={base:.0f}s slow={slow:.0f}s")
    print(f"    duplicate-dispatch: {heal:.0f}s (recovered {rec(heal):.0%})")
    print(f"    task-level release: {adap:.0f}s (recovered {rec(adap):.0%})")
    print(f"    both:               {both:.0f}s (recovered {rec(both):.0%})")
    assert heal < slow and both <= heal * 1.02, "mitigation must help"
    rows.append(dict(nodes=1024, straggler_clean=round(base, 1),
                     straggler_slow=round(slow, 1),
                     straggler_mitigated=round(heal, 1),
                     straggler_adaptive=round(adap, 1),
                     straggler_both=round(both, 1),
                     recovered=round(rec(both), 3)))
    return rows


if __name__ == "__main__":
    main()

"""Fault-tolerant scheduling: priced recovery arbitration + hazard-aware
re-prediction under injected failures (``runtime/fault.py`` through
``core/sched_engine.py``).

Two claims, both asserted (CI gates on them):

(a) **Recovery arbitrage** — on the paper's headline c-DG2 configuration
    (16 node-level Summit nodes) under lognormal durations with a
    trace-driven node-failure storm + software task failures, the
    arbitrated recovery policy (checkpoint only the sets whose expected
    failure loss beats the write overhead; restart-from-checkpoint only
    when the saved progress beats the read-back) matches or beats BOTH
    pure arms — always-rerun-from-scratch and always-restart — on every
    seed.

(b) **Hazard-aware prediction** — under stochastic node losses, folding
    the live failure hazard into the predictor's residual bound
    (``FaultOptions.hazard_aware``) lowers the mid-run re-prediction
    error vs. the same run with the hazard term off (the schedules are
    identical — the delta is pure predictor).

A third section re-runs committed-baseline configurations with
*disabled* ``FaultOptions()`` and asserts bit-identical makespans — the
whole fault layer must vanish when off.

Writes ``benchmarks/out/faults.json`` (compared against the committed
``benchmarks/baseline/faults.json`` by ``make bench-check``).
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (Allocation, FaultOptions, FeedbackOptions,
                        SimOptions, cdg_dag, simulate, summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

#: heavy-tailed durations (mean preserved, lognormal right tail)
LOGNORMAL = dict(tx_distribution="lognormal", lognormal_sigma=0.5)

#: trace-driven storm over the 16-node Summit allocation: four node
#: losses spread through the run (c-DG2 makespan ~4.3 ks), recovered
#: after 300 s — on top of a stochastic loss stream at roughly the same
#: intensity (so the arbiter's hazard prior is honest from t=0) and a
#: per-attempt software-failure lottery
FAILURE_TRACE = ((400.0, "summit", 2), (1200.0, "summit", 7),
                 (2100.0, "summit", 11), (3000.0, "summit", 4))

#: checkpoint economics: 60 s of progress per snapshot, 2 s to write,
#: 10 s to read back
CKPT = dict(checkpoint_interval=60.0, checkpoint_write_cost=2.0,
            checkpoint_read_cost=10.0)

RECOVERY_SEEDS = (1, 2, 3, 4, 5)
HAZARD_SEEDS = (2, 3, 5, 7, 11)


def storm(recovery: str, seed: int) -> FaultOptions:
    return FaultOptions(node_failure_trace=FAILURE_TRACE,
                        node_failure_rate=6e-5,
                        node_recovery_time=300.0,
                        task_failure_prob=0.10, seed=seed,
                        recovery=recovery, **CKPT)


def run_recovery() -> dict:
    pool = summit_pool(node_level=True)
    arms = {"always_rerun": "rerun", "always_restart": "restart",
            "arbitrated": "arbitrated"}
    out: dict = {"seeds": list(RECOVERY_SEEDS), "arms": {}}
    for arm, recovery in arms.items():
        makespans, restarts, reruns, nodefail, taskfail = [], 0, 0, 0, 0
        for seed in RECOVERY_SEEDS:
            res = simulate(cdg_dag("c-DG2"), pool, "async",
                           options=SimOptions(seed=seed, **LOGNORMAL),
                           faults=storm(recovery, seed))
            makespans.append(res.makespan)
            restarts += res.recoveries_restart
            reruns += res.recoveries_rerun
            nodefail += res.node_failures
            taskfail += res.task_failures
        out["arms"][arm] = dict(
            makespan_mean=round(sum(makespans) / len(makespans), 1),
            makespans=[round(m, 1) for m in makespans],
            recoveries_restart=restarts, recoveries_rerun=reruns,
            node_failures=nodefail, task_failures=taskfail)
    return out


def midrun_error(res, lo: float = 0.1, hi: float = 0.9) -> float:
    """Mean |predicted total - realized| / realized over the mid-run
    prediction window (done fraction in [lo, hi])."""
    errs = [abs(p.total - res.makespan) / res.makespan
            for p in res.predictions if lo <= p.done_fraction <= hi]
    return sum(errs) / len(errs)


def run_hazard() -> dict:
    pool = summit_pool(node_level=True)
    fb = FeedbackOptions(migrate=False)  # estimator-only: schedules equal
    per_seed = {}
    sum_with = sum_without = 0.0
    for seed in HAZARD_SEEDS:
        opts = SimOptions(seed=seed, **LOGNORMAL)
        runs = {}
        for label, aware in (("with", True), ("without", False)):
            runs[label] = simulate(
                cdg_dag("c-DG2"), pool, "async", options=opts, feedback=fb,
                faults=FaultOptions(node_failure_rate=2e-4,
                                    node_recovery_time=200.0, seed=seed,
                                    hazard_aware=aware, **CKPT))
        # same failures, same schedule — the error delta is pure predictor
        assert runs["with"].makespan == runs["without"].makespan
        e_with = midrun_error(runs["with"])
        e_without = midrun_error(runs["without"])
        per_seed[seed] = dict(makespan=round(runs["with"].makespan, 1),
                              node_failures=runs["with"].node_failures,
                              err_with=round(e_with, 4),
                              err_without=round(e_without, 4))
        sum_with += e_with
        sum_without += e_without
    n = len(HAZARD_SEEDS)
    return dict(seeds=list(HAZARD_SEEDS),
                err_with=round(sum_with / n, 4),
                err_without=round(sum_without / n, 4),
                per_seed=per_seed)


def run_baseline_identity() -> dict:
    """Recompute one seed of two committed-baseline configurations with
    *disabled* ``FaultOptions()`` and compare bit-exactly — every fault
    code path must be invisible when the options are off."""
    out: dict = {}

    # predictor.json convergence, seed 3: c-DG2 shared-GPU + lognormal
    shared = dataclasses.replace(summit_pool(), oversubscribe_gpus=True)
    res = simulate(cdg_dag("c-DG2"), shared, "async",
                   options=SimOptions(seed=3, **LOGNORMAL),
                   feedback=FeedbackOptions(straggler_k=2.0,
                                            speculate=True),
                   faults=FaultOptions())
    with open(os.path.join(BASELINE_DIR, "predictor.json")) as f:
        committed = json.load(f)["convergence"]["per_seed"]["3"]["makespan"]
    out["predictor_seed3_faults_off"] = dict(
        fresh=round(res.makespan, 1), committed=committed,
        identical=round(res.makespan, 1) == committed)

    # runtime_feedback.json c-DG2 migration arm, seed 3: split Summit +
    # lognormal + 10% x16 stragglers, lpt + full feedback
    half = summit_pool(8)
    split = Allocation(
        "summit-split",
        (dataclasses.replace(half, name="summit-a"),
         dataclasses.replace(half, name="summit-b")),
        transfer_cost=((0.0, 10.0), (10.0, 0.0)))
    res = simulate(cdg_dag("c-DG2"), split, "async",
                   options=SimOptions(seed=3, straggler_prob=0.1,
                                      straggler_factor=16.0, **LOGNORMAL),
                   scheduling="lpt",
                   feedback=FeedbackOptions(straggler_k=2.0),
                   faults=FaultOptions())
    with open(os.path.join(BASELINE_DIR, "runtime_feedback.json")) as f:
        wl = next(w for w in json.load(f)["workloads"]
                  if w["workload"] == "c-DG2")
    committed = wl["arms"]["migration"]["makespans"][0]
    out["feedback_seed3_faults_off"] = dict(
        fresh=round(res.makespan, 1), committed=committed,
        identical=round(res.makespan, 1) == committed)
    return out


def main() -> dict:
    print("== (a) recovery arbitrage, c-DG2 16-node Summit, lognormal + "
          "node-failure trace + software faults ==")
    rec = run_recovery()
    for arm, r in rec["arms"].items():
        print(f"  {arm:15s} mean={r['makespan_mean']:8.1f} "
              f"restarts={r['recoveries_restart']:3d} "
              f"reruns={r['recoveries_rerun']:3d}")
    a = rec["arms"]
    for j, seed in enumerate(rec["seeds"]):
        arb = a["arbitrated"]["makespans"][j]
        pure = min(a["always_rerun"]["makespans"][j],
                   a["always_restart"]["makespans"][j])
        # the arbiter must not lose to either pure arm, on ANY seed
        assert arb <= pure * 1.0001, (seed, arb, pure)
    # ... and must genuinely use both recovery mechanisms to get there
    assert a["arbitrated"]["recoveries_restart"] > 0, rec
    assert a["arbitrated"]["recoveries_rerun"] > 0, rec
    assert a["arbitrated"]["node_failures"] > 0, rec

    print("== (b) hazard-aware re-prediction, c-DG2 16-node Summit, "
          "stochastic node losses ==")
    haz = run_hazard()
    print(f"  mid-run |err|: hazard-on={haz['err_with']:.4f}  "
          f"hazard-off={haz['err_without']:.4f}")
    assert haz["err_with"] <= haz["err_without"], haz
    assert any(r["node_failures"] > 0 for r in haz["per_seed"].values())

    print("== (c) disabled FaultOptions stays bit-identical to the "
          "committed baselines ==")
    ident = run_baseline_identity()
    for which, r in ident.items():
        print(f"  {which}: fresh={r['fresh']} committed={r['committed']}"
              f" identical={r['identical']}")
        assert r["identical"], (which, ident)

    out = {"recovery": rec, "hazard": haz, "baseline_identity": ident}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  faults: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

"""Scheduling-policy comparison on the paper's workloads.

Runs every policy of the shared scheduling engine (``fifo`` / ``lpt`` /
``gpu_bestfit``) on the paper's c-DG1 / c-DG2 (Table 2) and DeepDriveMD
(Table 1) DGs, in sequential and asynchronous mode, and reports the
relative improvement I (Eqn. 5) per policy.

Two headline results:

1. With the paper's GPU-sharing configuration (the one that reproduces its
   measured c-DG2 TTX, see bench_cdg.py), the async-vs-sequential
   improvement on c-DG2 holds under EVERY policy — asynchronicity is a
   property of the workflow, not of one dispatch order.
2. With strict exclusive GPUs, policy choice matters enormously: naive LPT
   front-loads the widest GPU leaf sets (T3/T6), starves the T4/T5 -> T7
   chain, and erases the entire async win on c-DG2 — exactly the
   scheduling/execution separation argument of RADICAL-Pilot.

Also demonstrates heterogeneous multi-pool placement: DeepDriveMD on a
GPU-node + CPU-node allocation, where ``gpu_bestfit`` moves all CPU-only
Aggregation tasks onto the CPU partition.
"""

from __future__ import annotations

import dataclasses

from repro.core import (CDG_SEQUENTIAL_GROUPS, SCHEDULING_POLICIES,
                        SimOptions, cdg_dag, ddmd_sequential_stage_groups,
                        deepdrivemd_dag, hybrid_pool, relative_improvement,
                        simulate, summit_pool)

POLICIES = tuple(sorted(SCHEDULING_POLICIES))
OPTS = SimOptions(seed=11)

WORKLOADS = {
    "c-DG1": (lambda: cdg_dag("c-DG1"), CDG_SEQUENTIAL_GROUPS),
    "c-DG2": (lambda: cdg_dag("c-DG2"), CDG_SEQUENTIAL_GROUPS),
    "DeepDriveMD": (lambda: deepdrivemd_dag(3),
                    ddmd_sequential_stage_groups(3)),
}


def run(which: str, policy: str, shared_gpus: bool = False) -> dict:
    build, groups = WORKLOADS[which]
    pool = summit_pool(16)
    if shared_gpus:
        pool = dataclasses.replace(pool, oversubscribe_gpus=True)
    dag = build()
    seq = simulate(dag, pool, "sequential", options=OPTS,
                   sequential_stage_groups=groups, scheduling=policy)
    asy = simulate(dag, pool, "async", options=OPTS, scheduling=policy)
    return dict(
        which=which, policy=policy, shared_gpus=shared_gpus,
        t_seq=round(seq.makespan, 1), t_async=round(asy.makespan, 1),
        i=round(relative_improvement(seq.makespan, asy.makespan), 3),
        gpu_util_async=round(asy.gpu_utilization, 3),
    )


def run_hybrid_placement() -> dict:
    """DeepDriveMD on a heterogeneous GPU+CPU allocation: where do the
    CPU-only Aggregation tasks land under each policy?"""
    alloc = hybrid_pool(gpu_nodes=8, cpu_nodes=8)
    out = {}
    for policy in POLICIES:
        res = simulate(deepdrivemd_dag(3), alloc, "async", options=OPTS,
                       scheduling=policy)
        counts = res.per_pool_task_counts()
        agg_on_cpu = sum(1 for r in res.records
                         if r.gpus == 0 and r.pool.endswith("-cpu"))
        out[policy] = dict(makespan=round(res.makespan, 1),
                           per_pool=counts, cpu_only_on_cpu_pool=agg_on_cpu)
    return out


def main():
    print("== policy comparison (16 Summit nodes; paper Tables 1-2) ==")
    hdr = f"  {'workload':12s} {'policy':12s} {'gpus':7s} " \
          f"{'t_seq':>8s} {'t_async':>8s} {'I':>7s}"
    for shared in (False, True):
        label = "shared (paper-reproducing)" if shared else "strict exclusive"
        print(f"-- {label} GPUs --")
        print(hdr)
        for which in WORKLOADS:
            for policy in POLICIES:
                r = run(which, policy, shared_gpus=shared)
                print(f"  {r['which']:12s} {r['policy']:12s} "
                      f"{'shared' if shared else 'strict':7s} "
                      f"{r['t_seq']:8.1f} {r['t_async']:8.1f} {r['i']:7.3f}")
                if which == "c-DG2" and shared:
                    # the paper's headline, under EVERY policy
                    assert r["i"] > 0.15, (policy, r)
                if which == "c-DG2" and not shared and policy == "fifo":
                    assert r["i"] > 0.15, r  # strict fifo also masks

    print("-- heterogeneous multi-pool placement (DeepDriveMD, GPU+CPU nodes) --")
    hp = run_hybrid_placement()
    for policy, d in hp.items():
        print(f"  {policy:12s} makespan={d['makespan']:8.1f} "
              f"per_pool={d['per_pool']} "
              f"cpu_only_tasks_on_cpu_pool={d['cpu_only_on_cpu_pool']}")
    # gpu_bestfit must actually use the CPU partition for CPU-only work
    assert hp["gpu_bestfit"]["cpu_only_on_cpu_pool"] > 0
    print("  agreement: OK (c-DG2 async win holds under every policy)")


if __name__ == "__main__":
    main()

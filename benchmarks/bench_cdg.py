"""Paper §7.2-7.3 / Table 2 / Figs. 5-6: the two concrete workflows from
the abstract DG of Fig. 3b.

c-DG1 demonstrates asynchronicity HURTING (I ~= -0.015): the asynchronous
task sets are tiny (6-8% of TTX) so the 2% async overhead outweighs the
masking gain.  c-DG2 demonstrates a large win (I ~= 0.26): t(T3,T6) ~
t(T4,T5)+t(T7) gives near-perfect TX masking.
"""

from __future__ import annotations

import csv
import os

from repro.core import (CDG_SEQUENTIAL_GROUPS, ENTK_OVERHEAD, ASYNC_OVERHEAD,
                        SimOptions, async_ttx, cdg_dag,
                        cdg_sequential_stage_tx, relative_improvement,
                        sequential_ttx_grouped, simulate, summit_pool, wla)

PAPER = {
    "c-DG1": dict(t_seq=2000.0, t_seq_meas=1945.0, t_async_pred=1972.0,
                  t_async_meas=1975.0, i_pred=0.014, i_meas=-0.015,
                  doa_dep=2, doa_res=2, wla=2),
    "c-DG2": dict(t_seq=2000.0, t_seq_meas=1856.0, t_async_pred=1378.0,
                  t_async_meas=1372.0, i_pred=0.311, i_meas=0.261,
                  doa_dep=2, doa_res=2, wla=2),
}

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "paper")


def run(which: str, write_csv: bool = True, policy: str = "fifo") -> dict:
    """c-DG2's measured full masking requires GPU sharing: its rank-2 task
    sets demand 112 GPUs on the 96-GPU allocation, yet the paper measures
    t_async ~= the perfectly-masked 1372 s.  We therefore report BOTH a
    strict-exclusive-GPU schedule (honest contention) and a shared-GPU
    schedule (reproduces the paper); see DESIGN.md §Approximations."""
    import dataclasses as _dc
    pool = summit_pool(16)
    pool_shared = _dc.replace(pool, oversubscribe_gpus=True)
    dag = cdg_dag(which)

    stage_tx = cdg_sequential_stage_tx(which)
    t_seq_model = sequential_ttx_grouped(stage_tx)
    t_async_model, _ = async_ttx(dag)
    t_async_pred = t_async_model * (1 + ENTK_OVERHEAD)
    w = wla(dag, pool, "minimal")
    if w > 0:
        t_async_pred *= (1 + ASYNC_OVERHEAD)

    seq = simulate(dag, pool, "sequential",
                   sequential_stage_groups=CDG_SEQUENTIAL_GROUPS,
                   options=SimOptions(seed=11), scheduling=policy)
    asy = simulate(dag, pool, "async", options=SimOptions(seed=11),
                   scheduling=policy)
    asy_shared = simulate(dag, pool_shared, "async",
                          options=SimOptions(seed=11), scheduling=policy)

    out = dict(
        which=which, policy=policy,
        doa_dep=dag.doa_dep(), wla=w,
        t_seq_model=round(t_seq_model, 1),
        t_async_pred=round(t_async_pred, 1),
        t_seq_sim=round(seq.makespan, 1),
        t_async_sim_strict=round(asy.makespan, 1),
        t_async_sim_shared=round(asy_shared.makespan, 1),
        i_pred=round(relative_improvement(t_seq_model, t_async_pred), 3),
        i_sim_strict=round(
            relative_improvement(seq.makespan, asy.makespan), 3),
        i_sim_shared=round(
            relative_improvement(seq.makespan, asy_shared.makespan), 3),
        gpu_util_seq=round(seq.gpu_utilization, 3),
        gpu_util_async=round(asy.gpu_utilization, 3),
        paper=PAPER[which],
    )
    if write_csv and policy == "fifo":
        # the figN_*.csv artifacts are the paper's figures; only the paper's
        # (fifo) schedule may overwrite them
        os.makedirs(ART_DIR, exist_ok=True)
        fig = "fig5" if which == "c-DG1" else "fig6"
        for tag, res in (("seq", seq), ("async", asy)):
            ts, cpu, gpu = res.utilization_trace()
            with open(os.path.join(ART_DIR, f"{fig}_{tag}.csv"), "w",
                      newline="") as f:
                wtr = csv.writer(f)
                wtr.writerow(["t", "cpus", "gpus"])
                wtr.writerows(zip(ts, cpu, gpu))
    return out


def main(policy: str = "fifo"):
    for which in ("c-DG1", "c-DG2"):
        out = run(which, policy=policy)
        paper = out.pop("paper")
        print(f"== {which} (Table 2 workload) ==")
        for k, v in out.items():
            print(f"  {k:14s} {v}")
        print(f"  paper: i_pred={paper['i_pred']} i_meas={paper['i_meas']} "
              f"t_async_meas={paper['t_async_meas']}")
        assert out["doa_dep"] == paper["doa_dep"]
        assert out["wla"] == paper["wla"]
        if policy != "fifo":
            continue  # paper-agreement asserts hold for the paper's policy
        if which == "c-DG1":
            # the paper's headline: asynchronicity does NOT help here
            assert abs(out["i_sim_strict"]) < 0.06, out["i_sim_strict"]
        else:
            assert out["i_sim_strict"] > 0.18, out["i_sim_strict"]
            # shared-GPU schedule reproduces the paper's measured TTX
            assert abs(out["t_async_sim_shared"] - paper["t_async_meas"]) \
                / paper["t_async_meas"] < 0.08, out["t_async_sim_shared"]
    print("  agreement: OK" if policy == "fifo" else
          f"  (paper-agreement asserts skipped for policy={policy})")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="fifo",
                    help="scheduling policy: fifo | lpt | gpu_bestfit")
    main(policy=ap.parse_args().policy)

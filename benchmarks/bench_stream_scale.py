"""Trace-scale hot loop: prediction epochs + coalesced event passes +
streaming metric sketches (``PredictOptions``, ``coalesce_events``,
``record_policy="summary"``, ``RunResult.perf``).

The scenario: an open diurnal stream of small 2-CPU jobs on a 96-CPU
aggregate slice.  The diurnal peak overruns service capacity, so a
queue of a few hundred live workflows builds every cycle — exactly the
regime where per-event re-prediction (Eqns. 2-6 over every live set)
dominates the simulation's wall time.

Three arms, asserted + gated via ``benchmarks/baseline/
stream_scale.json`` + ``make bench-check``:

(a) **Throughput headline** — the hot-loop arm (epoch-throttled
    predictions + coalesced event passes + ``summary`` records) runs a
    full ~1e5-arrival stream; the unthrottled arm (per-event
    re-prediction, full trace) runs the same-seed stream cut to a 50x
    shorter horizon (an arrival-process *prefix* — thinning is a pure
    function of the seed — so the comparison is conservative: the short
    arm never reaches the deepest queues).  Gate: end-to-end simulated
    arrivals/sec at least ``5x`` higher on the hot-loop arm, with
    ``RunResult.perf`` attributing where the time went.

(b) **Dispatch identity** — on a fully-recorded mid-size stream, the
    throttled arm reproduces the unthrottled arm's record trace
    *bit-identically* per seed (predictions inform the trace, never
    placements).

(c) **Metric-query latency** — repeated ``slowdown_percentile`` /
    ``window_stats`` queries on the summary surface are O(1)-amortized:
    per-query latency at ~1e5 finished workflows is within 3x of the
    ~1e4 run (vs. the O(n log n)-per-call full re-sort this PR
    retires).

Writes ``benchmarks/out/stream_scale.json``.
"""

from __future__ import annotations

import gc
import json
import os
import time

from repro.core import (DAG, FeedbackOptions, GeneratedStream, NodeSpec,
                        PoolSpec, PredictOptions, RunConfig, SimOptions,
                        StreamTemplate, TaskSet, simulate)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

#: trough arrival rate (1/s); the diurnal swing peaks at RATE * PEAK
RATE = 0.4
PEAK = 5.0
PERIOD = 3600.0
#: full-stream horizon: mean rate RATE*(1+PEAK)/2 = 1.2/s -> ~1e5 arrivals
HORIZON = 83_000.0
#: the unthrottled arm runs the same seed on a 50x shorter horizon
PREFIX_FRACTION = 50
#: modelled-seconds floor between full re-predictions in the hot-loop arm
EPOCH = 900.0
WINDOW = 1800.0
SEED = 1
IDENTITY_SEEDS = (1, 2, 3)


def scale_pool() -> PoolSpec:
    """96 aggregate CPUs = 48 concurrent jobs = 1.6 jobs/s service rate:
    above the 1.2/s diurnal mean, below the 2.0/s peak."""
    return PoolSpec("scale", 1, NodeSpec(cpus=96, gpus=0))


def job_dag() -> DAG:
    g = DAG()
    g.add(TaskSet("job", 1, 2, 0, tx_mean=30.0, tx_sigma=6.0))
    return g


def build_stream(seed: int, horizon: float) -> GeneratedStream:
    tmpl = StreamTemplate("job", job_dag, deadline_slack=600.0,
                          reference_makespan=30.0)
    return GeneratedStream([tmpl], rate=RATE, horizon=horizon, seed=seed,
                           kind="diurnal", period=PERIOD, peak_ratio=PEAK,
                           name="scale")


#: keeps the estimator (so the predictor exists and Eqns. 2-6 re-run on
#: live TX) without migration/speculation noise in the comparison
FEEDBACK = FeedbackOptions(migrate=False)


def hot_config() -> RunConfig:
    return RunConfig(feedback=FEEDBACK,
                     predict=PredictOptions(min_interval=EPOCH),
                     coalesce_events=True, record_policy="summary",
                     slo_window=WINDOW, perf_counters=True)


def unthrottled_config() -> RunConfig:
    return RunConfig(feedback=FEEDBACK, perf_counters=True)


def perf_block(r) -> dict:
    p = r.perf
    return dict(engine_s=round(p.engine_s, 3), predict_s=round(p.predict_s, 3),
                events_s=round(p.events_s, 3), metrics_s=round(p.metrics_s, 3),
                total_s=round(p.total_s, 3), passes=p.passes,
                predicts=p.predicts, events=p.events)


def run_throughput() -> dict:
    opts = SimOptions(seed=SEED)
    t0 = time.perf_counter()
    hot = simulate(build_stream(SEED, HORIZON), scale_pool(),
                   options=opts, config=hot_config())
    wall_hot = time.perf_counter() - t0
    t0 = time.perf_counter()
    slow = simulate(build_stream(SEED, HORIZON / PREFIX_FRACTION),
                    scale_pool(), options=opts,
                    config=unthrottled_config())
    wall_slow = time.perf_counter() - t0
    for r in (hot, slow):
        assert r.stream["finished"] == r.stream["arrived"], r.stream
    rate_hot = hot.stream["arrived"] / wall_hot
    rate_slow = slow.stream["arrived"] / wall_slow
    return dict(
        arrived_hot=hot.stream["arrived"],
        arrived_unthrottled=slow.stream["arrived"],
        wall_s_hot=round(wall_hot, 3),
        wall_s_unthrottled=round(wall_slow, 3),
        arrivals_per_s_hot=round(rate_hot, 1),
        arrivals_per_s_unthrottled=round(rate_slow, 1),
        speedup=round(rate_hot / rate_slow, 2),
        predictions_hot=len(hot.predictions),
        predictions_unthrottled=len(slow.predictions),
        slo_hot=round(hot.slo_attainment(), 4),
        p99_slowdown_hot=round(hot.slowdown_percentile(0.99), 4),
        perf_hot=perf_block(hot), perf_unthrottled=perf_block(slow)), hot


def run_dispatch_identity() -> dict:
    """Both arms fully recorded + coalesced; only ``PredictOptions``
    differs.  The record traces must match bit-for-bit."""
    per_seed = {}
    for seed in IDENTITY_SEEDS:
        opts = SimOptions(seed=seed)
        horizon = 1500.0
        base = simulate(build_stream(seed, horizon), scale_pool(),
                        options=opts,
                        config=RunConfig(feedback=FEEDBACK,
                                         coalesce_events=True))
        thr = simulate(build_stream(seed, horizon), scale_pool(),
                       options=opts,
                       config=RunConfig(
                           feedback=FEEDBACK, coalesce_events=True,
                           predict=PredictOptions(min_interval=EPOCH)))
        identical = (thr.records == base.records
                     and thr.makespan == base.makespan
                     and thr.workflows == base.workflows)
        per_seed[seed] = dict(
            identical=identical,
            arrived=base.stream["arrived"],
            makespan_throttled=round(thr.makespan, 1),
            predictions_base=len(base.predictions),
            predictions_throttled=len(thr.predictions))
    return dict(per_seed=per_seed,
                identical_all=all(r["identical"]
                                  for r in per_seed.values()))


def _time_queries(r, reps: int) -> float:
    """Mean seconds per metric query (percentiles + window scan + SLO).
    Cyclic GC is drained + paused so the measurement is the query cost,
    not collector sweeps over the larger run's live object graph; one
    warm-up pass populates the memoized views first — the gate is on
    the *amortized* repeated-query latency."""
    qs = (0.5, 0.9, 0.99)
    gc.collect()
    gc.disable()
    try:
        for q in qs:
            r.slowdown_percentile(q)
        r.window_stats(WINDOW)
        r.slo_attainment()
        t0 = time.perf_counter()
        for _ in range(reps):
            for q in qs:
                r.slowdown_percentile(q)
            r.window_stats(WINDOW)
            r.slo_attainment()
        return (time.perf_counter() - t0) / (reps * (len(qs) + 2))
    finally:
        gc.enable()


def run_metric_latency(hot) -> dict:
    """Per-query latency must not scale with record count: a ~1e5-workflow
    summary surface answers within 3x of a ~1e4 one."""
    small = simulate(build_stream(SEED, HORIZON / PREFIX_FRACTION),
                     scale_pool(), options=SimOptions(seed=SEED),
                     config=hot_config())
    reps = 200
    per_small = _time_queries(small, reps)
    per_big = _time_queries(hot, reps)
    ratio = per_big / per_small
    return dict(workflows_small=small.stream["finished"],
                workflows_big=hot.stream["finished"],
                per_query_us_small=round(per_small * 1e6, 2),
                per_query_us_big=round(per_big * 1e6, 2),
                latency_ratio=round(ratio, 2))


def main() -> dict:
    print("== (a) throughput: hot-loop arm vs unthrottled prefix ==")
    tp, hot = run_throughput()
    print(f"  hot:         {tp['arrived_hot']} arrivals in "
          f"{tp['wall_s_hot']:.1f}s -> {tp['arrivals_per_s_hot']:.0f}/s "
          f"({tp['predictions_hot']} predictions)")
    print(f"  unthrottled: {tp['arrived_unthrottled']} arrivals in "
          f"{tp['wall_s_unthrottled']:.1f}s -> "
          f"{tp['arrivals_per_s_unthrottled']:.0f}/s "
          f"({tp['predictions_unthrottled']} predictions)")
    ph, pu = tp["perf_hot"], tp["perf_unthrottled"]
    print(f"  perf hot:         engine {ph['engine_s']}s predict "
          f"{ph['predict_s']}s events {ph['events_s']}s metrics "
          f"{ph['metrics_s']}s")
    print(f"  perf unthrottled: engine {pu['engine_s']}s predict "
          f"{pu['predict_s']}s events {pu['events_s']}s metrics "
          f"{pu['metrics_s']}s")
    print(f"  speedup: {tp['speedup']:.1f}x (gate: >= 5x)")
    assert tp["speedup"] >= 5.0, tp

    print("== (b) throttled predictions leave the dispatch sequence "
          "bit-identical ==")
    ident = run_dispatch_identity()
    for seed, r in ident["per_seed"].items():
        print(f"  seed {seed}: identical={r['identical']}  "
              f"predictions {r['predictions_base']} -> "
              f"{r['predictions_throttled']}  "
              f"({r['arrived']} workflows)")
        assert r["identical"], (seed, ident)
        assert r["predictions_throttled"] < r["predictions_base"], (seed,
                                                                    ident)

    print("== (c) summary metric queries are O(1)-amortized ==")
    lat = run_metric_latency(hot)
    print(f"  {lat['workflows_small']} wf: "
          f"{lat['per_query_us_small']:.1f}us/query   "
          f"{lat['workflows_big']} wf: "
          f"{lat['per_query_us_big']:.1f}us/query   "
          f"ratio {lat['latency_ratio']:.2f} (gate: <= 3)")
    assert lat["latency_ratio"] <= 3.0, lat

    out = {
        "throughput": tp, "dispatch_identity": ident,
        "metric_latency": lat,
        "headlines": dict(speedup=tp["speedup"],
                          dispatch_identity=ident["identical_all"],
                          metric_query_sublinear=(
                              lat["latency_ratio"] <= 3.0),
                          latency_ratio=lat["latency_ratio"]),
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "stream_scale.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  stream_scale: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

"""Runtime-feedback scheduling under heavy-tailed durations + stragglers.

The paper's model (Eqn. 5) assumes static mean task-execution times; real
ML-driven HPC tasks are lognormal-ish with stragglers.  This benchmark
stresses the paper's workloads (c-DG1 / c-DG2 Table 2, DeepDriveMD
Table 1) with lognormal TX sampling and injected stragglers, on an
allocation split into two partitions with a data-movement (transfer)
cost between them, and compares three arms through the shared engine:

- ``static``     fifo, static-TX scheduling (the paper's assumption);
- ``static_lpt`` lpt with static TXs — isolates the ordering change so
                 the feedback arms below are compared like-for-like;
- ``observed``   runtime feedback on (online EWMA TX estimates re-rank
                 ready sets under lpt) but migration disabled;
- ``migration``  full feedback: stragglers are preempted and requeued on
                 the other partition, paying the transfer cost.

Also checks the new ``locality`` placement policy preserves the paper's
headline: the shared-GPU c-DG2 async-vs-sequential win (I ~= 0.34
simulated) must survive data-movement-aware placement.

Writes ``benchmarks/out/runtime_feedback.json`` (uploaded as a CI
artifact).
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (CDG_SEQUENTIAL_GROUPS, Allocation, FeedbackOptions,
                        SimOptions, cdg_dag, ddmd_sequential_stage_groups,
                        deepdrivemd_dag, relative_improvement, simulate,
                        summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

WORKLOADS = {
    "c-DG1": (lambda: cdg_dag("c-DG1"), CDG_SEQUENTIAL_GROUPS),
    "c-DG2": (lambda: cdg_dag("c-DG2"), CDG_SEQUENTIAL_GROUPS),
    "DeepDriveMD": (lambda: deepdrivemd_dag(3),
                    ddmd_sequential_stage_groups(3)),
}

#: heavy-tailed durations + 10% of tasks stretched 16x — the regime the
#: static-TX model knows nothing about
HEAVY = dict(tx_distribution="lognormal", lognormal_sigma=0.5,
             straggler_prob=0.1, straggler_factor=16.0)
#: detection threshold: runtime > mean + 2 sigma of the running estimate
FEEDBACK = FeedbackOptions(straggler_k=2.0)
SEEDS = (3, 7, 11)


def split_summit(num_nodes: int = 16, transfer: float = 10.0) -> Allocation:
    """The paper's Summit allocation split into two equal partitions with a
    symmetric data-movement cost (s) between them — the smallest topology
    on which straggler migration and locality placement are non-trivial."""
    half = summit_pool(num_nodes // 2)
    return Allocation(
        "summit-split",
        (dataclasses.replace(half, name="summit-a"),
         dataclasses.replace(half, name="summit-b")),
        transfer_cost=((0.0, transfer), (transfer, 0.0)),
    )


def run_arms(which: str) -> dict:
    build, _groups = WORKLOADS[which]
    alloc = split_summit()
    arms = {
        "static": dict(scheduling="fifo", feedback=None),
        "static_lpt": dict(scheduling="lpt", feedback=None),
        "observed": dict(scheduling="lpt",
                         feedback=dataclasses.replace(FEEDBACK,
                                                      migrate=False)),
        "migration": dict(scheduling="lpt", feedback=FEEDBACK),
    }
    out: dict = {"workload": which, "arms": {}}
    for arm, kw in arms.items():
        makespans, migrations = [], 0
        for seed in SEEDS:
            res = simulate(build(), alloc, "async",
                           options=SimOptions(seed=seed, **HEAVY), **kw)
            makespans.append(res.makespan)
            migrations += res.migrations
        out["arms"][arm] = dict(
            makespan_mean=round(sum(makespans) / len(makespans), 1),
            makespans=[round(m, 1) for m in makespans],
            migrations=migrations,
        )
    return out


def run_locality_headline() -> dict:
    """The paper's shared-GPU c-DG2 async win under ``locality``."""
    pool = dataclasses.replace(summit_pool(16), oversubscribe_gpus=True)
    dag = cdg_dag("c-DG2")
    opts = SimOptions(seed=11)
    seq = simulate(dag, pool, "sequential", options=opts,
                   sequential_stage_groups=CDG_SEQUENTIAL_GROUPS,
                   scheduling="locality")
    asy = simulate(dag, pool, "async", options=opts, scheduling="locality")
    return dict(t_seq=round(seq.makespan, 1), t_async=round(asy.makespan, 1),
                i=round(relative_improvement(seq.makespan, asy.makespan), 3))


def main() -> dict:
    print("== runtime-feedback scheduling (lognormal TX + 10% 16x "
          "stragglers, split Summit allocation) ==")
    print(f"  {'workload':12s} {'static':>10s} {'static_lpt':>10s} "
          f"{'observed':>10s} {'migration':>10s} {'#migr':>6s}")
    results = []
    for which in WORKLOADS:
        r = run_arms(which)
        a = r["arms"]
        print(f"  {which:12s} {a['static']['makespan_mean']:10.1f} "
              f"{a['static_lpt']['makespan_mean']:10.1f} "
              f"{a['observed']['makespan_mean']:10.1f} "
              f"{a['migration']['makespan_mean']:10.1f} "
              f"{a['migration']['migrations']:6d}")
        results.append(r)
        if which == "c-DG2":
            # acceptance: observed-TX + migration must not lose to the
            # static-TX fifo baseline under stragglers...
            assert a["migration"]["makespan_mean"] <= \
                a["static"]["makespan_mean"] * 1.001, a
            # ...and the win must come from the feedback layer, not from
            # the fifo->lpt ordering switch (same-ordering comparison)
            assert a["migration"]["makespan_mean"] <= \
                a["static_lpt"]["makespan_mean"] * 1.001, a
            assert a["migration"]["migrations"] > 0, a

    print("  (static == static_lpt == observed is expected here: these "
          "makespans are tail-straggler-bound,\n   so dispatch ordering "
          "cannot move them — the whole win is preemption + migration)")
    loc = run_locality_headline()
    print(f"-- locality policy, shared-GPU c-DG2 (paper headline) --")
    print(f"  t_seq={loc['t_seq']} t_async={loc['t_async']} I={loc['i']}")
    # the paper's async win (I ~= 0.34 simulated) survives locality-aware
    # placement
    assert loc["i"] > 0.25, loc

    out = {"config": HEAVY, "seeds": list(SEEDS), "workloads": results,
           "locality_cdg2_shared": loc}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "runtime_feedback.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  agreement: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

"""Multi-workflow tenancy + prediction-driven admission
(``core/workflow.Campaign``, ``core/sched_engine.AdmissionOptions``).

Three claims, all asserted (CI gates on them via
``benchmarks/baseline/admission.json`` + ``make bench-check``):

(a) **Tenancy headline** — on a 3-workflow Summit campaign (DeepDriveMD
    at priority 2 next to c-DG1 / c-DG2 arriving 400 s / 800 s later),
    admission-controlled scheduling (the ``priority`` policy + the
    engine's admission controller) beats BOTH FIFO-admit-all and a
    static 6/5/5-node partition on fairness-weighted slowdown, per seed
    — while every workflow's slowdown against its dedicated single-
    tenant async run stays bounded (the tenancy never destroys a
    workflow's own async win).

(b) **Deferral** — on a latency-sensitive inference stream (8 staggered
    96-task 1-GPU waves) sharing the allocation with a wide, long
    low-priority training job (16 x 6-GPU x 600 s, arriving mid-stream),
    the admission controller defers the training set: its tasks would
    pin devices across ~10 of the stream's scheduling rounds
    (``hold_ratio``) with no predicted overlap win (``i_floor`` — both
    are GPU-bound, so the marginal Eqn.-5 improvement collapses).  With
    admission ON the stream preserves its single-tenant makespan
    (slowdown ~1.0) and weighted slowdown beats admission OFF on every
    seed; the conservation guard still completes the training job
    (deferred != lost).

(c) **Single-workflow bit-identity** — a one-workflow ``Campaign`` with
    admission off reproduces the committed single-workflow baselines
    exactly: ``predictor.json``'s convergence seed 3 (shared-GPU c-DG2 +
    lognormal + arbitrated feedback) and ``topology.json``'s fragmented
    nodepack seed 1 (node-level pool).  The tenancy plumbing may not
    disturb a single tenant's schedule by a single event.

Writes ``benchmarks/out/admission.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (DAG, AdmissionOptions, Campaign, FeedbackOptions,
                        SimOptions, TaskSet, cdg_dag, deepdrivemd_dag,
                        simulate, summit_pool)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baseline")

SEEDS = (1, 2, 3, 4, 5)
LOGNORMAL = dict(tx_distribution="lognormal", lognormal_sigma=0.5)
#: campaign (a): fairness weights and arrivals of the three workflows
CAMPAIGN_WF = dict(
    ddmd=dict(priority=2, arrival=0.0, weight=3.0, nodes=6),
    cdg1=dict(priority=1, arrival=400.0, weight=1.0, nodes=5),
    cdg2=dict(priority=0, arrival=800.0, weight=1.0, nodes=5),
)
#: per-workflow slowdown bound: tenancy must not destroy a workflow's
#: dedicated-async performance
SLOWDOWN_BOUND = 1.8


def campaign_dags() -> dict[str, DAG]:
    return {"ddmd": deepdrivemd_dag(3), "cdg1": cdg_dag("c-DG1"),
            "cdg2": cdg_dag("c-DG2")}


def references(seed: int) -> dict[str, float]:
    """Dedicated single-tenant async makespans (slowdown denominators)."""
    return {name: simulate(dag, summit_pool(), "async",
                           options=SimOptions(seed=seed, **LOGNORMAL)
                           ).makespan
            for name, dag in campaign_dags().items()}


def build_campaign(refs: dict[str, float]) -> Campaign:
    c = Campaign(name="summit-3wf")
    for name, dag in campaign_dags().items():
        p = CAMPAIGN_WF[name]
        c.add(name, dag, priority=p["priority"], arrival=p["arrival"],
              weight=p["weight"], reference_makespan=refs[name])
    return c


def run_tenancy() -> dict:
    per_seed = {}
    for seed in SEEDS:
        refs = references(seed)
        opts = SimOptions(seed=seed, **LOGNORMAL)
        fifo = simulate(build_campaign(refs), summit_pool(), "async",
                        options=opts, scheduling="fifo")
        adm = simulate(build_campaign(refs), summit_pool(), "async",
                       options=opts, scheduling="priority",
                       admission=AdmissionOptions())
        # static partitioning: each workflow alone on its fixed node slice
        num = den = 0.0
        for name, dag in campaign_dags().items():
            p = CAMPAIGN_WF[name]
            m = simulate(dag, summit_pool(p["nodes"]), "async",
                         options=opts).makespan
            num += p["weight"] * (m / refs[name])
            den += p["weight"]
        per_seed[seed] = dict(
            fifo_ws=round(fifo.weighted_slowdown(), 4),
            admission_ws=round(adm.weighted_slowdown(), 4),
            static_ws=round(num / den, 4),
            admission_slowdowns={k: round(v.slowdown, 4)
                                 for k, v in adm.workflows.items()},
            makespan_admission=round(adm.makespan, 1))
    mean = lambda key: round(  # noqa: E731 - tiny reduction helper
        sum(r[key] for r in per_seed.values()) / len(per_seed), 4)
    return dict(seeds=list(SEEDS), per_seed=per_seed,
                fifo_ws_mean=mean("fifo_ws"),
                admission_ws_mean=mean("admission_ws"),
                static_ws_mean=mean("static_ws"))


def serve_dag(n_waves: int = 8) -> DAG:
    """A latency-sensitive inference stream: staggered 96-task 1-GPU
    waves (each wave paces the next, as DDMD's simulations do)."""
    g = DAG()
    prev = None
    for i in range(n_waves):
        g.add(TaskSet(f"S{i}", 96, 4, 1, tx_mean=60.0, kind="inference"))
        if prev is not None:
            g.add_edge(prev, f"S{i}")
        prev = f"S{i}"
    return g


def train_dag() -> DAG:
    """The wide, long background job: 16 x 6-GPU x 600 s training tasks
    (each pins a whole Summit node for ~10 serve waves once started)."""
    g = DAG()
    g.add(TaskSet("T", 16, 4, 6, tx_mean=600.0, kind="training"))
    return g


def run_deferral() -> dict:
    per_seed = {}
    for seed in SEEDS:
        opts = SimOptions(seed=seed, **LOGNORMAL)
        ref_serve = simulate(serve_dag(), summit_pool(), "async",
                             options=opts).makespan
        ref_train = simulate(train_dag(), summit_pool(), "async",
                             options=opts).makespan

        def build() -> Campaign:
            c = Campaign(name="serve-train")
            c.add("serve", serve_dag(), priority=1, weight=4.0,
                  reference_makespan=ref_serve)
            c.add("train", train_dag(), priority=0, arrival=100.0,
                  weight=0.25, reference_makespan=ref_train)
            return c

        off = simulate(build(), summit_pool(), "async", options=opts,
                       scheduling="priority")
        on = simulate(build(), summit_pool(), "async", options=opts,
                      scheduling="priority", admission=AdmissionOptions())
        total = sum(ts.num_tasks for d in (serve_dag(), train_dag())
                    for ts in d.nodes.values())
        assert on.tasks_total == off.tasks_total == total  # deferred != lost
        per_seed[seed] = dict(
            off_ws=round(off.weighted_slowdown(), 4),
            on_ws=round(on.weighted_slowdown(), 4),
            deferrals=on.admission_deferrals,
            serve_slowdown_off=round(off.workflows["serve"].slowdown, 4),
            serve_slowdown_on=round(on.workflows["serve"].slowdown, 4))
    return dict(seeds=list(SEEDS), per_seed=per_seed)


def run_baseline_identity() -> dict:
    """One-workflow campaigns (admission off) must reproduce the
    committed single-workflow baselines bit-exactly."""
    out: dict = {}

    # predictor.json convergence seed 3: shared-GPU c-DG2, lognormal,
    # arbitrated feedback
    shared = dataclasses.replace(summit_pool(), oversubscribe_gpus=True)
    c = Campaign()
    c.add("solo", cdg_dag("c-DG2"))
    res = simulate(c, shared, "async",
                   options=SimOptions(seed=3, **LOGNORMAL),
                   feedback=FeedbackOptions(straggler_k=2.0, speculate=True))
    with open(os.path.join(BASELINE_DIR, "predictor.json")) as f:
        committed = json.load(f)["convergence"]["per_seed"]["3"]["makespan"]
    out["predictor_seed3"] = dict(fresh=round(res.makespan, 1),
                                  committed=committed,
                                  identical=round(res.makespan, 1)
                                  == committed)

    # topology.json fragmented nodepack seed 1: node-level pool
    from bench_topology import frag_dag, frag_pool
    c2 = Campaign()
    c2.add("solo", frag_dag())
    res2 = simulate(c2, frag_pool(), "async", options=SimOptions(seed=1),
                    scheduling="nodepack")
    with open(os.path.join(BASELINE_DIR, "topology.json")) as f:
        committed2 = json.load(f)["fragmented"]["arms"]["nodepack"][
            "makespans"][0]
    out["topology_nodepack_seed1"] = dict(fresh=round(res2.makespan, 1),
                                          committed=committed2,
                                          identical=round(res2.makespan, 1)
                                          == committed2)
    return out


def main() -> dict:
    print("== (a) 3-workflow Summit campaign: weighted slowdown ==")
    ten = run_tenancy()
    for seed, r in ten["per_seed"].items():
        print(f"  seed {seed}: fifo={r['fifo_ws']:.3f}  "
              f"admission={r['admission_ws']:.3f}  "
              f"static={r['static_ws']:.3f}")
        assert r["admission_ws"] <= r["fifo_ws"], (seed, ten)
        assert r["admission_ws"] <= r["static_ws"], (seed, ten)
        for wf, sd in r["admission_slowdowns"].items():
            assert sd <= SLOWDOWN_BOUND, (seed, wf, sd)
    print(f"  means: fifo={ten['fifo_ws_mean']:.3f}  "
          f"admission={ten['admission_ws_mean']:.3f}  "
          f"static={ten['static_ws_mean']:.3f}")

    print("== (b) deferral: inference stream + wide long training job ==")
    de = run_deferral()
    for seed, r in de["per_seed"].items():
        print(f"  seed {seed}: off={r['off_ws']:.3f}  on={r['on_ws']:.3f}  "
              f"deferrals={r['deferrals']}  serve "
              f"{r['serve_slowdown_off']:.3f} -> "
              f"{r['serve_slowdown_on']:.3f}")
        assert r["on_ws"] <= r["off_ws"], (seed, de)
        assert r["deferrals"] > 0, (seed, de)
        assert r["serve_slowdown_on"] <= 1.05, (seed, de)

    print("== (c) one-workflow campaign stays bit-identical to committed "
          "baselines ==")
    ident = run_baseline_identity()
    for which, r in ident.items():
        print(f"  {which:24s} fresh={r['fresh']} "
              f"committed={r['committed']} identical={r['identical']}")
        assert r["identical"], (which, ident)

    out = {"tenancy": ten, "deferral": de, "baseline_identity": ident}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "admission.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  admission: OK (wrote {os.path.relpath(path)})")
    return out


if __name__ == "__main__":
    main()

"""DeepDriveMD with REAL heterogeneous JAX payloads, executed
asynchronously by the middleware (the paper's §6.1 experiment, with jitted
model steps instead of `stress`).

Task types (all real JAX work on reduced configs):
  Simulation   autoregressive decode rollout (MD-like trajectory producer)
  Aggregation  reduction over produced trajectories (CPU-ish)
  Training     train_step()s of a reduced qwen2 on the aggregated tokens
  Inference    batched prefill scoring candidate sequences

The RealExecutor enforces the same (cpus, gpus) accounting as the paper's
middleware; sequential mode barriers each stage, async mode staggers the
three iterations — compare the measured makespans and the task throughput.

Run:  PYTHONPATH=src python examples/deepdrivemd_async.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import RealExecutor, deepdrivemd_dag, relative_improvement
from repro.core.workflow import ddmd_sequential_stage_groups
from repro.models.api import build_model
from repro.models.params import init_params
from repro.runtime import TrainOptions
from repro.runtime.steps import build_decode_step, build_prefill_step, \
    build_train_step, make_train_state

# -- build the real payloads (reduced config; jitted once, reused) ----------
CFG = get_config("qwen2-0.5b").reduced()
MODEL = build_model(CFG)
PARAMS = init_params(MODEL.specs(), jax.random.PRNGKey(0))
STATE = make_train_state(MODEL, jax.random.PRNGKey(1))
TRAIN_STEP, _ = build_train_step(MODEL, opts=TrainOptions(total_steps=100))
PREFILL, _ = build_prefill_step(MODEL)
DECODE, _ = build_decode_step(MODEL, batch=2, s_max=64)


def simulation_payload(i: int):
    """Decode rollout: 8 tokens for 2 'trajectories'."""
    cache = init_params(MODEL.cache_specs(2, 64), jax.random.PRNGKey(i))
    tok = jnp.full((2, 1), 3, jnp.int32)
    for t in range(8):
        nxt, _, cache = DECODE(PARAMS, cache, tok,
                               jnp.full((2,), t, jnp.int32))
        tok = nxt[:, None]
    return jax.block_until_ready(tok)


def aggregation_payload(i: int):
    x = jax.random.normal(jax.random.PRNGKey(i), (1 << 16,))
    return jax.block_until_ready(jnp.sort(x)[::64].sum())


def training_payload(i: int):
    global STATE
    batch = MODEL.make_batch(jax.random.PRNGKey(100 + i), batch=2, seq=32)
    STATE, metrics = TRAIN_STEP(STATE, batch)
    return jax.block_until_ready(metrics["loss"])


def inference_payload(i: int):
    batch = MODEL.make_batch(jax.random.PRNGKey(200 + i), batch=2, seq=32,
                             mode="prefill")
    return jax.block_until_ready(PREFILL(PARAMS, batch))


#: scaled-down task counts/durations (laptop-scale validation, §7 analogue)
TABLE = dict(
    simulation=dict(cpus=1, gpus=1, n=6, tx=0.0),
    aggregation=dict(cpus=2, gpus=0, n=3, tx=0.0),
    training=dict(cpus=1, gpus=1, n=1, tx=0.0),
    inference=dict(cpus=1, gpus=1, n=6, tx=0.0),
)

PAYLOADS = dict(simulation=simulation_payload, aggregation=aggregation_payload,
                training=training_payload, inference=inference_payload)


def main():
    # warm the jit caches so the comparison measures orchestration
    for fn in PAYLOADS.values():
        fn(0)

    from repro.core.resources import Allocation, NodeSpec, PoolSpec
    pool = PoolSpec("laptop", num_nodes=1, node=NodeSpec(cpus=8, gpus=4))
    dag = deepdrivemd_dag(3, table=TABLE, payloads=PAYLOADS)

    ex = RealExecutor(pool, launch_latency=0.002)
    t0 = time.perf_counter()
    seq = ex.run(dag, "sequential",
                 sequential_stage_groups=ddmd_sequential_stage_groups(3))
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    asy = ex.run(dag, "async")
    t_async = time.perf_counter() - t0

    i = relative_improvement(t_seq, t_async)
    print(f"sequential: {t_seq:6.2f}s   ({seq.tasks_total} tasks, "
          f"{seq.throughput():.1f} tasks/s)")
    print(f"async:      {t_async:6.2f}s   ({asy.tasks_total} tasks, "
          f"{asy.throughput():.1f} tasks/s)")
    print(f"I = {i:.3f}  (real JAX payloads, real thread-level concurrency)")

    # heterogeneous allocation: an accelerator partition + a CPU partition;
    # gpu_bestfit packs the CPU-only Aggregation tasks onto the CPU nodes.
    hetero = Allocation("laptop-hybrid", (
        PoolSpec("accel", num_nodes=1, node=NodeSpec(cpus=4, gpus=4)),
        PoolSpec("cpu", num_nodes=1, node=NodeSpec(cpus=8, gpus=0)),
    ))
    het = RealExecutor(hetero, launch_latency=0.002).run(
        dag, "async", scheduling="gpu_bestfit")
    print(f"hybrid pools (gpu_bestfit): {het.per_pool_task_counts()}")
    return i


if __name__ == "__main__":
    main()

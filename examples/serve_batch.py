"""Batched serving example: prefill + decode across heterogeneous
architectures (dense / MoE / RWKV6 / hybrid), demonstrating the unified
cache-specs + decode-step API the serving runtime is built on.

The scheduler-side view of a fleet of these jobs is
`examples/stream_tenancy.py`: an open `WorkflowStream` of prefill +
decode workflows with per-arrival SLOs, deadline-aware admission, and
elastic node leases.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.models.params import init_params
from repro.runtime.steps import build_decode_step, build_prefill_step

ARCHS = ("qwen2-0.5b", "qwen3-moe-30b-a3b", "rwkv6-1.6b", "zamba2-1.2b")
B, PROMPT, GEN, CACHE = 4, 24, 12, 64


def serve_one(arch: str):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))

    prefill, _ = build_prefill_step(model)
    batch = model.make_batch(jax.random.PRNGKey(1), batch=B, seq=PROMPT,
                             mode="prefill")
    batch.pop("labels", None)
    t0 = time.perf_counter()
    nxt = jnp.argmax(prefill(params, batch), axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    decode, _ = build_decode_step(model, batch=B, s_max=CACHE)
    cache = init_params(model.cache_specs(B, CACHE), jax.random.PRNGKey(2))
    toks = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for i in range(GEN):
        nxt, _, cache = decode(params, cache, nxt[:, None],
                               jnp.full((B,), PROMPT + i, jnp.int32))
        toks.append(np.asarray(nxt))
    dt = time.perf_counter() - t0
    gen = np.stack(toks, 1)
    assert gen.shape == (B, GEN + 1) and (gen >= 0).all()
    print(f"  {arch:22s} prefill {t_prefill:6.2f}s   decode "
          f"{B * GEN / dt:7.1f} tok/s   sample {gen[0][:6]}")


def main():
    print(f"batched serving: {B} requests, prompt {PROMPT}, +{GEN} tokens")
    for arch in ARCHS:
        serve_one(arch)
    print("OK: one serving loop, four architecture families")


if __name__ == "__main__":
    main()

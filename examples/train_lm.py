"""End-to-end driver: train a ~100M-param qwen2-style LM for a few hundred
steps on CPU with the full production substrate — sharded-ready step
builder, WSD/cosine schedule, grad accumulation, async checkpointing, an
injected node failure with elastic restart, and a loss that demonstrably
goes down.

Run:  PYTHONPATH=src python examples/train_lm.py            (~100M, slow on CPU)
Fast: PYTHONPATH=src python examples/train_lm.py --small --steps 60  (~3 min)
"""

import argparse
import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore_pytree
from repro.configs import get_config
from repro.data import SyntheticTokenDataset
from repro.models.api import build_model
from repro.models.params import count_params
from repro.runtime import TrainOptions
from repro.runtime.steps import build_train_step, make_train_state

CKPT = "/tmp/repro_train_lm"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step "
                         "(default: steps//2)")
    ap.add_argument("--small", action="store_true",
                    help="~33M variant for quick CPU validation")
    args = ap.parse_args(argv)
    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2

    if args.small:
        # ~33M params: same family, small vocab — minutes on one CPU core
        cfg = dataclasses.replace(
            get_config("qwen2-0.5b"), num_layers=8, d_model=512,
            num_heads=8, num_kv_heads=2, head_dim=64, d_ff=1536,
            vocab_size=8192, max_position=args.seq)
    else:
        # ~103M params: qwen2-0.5b geometry scaled down
        cfg = dataclasses.replace(
            get_config("qwen2-0.5b"), num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, max_position=args.seq)
    model = build_model(cfg)
    n = count_params(model.specs())
    print(f"model: {n / 1e6:.1f}M params "
          f"({cfg.num_layers}L x {cfg.d_model}d, vocab {cfg.vocab_size})")

    opts = TrainOptions(peak_lr=1e-3, warmup=20, total_steps=args.steps,
                        schedule="wsd", microbatches=2)
    step_fn, _ = build_train_step(model, opts=opts)
    state = make_train_state(model, jax.random.PRNGKey(0))
    ds = SyntheticTokenDataset(cfg.vocab_size, args.seq, args.batch, seed=42)

    shutil.rmtree(CKPT, ignore_errors=True)
    mgr = CheckpointManager(CKPT, interval=25, max_keep=2)

    losses = {}
    s, injected = 0, False
    t0 = time.perf_counter()
    while s < args.steps:
        if s == fail_at and not injected:
            injected = True
            # simulate losing the allocation: drop in-memory state,
            # restore from the latest async checkpoint
            mgr.wait()
            ls = latest_step(CKPT)
            print(f"!! injected node failure at step {s}; "
                  f"restoring from checkpoint step {ls}")
            assert ls is not None, "no checkpoint to restore from"
            state = restore_pytree(state, CKPT, ls)
            s = ls + 1
            continue
        hb = ds.host_batch(s)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses[s] = loss
        mgr.maybe_save(state, s)
        if s % 20 == 0:
            rate = (s + 1) / (time.perf_counter() - t0)
            print(f"step {s:4d}  loss {loss:.4f}  lr "
                  f"{float(metrics['lr']):.2e}  ({rate:.2f} steps/s)")
        s += 1
    mgr.close()

    first = losses[min(losses)]
    last = sum(losses[k] for k in sorted(losses)[-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(failure injected and recovered: {injected})")
    drop = 0.5 if args.steps >= 100 else 0.15
    assert last < first - drop, f"loss must drop by > {drop} nats"
    # determinism check: batch at a step is identical across restarts
    b1 = ds.host_batch(7)["tokens"]
    b2 = ds.host_batch(7)["tokens"]
    assert (b1 == b2).all()
    print("OK: loss decreased; pipeline deterministic; restart transparent")


if __name__ == "__main__":
    main()

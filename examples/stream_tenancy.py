"""Streaming tenancy quickstart: an open arrival stream of SLO-carrying
serving jobs, scheduled with deadline-aware admission, preemptive
revocation, and elastic node leases — the service-ification of the
paper's execution model.

1. Shape the workload as `StreamTemplate`s (a batch-decode job with a
   per-arrival SLO, a recurring low-priority fine-tune).
2. Generate a seeded diurnal arrival process (`GeneratedStream`).
3. Run it through the simulator with one frozen `RunConfig`.
4. Read steady-state metrics: SLO attainment, P99 weighted slowdown,
   sliding windows, the conservation partition, and the lease ledger.

Run:  PYTHONPATH=src python examples/stream_tenancy.py
"""

from repro.core import (DAG, AdmissionOptions, ElasticOptions,
                        GeneratedStream, RunConfig, SimOptions,
                        StreamTemplate, TaskSet, simulate, summit_pool)

HORIZON = 1800.0


def decode_job() -> DAG:
    """One batch-decode serving job (see `examples/serve_batch.py` for
    the real prefill + decode steps this models)."""
    g = DAG()
    g.add(TaskSet("prefill", 4, 4, 1, tx_mean=40.0, kind="inference"))
    g.add(TaskSet("decode", 4, 4, 1, tx_mean=60.0, kind="inference"))
    g.add_edge("prefill", "decode")
    return g


def finetune_job() -> DAG:
    g = DAG()
    g.add(TaskSet("tune", 2, 8, 6, tx_mean=400.0, kind="training"))
    return g


def main():
    infer = StreamTemplate("infer", decode_job, priority=2, weight=4.0,
                           deadline_slack=600.0,      # the SLO
                           reference_makespan=140.0)  # dedicated TTX
    tune = StreamTemplate("tune", finetune_job, priority=0, weight=0.5,
                          reference_makespan=420.0)
    stream = GeneratedStream([infer], rate=1 / 80.0, horizon=HORIZON,
                             seed=7, kind="diurnal", period=HORIZON,
                             peak_ratio=5.0, periodic=[(tune, 600.0)],
                             name="serve")
    print(f"== stream: {len(stream)} workflows over {HORIZON:.0f} s ==")

    res = simulate(stream, summit_pool(4, node_level=True),
                   options=SimOptions(seed=7),
                   config=RunConfig(
                       scheduling="priority",
                       admission=AdmissionOptions(deadline_aware=True,
                                                  revoke=True,
                                                  max_defer_time=400.0),
                       elastic=ElasticOptions(max_lease_nodes=2,
                                              lease_term=400.0)))

    print("== steady state ==")
    print(f"  SLO attainment : {res.slo_attainment():.3f}")
    print(f"  P50 / P99 slowdown: {res.slowdown_percentile(0.5):.2f} / "
          f"{res.slowdown_percentile(0.99):.2f}")
    for w in res.window_stats(600.0):
        slo = "-" if w["slo_attainment"] is None \
            else f"{w['slo_attainment']:.2f}"
        p99 = "-" if w["p99_slowdown"] is None \
            else f"{w['p99_slowdown']:.2f}"
        print(f"  [{w['t0']:6.0f}, {w['t1']:6.0f})  finished="
              f"{w['finished']:3d}  slo={slo}  p99={p99}")

    print("== conservation + mechanisms ==")
    print(f"  {res.stream}")
    print(f"  revocations={res.admission_revocations}  leases "
          f"+{res.leases_granted}/-{res.leases_expired}")
    assert res.stream["finished"] == res.stream["arrived"]


if __name__ == "__main__":
    main()

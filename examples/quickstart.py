"""Quickstart: the paper's model + middleware in 60 lines.

1. Build a workflow DG (DeepDriveMD, 3 iterations).
2. Compute the paper's metrics: DOA_dep, DOA_res, WLA (Eqn. 1).
3. Predict makespans with the analytic model (Eqns. 2-6).
4. Simulate sequential vs asynchronous execution and compare.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (ASYNC_OVERHEAD, ENTK_OVERHEAD, SimOptions,
                        deepdrivemd_dag, ddmd_sequential_stage_groups,
                        ddmd_stage_tx, maskable_stages, relative_improvement,
                        sequential_ttx_grouped, simulate,
                        staggered_async_ttx, summit_pool, wla)
from repro.core.workflow import DDMD_STAGE_ORDER, ddmd_task_sets


def main():
    dag = deepdrivemd_dag(n_iterations=3)
    pool = summit_pool(num_nodes=16)

    print("== workflow ==")
    print(f"  task sets: {len(dag)}  tasks: "
          f"{sum(ts.num_tasks for ts in dag.nodes.values())}")
    print(f"  DOA_dep = {dag.doa_dep()}  (independent branches - 1)")
    print(f"  WLA     = {wla(dag, pool, 'full_set')}  (Eqn. 1)")

    print("== analytic model (Eqns. 2 and 6) ==")
    stage_tx = ddmd_stage_tx()
    mask = maskable_stages([ddmd_task_sets(0)[k] for k in DDMD_STAGE_ORDER],
                           pool)
    t_seq = sequential_ttx_grouped(stage_tx, n_iterations=3)
    t_async = staggered_async_ttx(stage_tx, 3, mask) \
        * (1 + ENTK_OVERHEAD) * (1 + ASYNC_OVERHEAD)
    print(f"  t_seq   = {t_seq:7.1f} s")
    print(f"  t_async = {t_async:7.1f} s (Eqn. 6 + overhead corrections)")
    print(f"  I       = {relative_improvement(t_seq, t_async):.3f}")

    print("== simulated execution ==")
    seq = simulate(dag, pool, "sequential",
                   sequential_stage_groups=ddmd_sequential_stage_groups(3),
                   options=SimOptions(seed=0))
    asy = simulate(dag, pool, "async", options=SimOptions(seed=0))
    print(f"  sequential: {seq.makespan:7.1f} s  "
          f"(GPU util {seq.gpu_utilization:.0%})")
    print(f"  async:      {asy.makespan:7.1f} s  "
          f"(GPU util {asy.gpu_utilization:.0%})")
    print(f"  I = {relative_improvement(seq.makespan, asy.makespan):.3f} "
          "— asynchronous execution wins by masking Aggregation/Training "
          "behind Simulations")


if __name__ == "__main__":
    main()

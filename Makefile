PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all bench-policies bench-feedback bench-predictor \
        bench-check bench-paper docs-check lint format-check

## tier-1: everything except the slow subprocess multi-device runs
test-fast:
	$(PY) -m pytest -q -m "not slow"

## the full suite, slow distributed subprocess tests included
test-all:
	$(PY) -m pytest -q

## scheduling-policy comparison on the paper's workloads
bench-policies:
	$(PY) benchmarks/bench_policies.py

## runtime feedback: observed TX + straggler migration under heavy tails
bench-feedback:
	$(PY) benchmarks/bench_runtime_feedback.py

## predictive control plane: makespan re-prediction convergence + the
## speculation-vs-migration arbiter
bench-predictor:
	$(PY) benchmarks/bench_predictor.py

## benchmark-regression gate: fresh benchmarks/out/*.json vs the
## committed benchmarks/baseline/*.json (>10% makespan drift or a lost
## headline fails); run after the bench targets
bench-check:
	$(PY) tools/bench_check.py

## README/DESIGN sanity: referenced paths + policy names must exist
docs-check:
	$(PY) tools/docs_check.py

## ruff lint (CI `lint` job; needs ruff installed)
lint:
	ruff check src tools benchmarks

## ruff formatter drift report (advisory in CI until the tree has been
## `ruff format`-ed once; then fold into `lint`)
format-check:
	ruff format --check src

## the paper-reproduction benchmarks (Tables 1-3, Figs. 4-6)
bench-paper:
	$(PY) benchmarks/bench_deepdrivemd.py
	$(PY) benchmarks/bench_cdg.py

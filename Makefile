PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all test-cov bench-policies bench-feedback \
        bench-predictor bench-topology bench-admission \
        bench-engine-scale bench-faults bench-streaming \
        bench-stream-scale bench-scenarios bench-check bench-paper \
        docs-check lint format-check profile

## tier-1: everything except the slow subprocess multi-device runs
test-fast:
	$(PY) -m pytest -q -m "not slow"

## tier-1 with line coverage (CI; needs pytest-cov installed)
test-cov:
	$(PY) -m pytest -q -m "not slow" --cov=repro --cov-report=term-missing \
	    --cov-report=xml:coverage.xml

## the full suite, slow distributed subprocess tests included
test-all:
	$(PY) -m pytest -q

## scheduling-policy comparison on the paper's workloads
bench-policies:
	$(PY) benchmarks/bench_policies.py

## runtime feedback: observed TX + straggler migration under heavy tails
bench-feedback:
	$(PY) benchmarks/bench_runtime_feedback.py

## predictive control plane: makespan re-prediction convergence + the
## speculation-vs-migration arbiter
bench-predictor:
	$(PY) benchmarks/bench_predictor.py

## node-level topology: nodepack-vs-gpu_bestfit fragmentation win,
## contention-aware prediction on strict-GPU c-DG2, and the
## node_level=False bit-identity check against committed baselines
bench-topology:
	$(PY) benchmarks/bench_topology.py

## multi-workflow tenancy: admission-controlled weighted-slowdown win on
## the 3-workflow Summit campaign, the deferral arm, and one-workflow
## campaign bit-identity against committed baselines
bench-admission:
	$(PY) benchmarks/bench_admission.py

## engine scaling: indexed (incremental) vs brute-force-scan dispatch —
## decisions/sec, per-decision pass latency vs node count, and the
## two arms' dispatch-sequence identity (10^4-10^5 tasks, 10^2-10^3
## nodes)
bench-engine-scale:
	$(PY) benchmarks/bench_engine_scale.py

## fault tolerance: priced recovery arbitration beating both pure arms
## on the c-DG2 failure-storm scenario, hazard-aware re-prediction, and
## the FaultOptions-disabled bit-identity check against committed
## baselines
bench-faults:
	$(PY) benchmarks/bench_faults.py

## streaming tenancy: deadline-aware + elastic SLO/P99 win over
## deadline-blind static capacity on the 1-hour diurnal serving
## stream, revocation + lease expiry exercised, and the streaming run
## API's bit-identity to the committed closed-campaign baselines
bench-streaming:
	$(PY) benchmarks/bench_streaming.py

## trace-scale hot loop: epoch-throttled + coalesced + summary arm's
## >= 5x end-to-end arrivals/sec over the unthrottled arm on the
## ~1e5-arrival diurnal stream, throttled-prediction dispatch identity,
## and O(1)-amortized summary metric queries
bench-stream-scale:
	$(PY) benchmarks/bench_stream_scale.py

## scenario matrix: every policy x admission x feedback over the named
## SCENARIOS (service mixes, adversarial compositions, SWF replay) —
## the policy-selection table, adversarial separation, and the scenario
## engine's bit-identity to the committed baseline
bench-scenarios:
	$(PY) benchmarks/bench_scenarios.py

## cProfile any RunConfig scenario: top-20 cumulative hot spots
## (tools/profile_run.py --help for the knobs)
profile:
	$(PY) tools/profile_run.py

## benchmark-regression gate: fresh benchmarks/out/*.json vs the
## committed benchmarks/baseline/*.json (>10% makespan drift or a lost
## headline fails); run after the bench targets
bench-check:
	$(PY) tools/bench_check.py

## README/DESIGN sanity: referenced paths + policy names must exist
docs-check:
	$(PY) tools/docs_check.py

## ruff lint (CI `lint` job; needs ruff installed)
lint:
	ruff check src tools benchmarks

## formatting gate (BLOCKING in CI): the pure-Python checker in
## tools/format_check.py, so it runs in the dev container too (ruff is
## not installable there — the one-time cleanup it enforces landed with
## the topology PR).  `ruff check` above still runs on CI for the
## deeper lint rules.
format-check:
	$(PY) tools/format_check.py

## the paper-reproduction benchmarks (Tables 1-3, Figs. 4-6)
bench-paper:
	$(PY) benchmarks/bench_deepdrivemd.py
	$(PY) benchmarks/bench_cdg.py

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all bench-policies bench-feedback bench-paper docs-check

## tier-1: everything except the slow subprocess multi-device runs
test-fast:
	$(PY) -m pytest -q -m "not slow"

## the full suite, slow distributed subprocess tests included
test-all:
	$(PY) -m pytest -q

## scheduling-policy comparison on the paper's workloads
bench-policies:
	$(PY) benchmarks/bench_policies.py

## runtime feedback: observed TX + straggler migration under heavy tails
bench-feedback:
	$(PY) benchmarks/bench_runtime_feedback.py

## README/DESIGN sanity: referenced paths + policy names must exist
docs-check:
	$(PY) tools/docs_check.py

## the paper-reproduction benchmarks (Tables 1-3, Figs. 4-6)
bench-paper:
	$(PY) benchmarks/bench_deepdrivemd.py
	$(PY) benchmarks/bench_cdg.py

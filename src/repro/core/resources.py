"""Resource pools and the resource-permitted degree of asynchronicity
(DOA_res) from §5.2 of the paper.

The paper's experiments allocate 16 Summit nodes: 706 usable CPU cores and
96 GPUs.  We model an allocation as an aggregate pool of CPU cores and
GPUs/accelerators (with an optional node layout for placement-aware
policies).  Tasks are black boxes with a (cpus, gpus) footprint.

Building blocks (consumed by ``core/sched_engine.py``; see DESIGN.md):

- :class:`Resources` — a partially ordered (cpus, gpus) footprint;
- :class:`NodeSpec` / :class:`PoolSpec` — one homogeneous partition, with
  per-pool ``oversubscribe_cpus`` / ``oversubscribe_gpus`` flags and an
  optional ``only_kinds`` placement constraint;
- :class:`Allocation` — several pools scheduled as one heterogeneous
  resource, plus an optional pairwise ``transfer_cost`` data-movement
  matrix used by the ``locality`` scheduling policy and by straggler
  migration;
- builders: :func:`summit_pool` (the paper's 16-node allocation),
  :func:`hybrid_pool` (GPU + CPU-only partitions), :func:`tpu_pod_pool`.

``DOA_res`` in the paper is computed informally; it reasons with *full task
set* footprints for DeepDriveMD ("each Inference task set requires all
available resources") and with *task-level* footprints for the abstract-DG
workflows (whose full sets exceed the allocation even in sequential mode).
We implement both as explicit strategies and record which one each
benchmark uses:

- ``full_set``: a branch frontier is schedulable iff the *entire* task set
  fits in the pool next to the other chosen sets (reproduces DOA_res = 1
  for DeepDriveMD on the paper's Summit allocation);
- ``minimal``: a branch can make progress iff *one task* of its frontier
  set fits (reproduces DOA_res = 2 for c-DG1/c-DG2).

Both evaluate rank-by-rank: for each DG rank, the largest number of task
sets from *distinct branches* whose footprints co-fit is found; DOA_res is
the maximum over ranks minus 1.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Literal

from .dag import DAG, TaskSet


@dataclasses.dataclass(frozen=True)
class Resources:
    """A (cpus, gpus) footprint; partially ordered."""

    cpus: int = 0
    gpus: int = 0

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.cpus + o.cpus, self.gpus + o.gpus)

    def __sub__(self, o: "Resources") -> "Resources":
        return Resources(self.cpus - o.cpus, self.gpus - o.gpus)

    def fits_in(self, o: "Resources") -> bool:
        return self.cpus <= o.cpus and self.gpus <= o.gpus

    def clamped_to(self, o: "Resources") -> "Resources":
        return Resources(min(self.cpus, o.cpus), min(self.gpus, o.gpus))

    @staticmethod
    def of_task(ts: TaskSet) -> "Resources":
        return Resources(ts.cpus_per_task, ts.gpus_per_task)

    @staticmethod
    def of_full_set(ts: TaskSet) -> "Resources":
        return Resources(ts.full_set_cpus, ts.full_set_gpus)


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One homogeneous compute node."""

    cpus: int
    gpus: int


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """An allocation: ``num_nodes`` x ``node`` minus system reservations."""

    name: str
    num_nodes: int
    node: NodeSpec
    reserved_cpus: int = 0
    #: The paper's task sets oversubscribe CPU cores (96 Inference tasks x 16
    #: cores = 1536 cores on a 706-core allocation while being GPU-bound);
    #: when True, CPU demand beyond the pool queues only on GPUs.
    oversubscribe_cpus: bool = False
    #: GPU sharing (MPS/MIG-style).  The paper's measured c-DG2 run achieves
    #: full TX masking although rank-2 task sets demand 112 GPUs on a 96-GPU
    #: allocation — reproducible only if concurrent GPU tasks may share
    #: devices.  Off by default (strict exclusive GPUs).
    oversubscribe_gpus: bool = False
    #: placement constraint: when set, only task sets whose ``kind`` is in
    #: this tuple may be placed on the pool (e.g. a debug partition that only
    #: accepts ``aggregation`` tasks).  ``None`` accepts everything.
    only_kinds: tuple[str, ...] | None = None

    @property
    def total(self) -> Resources:
        return Resources(
            self.num_nodes * self.node.cpus - self.reserved_cpus,
            self.num_nodes * self.node.gpus,
        )

    def accepts(self, ts: TaskSet) -> bool:
        """Static placement eligibility (ignores current occupancy)."""
        if self.only_kinds is not None and ts.kind not in self.only_kinds:
            return False
        total = self.total
        need_c = 0 if self.oversubscribe_cpus else ts.cpus_per_task
        need_g = 0 if self.oversubscribe_gpus else ts.gpus_per_task
        return need_c <= total.cpus and need_g <= total.gpus


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A heterogeneous allocation: several :class:`PoolSpec` partitions
    scheduled as one resource (e.g. Summit-like GPU nodes next to CPU-only
    nodes).  Placement across pools is decided per task by the scheduling
    policy (see ``sched_engine``).

    ``transfer_cost`` models data movement between pools: entry ``[i][j]``
    is the cost in seconds of moving one task's inputs from pool ``i`` to
    pool ``j``.  The ``locality`` scheduling policy weighs it against
    queue depth when placing tasks, and straggler migration charges it on
    every preemption + requeue (see ``core/estimator.FeedbackOptions``).
    ``None`` means free movement (a uniform fabric)."""

    name: str
    pools: tuple[PoolSpec, ...]
    #: pairwise data-movement cost matrix, seconds, indexed [src][dst];
    #: must be square over ``pools`` with non-negative entries.
    transfer_cost: tuple[tuple[float, ...], ...] | None = None

    def __post_init__(self):
        if not self.pools:
            raise ValueError("Allocation needs at least one pool")
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names in allocation: {names}")
        if self.transfer_cost is not None:
            tc = tuple(tuple(float(c) for c in row)
                       for row in self.transfer_cost)
            if (len(tc) != len(self.pools)
                    or any(len(row) != len(self.pools) for row in tc)):
                raise ValueError(
                    f"transfer_cost must be {len(self.pools)}x"
                    f"{len(self.pools)} to match pools")
            if any(c < 0 for row in tc for c in row):
                raise ValueError("transfer_cost entries must be >= 0")
            object.__setattr__(self, "transfer_cost", tc)

    def transfer(self, src: int, dst: int) -> float:
        """Data-movement cost (s) from pool ``src`` to pool ``dst``."""
        if self.transfer_cost is None or src == dst:
            return 0.0
        return self.transfer_cost[src][dst]

    @property
    def total(self) -> Resources:
        out = Resources()
        for p in self.pools:
            out = out + p.total
        return out

    def pool(self, name: str) -> PoolSpec:
        for p in self.pools:
            if p.name == name:
                return p
        raise KeyError(name)


def as_allocation(pool: "PoolSpec | Allocation") -> Allocation:
    """Normalise the single-pool and multi-pool call conventions."""
    if isinstance(pool, Allocation):
        return pool
    return Allocation(pool.name, (pool,))


def hybrid_pool(gpu_nodes: int = 8, cpu_nodes: int = 8,
                gpu_node: NodeSpec = NodeSpec(cpus=48, gpus=6),
                cpu_node: NodeSpec = NodeSpec(cpus=64, gpus=0),
                name: str = "hybrid",
                transfer_cost: float = 0.0) -> Allocation:
    """A Summit-like heterogeneous allocation: GPU nodes plus CPU-only
    nodes.  GPU-node cores are oversubscribable (the paper's task sets are
    GPU-bound there); the CPU partition is strict, so CPU-only work queues
    honestly when packed around the GPU tasks.  ``transfer_cost`` is the
    symmetric data-movement cost (s) between the two partitions."""
    tc = None
    if transfer_cost:
        tc = ((0.0, float(transfer_cost)), (float(transfer_cost), 0.0))
    return Allocation(name, (
        PoolSpec(f"{name}-gpu", gpu_nodes, gpu_node, oversubscribe_cpus=True),
        PoolSpec(f"{name}-cpu", cpu_nodes, cpu_node),
    ), transfer_cost=tc)


def summit_pool(num_nodes: int = 16, oversubscribe_cpus: bool = True) -> PoolSpec:
    """The paper's allocation: 16 Summit nodes, 706 usable cores, 96 GPUs.

    Summit nodes expose 2x24 cores with 2 reserved per socket -> 44 usable,
    but the paper reports 706 usable cores for 16 nodes (62 reserved).
    """
    reserved = round(62 * num_nodes / 16)
    return PoolSpec("summit", num_nodes, NodeSpec(cpus=48, gpus=6),
                    reserved_cpus=reserved,
                    oversubscribe_cpus=oversubscribe_cpus)


def tpu_pod_pool(num_pods: int = 1, chips_per_pod: int = 256,
                 hosts_per_pod: int = 64) -> PoolSpec:
    """A v5e-pod-like allocation: hosts with 4 chips + a CPU complex each."""
    return PoolSpec(
        f"tpu-v5e-{num_pods}x{chips_per_pod}",
        num_nodes=num_pods * hosts_per_pod,
        node=NodeSpec(cpus=112, gpus=chips_per_pod // hosts_per_pod),
    )


DoaResStrategy = Literal["full_set", "minimal"]


def _branch_sets_by_rank(dag: DAG) -> list[list[tuple[int, str]]]:
    """For each rank, the (branch_id, task_set) pairs present at that rank."""
    branch_of = dag.branch_ids()
    out: list[list[tuple[int, str]]] = []
    for group in dag.rank_groups():
        out.append([(branch_of[n], n) for n in group])
    return out


def doa_res(dag: DAG, pool: PoolSpec,
            strategy: DoaResStrategy = "minimal") -> int:
    """Resource-permitted degree of asynchronicity (paper §5.2).

    For every DG rank, find the largest subset of task sets belonging to
    *distinct* branches whose footprints co-fit in the pool; the maximum
    over ranks, minus one, is DOA_res.  ``strategy`` picks the footprint
    definition (see module docstring).
    """
    total = pool.total
    footprint = (Resources.of_full_set if strategy == "full_set"
                 else Resources.of_task)
    best = 1 if len(dag) else 0
    for rank_sets in _branch_sets_by_rank(dag):
        # distinct branches only
        per_branch: dict[int, list[str]] = {}
        for b, n in rank_sets:
            per_branch.setdefault(b, []).append(n)
        branch_ids = sorted(per_branch)
        for k in range(len(branch_ids), best, -1):
            ok = False
            for combo in itertools.combinations(branch_ids, k):
                choices = [per_branch[b] for b in combo]
                for pick in itertools.product(*choices):
                    req = Resources()
                    for n in pick:
                        req = req + footprint(dag.node(n))
                    cpu_ok = (req.cpus <= total.cpus
                              or (pool.oversubscribe_cpus
                                  and strategy == "minimal"))
                    if cpu_ok and req.gpus <= total.gpus:
                        ok = True
                        break
                if ok:
                    break
            if ok:
                best = max(best, k)
                break
    return max(0, best - 1)


def wla(dag: DAG, pool: PoolSpec,
        strategy: DoaResStrategy = "minimal") -> int:
    """Workload-level asynchronicity, Eqn. 1: min(DOA_dep, DOA_res)."""
    return min(dag.doa_dep(), doa_res(dag, pool, strategy))

"""Resource pools and the resource-permitted degree of asynchronicity
(DOA_res) from §5.2 of the paper.

The paper's experiments allocate 16 Summit nodes: 706 usable CPU cores and
96 GPUs.  We model an allocation as an aggregate pool of CPU cores and
GPUs/accelerators (with an optional node layout for placement-aware
policies).  Tasks are black boxes with a (cpus, gpus) footprint.

Building blocks (consumed by ``core/sched_engine.py``; see DESIGN.md):

- :class:`Resources` — a partially ordered (cpus, gpus) footprint;
- :class:`NodeSpec` / :class:`PoolSpec` — one homogeneous partition, with
  per-pool ``oversubscribe_cpus`` / ``oversubscribe_gpus`` flags and an
  optional ``only_kinds`` placement constraint; ``NodeSpec.nvlink_groups``
  describes the node's NVLink islands (Summit: 2 groups of 3 GPUs);
- :class:`NodeState` / :func:`node_states` — per-node occupancy (free
  cores/GPUs and per-NVLink-group free maps) for ``node_level`` pools:
  placement is then node-granular (a task must fit ONE node — aggregate
  co-fit alone is fragmentation-dishonest) and the engine's aggregate
  counters become a derived view;
- :class:`Allocation` — several pools scheduled as one heterogeneous
  resource, plus an optional pairwise ``transfer_cost`` data-movement
  matrix used by the ``locality`` scheduling policy and by straggler
  migration.  With node-level endpoints, :meth:`Allocation.transfer`
  prices the topology distances same-NVLink-group <= same-node <=
  intra-pool <= cross-pool;
- builders: :func:`summit_pool` (the paper's 16-node allocation),
  :func:`hybrid_pool` (GPU + CPU-only partitions), :func:`tpu_pod_pool`.

``DOA_res`` in the paper is computed informally; it reasons with *full task
set* footprints for DeepDriveMD ("each Inference task set requires all
available resources") and with *task-level* footprints for the abstract-DG
workflows (whose full sets exceed the allocation even in sequential mode).
We implement both as explicit strategies and record which one each
benchmark uses:

- ``full_set``: a branch frontier is schedulable iff the *entire* task set
  fits in the pool next to the other chosen sets (reproduces DOA_res = 1
  for DeepDriveMD on the paper's Summit allocation);
- ``minimal``: a branch can make progress iff *one task* of its frontier
  set fits (reproduces DOA_res = 2 for c-DG1/c-DG2).

Both evaluate rank-by-rank: for each DG rank, the largest number of task
sets from *distinct branches* whose footprints co-fit is found; DOA_res is
the maximum over ranks minus 1.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Literal

from .dag import DAG, TaskSet


@dataclasses.dataclass(frozen=True)
class Resources:
    """A (cpus, gpus) footprint; partially ordered."""

    cpus: int = 0
    gpus: int = 0

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.cpus + o.cpus, self.gpus + o.gpus)

    def __sub__(self, o: "Resources") -> "Resources":
        return Resources(self.cpus - o.cpus, self.gpus - o.gpus)

    def fits_in(self, o: "Resources") -> bool:
        return self.cpus <= o.cpus and self.gpus <= o.gpus

    def clamped_to(self, o: "Resources") -> "Resources":
        return Resources(min(self.cpus, o.cpus), min(self.gpus, o.gpus))

    @staticmethod
    def of_task(ts: TaskSet) -> "Resources":
        return Resources(ts.cpus_per_task, ts.gpus_per_task)

    @staticmethod
    def of_full_set(ts: TaskSet) -> "Resources":
        return Resources(ts.full_set_cpus, ts.full_set_gpus)


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One homogeneous compute node.

    ``nvlink_groups`` is the number of NVLink islands the node's GPUs are
    wired into (Summit: 6 GPUs in 2 groups of 3, one per socket).  GPUs
    inside a group are "contiguous" for placement purposes: a multi-GPU
    task placed within one group communicates over NVLink, while spanning
    groups (or nodes) pays fabric costs — see :meth:`Allocation.transfer`.
    The default of one group keeps pool-aggregate behaviour unchanged.
    """

    cpus: int
    gpus: int
    nvlink_groups: int = 1

    def __post_init__(self):
        if self.nvlink_groups < 1:
            raise ValueError("nvlink_groups must be >= 1")
        if self.gpus and self.gpus % self.nvlink_groups:
            raise ValueError(
                f"gpus ({self.gpus}) must divide evenly into "
                f"nvlink_groups ({self.nvlink_groups})")

    @property
    def gpus_per_group(self) -> int:
        return self.gpus // self.nvlink_groups if self.gpus else 0


@dataclasses.dataclass
class NodeState:
    """Mutable occupancy of one node of a ``node_level`` pool: free CPU
    cores, free GPUs, and the per-NVLink-group free GPU counts.  Owned by
    the scheduling engine; the aggregate pool counters stay a derived
    view of these (see ``core/sched_engine.py``)."""

    spec: NodeSpec
    #: usable cores on this node (capacity minus its share of the pool's
    #: ``reserved_cpus``)
    cpus: int
    free_cpus: int = -1
    free_gpus: int = -1
    #: free GPUs per NVLink group (contiguity domains)
    group_free: list[int] = dataclasses.field(default_factory=list)
    #: node lost to a failure: fits nothing until :meth:`restore`
    down: bool = False
    #: leased node past its expiry: accepts no NEW placements but keeps
    #: running the tasks already on it; retired (-> ``down``) once idle,
    #: so lease expiry can never strand a placed task
    draining: bool = False

    def __post_init__(self):
        if self.free_cpus < 0:
            self.free_cpus = self.cpus
        if self.free_gpus < 0:
            self.free_gpus = self.spec.gpus
        if not self.group_free:
            self.group_free = [self.spec.gpus_per_group
                               for _ in range(self.spec.nvlink_groups)]

    def fits(self, need_cpus: int, need_gpus: int) -> bool:
        return (not self.down and not self.draining
                and need_cpus <= self.free_cpus
                and need_gpus <= self.free_gpus)

    @property
    def idle(self) -> bool:
        """Nothing placed here (free counters at full capacity)."""
        return (self.free_cpus == self.cpus
                and self.free_gpus == self.spec.gpus)

    def fail(self) -> tuple[int, int]:
        """Take the node down; returns the (cpus, gpus) that were still
        free (the engine removes them from the aggregate view).  The
        caller must have released/failed every task placed here first."""
        lost = (self.free_cpus, self.free_gpus)
        self.down = True
        self.draining = False
        self.free_cpus = 0
        self.free_gpus = 0
        self.group_free = [0] * self.spec.nvlink_groups
        return lost

    def restore(self) -> tuple[int, int]:
        """Bring a failed node back, fully idle; returns the (cpus, gpus)
        capacity being re-added to the aggregate view."""
        self.down = False
        self.draining = False
        self.free_cpus = self.cpus
        self.free_gpus = self.spec.gpus
        self.group_free = [self.spec.gpus_per_group
                           for _ in range(self.spec.nvlink_groups)]
        return (self.cpus, self.spec.gpus)

    def best_group(self, need_gpus: int) -> "int | None":
        """Tightest single NVLink group with ``need_gpus`` free, or
        ``None`` when no single group fits (the task must span groups)."""
        best, best_free = None, None
        for gi, free in enumerate(self.group_free):
            if free >= need_gpus and (best_free is None or free < best_free):
                best, best_free = gi, free
        return best

    def largest_block(self) -> int:
        """Largest contiguous free GPU block (within one NVLink group) —
        the fragmentation metric ``nodepack`` scores candidates by.
        A draining node offers no block: its free GPUs exist but accept
        nothing new."""
        if self.draining:
            return 0
        return max(self.group_free, default=0)

    def acquire(self, need_cpus: int,
                need_gpus: int) -> list[tuple[int, int]]:
        """Take resources; returns the per-group GPU takes (group index,
        gpus) so :meth:`release` can return exactly what was taken.
        Prefers a single NVLink group (tightest fit); spans groups —
        fullest first, to keep other groups contiguous — otherwise."""
        if not self.fits(need_cpus, need_gpus):
            raise ValueError("node cannot fit the requested resources")
        self.free_cpus -= need_cpus
        self.free_gpus -= need_gpus
        takes: list[tuple[int, int]] = []
        left = need_gpus
        if left:
            gi = self.best_group(left)
            if gi is not None:
                self.group_free[gi] -= left
                takes.append((gi, left))
                left = 0
            else:
                order = sorted(range(len(self.group_free)),
                               key=lambda g: (self.group_free[g], g))
                for gi in order:
                    take = min(left, self.group_free[gi])
                    if take:
                        self.group_free[gi] -= take
                        takes.append((gi, take))
                        left -= take
                    if not left:
                        break
        return takes

    def release(self, need_cpus: int,
                takes: "list[tuple[int, int]]") -> None:
        self.free_cpus += need_cpus
        for gi, g in takes:
            self.group_free[gi] += g
            self.free_gpus += g


def node_states(pool: "PoolSpec") -> list[NodeState]:
    """Fresh per-node occupancy for a pool, with ``reserved_cpus`` spread
    as evenly as possible (the first ``reserved % num_nodes`` nodes carry
    one extra reserved core)."""
    base, extra = divmod(pool.reserved_cpus, pool.num_nodes)
    return [NodeState(pool.node, pool.node.cpus - base - (1 if i < extra
                                                          else 0))
            for i in range(pool.num_nodes)]


@dataclasses.dataclass(frozen=True)
class ElasticOptions:
    """Elastic capacity: one ``node_level`` pool of the allocation may
    grow and shrink mid-run through whole-node *leases* with expiry, so
    slots follow queue depth (the cloud-bursting half of streaming
    tenancy).

    The engine's periodic elastic pass (driven by both substrates every
    ``check_interval`` modelled seconds) grants at most one lease node
    per pass while the ready queue's strict resource demand exceeds
    ``grow_threshold`` x the pool's usable free capacity, up to
    ``max_lease_nodes`` concurrently-leased nodes.  A lease lasts
    ``lease_term`` seconds; at expiry an idle node retires immediately,
    a busy one *drains* (no new placements, running tasks finish) and
    retires on its last release — lease expiry never strands a placed
    task.  Retired nodes are recycled by later grants, so the node table
    stays bounded on unbounded streams.

    ``max_lease_nodes = 0`` disables elasticity entirely (normalized to
    ``None`` by the engine — no elastic code path runs)."""

    #: name of the pool to elasticize (None = the allocation's first
    #: ``node_level`` pool); must be a node-level pool
    pool: "str | None" = None
    #: burst budget: concurrently-leased whole nodes (0 disables)
    max_lease_nodes: int = 4
    #: modelled seconds a granted node stays before expiry
    lease_term: float = 600.0
    #: grow when queued strict demand > threshold x usable free capacity
    grow_threshold: float = 2.0
    #: cadence (modelled s) of the substrates' elastic pass (grants and
    #: expiries are both evaluated at this granularity)
    check_interval: float = 60.0
    #: don't grow for a nearly-empty queue, whatever the ratio says
    min_queue_tasks: int = 1

    @property
    def enabled(self) -> bool:
        return self.max_lease_nodes > 0


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """An allocation: ``num_nodes`` x ``node`` minus system reservations."""

    name: str
    num_nodes: int
    node: NodeSpec
    reserved_cpus: int = 0
    #: The paper's task sets oversubscribe CPU cores (96 Inference tasks x 16
    #: cores = 1536 cores on a 706-core allocation while being GPU-bound);
    #: when True, CPU demand beyond the pool queues only on GPUs.
    oversubscribe_cpus: bool = False
    #: GPU sharing (MPS/MIG-style).  The paper's measured c-DG2 run achieves
    #: full TX masking although rank-2 task sets demand 112 GPUs on a 96-GPU
    #: allocation — reproducible only if concurrent GPU tasks may share
    #: devices.  Off by default (strict exclusive GPUs).
    oversubscribe_gpus: bool = False
    #: placement constraint: when set, only task sets whose ``kind`` is in
    #: this tuple may be placed on the pool (e.g. a debug partition that only
    #: accepts ``aggregation`` tasks).  ``None`` accepts everything.
    only_kinds: tuple[str, ...] | None = None
    #: node-granular placement: when True the engine accounts resources
    #: per node (see :class:`NodeState`) — a task must fit on ONE node, so
    #: a mix that only fits in aggregate is honestly rejected
    #: (fragmentation), and placements carry concrete node ids.  False
    #: (default) keeps the pool-aggregate accounting bit-identical.
    node_level: bool = False

    @property
    def total(self) -> Resources:
        return Resources(
            self.num_nodes * self.node.cpus - self.reserved_cpus,
            self.num_nodes * self.node.gpus,
        )

    @property
    def node_cpu_capacity(self) -> int:
        """Usable cores of the best node once ``reserved_cpus`` is spread
        evenly (the honest per-node CPU bound for node-level placement)."""
        return self.node.cpus - self.reserved_cpus // self.num_nodes

    def accepts(self, ts: TaskSet) -> bool:
        """Static placement eligibility (ignores current occupancy).  A
        ``node_level`` pool bounds the footprint per NODE — a task wider
        than one node can never be placed, even if the pool's aggregate
        would fit it."""
        if self.only_kinds is not None and ts.kind not in self.only_kinds:
            return False
        total = self.total
        need_c = 0 if self.oversubscribe_cpus else ts.cpus_per_task
        need_g = 0 if self.oversubscribe_gpus else ts.gpus_per_task
        if self.node_level:
            return (need_c <= self.node_cpu_capacity
                    and need_g <= self.node.gpus)
        return need_c <= total.cpus and need_g <= total.gpus


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A heterogeneous allocation: several :class:`PoolSpec` partitions
    scheduled as one resource (e.g. Summit-like GPU nodes next to CPU-only
    nodes).  Placement across pools is decided per task by the scheduling
    policy (see ``sched_engine``).

    ``transfer_cost`` models data movement between pools: entry ``[i][j]``
    is the cost in seconds of moving one task's inputs from pool ``i`` to
    pool ``j``.  The ``locality`` scheduling policy weighs it against
    queue depth when placing tasks, and straggler migration charges it on
    every preemption + requeue (see ``core/estimator.FeedbackOptions``).
    ``None`` means free movement (a uniform fabric).

    With node-level placement (``PoolSpec.node_level``) the distances
    become topology-derived — :meth:`transfer` accepts node/NVLink-group
    endpoints and prices the four hop classes

        same NVLink group  <=  same node  <=  intra-pool  <  cross-pool

    via ``same_group_cost`` / ``same_node_cost`` / ``intra_pool_cost``
    (all default 0, keeping aggregate behaviour bit-identical) and the
    ``transfer_cost`` matrix for the cross-pool hop."""

    name: str
    pools: tuple[PoolSpec, ...]
    #: pairwise data-movement cost matrix, seconds, indexed [src][dst];
    #: must be square over ``pools`` with non-negative entries.
    transfer_cost: tuple[tuple[float, ...], ...] | None = None
    #: data movement within one NVLink group (NVLink hop; effectively 0)
    same_group_cost: float = 0.0
    #: between NVLink groups of one node (PCIe/X-bus hop)
    same_node_cost: float = 0.0
    #: between nodes of one pool (fabric hop); cross-pool movement reads
    #: the ``transfer_cost`` matrix as before
    intra_pool_cost: float = 0.0

    def __post_init__(self):
        if not self.pools:
            raise ValueError("Allocation needs at least one pool")
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names in allocation: {names}")
        if not (0.0 <= self.same_group_cost <= self.same_node_cost
                <= self.intra_pool_cost):
            raise ValueError(
                "topology costs must satisfy 0 <= same_group_cost <= "
                "same_node_cost <= intra_pool_cost")
        if self.transfer_cost is not None:
            tc = tuple(tuple(float(c) for c in row)
                       for row in self.transfer_cost)
            if (len(tc) != len(self.pools)
                    or any(len(row) != len(self.pools) for row in tc)):
                raise ValueError(
                    f"transfer_cost must be {len(self.pools)}x"
                    f"{len(self.pools)} to match pools")
            if any(c < 0 for row in tc for c in row):
                raise ValueError("transfer_cost entries must be >= 0")
            # the documented distance ordering ends with the cross-pool
            # hop: off-diagonal entries may not undercut the intra-pool
            # hop, or the arbiter would "save" by moving data further
            if any(tc[i][j] < self.intra_pool_cost
                   for i in range(len(tc)) for j in range(len(tc))
                   if i != j):
                raise ValueError(
                    "cross-pool transfer_cost entries must be >= "
                    "intra_pool_cost (the topology distance ordering)")
            object.__setattr__(self, "transfer_cost", tc)

    def transfer(self, src: int, dst: int, src_node: int = -1,
                 dst_node: int = -1, src_group: int = -1,
                 dst_group: int = -1) -> float:
        """Data-movement cost (s) between two placements.

        Pool-granular calls (node args omitted) behave exactly as before:
        free within a pool, ``transfer_cost[src][dst]`` across pools.
        With node endpoints given (node-level pools) the same-pool case
        resolves to the topology distance: same NVLink group <= same node
        <= intra-pool fabric."""
        if src != dst:
            if self.transfer_cost is None:
                # a uniform (legacy-free) fabric still cannot beat the
                # intra-pool hop: a cross-pool move traverses it too
                return self.intra_pool_cost
            return self.transfer_cost[src][dst]
        if src_node < 0 or dst_node < 0:
            return 0.0  # aggregate view: legacy same-pool movement is free
        if src_node != dst_node:
            return self.intra_pool_cost
        if src_group < 0 or dst_group < 0 or src_group != dst_group:
            return self.same_node_cost
        return self.same_group_cost

    @property
    def total(self) -> Resources:
        out = Resources()
        for p in self.pools:
            out = out + p.total
        return out

    def pool(self, name: str) -> PoolSpec:
        for p in self.pools:
            if p.name == name:
                return p
        raise KeyError(name)


def as_allocation(pool: "PoolSpec | Allocation") -> Allocation:
    """Normalise the single-pool and multi-pool call conventions."""
    if isinstance(pool, Allocation):
        return pool
    return Allocation(pool.name, (pool,))


def hybrid_pool(gpu_nodes: int = 8, cpu_nodes: int = 8,
                gpu_node: NodeSpec = NodeSpec(cpus=48, gpus=6),
                cpu_node: NodeSpec = NodeSpec(cpus=64, gpus=0),
                name: str = "hybrid",
                transfer_cost: float = 0.0,
                node_level: bool = False) -> Allocation:
    """A Summit-like heterogeneous allocation: GPU nodes plus CPU-only
    nodes.  GPU-node cores are oversubscribable (the paper's task sets are
    GPU-bound there); the CPU partition is strict, so CPU-only work queues
    honestly when packed around the GPU tasks.  ``transfer_cost`` is the
    symmetric data-movement cost (s) between the two partitions;
    ``node_level`` turns on node-granular placement for both."""
    tc = None
    if transfer_cost:
        tc = ((0.0, float(transfer_cost)), (float(transfer_cost), 0.0))
    return Allocation(name, (
        PoolSpec(f"{name}-gpu", gpu_nodes, gpu_node, oversubscribe_cpus=True,
                 node_level=node_level),
        PoolSpec(f"{name}-cpu", cpu_nodes, cpu_node, node_level=node_level),
    ), transfer_cost=tc)


def summit_pool(num_nodes: int = 16, oversubscribe_cpus: bool = True,
                node_level: bool = False) -> PoolSpec:
    """The paper's allocation: 16 Summit nodes, 706 usable cores, 96 GPUs.

    Summit nodes expose 2x24 cores with 2 reserved per socket -> 44 usable,
    but the paper reports 706 usable cores for 16 nodes (62 reserved).

    ``node_level=True`` switches to node-granular accounting with the real
    Summit GPU wiring — 6 GPUs in 2 NVLink groups of 3, one per socket —
    so placement is fragmentation-honest and NVLink-locality-aware.
    """
    reserved = round(62 * num_nodes / 16)
    node = (NodeSpec(cpus=48, gpus=6, nvlink_groups=2) if node_level
            else NodeSpec(cpus=48, gpus=6))
    return PoolSpec("summit", num_nodes, node,
                    reserved_cpus=reserved,
                    oversubscribe_cpus=oversubscribe_cpus,
                    node_level=node_level)


def tpu_pod_pool(num_pods: int = 1, chips_per_pod: int = 256,
                 hosts_per_pod: int = 64) -> PoolSpec:
    """A v5e-pod-like allocation: hosts with 4 chips + a CPU complex each."""
    return PoolSpec(
        f"tpu-v5e-{num_pods}x{chips_per_pod}",
        num_nodes=num_pods * hosts_per_pod,
        node=NodeSpec(cpus=112, gpus=chips_per_pod // hosts_per_pod),
    )


DoaResStrategy = Literal["full_set", "minimal"]


def _branch_sets_by_rank(dag: DAG) -> list[list[tuple[int, str]]]:
    """For each rank, the (branch_id, task_set) pairs present at that rank."""
    branch_of = dag.branch_ids()
    out: list[list[tuple[int, str]]] = []
    for group in dag.rank_groups():
        out.append([(branch_of[n], n) for n in group])
    return out


def doa_res(dag: DAG, pool: "PoolSpec | Allocation",
            strategy: DoaResStrategy = "minimal") -> int:
    """Resource-permitted degree of asynchronicity (paper §5.2).

    For every DG rank, find the largest subset of task sets belonging to
    *distinct* branches whose footprints co-fit in the pool; the maximum
    over ranks, minus one, is DOA_res.  ``strategy`` picks the footprint
    definition (see module docstring).

    Accepts a single :class:`PoolSpec` or a heterogeneous
    :class:`Allocation` (e.g. :func:`hybrid_pool`): a multi-pool
    allocation is evaluated against its *aggregate* footprint — DOA_res
    is the paper's coarse co-fit metric, so the CPU check is waived when
    any pool oversubscribes cores (minimal strategy), matching the
    single-pool semantics.
    """
    alloc = as_allocation(pool)
    total = alloc.total
    oversub_cpus = any(p.oversubscribe_cpus for p in alloc.pools)
    footprint = (Resources.of_full_set if strategy == "full_set"
                 else Resources.of_task)
    best = 1 if len(dag) else 0
    for rank_sets in _branch_sets_by_rank(dag):
        # distinct branches only
        per_branch: dict[int, list[str]] = {}
        for b, n in rank_sets:
            per_branch.setdefault(b, []).append(n)
        branch_ids = sorted(per_branch)
        for k in range(len(branch_ids), best, -1):
            ok = False
            for combo in itertools.combinations(branch_ids, k):
                choices = [per_branch[b] for b in combo]
                for pick in itertools.product(*choices):
                    req = Resources()
                    for n in pick:
                        req = req + footprint(dag.node(n))
                    cpu_ok = (req.cpus <= total.cpus
                              or (oversub_cpus and strategy == "minimal"))
                    if cpu_ok and req.gpus <= total.gpus:
                        ok = True
                        break
                if ok:
                    break
            if ok:
                best = max(best, k)
                break
    return max(0, best - 1)


def wla(dag: DAG, pool: "PoolSpec | Allocation",
        strategy: DoaResStrategy = "minimal") -> int:
    """Workload-level asynchronicity, Eqn. 1: min(DOA_dep, DOA_res)."""
    return min(dag.doa_dep(), doa_res(dag, pool, strategy))

"""A real asynchronous executor for heterogeneous tasks.

This is the in-process analogue of the paper's EnTK + RADICAL-Pilot stack:
a *pilot* holds the allocation (CPU cores + accelerators), worker threads
execute black-box task payloads, and a dispatcher starts every
dependency-resolved task that fits in the free resources (backfilling).

Task payloads are arbitrary callables — in this framework they are
typically jitted JAX computations (training / inference steps), which
release the GIL while XLA executes, so heterogeneous tasks genuinely
overlap.  Synthetic tasks (``payload=None``) sleep for their sampled TX —
the `stress` analogue used by the paper's experiments.

The executor enforces the same semantics as the discrete-event simulator
(`repro.core.simulator`): set-level barriers by default, task-level
asynchronicity with ``task_level=True``, and PST stage barriers in
sequential mode.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from .dag import DAG
from .resources import PoolSpec
from .simulator import Mode, TaskRecord


@dataclasses.dataclass
class ExecResult:
    makespan: float
    records: list[TaskRecord]
    mode: str
    tasks_total: int

    def throughput(self) -> float:
        return self.tasks_total / self.makespan if self.makespan else 0.0


class RealExecutor:
    """Executes a task-set DG with real concurrency on the local host."""

    def __init__(self, pool: PoolSpec, max_workers: int = 64,
                 tx_scale: float = 1.0, seed: int = 0,
                 launch_latency: float = 0.0):
        self.pool = pool
        self.max_workers = max_workers
        #: wall-seconds per modelled TX second for synthetic payloads
        #: (lets laptop-scale runs validate Summit-scale workflows).
        self.tx_scale = tx_scale
        self.seed = seed
        self.launch_latency = launch_latency

    def run(self, dag: DAG, mode: Mode = "async", *, task_level: bool = False,
            sequential_stage_groups: Sequence[Sequence[str]] | None = None,
            ) -> ExecResult:
        g = dag if mode == "async" else dag.with_sequential_barriers(
            sequential_stage_groups)
        rng = random.Random(self.seed)
        total = self.pool.total
        order = g.topological_order()
        ranks = g.ranks()
        topo_pos = {n: k for k, n in enumerate(order)}

        durations: dict[tuple[str, int], float] = {}
        for name in order:
            ts = g.node(name)
            for i in range(ts.num_tasks):
                mu = ts.tx_mean
                d = max(0.0, rng.gauss(mu, ts.tx_sigma)) if mu else 0.0
                durations[(name, i)] = d

        lock = threading.Lock()
        cv = threading.Condition(lock)
        cpus_free = [total.cpus]
        gpus_free = [total.gpus]
        remaining: dict[tuple[str, int], int] = {}
        set_remaining = {n: g.node(n).num_tasks for n in order}
        child_waiters: dict[tuple[str, int], list[tuple[str, int]]] = {}
        if task_level:
            for name in order:
                nc = g.node(name).num_tasks
                for i in range(nc):
                    cnt = 0
                    for p in g.parents(name):
                        np_ = g.node(p).num_tasks
                        child_waiters.setdefault((p, i * np_ // nc), []).append(
                            (name, i))
                        cnt += 1
                    remaining[(name, i)] = cnt
        else:
            for name in order:
                cnt = sum(g.node(p).num_tasks for p in g.parents(name))
                for i in range(g.node(name).num_tasks):
                    remaining[(name, i)] = cnt

        ready: list[tuple[int, int, int, str, int]] = []
        for name in order:
            if not g.parents(name):
                for i in range(g.node(name).num_tasks):
                    heapq.heappush(ready, (ranks[name], topo_pos[name], i,
                                           name, i))
        n_total = sum(g.node(n).num_tasks for n in order)
        done_count = [0]
        records: list[TaskRecord] = []
        t0 = time.perf_counter()

        def body(name: str, i: int) -> None:
            ts = g.node(name)
            start = time.perf_counter() - t0
            if self.launch_latency:
                time.sleep(self.launch_latency)
            if ts.payload is not None:
                ts.payload(i)
            else:
                time.sleep(durations[(name, i)] * self.tx_scale)
            end = time.perf_counter() - t0
            with cv:
                cpus_free[0] = min(total.cpus,
                                   cpus_free[0] + ts.cpus_per_task)
                gpus_free[0] += ts.gpus_per_task
                records.append(TaskRecord(name, i, start, end,
                                          ts.cpus_per_task, ts.gpus_per_task))
                done_count[0] += 1
                set_remaining[name] -= 1
                if task_level:
                    for cn, ci in child_waiters.get((name, i), ()):
                        remaining[(cn, ci)] -= 1
                        if remaining[(cn, ci)] == 0:
                            heapq.heappush(ready, (ranks[cn], topo_pos[cn],
                                                   ci, cn, ci))
                elif set_remaining[name] == 0:
                    nt = ts.num_tasks
                    for c in g.children(name):
                        for j in range(g.node(c).num_tasks):
                            remaining[(c, j)] -= nt
                            if remaining[(c, j)] == 0:
                                heapq.heappush(ready, (ranks[c], topo_pos[c],
                                                       j, c, j))
                cv.notify_all()

        with ThreadPoolExecutor(max_workers=self.max_workers) as ex:
            with cv:
                while done_count[0] < n_total:
                    # backfill: start everything ready that fits
                    skipped: list[tuple[int, int, int, str, int]] = []
                    started = False
                    while ready:
                        item = heapq.heappop(ready)
                        _, _, _, name, i = item
                        ts = g.node(name)
                        need_c = (0 if self.pool.oversubscribe_cpus
                                  else ts.cpus_per_task)
                        if need_c <= cpus_free[0] and \
                                ts.gpus_per_task <= gpus_free[0]:
                            if not self.pool.oversubscribe_cpus:
                                cpus_free[0] -= ts.cpus_per_task
                            gpus_free[0] -= ts.gpus_per_task
                            ex.submit(body, name, i)
                            started = True
                        else:
                            skipped.append(item)
                    for it in skipped:
                        heapq.heappush(ready, it)
                    if done_count[0] < n_total and not (started and ready):
                        cv.wait(timeout=5.0)

        makespan = max((r.end for r in records), default=0.0)
        return ExecResult(makespan=makespan, records=records,
                          mode=mode if not task_level else f"{mode}+task_level",
                          tasks_total=len(records))

"""A real asynchronous executor for heterogeneous tasks.

This is the in-process analogue of the paper's EnTK + RADICAL-Pilot stack:
a *pilot* holds the allocation (CPU cores + accelerators), worker threads
execute black-box task payloads, and a dispatcher starts every
dependency-resolved task that fits in the free resources (backfilling).

Task payloads are arbitrary callables — in this framework they are
typically jitted JAX computations (training / inference steps), which
release the GIL while XLA executes, so heterogeneous tasks genuinely
overlap.  Synthetic tasks (``payload=None``) sleep for their sampled TX —
the `stress` analogue used by the paper's experiments.

All scheduling decisions — ready-queue order, dependency bookkeeping
(set-level by default, task-level with ``task_level=True``), per-pool
resource accounting and placement — are delegated to the SAME
:class:`~repro.core.sched_engine.SchedEngine` the discrete-event simulator
uses, so the two substrates enforce identical semantics by construction.
Heterogeneous multi-pool :class:`~repro.core.resources.Allocation`s and
the ``fifo`` / ``lpt`` / ``gpu_bestfit`` / ``locality`` / ``nodepack``
policies work unchanged here — node-level pools
(``PoolSpec.node_level``) stamp the concrete node of every winning
attempt onto its ``TaskRecord`` exactly as the simulator does — as does
runtime feedback (``feedback=FeedbackOptions()``):
completions feed the shared engine's online TX estimator (pool-tagged,
so per-pool splits work), a watchdog in the dispatcher mitigates
stragglers through the engine's arbiter — preempt + resubmit on another
pool (the abandoned attempt is invalidated by generation, exactly like
the simulator's migration events) or race a speculative duplicate
(first finisher wins; the loser is cancelled via the engine's finished
set) — and every scheduling pass re-predicts the makespan
(``ExecResult.predictions``, see ``core/predictor.py``).

Multi-workflow tenancy works here too: ``run()`` accepts a
:class:`~repro.core.workflow.Campaign` (arrivals gate dispatch on the
MODELLED clock — wall / ``tx_scale`` — so campaigns behave identically
to the simulator's), reports per-workflow metrics in
``ExecResult.workflows``, and honours ``admission=AdmissionOptions(...)``
through the shared engine.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from .dag import DAG
from .estimator import FeedbackOptions  # noqa: F401 (re-export surface)
from .resources import Allocation, PoolSpec
from .results import RunResult, TaskRecord
from .runconfig import _LEGACY, RunConfig, resolve_run_config
from .sched_engine import AdmissionOptions, SchedEngine, SchedulingPolicy
from .simulator import Mode
from .stream import WorkflowStream, prefix_view
from .workflow import Campaign, CampaignView, campaign_stats
from ..runtime.fault import FailureSchedule, FaultOptions


@dataclasses.dataclass
class ExecResult(RunResult):
    """A real-executor run's result: exactly the shared
    :class:`~repro.core.results.RunResult` protocol.  ``records`` are in
    WALL seconds; ``workflows`` (and everything derived from it — SLO
    attainment, slowdown percentiles, window stats) is on the MODELLED
    clock (wall / ``tx_scale``), commensurate with the simulator's."""


class RealExecutor:
    """Executes a task-set DG with real concurrency on the local host."""

    def __init__(self, pool: "PoolSpec | Allocation", max_workers: int = 64,
                 tx_scale: float = 1.0, seed: int = 0,
                 launch_latency: float = 0.0,
                 straggler_prob: float = 0.0,
                 straggler_factor: float = 4.0):
        self.pool = pool
        self.max_workers = max_workers
        #: wall-seconds per modelled TX second for synthetic payloads
        #: (lets laptop-scale runs validate Summit-scale workflows).
        self.tx_scale = tx_scale
        self.seed = seed
        self.launch_latency = launch_latency
        #: straggler injection for synthetic payloads (mirrors SimOptions):
        #: with probability p a task's sampled TX is stretched xfactor.
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor

    def run(self, dag: "DAG | Campaign | WorkflowStream",
            mode: Mode = "async", *,
            config: "RunConfig | None" = None,
            task_level=_LEGACY,
            sequential_stage_groups=_LEGACY,
            scheduling=_LEGACY,
            feedback=_LEGACY,
            admission=_LEGACY,
            faults=_LEGACY,
            ) -> ExecResult:
        """Execute ``dag`` (a DAG, a closed Campaign, or an open
        :class:`~repro.core.stream.WorkflowStream` consumed incrementally
        on the modelled clock).  Scheduling-semantics knobs arrive in
        ``config=RunConfig(...)``; the individual keyword arguments are
        the deprecated legacy form (bit-identical, not mixable with
        ``config=`` — see ``core/runconfig.py``)."""
        cfg = resolve_run_config(config, dict(
            task_level=task_level,
            sequential_stage_groups=sequential_stage_groups,
            scheduling=scheduling, feedback=feedback,
            admission=admission, faults=faults), "RealExecutor.run()")
        task_level = cfg.task_level
        sequential_stage_groups = cfg.sequential_stage_groups
        scheduling = cfg.scheduling
        feedback = cfg.feedback
        admission = cfg.admission
        faults = cfg.faults
        if cfg.record_policy != "full":
            # the executor's records ARE its measurement (wall-clock
            # spans); only the simulator can trade them for sketches
            raise ValueError(
                f"record_policy={cfg.record_policy!r} is simulator-only "
                f"(RealExecutor always keeps the full trace)")

        stream: "WorkflowStream | None" = None
        if isinstance(dag, WorkflowStream):
            closed = dag.closed_campaign
            if closed is not None:
                dag = closed  # a closed stream IS its campaign
            else:
                stream = dag
                stream.reset()
        view: "CampaignView | None" = None
        arrived_entries: "list" = []
        if stream is not None:
            if mode != "async":
                raise ValueError("streams execute asynchronously "
                                 "(mode='async')")
            arrived_entries = list(stream.take_until(0.0))
            view = prefix_view(arrived_entries, stream.name)
            g = view.dag
        elif isinstance(dag, Campaign):
            if mode != "async":
                raise ValueError("campaigns execute asynchronously "
                                 "(mode='async')")
            view = dag.view()
            g = view.dag
        else:
            g = dag if mode == "async" else dag.with_sequential_barriers(
                sequential_stage_groups)
        rng = random.Random(self.seed)
        engine = SchedEngine(g, self.pool, policy=scheduling,
                             task_level=task_level, feedback=feedback,
                             campaign=view, admission=admission,
                             faults=faults, elastic=cfg.elastic,
                             predict=cfg.predict,
                             incremental=cfg.incremental)
        # live for streams (add_workflow extends it); a superset-correct
        # copy of view.workflow_of for closed campaigns
        wf_of = engine.workflow_of if view is not None else {}
        #: distinct workflow arrivals (modelled s), for dispatcher wakeups
        arrivals = (sorted({w.arrival for w in view.entries})
                    if view is not None else [])
        faults = engine.faults  # disabled options normalized to None
        schedule = (FailureSchedule(faults,
                                    [(k, p.num_nodes)
                                     for k, p in enumerate(engine.pools)],
                                    [p.name for p in engine.pools])
                    if faults is not None else None)

        durations: dict[tuple[str, int], float] = {}

        def sample_durations(names: "Sequence[str]") -> None:
            """Pre-sample every task of ``names`` in set order (the RNG
            draw order is part of the trace contract)."""
            for name in names:
                ts = g.node(name)
                for i in range(ts.num_tasks):
                    mu = ts.tx_mean
                    d = max(0.0, rng.gauss(mu, ts.tx_sigma)) if mu else 0.0
                    if (self.straggler_prob
                            and rng.random() < self.straggler_prob):
                        d *= self.straggler_factor
                    durations[(name, i)] = d

        sample_durations(engine.order)

        lock = threading.Lock()
        cv = threading.Condition(lock)
        records: list[TaskRecord] = []
        #: wall start of the task's CURRENT attempt, stamped when a worker
        #: actually begins it (NOT at submit — tasks queued behind
        #: max_workers must not accrue phantom straggler runtime) and
        #: absent between a preemption and its re-run's first breath
        started: dict[tuple[str, int], float] = {}
        #: wall start of the FIRST attempt (task records span the task)
        first_start: dict[tuple[str, int], float] = {}
        #: attempt generation; a migration bumps it, invalidating the
        #: preempted attempt's completion (same scheme as the simulator).
        #: Under faults a failure of the primary attempt bumps it too.
        gen: dict[tuple[str, int], int] = {}
        #: speculative-attempt generation: bumped to invalidate a racing
        #: duplicate whose node died (``FailureEvent.cancelled``) without
        #: touching the primary's ``gen``
        spec_gen: dict[tuple[str, int], int] = {}
        #: duplicates promoted to primary (their primary's node died):
        #: the spec worker completes the task as the primary instead
        promoted_keys: set[tuple[str, int]] = set()
        t0 = time.perf_counter()

        def preemptible_sleep(name: str, i: int, my_gen: int,
                              seconds: float, spec: bool = False) -> bool:
            """Sleep that wakes early when the attempt is preempted (gen
            bumped) or another attempt already finished the task, so an
            abandoned synthetic attempt does not hold its worker slot for
            the full straggler duration.  True = slept to completion,
            False = superseded.  (Real payloads cannot be interrupted this
            way — they run to completion and their stale result is
            discarded at the completion check.)  Speculative attempts
            check their own generation (``spec_gen``): a primary-side
            failure must not abort the replica racing to replace it."""
            deadline = time.perf_counter() + seconds
            g_of = spec_gen if spec else gen
            with cv:
                while True:
                    if (my_gen != g_of.get((name, i), 0)
                            or (name, i) in engine.finished):
                        return False
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return True
                    cv.wait(timeout=remaining)

        def apply_failure_event(ev) -> None:
            """Invalidate the worker attempts a FailureEvent superseded
            (caller holds ``cv``).  Failed primaries bump ``gen`` (their
            synthetic sleeps wake and abort; the engine already re-enqueued
            the task); a promoted replica's primary dies the same way but
            the replica keeps racing and will complete as the primary; a
            cancelled replica bumps ``spec_gen`` only."""
            for key in ev.failed:
                gen[key] = gen.get(key, 0) + 1
                spec_gen[key] = spec_gen.get(key, 0) + 1
                promoted_keys.discard(key)
                started.pop(key, None)
            for key in ev.promoted:
                gen[key] = gen.get(key, 0) + 1
                promoted_keys.add(key)
                started.pop(key, None)
            for key in ev.cancelled:
                spec_gen[key] = spec_gen.get(key, 0) + 1
            cv.notify_all()

        #: tasks that were straggler-migrated (the record flag; under
        #: faults ``gen`` is also bumped by failures)
        mig_tasks: set[tuple[str, int]] = set()

        def valid(name: str, i: int, my_gen: int, spec: bool) -> bool:
            """Is this attempt still the live one? (caller holds ``cv``)"""
            if (name, i) in engine.finished:
                return False
            g_of = spec_gen if spec else gen
            return my_gen == g_of.get((name, i), 0)

        def body(name: str, i: int, pool_idx: int, my_gen: int,
                 migration_cost: float = 0.0,
                 rerun_tx: float = 0.0,
                 spec: bool = False,
                 fail_frac: "float | None" = None) -> None:
            ts = g.node(name)
            with cv:
                if not valid(name, i, my_gen, spec):
                    return  # superseded while still queued
                first_start.setdefault((name, i),
                                       time.perf_counter() - t0)
            if self.launch_latency:
                time.sleep(self.launch_latency)
            if migration_cost:
                # data movement for a migrated or speculative re-run
                time.sleep(migration_cost * self.tx_scale)
            with cv:
                if not valid(name, i, my_gen, spec):
                    return
                # straggler/estimator clock starts when the WORK starts:
                # raw launch latency and migration/data cost must not read
                # as (tx_scale-modelled) task duration.  A speculative
                # duplicate keeps its own clock — the original's straggler
                # clock must keep running while they race.
                work_start = time.perf_counter() - t0
                if not spec:
                    started[(name, i)] = work_start
            if ts.payload is not None:
                ts.payload(i)
            elif not spec and fail_frac is not None:
                # seeded software failure: the attempt dies at fail_frac
                # of its run and the engine re-enqueues (or promotes)
                if not preemptible_sleep(name, i, my_gen,
                                         fail_frac * rerun_tx
                                         * self.tx_scale):
                    return
                with cv:
                    if not valid(name, i, my_gen, spec=False):
                        return
                    nowm = (time.perf_counter() - t0) / self.tx_scale
                    ev = engine.fail_task(name, i, now=nowm,
                                          elapsed=fail_frac * rerun_tx)
                    if ev is not None:
                        apply_failure_event(ev)
                return
            elif spec or my_gen or faults is not None:
                # migrated or speculative re-run (regardless of the
                # fabric's cost): a fresh attempt at the TX estimate read
                # at mitigation time.  Under faults every dispatch passes
                # its recovery/checkpoint-adjusted duration this way.
                if not preemptible_sleep(name, i, my_gen,
                                         rerun_tx * self.tx_scale, spec):
                    return
            else:
                if not preemptible_sleep(name, i, my_gen,
                                         durations[(name, i)]
                                         * self.tx_scale):
                    return
            end = time.perf_counter() - t0
            with cv:
                if not valid(name, i, my_gen, spec):
                    return  # lost the race / preempted; not ours anymore
                won_promoted = spec and (name, i) in promoted_keys
                attempt_start = (work_start if spec
                                 else started.pop((name, i), end))
                if spec:
                    started.pop((name, i), None)
                start = first_start.pop((name, i), attempt_start)
                # node id must be read before complete() frees the slot
                if won_promoted:
                    # the replica became the primary when the original's
                    # node died: finish the task as the primary attempt
                    promoted_keys.discard((name, i))
                    node = engine.node_placement(name, i)
                    engine.complete(name, i)
                else:
                    node = (engine.spec_node(name, i) if spec
                            else engine.node_placement(name, i))
                    # a winning duplicate's placement becomes the task's
                    # final one (children's data costs price the actual
                    # output node)
                    engine.complete(name, i, spec_won=spec)
                # observe in MODELLED seconds (wall / tx_scale) so the
                # estimates stay commensurate with the tx_mean priors and
                # the allocation's transfer costs
                engine.observe(name, (end - attempt_start) / self.tx_scale,
                               pool=pool_idx)
                records.append(TaskRecord(name, i, start, end,
                                          ts.cpus_per_task, ts.gpus_per_task,
                                          duplicate=spec and not won_promoted,
                                          pool=engine.pool_name(pool_idx),
                                          migrated=(name, i) in mig_tasks,
                                          node=node,
                                          workflow=wf_of.get(name, "")))
                cv.notify_all()

        # the watchdog needs a mitigation that can actually fire: migration
        # needs a second pool; speculation only needs a free slot, so it
        # keeps the watchdog alive even on single-pool allocations.
        # Proactive replication rides the same cadence.
        watchdog = (feedback is not None
                    and (feedback.speculate
                         or (feedback.migrate and len(engine.pools) > 1)))
        replicating = faults is not None and faults.replicate
        #: next node-failure event from the shared schedule (modelled s)
        next_fail = (schedule.next_node_failure()
                     if schedule is not None else None)
        #: pending node recoveries: (modelled time, pool, node) heap
        recoveries: list[tuple[float, int, int]] = []
        #: next elastic control step (modelled s)
        next_elastic = (engine.elastic.check_interval
                        if engine.elastic is not None else math.inf)

        def stream_pending() -> bool:
            return stream is not None and stream.next_arrival() is not None

        #: engine snapshot behind the newest prediction (idle-wakeup guard)
        last_stamp = None
        with ThreadPoolExecutor(max_workers=self.max_workers) as ex:
            with cv:
                while not engine.done() or stream_pending():
                    # backfill: start everything ready that fits.  The
                    # pass runs on the modelled clock (see observe) so
                    # campaign arrivals gate on the same time base as the
                    # simulator's — and so do failure/recovery, stream
                    # arrival, and elastic lease events
                    now = (time.perf_counter() - t0) / self.tx_scale
                    if stream is not None:
                        new_names: list[str] = []
                        for w in stream.take_until(now):
                            arrived_entries.append(w)
                            new_names.extend(
                                engine.add_workflow(w, now=now))
                        sample_durations(new_names)
                    if now >= next_elastic:
                        engine.elastic_pass(now)
                        next_elastic = (now
                                        + engine.elastic.check_interval)
                    while recoveries and recoveries[0][0] <= now:
                        _, rk, rn = heapq.heappop(recoveries)
                        engine.recover_node(rk, rn, now=now)
                    while (next_fail is not None and next_fail[0] <= now
                           and not engine.done()):
                        _, fk, fn = next_fail
                        modelled = {k: v / self.tx_scale
                                    for k, v in started.items()}
                        ev = engine.fail_node(fk, fn, now=now,
                                              started=modelled)
                        if ev is not None:
                            apply_failure_event(ev)
                            if math.isfinite(faults.node_recovery_time):
                                heapq.heappush(
                                    recoveries,
                                    (now + faults.node_recovery_time,
                                     fk, fn))
                        next_fail = schedule.next_node_failure()
                    batch = engine.startable(now)
                    for name, i, pool_idx in batch:
                        if faults is None:
                            ex.submit(body, name, i, pool_idx, 0)
                            continue
                        d = engine.dispatch_duration(
                            name, i, durations[(name, i)], pool_idx)
                        frac = schedule.attempt_failure(
                            name, i, engine.attempt_number(name, i))
                        ex.submit(body, name, i, pool_idx,
                                  gen.get((name, i), 0), 0.0, d, False,
                                  frac)
                    if (not engine.done() or stream_pending()) \
                            and not batch:
                        # with mitigation on, the wait doubles as the
                        # straggler watchdog cadence; a pending campaign
                        # arrival (or fault/recovery/stream/lease event)
                        # bounds the sleep so its pass is not missed
                        timeout = 0.05 if (watchdog or replicating) else 5.0
                        nxt = next((a for a in arrivals if a > now), None)
                        if next_fail is not None:
                            nxt = (next_fail[0] if nxt is None
                                   else min(nxt, next_fail[0]))
                        if recoveries:
                            nxt = (recoveries[0][0] if nxt is None
                                   else min(nxt, recoveries[0][0]))
                        if stream_pending():
                            na = stream.next_arrival()
                            nxt = na if nxt is None else min(nxt, na)
                        if next_elastic < math.inf:
                            nxt = (next_elastic if nxt is None
                                   else min(nxt, next_elastic))
                        if nxt is not None:
                            timeout = min(timeout, max(
                                0.0, (nxt - now) * self.tx_scale) + 1e-3)
                        cv.wait(timeout=timeout)
                    # scheduling pass on the modelled clock (see observe)
                    now = (time.perf_counter() - t0) / self.tx_scale
                    modelled = {k: v / self.tx_scale
                                for k, v in started.items()}
                    if watchdog:
                        for (sn, si) in engine.stragglers(modelled, now):
                            act = engine.arbitrate(
                                sn, si, now - modelled[(sn, si)])
                            if act is None:
                                continue
                            kind, dst, cost = act
                            if kind == "migrate":
                                gen[(sn, si)] = gen.get((sn, si), 0) + 1
                                mig_tasks.add((sn, si))
                                # straggler clock pauses until the re-run's
                                # worker stamps its own start
                                started.pop((sn, si), None)
                                ex.submit(body, sn, si, dst, gen[(sn, si)],
                                          cost,
                                          engine.tx_estimate(sn, pool=dst))
                                # wake preempted synthetic sleeps so they
                                # release their worker slots promptly
                                cv.notify_all()
                            else:  # speculate: a duplicate races the task
                                ex.submit(body, sn, si, dst,
                                          spec_gen.get((sn, si), 0), cost,
                                          engine.tx_estimate(sn, pool=dst),
                                          True)
                    if replicating:
                        # proactively duplicate at-risk tasks onto another
                        # node through the speculation machinery
                        for (rn2, ri2) in engine.at_risk(modelled, now):
                            rep = engine.try_replicate(rn2, ri2)
                            if rep is None:
                                continue
                            dst, cost = rep
                            ex.submit(body, rn2, ri2, dst,
                                      spec_gen.get((rn2, ri2), 0), cost,
                                      engine.tx_estimate(rn2, pool=dst),
                                      True)
                    # online makespan re-prediction (core/predictor.py).
                    # The dispatcher's poll loop wakes on a timeout even
                    # when nothing happened; an idle wakeup (no running
                    # tasks, no engine state moved since the last
                    # snapshot) would append one identical prediction per
                    # poll — skip those, re-predict on everything else
                    if (modelled or not engine.predictions
                            or engine.predict_stamp() != last_stamp):
                        engine.repredict(now, modelled)
                        last_stamp = engine.predict_stamp()

        makespan = max((r.end for r in records), default=0.0)
        if stream is not None:
            # final per-workflow stats span everything that arrived (the
            # re-merged view names sets exactly as add_workflow did)
            view = prefix_view(arrived_entries, stream.name)
        workflows = None
        if view is not None:
            # per-workflow stats on the MODELLED clock, commensurate with
            # the entries' arrival times and the simulator's metrics
            scale = self.tx_scale or 1.0
            scaled = [dataclasses.replace(r, start=r.start / scale,
                                          end=r.end / scale)
                      for r in records]
            workflows = campaign_stats(view, scaled)
        return ExecResult(makespan=makespan, records=records,
                          mode=mode if not task_level else f"{mode}+task_level",
                          tasks_total=len(records),
                          policy=engine.policy.name,
                          migrations=engine.migrations,
                          speculations=engine.speculations,
                          predictions=engine.predictions,
                          workflows=workflows,
                          admission_deferrals=engine.admission_deferrals,
                          node_failures=engine.node_failures,
                          task_failures=engine.task_failures,
                          recoveries_restart=engine.recoveries_restart,
                          recoveries_rerun=engine.recoveries_rerun,
                          replications=engine.replications,
                          fault_log=engine.fault_log,
                          admission_revocations=engine.admission_revocations,
                          leases_granted=engine.leases_granted,
                          leases_expired=engine.leases_expired,
                          lease_log=engine.lease_log,
                          stream=(engine.stream_accounting()
                                  if stream is not None else None))

"""A real asynchronous executor for heterogeneous tasks.

This is the in-process analogue of the paper's EnTK + RADICAL-Pilot stack:
a *pilot* holds the allocation (CPU cores + accelerators), worker threads
execute black-box task payloads, and a dispatcher starts every
dependency-resolved task that fits in the free resources (backfilling).

Task payloads are arbitrary callables — in this framework they are
typically jitted JAX computations (training / inference steps), which
release the GIL while XLA executes, so heterogeneous tasks genuinely
overlap.  Synthetic tasks (``payload=None``) sleep for their sampled TX —
the `stress` analogue used by the paper's experiments.

All scheduling decisions — ready-queue order, dependency bookkeeping
(set-level by default, task-level with ``task_level=True``), per-pool
resource accounting and placement — are delegated to the SAME
:class:`~repro.core.sched_engine.SchedEngine` the discrete-event simulator
uses, so the two substrates enforce identical semantics by construction.
Heterogeneous multi-pool :class:`~repro.core.resources.Allocation`s and
the ``fifo`` / ``lpt`` / ``gpu_bestfit`` policies work unchanged here.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from .dag import DAG
from .resources import Allocation, PoolSpec
from .sched_engine import SchedEngine, SchedulingPolicy
from .simulator import Mode, TaskRecord, per_pool_task_counts


@dataclasses.dataclass
class ExecResult:
    makespan: float
    records: list[TaskRecord]
    mode: str
    tasks_total: int
    policy: str = "fifo"

    def throughput(self) -> float:
        return self.tasks_total / self.makespan if self.makespan else 0.0

    def per_pool_task_counts(self) -> dict[str, int]:
        return per_pool_task_counts(self.records)


class RealExecutor:
    """Executes a task-set DG with real concurrency on the local host."""

    def __init__(self, pool: "PoolSpec | Allocation", max_workers: int = 64,
                 tx_scale: float = 1.0, seed: int = 0,
                 launch_latency: float = 0.0):
        self.pool = pool
        self.max_workers = max_workers
        #: wall-seconds per modelled TX second for synthetic payloads
        #: (lets laptop-scale runs validate Summit-scale workflows).
        self.tx_scale = tx_scale
        self.seed = seed
        self.launch_latency = launch_latency

    def run(self, dag: DAG, mode: Mode = "async", *, task_level: bool = False,
            sequential_stage_groups: Sequence[Sequence[str]] | None = None,
            scheduling: "str | SchedulingPolicy" = "fifo",
            ) -> ExecResult:
        g = dag if mode == "async" else dag.with_sequential_barriers(
            sequential_stage_groups)
        rng = random.Random(self.seed)
        engine = SchedEngine(g, self.pool, policy=scheduling,
                             task_level=task_level)

        durations: dict[tuple[str, int], float] = {}
        for name in engine.order:
            ts = g.node(name)
            for i in range(ts.num_tasks):
                mu = ts.tx_mean
                d = max(0.0, rng.gauss(mu, ts.tx_sigma)) if mu else 0.0
                durations[(name, i)] = d

        lock = threading.Lock()
        cv = threading.Condition(lock)
        records: list[TaskRecord] = []
        t0 = time.perf_counter()

        def body(name: str, i: int, pool_idx: int) -> None:
            ts = g.node(name)
            start = time.perf_counter() - t0
            if self.launch_latency:
                time.sleep(self.launch_latency)
            if ts.payload is not None:
                ts.payload(i)
            else:
                time.sleep(durations[(name, i)] * self.tx_scale)
            end = time.perf_counter() - t0
            with cv:
                engine.complete(name, i)
                records.append(TaskRecord(name, i, start, end,
                                          ts.cpus_per_task, ts.gpus_per_task,
                                          pool=engine.pool_name(pool_idx)))
                cv.notify_all()

        with ThreadPoolExecutor(max_workers=self.max_workers) as ex:
            with cv:
                while not engine.done():
                    # backfill: start everything ready that fits
                    batch = engine.startable()
                    for name, i, pool_idx in batch:
                        ex.submit(body, name, i, pool_idx)
                    if not engine.done() and not batch:
                        cv.wait(timeout=5.0)

        makespan = max((r.end for r in records), default=0.0)
        return ExecResult(makespan=makespan, records=records,
                          mode=mode if not task_level else f"{mode}+task_level",
                          tasks_total=len(records),
                          policy=engine.policy.name)

"""Unified run configuration for both execution substrates.

Seven PRs of options accreted into parallel kwarg sprawl on
``simulate()`` and ``RealExecutor.run()`` (``scheduling=``,
``feedback=``, ``admission=``, ``faults=``, ...).  :class:`RunConfig`
bundles them — plus the streaming-tenancy knobs this PR adds
(``elastic``, ``slo_window``) — into one frozen dataclass accepted as
``simulate(dag, pool, config=RunConfig(...))`` and
``executor.run(dag, config=RunConfig(...))``.

Legacy kwargs keep working through :func:`resolve_run_config`: the shim
emits one :class:`DeprecationWarning` per process *per call site* (the
``where`` string — ``simulate()`` and ``RealExecutor.run()`` each warn
once) the first time that site sees a legacy kwarg, and *forbids
mixing* the kwarg and config forms in one call (silently preferring
either would make the other a no-op).  Resolution is purely mechanical
— a legacy call and its ``RunConfig`` equivalent produce bit-identical
runs.  Tests reset the warn-once state with
:func:`reset_legacy_warnings`.
"""

from __future__ import annotations

import dataclasses
import warnings

from ..runtime.fault import FaultOptions
from .estimator import FeedbackOptions
from .resources import ElasticOptions
from .sched_engine import AdmissionOptions, PredictOptions, SchedulingPolicy

__all__ = ["RunConfig", "resolve_run_config", "reset_legacy_warnings"]

#: sentinel distinguishing "kwarg not passed" from an explicit None/default
#: (passing ``scheduling="fifo"`` explicitly still counts as legacy usage)
_LEGACY = object()


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything about *how* to run a workload, substrate-independent.

    What to run (DAG / Campaign / WorkflowStream), where (PoolSpec /
    Allocation) and the substrate's own physics (SimOptions sampling,
    RealExecutor tx_scale) stay separate arguments — this bundles the
    scheduling-semantics knobs the two substrates must agree on."""

    #: scheduling policy name or instance (``SCHEDULING_POLICIES``)
    scheduling: "str | SchedulingPolicy" = "fifo"
    #: task-level dependency granularity (the paper's future-work mode)
    task_level: bool = False
    #: explicit PST stage groups for ``mode="sequential"``
    sequential_stage_groups: "list | None" = None
    #: runtime feedback / straggler mitigation (``core/estimator.py``)
    feedback: "FeedbackOptions | None" = None
    #: prediction-driven admission control (campaign/stream runs)
    admission: "AdmissionOptions | None" = None
    #: fault injection + priced recovery (``runtime/fault.py``)
    faults: "FaultOptions | None" = None
    #: elastic capacity leases (``core/resources.ElasticOptions``)
    elastic: "ElasticOptions | None" = None
    #: sliding-window width (modelled s) for ``RunResult.window_stats``
    #: consumers; recorded on the config for benchmarks to share
    slo_window: "float | None" = None
    #: prediction-epoch throttling of ``SchedEngine.repredict``
    #: (``PredictOptions``; None = re-evaluate on every scheduling pass).
    #: Placement-neutral by construction — predictions inform the trace
    #: and the mitigation arbiter's inputs are computed separately — so
    #: throttling thins the prediction *trace* without moving a task.
    predict: "PredictOptions | None" = None
    #: drain all same-timestamp heap events (arrival batches, completion
    #: bursts) into one scheduling pass + one repredict instead of N
    coalesce_events: bool = False
    #: "full" keeps the per-task ``TaskRecord`` trace and per-workflow
    #: stats dict; "summary" (simulator-only) streams finished workflows
    #: into bounded ``core/metrics.StreamMetrics`` sketches instead,
    #: capping memory on million-task runs
    record_policy: str = "full"
    #: collect ``RunResult.perf`` hot-loop wall-time attribution
    #: (pure-Python timers; zero overhead when False)
    perf_counters: bool = False
    #: engine pass structures: the indexed fast path (default) vs the
    #: brute-force scans (``core/sched_engine.py``); dispatch-identical
    #: by the engine's invariant suite — exposed here so determinism
    #: tests (and A/B runs) can flip it through the public run API
    incremental: bool = True


#: call sites (``where`` strings) that have already warned this process.
#: Keyed per site — one module-level bool silenced every call site after
#: the first, so whichever entry point a test module happened to exercise
#: first stole the warning from the others (test order decided which
#: ``pytest.warns`` assertion saw it).
_warned_sites: "set[str]" = set()


def reset_legacy_warnings() -> None:
    """Forget which call sites have warned (test hook: lets a test assert
    the warn-once behaviour without depending on process history)."""
    _warned_sites.clear()


def _warn_legacy(where: str, names: "list[str]") -> None:
    if where in _warned_sites:
        return
    _warned_sites.add(where)
    warnings.warn(
        f"{where}: passing {', '.join(sorted(names))} as separate keyword "
        f"arguments is deprecated — bundle them in config=RunConfig(...) "
        f"(this warning is emitted once per call site per process)",
        DeprecationWarning, stacklevel=4)


def resolve_run_config(config: "RunConfig | None", legacy: dict,
                       where: str) -> RunConfig:
    """Fold a substrate entry point's arguments into one ``RunConfig``.

    ``legacy`` maps kwarg name -> passed value, with the module-level
    ``_LEGACY`` sentinel marking "not passed".  Mixing any legacy kwarg
    with ``config=`` raises ``TypeError``; pure-legacy calls warn once
    per call site (``where``) per process and resolve to the equivalent
    config."""
    used = {k: v for k, v in legacy.items() if v is not _LEGACY}
    if config is not None:
        if used:
            raise TypeError(
                f"{where}: pass either config=RunConfig(...) or the legacy "
                f"keyword arguments ({', '.join(sorted(used))}), not both")
        return config
    if used:
        _warn_legacy(where, list(used))
    return RunConfig(**used)

"""Online makespan / asynchronicity prediction — the paper's analytic
model (Eqns. 2-6) re-evaluated mid-run against *live* estimator state.

The offline model (``core/model.py``) predicts the makespan once, from the
static ``TaskSet.tx_mean`` priors.  PR 2 showed real runs have heavy-tailed,
drifting durations that an online EWMA estimator tracks well — but the
analytic model never saw the updates.  This module closes that loop:

``MakespanPredictor``
    Owns one workflow DG + allocation and re-evaluates the shared equation
    implementations (``sequential_ttx`` / ``async_ttx`` /
    ``relative_improvement`` / ``staggered_async_ttx`` — the *same* code
    the offline model runs, via their ``tx`` lookup parameter) with the
    engine's live TX estimates, plus a resource-aware *residual* bound on
    the remaining makespan:

    - per-set residual TTX: the not-yet-started tasks execute in waves of
      ``slots_s`` (how many tasks of the set the whole allocation can run
      concurrently); a wave of ``k`` tasks spans the *maximum* of ``k``
      draws, so each wave is priced ``t_s + tail_factor * sigma_s *
      sqrt(2 ln k)`` (the Gaussian expected-maximum order statistic) with
      ``sigma_s`` the estimator's live dispersion — under heavy-tailed
      durations the mean alone systematically underpredicts, and the
      dispersion is exactly the information that accumulates as the run
      observes completions;
    - running tasks contribute their longest expected remainder
      (``max(t_s - elapsed, 0)`` plus the same tail term);
    - with node-level occupancy (``contention=True``, set by the engine
      for ``PoolSpec.node_level`` allocations) a cross-set GPU
      contention term shrinks ``slots_s`` to the set's share of strict
      GPU capacity whenever order-unrelated sets' live demand exceeds it
      (strict-GPU c-DG2's T3/T6 waves serialize behind T4/T5's GPUs,
      which the per-set path bound alone cannot see);
    - remaining makespan = max(longest residual dependency path, residual
      work / capacity per non-oversubscribed resource class);
    - predicted total = ``now + remaining``.

``SchedEngine.repredict`` calls this at every scheduling pass (substrates
amortise exactly like the straggler scans) and appends the result to the
``SimResult`` / ``ExecResult`` ``predictions`` trace; as observations
accumulate the predicted total converges onto the realized makespan
(``benchmarks/bench_predictor.py`` asserts the error shrinks
monotonically).

The predictor also prices straggler mitigation for the engine's arbiter
(``SchedEngine.arbitrate``): :meth:`MakespanPredictor.straggler_baseline`
models a flagged straggler left alone (heavy tails stay heavy:
``max(mean, tail_ratio * mean - elapsed)``) and
:meth:`MakespanPredictor.mitigation_delta` is the marginal-makespan delta
of an action — ``(cost + rerun TX) - baseline`` — negative when acting
beats waiting.  Migration vs speculation is then a pure cost comparison.

Early predictions lean on the static priors (no observations yet), which
exclude the EnTK/async overheads that observed durations include — one of
the error sources the convergence benchmark watches shrink.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

from .dag import DAG, TaskSet
from .model import (async_ttx, relative_improvement, sequential_ttx,
                    staggered_async_ttx)
from .resources import Allocation, PoolSpec, as_allocation

TxFn = Callable[[str], float]


@dataclasses.dataclass(frozen=True)
class MakespanPrediction:
    """One mid-run snapshot of the re-evaluated analytic model."""

    #: scheduling clock the prediction was made at (modelled seconds)
    now: float
    #: fraction of the workflow's tasks finished at ``now``
    done_fraction: float
    #: Eqn. 2 on live TXs (whole workflow, sequential/BSP semantics)
    t_seq: float
    #: Eqn. 3/4 on live TXs (whole workflow, asynchronous semantics)
    t_async: float
    #: Eqn. 5 on live TXs: I = 1 - t_async / t_seq
    improvement: float
    #: predicted makespan still ahead of ``now`` (residual bound)
    remaining: float
    #: predicted total makespan: ``now + remaining``
    total: float
    #: sum of the per-set residual spans — the remaining work executed
    #: back to back with no cross-set overlap (the Eqn.-2-shaped serial
    #: counterpart of ``remaining``, for prediction-trace consumers).
    residual_seq: float = 0.0
    #: per-workflow predicted finish times of a multi-tenant run:
    #: ``(workflow, predicted_finish_clock)`` pairs, sorted by name, for
    #: every workflow that still has pending or running work.  Empty for
    #: single-workflow runs — the field defaults keep those snapshots
    #: bit-identical to their pre-streaming form.
    wf_finish: "tuple[tuple[str, float], ...]" = ()
    #: per-workflow Eqn. 2-5 snapshots ``(workflow, t_seq, t_async,
    #: improvement)``, batch-evaluated via ``BatchEqns`` when a pass
    #: prices many workflows at once (see ``SchedEngine.repredict``)
    wf_models: "tuple[tuple[str, float, float, float], ...]" = ()

    def predicted_finish(self, workflow: str) -> "float | None":
        """This snapshot's predicted finish clock for one workflow
        (``None`` when the workflow has no remaining work here)."""
        for wf, fin in self.wf_finish:
            if wf == workflow:
                return fin
        return None

    @property
    def residual_improvement(self) -> float:
        """Eqn. 5 over the *remaining* work: how much asynchronicity the
        rest of the run is still predicted to extract (0 = fully
        serialized).  Observability only — the admission controller's
        ``i_adm`` is the cross-snapshot analogue, computed in
        ``SchedEngine._admit_decision`` from three predictions."""
        if self.residual_seq <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.remaining / self.residual_seq)


class MakespanPredictor:
    """Re-evaluate Eqns. 2-6 for one DG + allocation from live TX state.

    ``tail_factor`` scales the dispersion (expected-maximum) term of the
    residual bound; 0 disables it (pure mean-based waves, the paper's
    assumption), 1.0 prices each wave at mean + sigma * sqrt(2 ln k).
    """

    def __init__(self, dag: DAG, pool: "PoolSpec | Allocation",
                 tail_factor: float = 1.0, contention: bool = False,
                 workflow_of: "Mapping[str, str] | None" = None,
                 cache: bool = False):
        self.g = dag
        self.tail_factor = tail_factor
        self.alloc = as_allocation(pool)
        #: opt-in whole-workflow (Eqn. 2-5) snapshot caching, keyed by the
        #: invalidation epoch.  Only safe when ONE tx source ever calls
        #: :meth:`predict` and every TX move goes through
        #: :meth:`invalidate` — exactly the engine's contract
        #: (``SchedEngine.observe``), so the engine constructs with
        #: ``cache=True`` and standalone users keep uncached semantics.
        self.cache = cache
        #: bumped by :meth:`invalidate`; stamps the Eqn. 2-5 cache
        self._tx_epoch = 0
        #: set -> ((t, sigma, pending, slots), residual): self-invalidating
        #: memo of the idle-set residual terms — ``repredict`` re-prices
        #: only the sets whose inputs moved (dirty sets)
        self._residual_memo: dict[str, tuple[tuple, float]] = {}
        self._model_cache: "tuple | None" = None
        #: epoch-keyed cache of the batched per-workflow Eqn. 2-5
        #: snapshot (see :meth:`workflow_models`)
        self._wf_model_cache: "tuple | None" = None
        #: lazily-compiled ``BatchEqns`` over ``self.g`` (rebuilt when
        #: :meth:`add_sets` grows the graph)
        self._batch_eqns = None
        #: cross-set GPU contention term (see :meth:`_effective_slots`):
        #: enabled by the engine when the allocation carries node-level
        #: occupancy (``PoolSpec.node_level``), whose honest accounting is
        #: what makes the live ``gpu_held`` signal trustworthy.
        self.contention = contention
        #: set -> workflow map of a multi-tenant campaign.  Sets of
        #: *different* workflows always contend for strict GPUs (there is
        #: no dependency path between them by construction), so the
        #: demand-share slot scaling applies to cross-workflow contenders
        #: even on aggregate pools; same-workflow contention keeps
        #: requiring node-level occupancy (``contention=True``), which is
        #: what keeps single-workflow aggregate runs bit-identical.
        self.workflow_of = dict(workflow_of or {})
        self._order = dag.topological_order()
        #: sets retired by the engine (fully finished, set-level runs):
        #: their residual / work / DP terms are exact zeros, so the
        #: prediction loops skip them — see :meth:`retire`
        self._retired: set[str] = set()
        self._retired_pending = 0
        #: ``_order`` minus (lazily-compacted) retired sets — what the
        #: prediction loops walk, so per-prediction cost tracks the live
        #: frontier instead of everything that ever arrived
        self._live_order: list[str] = self._order
        #: extend ``_order`` by appending arrivals instead of re-deriving
        #: the whole topological order per ``add_sets`` (valid because
        #: arrivals are dependency-disconnected); opt-in — the engine
        #: enables it for throttled (``PredictOptions``) runs
        self.incremental_order = False
        self._slots = {n: self._set_slots(dag.node(n)) for n in self._order}
        # resource classes the work bound may use: skip a class as soon as
        # any pool oversubscribes it (its capacity is then not a bound)
        self._bound_cpus = (not any(p.oversubscribe_cpus
                                    for p in self.alloc.pools))
        self._bound_gpus = (not any(p.oversubscribe_gpus
                                    for p in self.alloc.pools))
        #: sets related by a dependency path (ancestors/descendants/self):
        #: those can NEVER contend — only order-unrelated sets co-run
        self._related = {n: self._related_sets(n) for n in self._order}
        #: hazard-of-failure term (set via :meth:`set_hazard` by the
        #: engine when fault injection is on; 0 = exact pre-fault bound)
        self.hazard_rate = 0.0
        #: set -> (interval, write, read) checkpoint params, or None —
        #: decides which failure-inflation model prices the set's waves
        self.ckpt_of: "Callable[[str], tuple | None] | None" = None

    def set_hazard(self, rate: float,
                   ckpt_of: "Callable[[str], tuple | None] | None" = None,
                   ) -> None:
        """Arm the residual bound's hazard-of-failure term: ``rate`` is
        the per-attempt per-second failure hazard, ``ckpt_of`` resolves a
        set's checkpoint params (None = the set re-runs from scratch)."""
        self.hazard_rate = rate
        self.ckpt_of = ckpt_of

    def _hazard_adjust(self, t: float, name: str) -> float:
        """Expected completion time of a ``t``-second task under Poisson
        failures at ``hazard_rate``: the classic ``(e^(lam t) - 1)/lam``
        restart-from-scratch inflation, or — when the set checkpoints
        every ``c`` seconds — the write overhead plus ``lam*t`` expected
        failures each losing half an interval + one read-back."""
        lam = self.hazard_rate
        if lam <= 0.0 or t <= 0.0:
            return t
        ck = self.ckpt_of(name) if self.ckpt_of is not None else None
        if ck is not None:
            c, w, r = ck
            return (t + math.floor(t / c) * w
                    + lam * t * (c / 2.0 + r))
        return math.expm1(min(lam * t, 50.0)) / lam

    def _related_sets(self, name: str) -> set[str]:
        out = {name}
        for direction in (self.g.parents, self.g.children):
            frontier = [name]
            while frontier:
                cur = frontier.pop()
                for m in direction(cur):
                    if m not in out:
                        out.add(m)
                        frontier.append(m)
        return out

    @staticmethod
    def _node_level_slots(p: PoolSpec, ts: TaskSet) -> int:
        """Per-node slot count for a node-level pool, summed over the
        pool's two node-capacity classes (``reserved_cpus`` spreads as
        evenly as possible, so the first ``reserved % num_nodes`` nodes
        carry one core less — mirroring ``resources.node_states``)."""
        base, extra = divmod(p.reserved_cpus, p.num_nodes)
        out = 0
        for cap_c, count in ((p.node.cpus - base - 1, extra),
                             (p.node.cpus - base, p.num_nodes - extra)):
            if not count:
                continue
            lims = []
            if ts.cpus_per_task > 0 and not p.oversubscribe_cpus:
                lims.append(cap_c // ts.cpus_per_task)
            if ts.gpus_per_task > 0 and not p.oversubscribe_gpus:
                lims.append(p.node.gpus // ts.gpus_per_task)
            out += (min(lims) if lims else ts.num_tasks) * count
        return out

    def _set_slots(self, ts: TaskSet) -> int:
        """How many tasks of ``ts`` the allocation can run concurrently.
        Node-level pools bound this per node (a task must fit one node),
        so e.g. 4-GPU tasks on 6-GPU nodes get one slot per node — not
        ``total_gpus // 4`` — matching the engine's placement honesty."""
        total = 0
        for p in self.alloc.pools:
            if not p.accepts(ts):
                continue
            if p.node_level:
                total += self._node_level_slots(p, ts)
                continue
            lims = []
            if ts.cpus_per_task > 0 and not p.oversubscribe_cpus:
                lims.append(p.total.cpus // ts.cpus_per_task)
            if ts.gpus_per_task > 0 and not p.oversubscribe_gpus:
                lims.append(p.total.gpus // ts.gpus_per_task)
            total += min(lims) if lims else ts.num_tasks
        return max(1, min(ts.num_tasks, total))

    # -- explicit cache invalidation (engine-driven) ------------------------
    def invalidate(self, name: "str | None" = None) -> None:
        """Drop the cached terms that depend on set ``name``'s TX (all
        sets when ``None``): its memoized residual and the whole-workflow
        Eqn. 2-5 snapshot.  The engine calls this from ``observe`` —
        completions/observations are the only events that move a live TX,
        so between them ``predict`` re-prices only dirty sets."""
        self._tx_epoch += 1
        self._model_cache = None
        self._wf_model_cache = None
        if name is None:
            self._residual_memo.clear()
        else:
            self._residual_memo.pop(name, None)

    def add_sets(self, names: "Sequence[str]",
                 workflow_of: "Mapping[str, str] | None" = None) -> None:
        """Register sets that joined ``self.g`` after construction (a
        stream arrival merged by ``SchedEngine.add_workflow``).  The
        construction-time structure snapshots (topological order, slot
        counts, related-set closures) are extended; existing entries stay
        valid because an arriving workflow is dependency-disconnected
        from everything already in the graph."""
        self.workflow_of.update(workflow_of or {})
        if self.incremental_order:
            # arrivals are dependency-disconnected from every merged set,
            # so appending keeps the order topologically valid — O(new)
            # instead of re-deriving O(all) per arrival.  Opt-in (engine
            # throttled runs): the re-derived order can interleave sets
            # differently, and float summation order feeds the admission
            # prices the committed streaming baseline pins.
            if self._live_order is self._order:
                # de-alias once so the in-place extends stay independent
                self._live_order = list(self._order)
            self._order.extend(names)
            self._live_order.extend(names)
        else:
            self._order = self.g.topological_order()
            self._live_order = (
                [n for n in self._order if n not in self._retired]
                if self._retired else self._order)
        for n in names:
            self._slots[n] = self._set_slots(self.g.node(n))
            self._related[n] = self._related_sets(n)
        self._batch_eqns = None
        self.invalidate()

    def retire(self, name: str) -> None:
        """Drop a fully-finished set from the prediction loops (the
        engine calls this from ``complete`` on set-level runs).  Exact:
        a finished set has zero pending and zero running tasks, so its
        residual and work terms are exactly ``0.0`` and — set-level
        dependencies meaning every ancestor of a finished set is
        finished — its critical-path entry is too.  ``_live_order``
        compacts lazily once half of it is retired, keeping retirement
        O(1) amortized."""
        self._retired.add(name)
        self._residual_memo.pop(name, None)
        self._retired_pending += 1
        if self._retired_pending * 2 >= len(self._live_order):
            self._live_order = [n for n in self._live_order
                                if n not in self._retired]
            self._retired_pending = 0

    # -- Eqns. 2-6 on live TXs ---------------------------------------------
    def live_model(self, tx: TxFn) -> tuple[float, float, float]:
        """Whole-workflow Eqns. 2-5 with live TXs:
        ``(t_seq, t_async, improvement)``.  With ``cache`` on, the
        snapshot is reused until :meth:`invalidate` bumps the TX epoch
        (no TX moved => bit-identical recomputation, skipped)."""
        if self.cache:
            c = self._model_cache
            if c is not None and c[0] == self._tx_epoch:
                return c[1], c[2], c[3]
        t_seq = sequential_ttx(self.g, tx=tx)
        t_async, _ = async_ttx(self.g, tx=tx)
        out = (t_seq, t_async, relative_improvement(t_seq, t_async))
        if self.cache:
            self._model_cache = (self._tx_epoch,) + out
        return out

    def live_staggered(self, stage_names: Sequence[str], n: int,
                       maskable: Sequence[bool], tx: TxFn) -> float:
        """Eqn. 6 (staggered multi-iteration pipelines) with live stage
        TXs — e.g. DeepDriveMD's ``3 t_seq - 2 t_Aggr - 1 t_Train``."""
        return staggered_async_ttx([tx(s) for s in stage_names], n,
                                   list(maskable))

    def workflow_models(self, tx: TxFn, workflows: "Sequence[str]",
                        ) -> "tuple[tuple[str, float, float, float], ...]":
        """Per-workflow Eqn. 2-5 snapshots ``(wf, t_seq, t_async, I)``,
        evaluated for ALL workflows in one :class:`BatchEqns` pass over
        the merged graph with each row's TX vector masked to its
        workflow's sets (a masked stage contributes a 0 span, so the row
        reduces to the workflow's own subgraph).  This is the
        many-candidate pricing path streams make hot: one vectorized
        NumPy segment reduction instead of W scalar graph walks, cached
        on the TX epoch like :meth:`live_model` (same invalidation
        discipline, so serving the cache is bit-identical)."""
        wfs = tuple(sorted(workflows))
        if not wfs:
            return ()
        if self.cache:
            c = self._wf_model_cache
            if c is not None and c[0] == (self._tx_epoch, wfs):
                return c[1]
        if self._batch_eqns is None:
            from .model_batch import BatchEqns
            self._batch_eqns = BatchEqns(self.g, backend="numpy")
        eq = self._batch_eqns
        rows = []
        for wf in wfs:
            rows.append([tx(n) if self.workflow_of.get(n) == wf else 0.0
                         for n in eq.names])
        import numpy as np
        t_seq, t_async, imp = eq.evaluate(np.asarray(rows, dtype=np.float64))
        out = tuple((wf, float(t_seq[j]), float(t_async[j]), float(imp[j]))
                    for j, wf in enumerate(wfs))
        if self.cache:
            self._wf_model_cache = ((self._tx_epoch, wfs), out)
        return out

    # -- residual (remaining-makespan) bound -------------------------------
    def _wave_span(self, t: float, sigma: float, k: int) -> float:
        """Expected span of one wave of ``k`` concurrent task draws: the
        mean plus the expected-maximum excess ``sigma * sqrt(2 ln k)``."""
        if k <= 1 or sigma <= 0.0 or self.tail_factor <= 0.0:
            return t
        return t + self.tail_factor * sigma * math.sqrt(2.0 * math.log(k))

    @staticmethod
    def _norm_cdf(x: float) -> float:
        return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))

    def expected_remaining(self, t: float, sigma: float,
                           elapsed: float) -> float:
        """Expected remaining runtime of a task that has already run
        ``elapsed`` seconds, modelling its duration as lognormal with mean
        ``t`` and standard deviation ``sigma``: ``E[T | T > e] - e``.

        This is the hazard correction the mean alone misses — under heavy
        tails a task that has outlived its mean is *expected to keep
        running*, and the correction grows with ``elapsed``.  With
        ``sigma = 0`` it degenerates to ``max(t - elapsed, 0)``.
        """
        if elapsed <= 0.0:
            return t
        if sigma <= 0.0 or t <= 0.0 or self.tail_factor <= 0.0:
            return max(0.0, t - elapsed)
        s2 = math.log(1.0 + (sigma / t) ** 2)     # sigma_log^2
        if s2 <= 0.0:   # dispersion below float resolution: as if exact
            return max(0.0, t - elapsed)
        s = math.sqrt(s2)
        mu = math.log(t) - 0.5 * s2
        d = (math.log(elapsed) - mu) / s
        denom = self._norm_cdf(-d)
        if denom < 1e-12:       # far in the tail: heavy-tail linear growth
            return max(0.0, t - elapsed) + sigma
        cond_mean = t * self._norm_cdf(s - d) / denom
        return max(max(0.0, t - elapsed), cond_mean - elapsed)

    def _effective_slots(self, name: str, pending: Mapping[str, int],
                         run_count: Mapping[str, int],
                         gpu_held: Mapping[str, int]) -> int:
        """Cross-set GPU contention: shrink a set's concurrency to its
        *share* of the strict GPU capacity when order-unrelated sets with
        remaining work compete for the same devices.

        The per-set path bound prices each set's waves as if it had the
        whole allocation; under strict GPUs, co-runnable sets (e.g.
        c-DG2's T3/T6 next to T4/T5) serialize behind each other's
        devices, which that bound cannot see.  Each contender's demand is
        its *live* GPU holdings (``gpu_held``, from the engine's
        node-level occupancy) plus what its still-pending tasks can draw;
        set ``name``'s slots scale by its demand share whenever the total
        exceeds capacity."""
        slots = self._slots[name]
        if not ((self.contention or self.workflow_of) and self._bound_gpus):
            return slots
        g_n = self.g.node(name).gpus_per_task
        if g_n <= 0:
            return slots

        def demand(m: str) -> int:
            g = self.g.node(m).gpus_per_task
            can_start = max(0, self._slots[m] - run_count.get(m, 0))
            return (gpu_held.get(m, run_count.get(m, 0) * g)
                    + min(pending.get(m, 0), can_start) * g)

        mine = demand(name)
        if mine <= 0:
            return slots
        capacity = self.alloc.total.gpus
        wf = self.workflow_of.get(name)
        total = mine
        #: per-contending-workflow demand, capped at capacity below — a
        #: workflow's sets cannot hold more GPUs than exist no matter how
        #: much rank-unexpanded pending demand they stack up
        per_wf: dict[str, int] = {}
        for m in self._live_order:
            if m in self._retired or m in self._related[name]:
                continue
            if not (pending.get(m, 0) or run_count.get(m, 0)):
                continue
            # same-workflow contenders need the node-level occupancy
            # signal; cross-workflow contenders always count (tenancy)
            wf_m = self.workflow_of.get(m)
            if wf_m is not None and wf_m != wf:
                per_wf[wf_m] = per_wf.get(wf_m, 0) + demand(m)
                continue
            if not self.contention:
                continue
            total += demand(m)
        for d in per_wf.values():
            total += min(d, capacity)
        if total <= capacity:
            return slots  # no contention: everyone fits side by side
        eff = int(capacity * (mine / total)) // g_n
        return max(1, min(slots, eff))

    def predict(self, tx: TxFn, now: float,
                pending: Mapping[str, int],
                running_elapsed: "Mapping[tuple[str, int], float]",
                done_fraction: float = 0.0,
                tx_std: "TxFn | None" = None,
                gpu_held: "Mapping[str, int] | None" = None,
                ) -> MakespanPrediction:
        """One prediction snapshot.

        ``pending`` maps set -> tasks not yet started (queued or blocked);
        ``running_elapsed`` maps (set, index) -> seconds the task has been
        running on the caller's clock (the same clock the estimator was
        fed, so live TXs and elapsed times are commensurate); ``tx_std``
        supplies the live dispersion per set (``None`` = no tail term);
        ``gpu_held`` the GPUs each set's running tasks hold right now
        (the engine's occupancy accounting — only read by the cross-set
        contention term, see :meth:`_effective_slots`).
        """
        std = tx_std or (lambda _n: 0.0)
        run_rem: dict[str, float] = {}
        run_work: dict[str, float] = {}
        run_count: dict[str, int] = {}
        hazard = self.hazard_rate > 0.0
        for (name, _i), elapsed in running_elapsed.items():
            rem = self.expected_remaining(tx(name), std(name), elapsed)
            if hazard:
                # the remaining work is itself at risk of being lost and
                # re-run — the same inflation the pending waves pay
                rem = self._hazard_adjust(rem, name)
            run_rem[name] = max(run_rem.get(name, 0.0), rem)
            run_work[name] = run_work.get(name, 0.0) + rem
            run_count[name] = run_count.get(name, 0) + 1

        residual: dict[str, float] = {}
        cpu_work = gpu_work = 0.0
        held = gpu_held or {}
        # the live frontier only (``retire``): a retired set's residual
        # and work terms are exact zeros, and the DP below reads absent
        # ancestors as 0.0 — bit-identical to walking the full order
        for n in self._live_order:
            if n in self._retired:
                continue
            ts = self.g.node(n)
            t = tx(n)
            if hazard:
                t = self._hazard_adjust(t, n)
            s = std(n)
            m = pending.get(n, 0)
            slots = self._effective_slots(n, pending, run_count, held)
            k_run = run_count.get(n, 0)
            key = (t, s, m, slots)
            memo = self._residual_memo.get(n) if not k_run else None
            if memo is not None and memo[0] == key:
                # idle set with unchanged inputs: the pure wave-span terms
                # recompute bit-identically, so serve the memo (dirty sets
                # miss on the key — TX/pending/slots moved — or carry
                # running tasks, whose elapsed changes every pass)
                r = memo[1]
            else:
                full, last = divmod(m, slots)
                r = full * self._wave_span(t, s, slots)
                if last:
                    r += self._wave_span(t, s, last)
                if k_run:
                    r += (run_rem.get(n, 0.0)
                          + self._wave_span(0.0, s, k_run))
                else:
                    self._residual_memo[n] = (key, r)
            residual[n] = r
            work = m * t + run_work.get(n, 0.0)
            cpu_work += work * ts.cpus_per_task
            gpu_work += work * ts.gpus_per_task

        # longest residual dependency path (finished sets weigh 0)
        best: dict[str, float] = {}
        for n in self._live_order:
            if n in self._retired:
                continue
            base = max((best.get(p, 0.0) for p in self.g.parents(n)),
                       default=0.0)
            best[n] = base + residual[n]
        remaining = max(best.values(), default=0.0)

        # residual work / capacity, per non-oversubscribed resource class
        total = self.alloc.total
        if self._bound_cpus and total.cpus:
            remaining = max(remaining, cpu_work / total.cpus)
        if self._bound_gpus and total.gpus:
            remaining = max(remaining, gpu_work / total.gpus)

        # per-workflow predicted finish: the longest residual path into
        # any of the workflow's sets that still carry work (multi-tenant
        # runs only — single-workflow snapshots keep the empty default)
        wf_fin: dict[str, float] = {}
        if self.workflow_of:
            for n in self._live_order:
                if n in self._retired:
                    continue
                if not (pending.get(n, 0) or run_count.get(n, 0)):
                    continue
                wf = self.workflow_of.get(n)
                if wf is None:
                    continue
                wf_fin[wf] = max(wf_fin.get(wf, 0.0), best[n])

        t_seq, t_async, improvement = self.live_model(tx)
        return MakespanPrediction(
            now=now, done_fraction=done_fraction, t_seq=t_seq,
            t_async=t_async, improvement=improvement,
            remaining=remaining, total=now + remaining,
            residual_seq=sum(residual.values()),
            wf_finish=tuple(sorted((wf, now + b)
                                   for wf, b in wf_fin.items())))

    # -- straggler-mitigation pricing (the arbiter's cost model) -----------
    @staticmethod
    def straggler_baseline(t_est: float, elapsed: float,
                           tail_ratio: float) -> float:
        """Expected *remaining* runtime of a flagged straggler left alone:
        heavy-tailed durations stay heavy once past the detection
        threshold, so assume ``tail_ratio x`` the set mean in total (but
        never less than one fresh mean ahead)."""
        return max(t_est, tail_ratio * t_est - elapsed)

    @staticmethod
    def mitigation_delta(t_rerun: float, cost: float,
                         baseline_remaining: float) -> float:
        """Marginal-makespan delta of *migration*: the original attempt is
        killed, so the task finishes after data-movement cost + a fresh
        rerun, against leaving the straggler alone.  Negative = the action
        is predicted to finish the task sooner."""
        return (cost + t_rerun) - baseline_remaining

    @staticmethod
    def speculation_delta(t_rerun: float, cost: float,
                          baseline_remaining: float,
                          slot_pressure: bool) -> float:
        """Marginal-makespan delta of a *speculative duplicate*: first
        finisher wins, so the task finishes at ``min(baseline, cost +
        rerun)`` — but the race holds a second slot for its duration,
        which under ``slot_pressure`` (queued work exists that could have
        used it) is charged as displaced work.  Without pressure (tail
        phase, idle capacity) the duplicate slot is free and speculation
        strictly dominates."""
        delta = min(baseline_remaining, cost + t_rerun) - baseline_remaining
        if slot_pressure:
            delta += min(t_rerun, baseline_remaining)
        return delta

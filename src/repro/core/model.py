"""The paper's analytic performance model (§5.3, §7): Eqns. 1-7.

Predicts sequential and asynchronous makespans (TTX), TX masking, and the
relative improvement I = 1 - t_async / t_seq, including the paper's
framework-overhead corrections (EnTK ~4%; enabling asynchronicity ~2%,
Table 3 caption).

Every equation evaluator takes an optional ``tx`` lookup (a callable
``name -> mean TX`` or a mapping) overriding the static ``TaskSet.tx_mean``
values: the offline model passes nothing (the paper's static priors) while
the online predictor (``core/predictor.py``) passes the live EWMA
estimates — one shared implementation of Eqns. 2-6, two TX sources.

Terminology (paper):
  TX   task execution time
  TTX  total time to execution (makespan)
  C    constant middleware overhead (Eqn. 2), negligible for TX >= O(10min)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

from .dag import DAG
from .resources import PoolSpec, Resources, doa_res, DoaResStrategy

#: override for the static ``TaskSet.tx_mean``: ``name -> mean TX``
TxLookup = Callable[[str], float] | Mapping[str, float] | None


def tx_lookup_fn(dag: DAG, tx: TxLookup = None) -> Callable[[str], float]:
    """Normalise a :data:`TxLookup` into a ``name -> TX`` function, falling
    back to the DG's static ``tx_mean`` (``tx=None`` or a mapping miss)."""
    if tx is None:
        return lambda n: dag.node(n).tx_mean
    if callable(tx):
        return tx
    mapping = tx
    return lambda n: mapping.get(n, dag.node(n).tx_mean)

#: Overhead fractions measured by the paper (Table 3 caption).
ENTK_OVERHEAD = 0.04
ASYNC_OVERHEAD = 0.02


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Model output for one workflow + allocation."""

    t_seq: float
    t_async: float
    improvement: float          # Eqn. 5
    doa_dep: int
    doa_res: int
    wla: int                    # Eqn. 1
    masked_sets: tuple[str, ...] = ()

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Eqn. 2 — sequential (BSP) makespan
# ---------------------------------------------------------------------------

def sequential_ttx(dag: DAG, overhead_c: float = 0.0,
                   n_iterations: int = 1, tx: TxLookup = None) -> float:
    """Eqn. 2: ``t_seq = sum_i t_i + C`` over PST stages.

    A stage is one DG rank executed under a BSP barrier; task sets sharing a
    rank run concurrently within the stage, so the stage TX is their max.
    For the paper's single-chain workflows this reduces literally to the sum
    of task-set TXs; ``n_iterations`` scales the whole pipeline (the paper's
    ``3 t_seq`` for three DeepDriveMD iterations).  ``tx`` overrides the
    static per-set TXs (see :data:`TxLookup`).
    """
    t = tx_lookup_fn(dag, tx)
    total = 0.0
    for group in dag.rank_groups():
        total += max(t(n) for n in group)
    return n_iterations * total + overhead_c


def sequential_ttx_grouped(stage_tx: list[float], overhead_c: float = 0.0,
                           n_iterations: int = 1) -> float:
    """Eqn. 2 on explicit stage TXs (the paper's five type-group stages)."""
    return n_iterations * sum(stage_tx) + overhead_c


# ---------------------------------------------------------------------------
# Eqn. 3/4 — asynchronous makespan via independent branches
# ---------------------------------------------------------------------------

def async_ttx(dag: DAG, overhead_c: float = 0.0,
              tx: TxLookup = None) -> tuple[float, list[float]]:
    """Eqn. 3: ``t_async = sum_i t_i + max_j tt_Hj + C``.

    ``sum_i t_i`` covers the sequential *trunk* (ranks before the last fork
    that still has a single live branch); each independent branch ``H_j``
    contributes its chain TTX (Eqn. 4) and only the longest one survives
    (TX masking).  Task sets sharing a rank within the same trunk stage or
    branch segment run concurrently (max), mirroring Eqn. 2's stage rule.
    ``tx`` overrides the static per-set TXs (see :data:`TxLookup`).
    """
    t = tx_lookup_fn(dag, tx)
    branch_of = dag.branch_ids()
    n_branches = len(set(branch_of.values()))

    if n_branches <= 1:
        return sequential_ttx(dag, overhead_c, tx=tx), []

    # The sequential trunk is the prefix of ranks whose task sets all belong
    # to the branch of the first source; after the first rank that mixes
    # branch ids, every branch accumulates its own chain TTX (Eqn. 4).
    first_branch = branch_of[dag.rank_groups()[0][0]]
    trunk_tx = 0.0
    branch_tail: dict[int, float] = {}
    forked = False
    for group in dag.rank_groups():
        ids = {branch_of[n] for n in group}
        if not forked and ids == {first_branch}:
            trunk_tx += max(t(n) for n in group)
            continue
        forked = True
        per_branch: dict[int, float] = {}
        for n in group:
            b = branch_of[n]
            per_branch[b] = max(per_branch.get(b, 0.0), t(n))
        for b, btx in per_branch.items():
            branch_tail[b] = branch_tail.get(b, 0.0) + btx

    tails = sorted(branch_tail.values(), reverse=True)
    total = trunk_tx + (tails[0] if tails else 0.0) + overhead_c
    return total, tails


def relative_improvement(t_seq: float, t_async: float) -> float:
    """Eqn. 5: ``I = 1 - t_async / t_seq`` (0 on an empty workload —
    an open stream's engine can be legitimately empty before the first
    arrival)."""
    if t_seq == 0:
        return 0.0
    return 1.0 - t_async / t_seq


# ---------------------------------------------------------------------------
# Eqns. 6/7 — staggered multi-iteration pipelines (DeepDriveMD)
# ---------------------------------------------------------------------------

def maskable_stages(stage_sets: list, pool: PoolSpec) -> list[bool]:
    """A stage's task set can be masked by a concurrent pacing stage iff it
    does not demand 100% of any resource class (§7.1: Simulation and
    Inference sets each need all 96 GPUs and are "ineligible for
    asynchronicity"; Aggregation/Training are maskable)."""
    total = pool.total
    out = []
    for ts in stage_sets:
        full = Resources.of_full_set(ts)
        monopolises = ((total.gpus > 0 and full.gpus >= total.gpus)
                       or (not pool.oversubscribe_cpus
                           and full.cpus >= total.cpus))
        out.append(not monopolises)
    return out


def staggered_async_ttx(stage_tx: list[float], n: int,
                        maskable: list[bool],
                        overhead_c: float = 0.0) -> float:
    """Eqns. 6/7: asynchronous TTX of ``n`` staggered iterations of a
    sequential pipeline with per-stage TXs ``stage_tx``.

    Maskable stage k (1-indexed position within the pipeline) overlaps with
    later iterations' pacing stages, so ``n - k`` of its ``n`` instances are
    hidden::

        t_async = n * t_seq_one - sum_{maskable k} (n - k) * t_k

    For DeepDriveMD (stages [Sim, Aggr, Train, Infer], Aggr/Train maskable):
    ``t_async = 3 t_seq - 2 t_Aggr - 1 t_Train`` = Eqn. 6 with n = 3.
    """
    if len(maskable) != len(stage_tx):
        raise ValueError("maskable mask must match stage list")
    t_one = sum(stage_tx)
    t = n * t_one
    for k, (tx, m) in enumerate(zip(stage_tx, maskable)):
        if m and k >= 1:
            t -= max(0, n - k) * tx
    return t + overhead_c


# ---------------------------------------------------------------------------
# End-to-end prediction with the paper's overhead corrections
# ---------------------------------------------------------------------------

def predict(dag: DAG, pool: PoolSpec, *,
            strategy: DoaResStrategy = "minimal",
            entk_overhead: float = ENTK_OVERHEAD,
            async_overhead: float = ASYNC_OVERHEAD,
            apply_overheads: bool = True,
            tx: TxLookup = None) -> Prediction:
    """Predict t_seq, t_async and I for a workflow DG on an allocation.

    Matches the paper's Table 3 ``Pred.`` columns: the asynchronous
    prediction is inflated by the EnTK overhead (4%) and, when the DG
    actually admits asynchronicity, by the async-enablement overhead (2%).
    ``tx`` swaps the static per-set TXs for live estimates (this is how
    ``core/predictor.py`` re-evaluates Eqns. 2-5 mid-run).
    """
    t_seq = sequential_ttx(dag, tx=tx)
    t_async_raw, _ = async_ttx(dag, tx=tx)
    dd = dag.doa_dep()
    dr = doa_res(dag, pool, strategy)
    w = min(dd, dr)
    if w <= 0:
        t_async_raw = t_seq
    if apply_overheads:
        t_async = t_async_raw * (1 + entk_overhead)
        if w > 0:
            t_async *= (1 + async_overhead)
    else:
        t_async = t_async_raw
    return Prediction(
        t_seq=t_seq, t_async=t_async,
        improvement=relative_improvement(t_seq, t_async),
        doa_dep=dd, doa_res=dr, wla=w)

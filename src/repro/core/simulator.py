"""Discrete-event simulator for workflow execution on an allocation.

This is the framework's "measured" analogue of the paper's Summit runs: it
executes a task-set DG on a :class:`~repro.core.resources.PoolSpec` (or a
heterogeneous multi-pool :class:`~repro.core.resources.Allocation`) with a
pluggable backfilling scheduler (the RADICAL-Pilot agent analogue), sampled
task durations (``N(mu, 0.05 mu)``, Table 1/2 captions), EnTK-like dispatch
overheads, and optional straggler injection + duplicate-dispatch
mitigation.  A pure event loop over aggregate resource counters, it
simulates thousands of nodes and ~10^5 tasks in well under a second.

Scheduling decisions (ready-queue order, pool placement, dependency and
resource bookkeeping) live in :class:`~repro.core.sched_engine.SchedEngine`,
which the real executor shares — this module only advances the simulated
clock.  Select a policy with ``scheduling="fifo" | "lpt" | "gpu_bestfit" |
"locality" | "nodepack"``; with node-level pools
(``PoolSpec.node_level``) every ``TaskRecord`` carries the concrete node
the winning attempt ran on.  Pass ``feedback=FeedbackOptions(...)`` to
drive the policy
by *observed* TX (online EWMA estimates, per-pool splits), to mitigate
stragglers (arbitrated preemption + migration vs speculative duplicates,
see ``core/estimator.py`` / ``SchedEngine.arbitrate``), and to re-predict
the makespan mid-run (``SimResult.predictions``, ``core/predictor.py``).

Modes:
  ``async``       dependency-driven dispatch (the paper's asynchronous mode)
  ``sequential``  PST stage barriers (the paper's sequential/BSP mode)

Multi-workflow tenancy: pass a :class:`~repro.core.workflow.Campaign`
instead of a DAG to multiplex several prioritized, staggered workflows
over the allocation (arrival-gated dispatch, per-workflow metrics in
``SimResult.workflows``), with ``admission=AdmissionOptions(...)``
enabling the engine's prediction-driven admission controller.

Task-level asynchronicity (the paper's future work, our ``adaptive``
scheduler) is enabled with ``task_level=True``: a task becomes eligible as
soon as its *matching* parent tasks complete instead of waiting for whole
parent sets.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import random
import time
from typing import Literal, Sequence

from .dag import DAG, TaskSet
from .estimator import FeedbackOptions
from .metrics import StreamMetrics
from .resources import Allocation, PoolSpec, as_allocation
from .results import (PerfCounters, RunResult, TaskRecord,  # noqa: F401
                      per_pool_task_counts)
from .runconfig import _LEGACY, RunConfig, resolve_run_config
from .sched_engine import AdmissionOptions, SchedEngine, SchedulingPolicy
from .stream import WorkflowStream, prefix_view
from .workflow import Campaign, CampaignView, WorkflowStats, campaign_stats
from ..runtime.fault import FailureSchedule, FaultOptions

Mode = Literal["async", "sequential"]

#: sentinel event name for the simulator's periodic straggler watchdog
#: (never collides with a task-set name: "\x00" is not valid in one)
_WATCHDOG = "\x00watchdog"
#: sentinel event name for a campaign workflow's arrival (dispatch pass)
_ARRIVAL = "\x00arrival"
#: sentinel event names for fault injection (payload keyed by event seq)
_FAIL = "\x00fail"
_RECOVER = "\x00recover"
_TASKFAIL = "\x00taskfail"
#: sentinel event name for an open stream's next workflow arrival
_STREAM = "\x00streamarrival"
#: sentinel event name for the periodic elastic-capacity pass
_ELASTIC = "\x00elastic"


@dataclasses.dataclass
class SimResult(RunResult):
    """A simulator run's result: the shared :class:`RunResult` protocol
    plus the simulator-only utilization/duplication accounting.  Always
    constructed keyword-only."""

    pool_cpus: int = 0
    pool_gpus: int = 0
    #: fraction of (resource x makespan) area actually used
    cpu_utilization: float = 0.0
    gpu_utilization: float = 0.0
    duplicates: int = 0

    def utilization_trace(self, resolution: int = 256
                          ) -> tuple[list[float], list[int], list[int]]:
        """(time, cpus_in_use, gpus_in_use) sampled on a uniform grid —
        the data behind the paper's Figs. 4-6."""
        ts = [self.makespan * i / (resolution - 1) for i in range(resolution)]
        cpu = [0] * resolution
        gpu = [0] * resolution
        for r in self.records:
            for i, t in enumerate(ts):
                if r.start <= t < r.end:  # instantaneous usage at time t
                    cpu[i] += r.cpus
                    gpu[i] += r.gpus
        return ts, cpu, gpu


@dataclasses.dataclass(frozen=True)
class SimOptions:
    seed: int = 0
    sample_tx: bool = True
    #: task-duration distribution: "normal" is the paper's N(mu, sigma);
    #: "lognormal" keeps mean mu but has the heavy right tail real
    #: ML-driven HPC tasks show (sigma_log = ``lognormal_sigma``).
    tx_distribution: Literal["normal", "lognormal"] = "normal"
    lognormal_sigma: float = 0.5
    #: EnTK-like middleware overhead: fractional stretch on every task
    #: duration (Table 3 caption: ~4%).
    entk_overhead: float = 0.04
    #: extra fractional overhead when running in asynchronous mode (~2%).
    async_overhead: float = 0.02
    #: fixed per-task dispatch latency (s).
    launch_latency: float = 0.5
    #: straggler injection: with probability p a task runs xfactor slower.
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    #: duplicate-dispatch mitigation: relaunch a task if it exceeds
    #: ``threshold x`` its set's mean sampled duration; first finish wins.
    mitigate_stragglers: bool = False
    mitigation_threshold: float = 2.0


def simulate(dag: "DAG | Campaign | WorkflowStream",
             pool: "PoolSpec | Allocation",
             mode: Mode = "async", *,
             options: SimOptions = SimOptions(),
             config: "RunConfig | None" = None,
             task_level=_LEGACY,
             sequential_stage_groups=_LEGACY,
             scheduling=_LEGACY,
             feedback=_LEGACY,
             admission=_LEGACY,
             faults=_LEGACY,
             ) -> SimResult:
    """Run one workflow execution and return its schedule.

    Scheduling-semantics knobs are bundled in ``config=RunConfig(...)``
    (``core/runconfig.py``); the individual keyword arguments
    (``scheduling=``, ``feedback=``, ...) are a deprecated legacy form
    that resolves to the equivalent config (bit-identical runs) and may
    not be mixed with ``config=``.

    ``RunConfig.feedback`` enables the runtime-feedback loop
    (core/estimator.py): every completion updates the engine's per-set
    (and per-pool) TX estimate, ordering policies re-rank by observed TX,
    stragglers (runtime > mean + k*sigma of the running estimate) are
    mitigated by preemptive migration and/or speculative duplicates —
    arbitrated per straggler by predicted marginal makespan when both are
    enabled — and the analytic model is re-evaluated mid-run on the live
    estimates (``SimResult.predictions``).

    ``dag`` may be a :class:`~repro.core.workflow.Campaign`: the member
    workflows are multiplexed over the allocation (tasks gated on each
    workflow's arrival time), ``SimResult.workflows`` carries per-workflow
    makespan/wait/slowdown metrics, and ``admission=AdmissionOptions()``
    enables the engine's prediction-driven admission controller
    (campaigns run asynchronously — ``mode`` must be ``"async"``).

    ``dag`` may also be a :class:`~repro.core.stream.WorkflowStream`:
    an *open* arrival stream consumed incrementally — the engine only
    ever sees the arrived prefix (each arrival merges via
    ``SchedEngine.add_workflow``), and ``SimResult.stream`` carries the
    conservation partition.  A stream wrapping a closed campaign
    (:attr:`~repro.core.stream.WorkflowStream.closed_campaign`) routes
    through the campaign path verbatim.  ``RunConfig.elastic`` adds
    whole-node capacity leases driven by a periodic control event.

    ``RunConfig.faults`` injects seeded node losses (stochastic
    and/or trace-driven) and per-attempt software failures into the run:
    in-flight attempts on a dying node are released and re-enqueued (or
    their replica promoted), the recovery arbiter prices
    restart-from-checkpoint vs. re-run per failure, and re-predictions
    fold the live hazard in (``FaultOptions.hazard_aware``).  Disabled
    options (the default instance) are treated exactly like ``None`` —
    the dispatch trace stays bit-identical."""
    cfg = resolve_run_config(config, dict(
        task_level=task_level,
        sequential_stage_groups=sequential_stage_groups,
        scheduling=scheduling, feedback=feedback,
        admission=admission, faults=faults), "simulate()")
    task_level = cfg.task_level
    sequential_stage_groups = cfg.sequential_stage_groups
    scheduling = cfg.scheduling
    feedback = cfg.feedback
    admission = cfg.admission
    faults = cfg.faults
    if cfg.record_policy not in ("full", "summary"):
        raise ValueError(f"unknown record_policy {cfg.record_policy!r}; "
                         f"known: 'full', 'summary'")
    summary = cfg.record_policy == "summary"
    coalesce = cfg.coalesce_events
    perf = PerfCounters() if cfg.perf_counters else None

    rng = random.Random(options.seed)
    stream: "WorkflowStream | None" = None
    if isinstance(dag, WorkflowStream):
        closed = dag.closed_campaign
        if closed is not None:
            dag = closed  # a closed stream IS its campaign — same path
        else:
            stream = dag
            stream.reset()
    view: "CampaignView | None" = None
    arrived_entries: "list" = []
    if stream is not None:
        if mode != "async":
            raise ValueError("streams execute asynchronously "
                             "(mode='async')")
        arrived_entries = list(stream.take_until(0.0))
        view = prefix_view(arrived_entries, stream.name)
        g = view.dag
    elif isinstance(dag, Campaign):
        if mode != "async":
            raise ValueError("campaigns execute asynchronously "
                             "(mode='async')")
        view = dag.view()
        g = view.dag
    else:
        g = dag if mode == "async" else dag.with_sequential_barriers(
            sequential_stage_groups)
    alloc = as_allocation(pool)
    total = alloc.total

    overhead = (1 + options.entk_overhead)
    if mode == "async":
        overhead *= (1 + options.async_overhead)

    def sample_base(ts: TaskSet) -> float:
        """One task duration, pre-overhead, without straggler injection."""
        mu = ts.tx_mean
        if not options.sample_tx or mu <= 0:
            return mu
        if options.tx_distribution == "lognormal":
            s = options.lognormal_sigma
            return mu * math.exp(rng.gauss(0.0, s) - 0.5 * s * s)
        return max(0.0, rng.gauss(mu, ts.tx_sigma))

    # ---- expand task sets into tasks -------------------------------------
    engine = SchedEngine(g, alloc, policy=scheduling, task_level=task_level,
                         feedback=feedback, campaign=view,
                         admission=admission, faults=faults,
                         elastic=cfg.elastic, predict=cfg.predict,
                         incremental=cfg.incremental)
    faults = engine.faults  # disabled options normalized to None
    schedule = (FailureSchedule(faults,
                                [(k, p.num_nodes)
                                 for k, p in enumerate(engine.pools)],
                                [p.name for p in engine.pools])
                if faults is not None else None)
    order = engine.order
    # live for streams (add_workflow extends it); a superset-correct copy
    # of view.workflow_of for closed campaigns
    wf_of = engine.workflow_of if view is not None else {}
    durations: dict[tuple[str, int], float] = {}

    def sample_durations(names: "Sequence[str]") -> None:
        """Pre-sample every task of ``names`` in set order (RNG draw order
        is part of the trace contract — see the bit-identity tests)."""
        for name in names:
            ts = g.node(name)
            for i in range(ts.num_tasks):
                d = sample_base(ts)
                if (options.straggler_prob
                        and rng.random() < options.straggler_prob):
                    d *= options.straggler_factor
                durations[(name, i)] = d * overhead

    sample_durations(order)

    # ---- event loop -------------------------------------------------------
    # Ready bookkeeping is PER SET inside the engine: all tasks of a set
    # share (rank, topo position, resource footprint), so scheduling scans
    # O(#sets x #pools) instead of O(#tasks) — the loop stays fast at
    # 10^5+ tasks (4096-node runs).
    #: start of the CURRENT attempt (reset on migration: the straggler
    #: clock and the estimator must measure the re-run, not the preempted
    #: attempt, or a migrated task is instantly re-flagged)
    running: dict[tuple[str, int], float] = {}
    #: start of the FIRST attempt (task records span the whole task)
    first_start: dict[tuple[str, int], float] = {}
    records: list[TaskRecord] = []
    # (t, seq, name, i, dup, gen): gen invalidates events superseded by a
    # migration (the preempted attempt's completion must be ignored)
    events: list[tuple[float, int, str, int, bool, int]] = []
    gen: dict[tuple[str, int], int] = {}
    seq = 0
    now = 0.0
    duplicates = 0
    duplicated: set[tuple[str, int]] = set()
    set_durations: dict[str, list[float]] = {}
    #: tasks that were straggler-migrated (the record flag; under faults
    #: ``gen`` is also bumped by failures, so membership in ``gen`` no
    #: longer means "migrated")
    mig_tasks: set[tuple[str, int]] = set()
    #: payload store for fault sentinel events, keyed by event seq
    payload: dict[int, tuple] = {}
    #: (set, i) -> scheduled end of the current primary / duplicate event
    #: (re-pushed with a fresh gen when a failure invalidates a survivor)
    end_of: dict[tuple[str, int], float] = {}
    spec_end: dict[tuple[str, int], float] = {}
    #: primary attempts doomed by a seeded software failure: the pending
    #: _TASKFAIL time (re-pushed instead of a completion on gen bumps)
    fail_at: dict[tuple[str, int], float] = {}

    # ---- streaming-summary state (record_policy="summary") ---------------
    # instead of growing ``records`` one TaskRecord per task, fold each
    # completion into scalar accumulators and each finished workflow into
    # bounded StreamMetrics sketches the moment its last task completes —
    # the ``campaign_stats`` fold, computed online
    metrics = (StreamMetrics(window=cfg.slo_window or 900.0)
               if summary else None)
    n_records = 0
    makespan_acc = 0.0
    cpu_area_acc = gpu_area_acc = 0.0
    #: workflow -> [min start, max end, completed count] (in-flight only)
    wf_agg: dict[str, list] = {}
    #: workflow -> its WorkflowEntry, dropped once folded into metrics
    wf_entry: dict = {}
    #: workflow -> total task count (fold trigger)
    wf_expected: dict[str, int] = {}
    #: per-set durations feed only the legacy mitigation scan — skip the
    #: O(#tasks) growth on summary runs that don't use it
    track_durations = not summary or options.mitigate_stragglers

    def note_entries(entries) -> None:
        for w in entries:
            wf_entry[w.name] = w
            wf_expected[w.name] = sum(ts.num_tasks
                                      for ts in w.dag.nodes.values())

    if summary and view is not None:
        note_entries(view.entries)

    def emit_workflow(wf: str) -> None:
        """Fold one workflow's final stats into the streaming sketches
        (exactly the values ``campaign_stats`` would compute for it)."""
        w = wf_entry.pop(wf)
        a = wf_agg.pop(wf, None)
        t0 = time.perf_counter() if perf is not None else 0.0
        metrics.observe_workflow(WorkflowStats(
            name=w.name, arrival=w.arrival,
            start=a[0] if a else w.arrival,
            finish=a[1] if a else w.arrival,
            tasks=a[2] if a else 0,
            priority=w.priority, weight=w.weight, deadline=w.deadline,
            reference_makespan=w.reference_makespan))
        if perf is not None:
            perf.metrics_s += time.perf_counter() - t0

    def try_start() -> None:
        nonlocal seq
        for name, i, pool_k in engine.startable(now):
            d = durations[(name, i)]
            if faults is None:
                first_start[(name, i)] = now
            else:
                # retried attempts keep the original start: the record
                # spans the whole task, failed attempts included
                first_start.setdefault((name, i), now)
                d = engine.dispatch_duration(name, i, d, pool_k)
            end = now + options.launch_latency + d
            # straggler/estimator clock starts when the WORK starts:
            # launch latency must not read as task duration
            running[(name, i)] = now + options.launch_latency
            end_of[(name, i)] = end
            g0 = gen.get((name, i), 0)
            frac = (schedule.attempt_failure(
                        name, i, engine.attempt_number(name, i))
                    if schedule is not None else None)
            if frac is not None:
                # the attempt dies mid-run: push the failure, not the
                # completion (a gen bump re-derives one from fail_at)
                t_fail = now + options.launch_latency + frac * d
                fail_at[(name, i)] = t_fail
                payload[seq] = (name, i, g0)
                heapq.heappush(events, (t_fail, seq, _TASKFAIL, -1,
                                        False, 0))
            else:
                heapq.heappush(events, (end, seq, name, i, False, g0))
            seq += 1

    #: speculative duplicates in flight: (set, i) -> (work start, pool)
    spec_info: dict[tuple[str, int], tuple[float, int]] = {}

    def complete(name: str, i: int, dup: bool = False) -> None:
        ts = g.node(name)
        spec = spec_info.pop((name, i), None)
        if dup and spec is not None:
            # the speculative duplicate won: the original attempt is the
            # loser — engine.complete frees both slots, the record and the
            # estimate belong to the duplicate's pool and work span
            attempt_start, k = spec
            node = engine.spec_node(name, i)
            running.pop((name, i), None)
            # spec_won records the duplicate's pool/node as the task's
            # final placement (children's data costs must price pulls
            # from where the output actually lives)
            engine.complete(name, i, spec_won=True)
            won_by_dup = True
        else:
            attempt_start = running.pop((name, i))
            node = engine.node_placement(name, i)
            k = engine.complete(name, i)
            won_by_dup = False
        start = first_start.pop((name, i), attempt_start)
        end_of.pop((name, i), None)
        spec_end.pop((name, i), None)
        if summary:
            nonlocal n_records, makespan_acc, cpu_area_acc, gpu_area_acc
            n_records += 1
            if now > makespan_acc:
                makespan_acc = now
            dur = now - start
            cpu_area_acc += dur * ts.cpus_per_task
            gpu_area_acc += dur * ts.gpus_per_task
            wf = wf_of.get(name, "")
            if wf:
                a = wf_agg.get(wf)
                if a is None:
                    a = wf_agg[wf] = [start, now, 0]
                else:
                    if start < a[0]:
                        a[0] = start
                    if now > a[1]:
                        a[1] = now
                a[2] += 1
                if a[2] == wf_expected[wf]:
                    emit_workflow(wf)
        else:
            records.append(TaskRecord(name, i, start, now,
                                      ts.cpus_per_task, ts.gpus_per_task,
                                      duplicate=won_by_dup,
                                      pool=engine.pool_name(k),
                                      migrated=(name, i) in mig_tasks,
                                      node=node,
                                      workflow=wf_of.get(name, "")))
        if track_durations:
            set_durations.setdefault(name, []).append(now - attempt_start)
        engine.observe(name, now - attempt_start, pool=k)

    def mitigate_scan() -> None:
        nonlocal seq
        for (sn, si) in engine.stragglers(running, now):
            act = engine.arbitrate(sn, si, now - running[(sn, si)])
            if act is None:
                continue
            kind, dst, cost = act
            d = sample_base(g.node(sn)) * overhead
            work_start = now + cost + options.launch_latency
            if kind == "migrate":
                gen[(sn, si)] = gen.get((sn, si), 0) + 1
                mig_tasks.add((sn, si))
                # migration pre-empts the attempt: any pending seeded
                # software failure dies with it (the re-run is fresh)
                fail_at.pop((sn, si), None)
                end_of[(sn, si)] = work_start + d
                heapq.heappush(events, (work_start + d, seq, sn, si,
                                        False, gen[(sn, si)]))
                seq += 1
                # reset the straggler clock to the re-run's WORK start:
                # the migration cost must not contaminate the TX estimate
                # the detector and the cost/benefit gate consult
                running[(sn, si)] = work_start
            else:  # speculate: the original keeps running, a dup races it
                spec_info[(sn, si)] = (work_start, dst)
                spec_end[(sn, si)] = work_start + d
                heapq.heappush(events, (work_start + d, seq, sn, si,
                                        True, gen.get((sn, si), 0)))
                seq += 1

    def apply_failure_event(ev) -> None:
        """Invalidate the sim events a :class:`FailureEvent` superseded.
        Failed attempts simply vanish (the engine re-enqueued them);
        promoted replicas re-push their completion as the new primary's;
        a cancelled replica's primary re-pushes its pending outcome
        (completion, or the doomed software failure) under the fresh gen."""
        nonlocal seq
        for key in ev.failed:
            gen[key] = gen.get(key, 0) + 1
            running.pop(key, None)
            spec_info.pop(key, None)
            end_of.pop(key, None)
            spec_end.pop(key, None)
            fail_at.pop(key, None)
        for key in ev.promoted:
            gen[key] = gen.get(key, 0) + 1
            st, _dst = spec_info.pop(key)
            running[key] = st
            end = spec_end.pop(key)
            end_of[key] = end
            fail_at.pop(key, None)
            heapq.heappush(events, (end, seq, key[0], key[1], False,
                                    gen[key]))
            seq += 1
        for key in ev.cancelled:
            gen[key] = gen.get(key, 0) + 1
            spec_info.pop(key, None)
            spec_end.pop(key, None)
            tf = fail_at.get(key)
            if tf is not None:
                payload[seq] = (key[0], key[1], gen[key])
                heapq.heappush(events, (tf, seq, _TASKFAIL, -1, False, 0))
            else:
                heapq.heappush(events, (end_of[key], seq, key[0], key[1],
                                        False, gen[key]))
            seq += 1

    def push_next_failure() -> None:
        """Feed the next node-failure event into the heap — one in flight
        at a time, and none once the workload is done (the stochastic
        stream is infinite; it must not keep the loop alive)."""
        nonlocal seq
        if schedule is None or engine.done():
            return
        nxt = schedule.next_node_failure()
        if nxt is None:
            return
        t, fk, fn = nxt
        payload[seq] = (fk, fn)
        heapq.heappush(events, (max(t, now), seq, _FAIL, -1, False, 0))
        seq += 1

    def replicate_scan() -> None:
        """Proactively duplicate at-risk tasks (``FaultOptions.replicate``)
        through the speculation machinery — same event shape as
        ``mitigate_scan``'s speculate branch."""
        nonlocal seq
        for (rn, ri) in engine.at_risk(running, now):
            rep = engine.try_replicate(rn, ri)
            if rep is None:
                continue
            dst, cost = rep
            d = sample_base(g.node(rn)) * overhead
            work_start = now + cost + options.launch_latency
            spec_info[(rn, ri)] = (work_start, dst)
            spec_end[(rn, ri)] = work_start + d
            heapq.heappush(events, (work_start + d, seq, rn, ri, True,
                                    gen.get((rn, ri), 0)))
            seq += 1

    # periodic watchdog (mitigation enabled only): completions trigger
    # scans too, but a lone tail straggler has no completion left to
    # piggyback on — without a timer event it would never be detected.
    # Migration needs a second pool; speculation only needs a free slot,
    # so it keeps the watchdog alive even on single-pool allocations.
    # Proactive replication rides the same timer.
    migrating = (feedback is not None
                 and (feedback.speculate
                      or (feedback.migrate and len(engine.pools) > 1)))
    replicating = faults is not None and faults.replicate
    if migrating or replicating:
        positive = [ts.tx_mean for ts in g.nodes.values() if ts.tx_mean > 0]
        scan_dt = ((feedback.watchdog_interval
                    if feedback is not None else 0.0)
                   or (0.5 * min(positive) if positive else 1.0))
    watchdog_pending = False

    def schedule_scan() -> None:
        nonlocal watchdog_pending, seq
        if (migrating or replicating) and not watchdog_pending and running:
            heapq.heappush(events, (now + scan_dt, seq, _WATCHDOG, -1,
                                    False, 0))
            seq += 1
            watchdog_pending = True

    # ---- hot-loop attribution (RunConfig.perf_counters) ------------------
    # rebind the pass entry points through timers; Python resolves the
    # closure names at call time, so every call site below is covered.
    # With perf off the originals run unwrapped — zero added cost.
    repredict = engine.repredict
    if perf is not None:
        def repredict(t, r, _rp=engine.repredict):
            t0 = time.perf_counter()
            out = _rp(t, r)
            perf.predict_s += time.perf_counter() - t0
            return out

        def try_start(_ts=try_start):
            t0 = time.perf_counter()
            _ts()
            perf.engine_s += time.perf_counter() - t0
            perf.passes += 1

    # ---- coalesced event passes (RunConfig.coalesce_events) --------------
    # every event branch ends in the same epilogue: an optional repredict
    # plus one try_start/schedule_scan pass.  ``tail`` runs it inline by
    # default (bit-identical to the historical per-event passes); under
    # coalescing it only raises flags, and ``flush`` runs ONE combined
    # epilogue once the heap's next event is strictly later — arrival
    # batches and completion bursts at one timestamp collapse into a
    # single scheduling pass + a single repredict instead of N.
    pred_due = False
    pass_due = False

    def drain_stream() -> None:
        """Admit every stream arrival due at (exactly) the current
        timestamp before a scheduling pass runs.

        Arrival-boundary contract (shared with the executor's
        dispatcher): a pass at time ``t`` must see every arrival with
        ``arrival <= t`` — the executor always drains
        ``stream.take_until(now)`` before ``engine.startable(now)`` in
        the same loop iteration.  Without this, an arrival landing
        exactly on a completion's timestamp could be admitted only
        *after* the completion's pass handed the freed capacity to
        already-queued work (the ``_STREAM`` sentinel popping second at
        an equal heap timestamp), diverging from both the executor and
        the coalesced path.  For non-colliding arrivals the sentinel
        still pops strictly first, so this is a no-op and the dispatch
        trace is unchanged."""
        nxt = stream.next_arrival() if stream is not None else None
        if nxt is None or nxt > now:
            return
        new_names: list[str] = []
        new_entries: list = []
        for w in stream.take_until(now):
            arrived_entries.append(w)
            new_entries.append(w)
            new_names.extend(engine.add_workflow(w, now=now))
        sample_durations(new_names)
        if summary:
            note_entries(new_entries)

    def tail(pred: bool) -> None:
        nonlocal pred_due, pass_due
        if coalesce:
            pred_due = pred_due or pred
            pass_due = True
            return
        drain_stream()
        if pred:
            repredict(now, running)
        try_start()
        schedule_scan()

    def flush() -> None:
        nonlocal pred_due, pass_due
        if pred_due:
            repredict(now, running)
        if pass_due:
            try_start()
            schedule_scan()
        pred_due = pass_due = False

    # campaign arrivals: a dispatch pass must run when a workflow arrives
    # (its sets become eligible), even with nothing completing right then
    if view is not None:
        for t in sorted({w.arrival for w in view.entries if w.arrival > 0}):
            heapq.heappush(events, (t, seq, _ARRIVAL, -1, False, 0))
            seq += 1
    # open stream: one in-flight sentinel at the next unconsumed arrival
    # (the handler re-pushes; it also keeps the loop alive through lulls
    # where nothing is running)
    if stream is not None:
        nxt = stream.next_arrival()
        if nxt is not None:
            heapq.heappush(events, (nxt, seq, _STREAM, -1, False, 0))
            seq += 1
    # elastic capacity: periodic control event (lease grant/expiry)
    if engine.elastic is not None:
        heapq.heappush(events, (engine.elastic.check_interval, seq,
                                _ELASTIC, -1, False, 0))
        seq += 1

    t_loop0 = time.perf_counter()
    try_start()
    schedule_scan()
    push_next_failure()
    repredict(now, running)   # prior-based prediction at t = 0
    event_count = 0
    while True:
        if not events:
            # a deferred flush may launch work (and so push new events)
            if pred_due or pass_due:
                flush()
                if events:
                    continue
            break
        if (pred_due or pass_due) and events[0][0] > now:
            flush()  # timestamp batch drained: one combined epilogue
            continue
        now_, sq, name, i, dup, g_ = heapq.heappop(events)
        now = now_
        if perf is not None:
            perf.events += 1
        if name is _WATCHDOG:
            watchdog_pending = False
            if migrating:
                mitigate_scan()
            if replicating:
                replicate_scan()
            tail(True)
            continue
        if name is _ARRIVAL:
            tail(True)  # the new workflow is visible
            continue
        if name is _STREAM:
            # a preceding same-timestamp pass may already have drained
            # this sentinel's arrivals (see drain_stream); the sentinel
            # then only re-arms itself and runs the visibility pass
            drain_stream()
            nxt = stream.next_arrival()
            if nxt is not None:
                heapq.heappush(events, (nxt, seq, _STREAM, -1, False, 0))
                seq += 1
            tail(True)  # the arrivals are visible
            continue
        if name is _ELASTIC:
            if engine.elastic_pass(now):
                tail(True)  # capacity changed
            if (not engine.done()
                    or (stream is not None
                        and stream.next_arrival() is not None)):
                heapq.heappush(events,
                               (now + engine.elastic.check_interval,
                                seq, _ELASTIC, -1, False, 0))
                seq += 1
            continue
        if name is _FAIL:
            fk, fn = payload.pop(sq)
            if not engine.done():
                ev = engine.fail_node(fk, fn, now=now, started=running)
                if ev is not None:
                    apply_failure_event(ev)
                    if math.isfinite(faults.node_recovery_time):
                        payload[seq] = (fk, fn)
                        heapq.heappush(
                            events, (now + faults.node_recovery_time,
                                     seq, _RECOVER, -1, False, 0))
                        seq += 1
                    tail(True)
            push_next_failure()
            continue
        if name is _RECOVER:
            rk, rn = payload.pop(sq)
            if engine.recover_node(rk, rn, now=now):
                tail(False)
            continue
        if name is _TASKFAIL:
            tn, ti, g0 = payload.pop(sq)
            if (tn, ti) in engine.finished or g0 != gen.get((tn, ti), 0):
                continue
            fail_at.pop((tn, ti), None)
            ev = engine.fail_task(tn, ti, now=now,
                                  elapsed=now - running.get((tn, ti), now))
            if ev is not None:
                apply_failure_event(ev)
                tail(True)
            continue
        if (name, i) in engine.finished:
            continue  # a duplicate already finished this task
        if g_ != gen.get((name, i), 0):
            # attempt preempted by a migration.  Speculative duplicates
            # carry the gen current at launch and the engine never
            # migrates a task while its duplicate races (stragglers()
            # skips it), so they always pass; legacy adaptive duplicates
            # are correctly discarded here, as before the arbiter.
            continue
        complete(name, i, dup)
        event_count += 1
        # straggler mitigation: inspect running tasks, duplicate laggards.
        # The scan is O(running); amortise it by checking every 32
        # completions (watchdogs poll, they don't run per-event).
        if options.mitigate_stragglers and event_count % 32 == 0:
            for (rn, ri), st in list(running.items()):
                if (rn, ri) in duplicated:
                    continue
                ds = set_durations.get(rn)
                if not ds:
                    continue
                mean = sum(ds) / len(ds)
                if (now - st) > options.mitigation_threshold * mean:
                    # relaunch with a fresh (non-straggler) duration
                    ts = g.node(rn)
                    d = ts.tx_mean * overhead
                    heapq.heappush(events, (now + options.launch_latency + d,
                                            seq, rn, ri, True,
                                            gen.get((rn, ri), 0)))
                    seq += 1
                    duplicates += 1
                    duplicated.add((rn, ri))
                    running[(rn, ri)] = min(running[(rn, ri)], st)
        # runtime feedback: mitigate stragglers (arbitrated migration /
        # speculation) and re-predict the makespan.  The scans are
        # O(running); amortise them on big workloads (every 16
        # completions) — the periodic watchdog above covers the gaps.
        scan_every = 16 if engine.tasks_total >= 1024 else 1
        due = event_count % scan_every == 0
        if due and migrating:
            mitigate_scan()
        tail(due)

    if perf is not None:
        perf.total_s = time.perf_counter() - t_loop0
        perf.events_s = max(0.0, perf.total_s - perf.engine_s
                            - perf.predict_s - perf.metrics_s)
        perf.predicts = engine._pred_evals
    if summary:
        # flush workflows still in flight (or never started) with the
        # same defaults campaign_stats applies, in a deterministic order
        for wf in sorted(wf_entry):
            emit_workflow(wf)
        makespan = makespan_acc
        cpu_area, gpu_area = cpu_area_acc, gpu_area_acc
        n_total = n_records
    else:
        makespan = max((r.end for r in records), default=0.0)
        cpu_area = sum(r.duration * r.cpus for r in records)
        gpu_area = sum(r.duration * r.gpus for r in records)
        n_total = len(records)
    if stream is not None and not summary:
        # final per-workflow stats span everything that arrived (the
        # re-merged view names sets exactly as add_workflow did)
        view = prefix_view(arrived_entries, stream.name)
    return SimResult(
        makespan=makespan,
        records=records,
        pool_cpus=total.cpus,
        pool_gpus=total.gpus,
        mode=mode if not task_level else f"{mode}+task_level",
        cpu_utilization=(cpu_area / (total.cpus * makespan)
                         if makespan and total.cpus else 0.0),
        gpu_utilization=(gpu_area / (total.gpus * makespan)
                         if makespan and total.gpus else 0.0),
        tasks_total=n_total,
        duplicates=duplicates,
        policy=engine.policy.name,
        migrations=engine.migrations,
        speculations=engine.speculations,
        predictions=engine.predictions,
        workflows=(campaign_stats(view, records)
                   if view is not None and not summary else None),
        metrics=metrics,
        perf=perf,
        admission_deferrals=engine.admission_deferrals,
        node_failures=engine.node_failures,
        task_failures=engine.task_failures,
        recoveries_restart=engine.recoveries_restart,
        recoveries_rerun=engine.recoveries_rerun,
        replications=engine.replications,
        fault_log=engine.fault_log,
        admission_revocations=engine.admission_revocations,
        leases_granted=engine.leases_granted,
        leases_expired=engine.leases_expired,
        lease_log=engine.lease_log,
        stream=(engine.stream_accounting() if stream is not None else None),
    )

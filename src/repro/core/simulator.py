"""Discrete-event simulator for workflow execution on an allocation.

This is the framework's "measured" analogue of the paper's Summit runs: it
executes a task-set DG on a :class:`~repro.core.resources.PoolSpec` with a
backfilling resource scheduler (the RADICAL-Pilot agent analogue), sampled
task durations (``N(mu, 0.05 mu)``, Table 1/2 captions), EnTK-like dispatch
overheads, and optional straggler injection + duplicate-dispatch
mitigation.  A pure event loop over aggregate resource counters, it
simulates thousands of nodes and ~10^5 tasks in well under a second.

Modes:
  ``async``       dependency-driven dispatch (the paper's asynchronous mode)
  ``sequential``  PST stage barriers (the paper's sequential/BSP mode)

Task-level asynchronicity (the paper's future work, our ``adaptive``
scheduler) is enabled with ``task_level=True``: a task becomes eligible as
soon as its *matching* parent tasks complete instead of waiting for whole
parent sets.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Literal, Sequence

from .dag import DAG
from .resources import PoolSpec

Mode = Literal["async", "sequential"]


@dataclasses.dataclass(frozen=True)
class TaskRecord:
    set_name: str
    index: int
    start: float
    end: float
    cpus: int
    gpus: int
    duplicate: bool = False

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class SimResult:
    makespan: float
    records: list[TaskRecord]
    pool_cpus: int
    pool_gpus: int
    mode: str
    #: fraction of (resource x makespan) area actually used
    cpu_utilization: float = 0.0
    gpu_utilization: float = 0.0
    tasks_total: int = 0
    duplicates: int = 0

    def throughput(self) -> float:
        return self.tasks_total / self.makespan if self.makespan else 0.0

    def utilization_trace(self, resolution: int = 256
                          ) -> tuple[list[float], list[int], list[int]]:
        """(time, cpus_in_use, gpus_in_use) sampled on a uniform grid —
        the data behind the paper's Figs. 4-6."""
        ts = [self.makespan * i / (resolution - 1) for i in range(resolution)]
        cpu = [0] * resolution
        gpu = [0] * resolution
        for r in self.records:
            for i, t in enumerate(ts):
                if r.start <= t < r.end:  # instantaneous usage at time t
                    cpu[i] += r.cpus
                    gpu[i] += r.gpus
        return ts, cpu, gpu


@dataclasses.dataclass(frozen=True)
class SimOptions:
    seed: int = 0
    sample_tx: bool = True
    #: EnTK-like middleware overhead: fractional stretch on every task
    #: duration (Table 3 caption: ~4%).
    entk_overhead: float = 0.04
    #: extra fractional overhead when running in asynchronous mode (~2%).
    async_overhead: float = 0.02
    #: fixed per-task dispatch latency (s).
    launch_latency: float = 0.5
    #: straggler injection: with probability p a task runs xfactor slower.
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    #: duplicate-dispatch mitigation: relaunch a task if it exceeds
    #: ``threshold x`` its set's mean sampled duration; first finish wins.
    mitigate_stragglers: bool = False
    mitigation_threshold: float = 2.0


def simulate(dag: DAG, pool: PoolSpec, mode: Mode = "async", *,
             options: SimOptions = SimOptions(),
             task_level: bool = False,
             sequential_stage_groups: Sequence[Sequence[str]] | None = None,
             ) -> SimResult:
    """Run one workflow execution and return its schedule."""
    rng = random.Random(options.seed)
    g = dag if mode == "async" else dag.with_sequential_barriers(
        sequential_stage_groups)
    total = pool.total
    cpus_free = total.cpus
    gpus_free = total.gpus

    overhead = (1 + options.entk_overhead)
    if mode == "async":
        overhead *= (1 + options.async_overhead)

    # ---- expand task sets into tasks -------------------------------------
    order = g.topological_order()
    ranks = g.ranks()
    durations: dict[tuple[str, int], float] = {}
    for name in order:
        ts = g.node(name)
        for i in range(ts.num_tasks):
            mu = ts.tx_mean
            d = (rng.gauss(mu, ts.tx_sigma)
                 if options.sample_tx and mu > 0 else mu)
            d = max(0.0, d)
            if options.straggler_prob and rng.random() < options.straggler_prob:
                d *= options.straggler_factor
            durations[(name, i)] = d * overhead

    remaining_parent_tasks: dict[tuple[str, int], int] = {}
    set_remaining: dict[str, int] = {n: g.node(n).num_tasks for n in order}

    def parents_satisfied(name: str, i: int) -> bool:
        return remaining_parent_tasks[(name, i)] == 0

    # dependency bookkeeping
    if task_level:
        # task i of a child set depends on task j of each parent set with
        # j = i mapped proportionally (i * np // nc); a parent task may
        # therefore unlock several child tasks.
        child_waiters: dict[tuple[str, int], list[tuple[str, int]]] = {}
        for name in order:
            nc = g.node(name).num_tasks
            for i in range(nc):
                cnt = 0
                for p in g.parents(name):
                    np_ = g.node(p).num_tasks
                    j = i * np_ // nc
                    child_waiters.setdefault((p, j), []).append((name, i))
                    cnt += 1
                remaining_parent_tasks[(name, i)] = cnt
    else:
        # set-level: every task of a child set waits for *all* tasks of all
        # parent sets (the paper's stage semantics).
        for name in order:
            cnt = sum(g.node(p).num_tasks for p in g.parents(name))
            for i in range(g.node(name).num_tasks):
                remaining_parent_tasks[(name, i)] = cnt

    # ---- event loop -------------------------------------------------------
    # Ready bookkeeping is PER SET: all tasks of a set share (rank, topo
    # position, resource footprint), so scheduling scans O(#sets) instead
    # of O(#tasks) — the loop stays fast at 10^5+ tasks (4096-node runs).
    topo_pos = {n: k for k, n in enumerate(order)}
    set_priority = sorted(order, key=lambda n: (ranks[n], topo_pos[n]))
    ready_sets: dict[str, deque] = {n: deque() for n in order}
    finished: set[tuple[str, int]] = set()
    running: dict[tuple[str, int], float] = {}
    launched: set[tuple[str, int]] = set()
    records: list[TaskRecord] = []
    events: list[tuple[float, int, str, int, bool]] = []  # (t, seq, name, i, dup)
    seq = 0
    now = 0.0
    duplicates = 0
    duplicated: set[tuple[str, int]] = set()
    set_durations: dict[str, list[float]] = {}

    def push_ready(name: str, i: int) -> None:
        ready_sets[name].append(i)

    for name in order:
        if not g.parents(name):
            for i in range(g.node(name).num_tasks):
                push_ready(name, i)

    def try_start() -> None:
        nonlocal cpus_free, gpus_free, seq
        # backfill: walk sets in priority order, start whatever fits
        for name in set_priority:
            q = ready_sets[name]
            if not q:
                continue
            ts = g.node(name)
            need_c = ts.cpus_per_task if not pool.oversubscribe_cpus else 0
            need_g = ts.gpus_per_task if not pool.oversubscribe_gpus else 0
            n_fit = len(q)
            if need_c:
                n_fit = min(n_fit, cpus_free // need_c)
            if need_g:
                n_fit = min(n_fit, gpus_free // need_g)
            for _ in range(max(0, n_fit)):
                i = q.popleft()
                if (name, i) in finished or (name, i) in launched:
                    continue
                if not pool.oversubscribe_cpus:
                    cpus_free -= ts.cpus_per_task
                if not pool.oversubscribe_gpus:
                    gpus_free -= ts.gpus_per_task
                launched.add((name, i))
                end = now + options.launch_latency + durations[(name, i)]
                running[(name, i)] = now
                heapq.heappush(events, (end, seq, name, i, False))
                seq += 1

    def complete(name: str, i: int) -> None:
        nonlocal cpus_free, gpus_free
        ts = g.node(name)
        start = running.pop((name, i))
        if not pool.oversubscribe_cpus:
            cpus_free = min(total.cpus, cpus_free + ts.cpus_per_task)
        if not pool.oversubscribe_gpus:
            gpus_free += ts.gpus_per_task
        finished.add((name, i))
        records.append(TaskRecord(name, i, start, now,
                                  ts.cpus_per_task, ts.gpus_per_task))
        set_durations.setdefault(name, []).append(now - start)
        set_remaining[name] -= 1
        if task_level:
            for (cn, ci) in child_waiters.get((name, i), ()):  # type: ignore[union-attr]
                remaining_parent_tasks[(cn, ci)] -= 1
                if remaining_parent_tasks[(cn, ci)] == 0:
                    push_ready(cn, ci)
        elif set_remaining[name] == 0:
            for c in g.children(name):
                nt = g.node(name).num_tasks
                for j in range(g.node(c).num_tasks):
                    remaining_parent_tasks[(c, j)] -= nt
                    if remaining_parent_tasks[(c, j)] == 0:
                        push_ready(c, j)

    try_start()
    event_count = 0
    while events:
        now_, _, name, i, dup = heapq.heappop(events)
        now = now_
        if (name, i) in finished:
            continue  # a duplicate already finished this task
        complete(name, i)
        event_count += 1
        # straggler mitigation: inspect running tasks, duplicate laggards.
        # The scan is O(running); amortise it by checking every 32
        # completions (watchdogs poll, they don't run per-event).
        if options.mitigate_stragglers and event_count % 32 == 0:
            for (rn, ri), st in list(running.items()):
                if (rn, ri) in duplicated:
                    continue
                ds = set_durations.get(rn)
                if not ds:
                    continue
                mean = sum(ds) / len(ds)
                if (now - st) > options.mitigation_threshold * mean:
                    # relaunch with a fresh (non-straggler) duration
                    ts = g.node(rn)
                    d = ts.tx_mean * overhead
                    heapq.heappush(events, (now + options.launch_latency + d,
                                            seq, rn, ri, True))
                    seq += 1
                    duplicates += 1
                    duplicated.add((rn, ri))
                    running[(rn, ri)] = min(running[(rn, ri)], st)
        try_start()

    makespan = max((r.end for r in records), default=0.0)
    cpu_area = sum(r.duration * r.cpus for r in records)
    gpu_area = sum(r.duration * r.gpus for r in records)
    return SimResult(
        makespan=makespan,
        records=records,
        pool_cpus=total.cpus,
        pool_gpus=total.gpus,
        mode=mode if not task_level else f"{mode}+task_level",
        cpu_utilization=(cpu_area / (total.cpus * makespan)
                         if makespan and total.cpus else 0.0),
        gpu_utilization=(gpu_area / (total.gpus * makespan)
                         if makespan and total.gpus else 0.0),
        tasks_total=len(records),
        duplicates=duplicates,
    )

"""Discrete-event simulator for workflow execution on an allocation.

This is the framework's "measured" analogue of the paper's Summit runs: it
executes a task-set DG on a :class:`~repro.core.resources.PoolSpec` (or a
heterogeneous multi-pool :class:`~repro.core.resources.Allocation`) with a
pluggable backfilling scheduler (the RADICAL-Pilot agent analogue), sampled
task durations (``N(mu, 0.05 mu)``, Table 1/2 captions), EnTK-like dispatch
overheads, and optional straggler injection + duplicate-dispatch
mitigation.  A pure event loop over aggregate resource counters, it
simulates thousands of nodes and ~10^5 tasks in well under a second.

Scheduling decisions (ready-queue order, pool placement, dependency and
resource bookkeeping) live in :class:`~repro.core.sched_engine.SchedEngine`,
which the real executor shares — this module only advances the simulated
clock.  Select a policy with ``scheduling="fifo" | "lpt" | "gpu_bestfit"``.

Modes:
  ``async``       dependency-driven dispatch (the paper's asynchronous mode)
  ``sequential``  PST stage barriers (the paper's sequential/BSP mode)

Task-level asynchronicity (the paper's future work, our ``adaptive``
scheduler) is enabled with ``task_level=True``: a task becomes eligible as
soon as its *matching* parent tasks complete instead of waiting for whole
parent sets.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Literal, Sequence

from .dag import DAG
from .resources import Allocation, PoolSpec, as_allocation
from .sched_engine import SchedEngine, SchedulingPolicy

Mode = Literal["async", "sequential"]


def per_pool_task_counts(records: "Sequence[TaskRecord]") -> dict[str, int]:
    """How many tasks each pool of the allocation executed."""
    out: dict[str, int] = {}
    for r in records:
        out[r.pool] = out.get(r.pool, 0) + 1
    return out


@dataclasses.dataclass(frozen=True)
class TaskRecord:
    set_name: str
    index: int
    start: float
    end: float
    cpus: int
    gpus: int
    duplicate: bool = False
    #: name of the pool the task was placed on ("" for legacy records)
    pool: str = ""

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class SimResult:
    makespan: float
    records: list[TaskRecord]
    pool_cpus: int
    pool_gpus: int
    mode: str
    #: fraction of (resource x makespan) area actually used
    cpu_utilization: float = 0.0
    gpu_utilization: float = 0.0
    tasks_total: int = 0
    duplicates: int = 0
    #: scheduling policy used (see sched_engine.SCHEDULING_POLICIES)
    policy: str = "fifo"

    def throughput(self) -> float:
        return self.tasks_total / self.makespan if self.makespan else 0.0

    def utilization_trace(self, resolution: int = 256
                          ) -> tuple[list[float], list[int], list[int]]:
        """(time, cpus_in_use, gpus_in_use) sampled on a uniform grid —
        the data behind the paper's Figs. 4-6."""
        ts = [self.makespan * i / (resolution - 1) for i in range(resolution)]
        cpu = [0] * resolution
        gpu = [0] * resolution
        for r in self.records:
            for i, t in enumerate(ts):
                if r.start <= t < r.end:  # instantaneous usage at time t
                    cpu[i] += r.cpus
                    gpu[i] += r.gpus
        return ts, cpu, gpu

    def per_pool_task_counts(self) -> dict[str, int]:
        return per_pool_task_counts(self.records)


@dataclasses.dataclass(frozen=True)
class SimOptions:
    seed: int = 0
    sample_tx: bool = True
    #: EnTK-like middleware overhead: fractional stretch on every task
    #: duration (Table 3 caption: ~4%).
    entk_overhead: float = 0.04
    #: extra fractional overhead when running in asynchronous mode (~2%).
    async_overhead: float = 0.02
    #: fixed per-task dispatch latency (s).
    launch_latency: float = 0.5
    #: straggler injection: with probability p a task runs xfactor slower.
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    #: duplicate-dispatch mitigation: relaunch a task if it exceeds
    #: ``threshold x`` its set's mean sampled duration; first finish wins.
    mitigate_stragglers: bool = False
    mitigation_threshold: float = 2.0


def simulate(dag: DAG, pool: "PoolSpec | Allocation", mode: Mode = "async", *,
             options: SimOptions = SimOptions(),
             task_level: bool = False,
             sequential_stage_groups: Sequence[Sequence[str]] | None = None,
             scheduling: "str | SchedulingPolicy" = "fifo",
             ) -> SimResult:
    """Run one workflow execution and return its schedule."""
    rng = random.Random(options.seed)
    g = dag if mode == "async" else dag.with_sequential_barriers(
        sequential_stage_groups)
    alloc = as_allocation(pool)
    total = alloc.total

    overhead = (1 + options.entk_overhead)
    if mode == "async":
        overhead *= (1 + options.async_overhead)

    # ---- expand task sets into tasks -------------------------------------
    engine = SchedEngine(g, alloc, policy=scheduling, task_level=task_level)
    order = engine.order
    durations: dict[tuple[str, int], float] = {}
    for name in order:
        ts = g.node(name)
        for i in range(ts.num_tasks):
            mu = ts.tx_mean
            d = (rng.gauss(mu, ts.tx_sigma)
                 if options.sample_tx and mu > 0 else mu)
            d = max(0.0, d)
            if options.straggler_prob and rng.random() < options.straggler_prob:
                d *= options.straggler_factor
            durations[(name, i)] = d * overhead

    # ---- event loop -------------------------------------------------------
    # Ready bookkeeping is PER SET inside the engine: all tasks of a set
    # share (rank, topo position, resource footprint), so scheduling scans
    # O(#sets x #pools) instead of O(#tasks) — the loop stays fast at
    # 10^5+ tasks (4096-node runs).
    running: dict[tuple[str, int], float] = {}
    records: list[TaskRecord] = []
    events: list[tuple[float, int, str, int, bool]] = []  # (t, seq, name, i, dup)
    seq = 0
    now = 0.0
    duplicates = 0
    duplicated: set[tuple[str, int]] = set()
    set_durations: dict[str, list[float]] = {}

    def try_start() -> None:
        nonlocal seq
        for name, i, _pool in engine.startable():
            end = now + options.launch_latency + durations[(name, i)]
            running[(name, i)] = now
            heapq.heappush(events, (end, seq, name, i, False))
            seq += 1

    def complete(name: str, i: int) -> None:
        ts = g.node(name)
        start = running.pop((name, i))
        k = engine.complete(name, i)
        records.append(TaskRecord(name, i, start, now,
                                  ts.cpus_per_task, ts.gpus_per_task,
                                  pool=engine.pool_name(k)))
        set_durations.setdefault(name, []).append(now - start)

    try_start()
    event_count = 0
    while events:
        now_, _, name, i, dup = heapq.heappop(events)
        now = now_
        if (name, i) in engine.finished:
            continue  # a duplicate already finished this task
        complete(name, i)
        event_count += 1
        # straggler mitigation: inspect running tasks, duplicate laggards.
        # The scan is O(running); amortise it by checking every 32
        # completions (watchdogs poll, they don't run per-event).
        if options.mitigate_stragglers and event_count % 32 == 0:
            for (rn, ri), st in list(running.items()):
                if (rn, ri) in duplicated:
                    continue
                ds = set_durations.get(rn)
                if not ds:
                    continue
                mean = sum(ds) / len(ds)
                if (now - st) > options.mitigation_threshold * mean:
                    # relaunch with a fresh (non-straggler) duration
                    ts = g.node(rn)
                    d = ts.tx_mean * overhead
                    heapq.heappush(events, (now + options.launch_latency + d,
                                            seq, rn, ri, True))
                    seq += 1
                    duplicates += 1
                    duplicated.add((rn, ri))
                    running[(rn, ri)] = min(running[(rn, ri)], st)
        try_start()

    makespan = max((r.end for r in records), default=0.0)
    cpu_area = sum(r.duration * r.cpus for r in records)
    gpu_area = sum(r.duration * r.gpus for r in records)
    return SimResult(
        makespan=makespan,
        records=records,
        pool_cpus=total.cpus,
        pool_gpus=total.gpus,
        mode=mode if not task_level else f"{mode}+task_level",
        cpu_utilization=(cpu_area / (total.cpus * makespan)
                         if makespan and total.cpus else 0.0),
        gpu_utilization=(gpu_area / (total.gpus * makespan)
                         if makespan and total.gpus else 0.0),
        tasks_total=len(records),
        duplicates=duplicates,
        policy=engine.policy.name,
    )

"""Shared run-result protocol of both execution substrates.

``SimResult`` (``core/simulator.py``) and ``ExecResult``
(``core/executor.py``) grew the same surface seven PRs in a row —
records, predictions, per-workflow stats, fault/admission counters —
duplicated field by field.  :class:`RunResult` is the extracted base
both now subclass, so benchmarks and tests consume one protocol instead
of special-casing the substrate, and the streaming-tenancy metrics (SLO
attainment, weighted-slowdown percentiles, sliding-window steady-state
stats) are defined exactly once.

:class:`TaskRecord` lives here too (it is the execution trace both
substrates emit); ``core/simulator.py`` re-exports it for existing
imports.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Sequence

from .workflow import WorkflowStats, weighted_slowdown

__all__ = ["TaskRecord", "RunResult", "PerfCounters",
           "per_pool_task_counts"]


def per_pool_task_counts(records: "Sequence[TaskRecord]") -> dict[str, int]:
    """How many tasks each pool of the allocation executed."""
    out: dict[str, int] = {}
    for r in records:
        out[r.pool] = out.get(r.pool, 0) + 1
    return out


@dataclasses.dataclass(frozen=True)
class TaskRecord:
    set_name: str
    index: int
    start: float
    end: float
    cpus: int
    gpus: int
    duplicate: bool = False
    #: name of the pool the task was placed on ("" for legacy records)
    pool: str = ""
    #: True when the task was preempted + migrated off a straggling pool
    #: (``pool`` is the pool it finally completed on)
    migrated: bool = False
    #: node index within the pool the winning attempt ran on (-1 on
    #: aggregate pools — see ``PoolSpec.node_level``)
    node: int = -1
    #: owning workflow of a campaign run ("" for single-workflow runs)
    workflow: str = ""

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class PerfCounters:
    """Wall-time attribution of one run's hot loop
    (``RunConfig.perf_counters=True``; all zeros otherwise unused).

    The buckets partition the substrate's event loop: ``engine_s`` is
    dispatch passes (``try_start`` + elastic/watchdog scans),
    ``predict_s`` is ``SchedEngine.repredict``, ``metrics_s`` is
    streaming-summary folding, and ``events_s`` is the remaining loop
    wall time (heap pops, event bookkeeping).  ``predicts`` counts
    *evaluated* predictions — throttled/deduped ``repredict`` calls that
    returned a cached prediction are excluded, which is how benchmarks
    attribute the prediction-epoch win."""

    engine_s: float = 0.0
    predict_s: float = 0.0
    events_s: float = 0.0
    metrics_s: float = 0.0
    total_s: float = 0.0
    passes: int = 0
    predicts: int = 0
    events: int = 0


@dataclasses.dataclass
class RunResult:
    """What one run produced, whichever substrate executed it.

    Every field a substrate does not fill keeps its default — e.g. a
    single-workflow simulator run has ``workflows=None`` and all
    fault/lease counters at zero.  Both substrates construct their
    results keyword-only, so subclass field ordering is not part of the
    protocol."""

    makespan: float
    records: "list[TaskRecord]"
    mode: str = "async"
    tasks_total: int = 0
    #: scheduling policy used (see sched_engine.SCHEDULING_POLICIES)
    policy: str = "fifo"
    #: straggler preemption + migration count (runtime feedback enabled)
    migrations: int = 0
    #: speculative-duplicate launches (first finisher wins, loser freed)
    speculations: int = 0
    #: mid-run makespan re-predictions (``SchedEngine.repredict`` trace,
    #: feedback enabled; see ``core/predictor.py``)
    predictions: list = dataclasses.field(default_factory=list)
    #: per-workflow metrics of a campaign/stream run (None otherwise);
    #: see ``core/workflow.WorkflowStats``
    workflows: "dict[str, WorkflowStats] | None" = None
    #: task sets the admission controller deferred at least once
    admission_deferrals: int = 0
    #: workflows preemptively un-admitted for a deadline admit
    admission_revocations: int = 0
    #: fault injection (``faults=FaultOptions(...)``): applied node losses,
    #: software task failures, and the recovery arms taken per failure
    node_failures: int = 0
    task_failures: int = 0
    recoveries_restart: int = 0
    recoveries_rerun: int = 0
    #: proactive at-risk replications launched (``FaultOptions.replicate``)
    replications: int = 0
    #: the engine's failure trace: (time, kind, detail...) tuples
    fault_log: list = dataclasses.field(default_factory=list)
    #: elastic capacity (``RunConfig.elastic``): leases granted / expired
    #: and the (time, event, node) lease trace
    leases_granted: int = 0
    leases_expired: int = 0
    lease_log: list = dataclasses.field(default_factory=list)
    #: open-stream conservation partition (``stream_accounting``; None
    #: for closed campaigns / single workflows)
    stream: "dict | None" = None
    #: bounded streaming-summary accumulators
    #: (``RunConfig.record_policy="summary"``; ``core/metrics.py``).
    #: When set, ``records``/``workflows`` are empty and the metric
    #: surface below answers from the sketches instead.
    metrics: "object | None" = None
    #: hot-loop wall-time attribution (``RunConfig.perf_counters=True``)
    perf: "PerfCounters | None" = None

    # -- shared metric surface ---------------------------------------------
    def throughput(self) -> float:
        return self.tasks_total / self.makespan if self.makespan else 0.0

    def weighted_slowdown(self) -> "float | None":
        """Fairness-weighted mean slowdown of a campaign run (None for
        single-workflow runs or when no reference makespans are set)."""
        if not self.workflows:
            if self.metrics is not None:
                return self.metrics.weighted_slowdown()
            return None
        return weighted_slowdown(self.workflows)

    def workflow_records(self, name: str) -> "list[TaskRecord]":
        """The trace of one campaign workflow's tasks."""
        return [r for r in self.records if r.workflow == name]

    def per_pool_task_counts(self) -> dict[str, int]:
        return per_pool_task_counts(self.records)

    # -- streaming / SLO metrics -------------------------------------------
    # Repeated queries are the common shape (bench_check walks every
    # percentile of every baseline), so the sorted slowdown view and the
    # window buckets are memoized lazily on the instance; the memos
    # assume ``workflows`` is not mutated after the first query, which
    # both substrates guarantee (results are built once, at the end).
    def _slowdown_view(self):
        view = self.__dict__.get("_slow_view")
        if view is None:
            pts = sorted((w.slowdown, w.weight)
                         for w in (self.workflows or {}).values()
                         if w.slowdown is not None and w.weight > 0)
            cum: list[float] = []
            acc = 0.0
            for _s, wt in pts:
                acc += wt
                cum.append(acc)
            view = self.__dict__["_slow_view"] = (pts, cum)
        return view

    def slo_attainment(self) -> "float | None":
        """Fraction of deadline-carrying workflows that finished by their
        deadline (None when no workflow carries one)."""
        if not self.workflows:
            if self.metrics is not None:
                return self.metrics.slo_attainment()
            return None
        ws = [w for w in self.workflows.values()
              if w.deadline is not None]
        if not ws:
            return None
        return sum(1 for w in ws if w.met_deadline) / len(ws)

    def slowdown_percentile(self, q: float) -> "float | None":
        """Weight-respecting percentile of the per-workflow slowdowns
        (``q`` in [0, 1]; e.g. 0.99 for the P99 tail): the smallest
        slowdown at which the cumulative ``WorkflowEntry.weight`` mass
        reaches ``q``.  None when no workflow carries a
        ``reference_makespan``."""
        if not self.workflows and self.metrics is not None:
            return self.metrics.slowdown_percentile(q)
        pts, cum = self._slowdown_view()
        if not pts:
            return None
        # bisect over the cumulative mass == the linear acc-walk this
        # replaced (first point with acc >= q*total - 1e-12), minus the
        # per-call re-sort and re-scan
        idx = bisect.bisect_left(cum, q * cum[-1] - 1e-12)
        if idx >= len(pts):
            return pts[-1][0]
        return pts[idx][0]

    def window_stats(self, window: float) -> "list[dict]":
        """Steady-state view: workflows bucketed by *finish* time into
        consecutive windows of ``window`` modelled seconds; per window the
        finished count, SLO attainment and P50/P99 weighted slowdown (the
        streaming replacement for one end-of-run makespan).  Empty
        windows are omitted.  Summary-mode results
        (``record_policy="summary"``) answer from their fixed-width
        accumulators and reject any other ``window``."""
        if window <= 0:
            raise ValueError("window must be > 0")
        if not self.workflows and self.metrics is not None:
            if window != self.metrics.window:
                raise ValueError(
                    f"summary-mode run accumulated window={self.metrics.window}"
                    f" buckets; cannot re-bucket to window={window}")
            return self.metrics.window_stats()
        memo = self.__dict__.setdefault("_window_memo", {})
        out = memo.get(window)
        if out is not None:
            return out
        buckets: dict[int, list[WorkflowStats]] = {}
        for w in (self.workflows or {}).values():
            if w.tasks <= 0:
                continue  # never started (e.g. still deferred at the end)
            buckets.setdefault(int(w.finish // window), []).append(w)
        out = []
        for b in sorted(buckets):
            ws = buckets[b]
            sub = RunResult(makespan=0.0, records=[],
                            workflows={w.name: w for w in ws})
            out.append(dict(
                t0=b * window, t1=(b + 1) * window, finished=len(ws),
                slo_attainment=sub.slo_attainment(),
                p50_slowdown=sub.slowdown_percentile(0.50),
                p99_slowdown=sub.slowdown_percentile(0.99)))
        memo[window] = out
        return out

"""Task-set dependency graphs (DGs) and the dependency-permitted degree of
asynchronicity (DOA_dep) from §5.1 of the paper.

A workflow is a DAG whose nodes are *task sets* (groups of identical tasks
that may execute concurrently, e.g. "all 96 Simulation tasks") and whose
edges are data dependencies.  Task-set indices are ordered breadth-first, as
in the paper's Fig. 2 / Fig. 3.

``DOA_dep`` is defined by the paper as "the number of independent execution
branches minus 1".  Operationally we count branches as::

    branches = (#source nodes) + sum_v max(0, outdeg(v) - 1)
                                - sum_v max(0, indeg(v) - 1)

i.e. every fork with diverging paths opens a new branch and every
convergence closes one.  This reproduces the paper's published values for
every DG it analyses: Fig. 2a -> 0, Fig. 2b -> 1, Fig. 2d -> n,
Fig. 3a (DeepDriveMD, 3 staggered iterations) -> 2, and Fig. 3b -> 2.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Iterable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class TaskSet:
    """A set of identical tasks (one DG node).

    Attributes mirror the paper's Table 1 / Table 2 columns.

    ``tx_mean`` is the mean task execution time (TX) in seconds; actual TX
    values are sampled as ``N(tx_mean, tx_sigma)`` with an *absolute* sigma
    of 0.05 s (Table 2: "sampled from N(mu, sigma=0.05)") to "mimic the
    stochastic behaviour of actual executables".  Set a larger ``tx_sigma``
    to study noisy tasks / stragglers.
    """

    name: str
    num_tasks: int
    cpus_per_task: int
    gpus_per_task: int
    tx_mean: float
    tx_sigma: float = 0.05
    #: payload factory: called as payload(task_index) -> None to run a real
    #: task body (e.g. a jitted JAX step) in the RealExecutor.  The analytic
    #: model and the discrete-event simulator never call it.
    payload: Callable[[int], object] | None = None
    #: task type tag (``simulation`` | ``aggregation`` | ``training`` |
    #: ``inference`` | ...), used for reporting and adaptive policies.
    kind: str = "generic"

    @property
    def full_set_cpus(self) -> int:
        return self.num_tasks * self.cpus_per_task

    @property
    def full_set_gpus(self) -> int:
        return self.num_tasks * self.gpus_per_task

    def with_(self, **kw) -> "TaskSet":
        return dataclasses.replace(self, **kw)


class DAG:
    """A directed acyclic graph of :class:`TaskSet` nodes."""

    def __init__(self, task_sets: Iterable[TaskSet] = (),
                 edges: Iterable[tuple[str, str]] = ()):
        self._nodes: dict[str, TaskSet] = {}
        self._children: dict[str, list[str]] = {}
        self._parents: dict[str, list[str]] = {}
        #: memoized structural traversals (topo order, ranks, branches);
        #: invalidated on node/edge mutation.  The online predictor
        #: re-evaluates Eqns. 2-6 every scheduling pass, so these being
        #: O(V+E)-once instead of O(V+E)-per-call matters.
        self._struct_cache: dict = {}
        for ts in task_sets:
            self.add(ts)
        for u, v in edges:
            self.add_edge(u, v)

    # -- construction -----------------------------------------------------
    def add(self, ts: TaskSet) -> TaskSet:
        if ts.name in self._nodes:
            raise ValueError(f"duplicate task set {ts.name!r}")
        self._nodes[ts.name] = ts
        self._children[ts.name] = []
        self._parents[ts.name] = []
        self._struct_cache.clear()
        return ts

    def add_edge(self, parent: str, child: str) -> None:
        if parent not in self._nodes or child not in self._nodes:
            raise KeyError(f"unknown task set in edge ({parent!r}, {child!r})")
        if child in self._children[parent]:
            return
        self._children[parent].append(child)
        self._parents[child].append(parent)
        self._struct_cache.clear()
        if self._has_cycle():
            self._children[parent].remove(child)
            self._parents[child].remove(parent)
            self._struct_cache.clear()
            raise ValueError(f"edge ({parent!r}, {child!r}) creates a cycle")

    def replace(self, name: str, **kw) -> None:
        self._nodes[name] = self._nodes[name].with_(**kw)

    # -- queries ----------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    def __len__(self) -> int:
        return len(self._nodes)

    def node(self, name: str) -> TaskSet:
        return self._nodes[name]

    @property
    def nodes(self) -> Mapping[str, TaskSet]:
        return dict(self._nodes)

    def children(self, name: str) -> Sequence[str]:
        return tuple(self._children[name])

    def parents(self, name: str) -> Sequence[str]:
        return tuple(self._parents[name])

    def edges(self) -> list[tuple[str, str]]:
        return [(u, v) for u, cs in self._children.items() for v in cs]

    def sources(self) -> list[str]:
        return [n for n in self._nodes if not self._parents[n]]

    def sinks(self) -> list[str]:
        return [n for n in self._nodes if not self._children[n]]

    def _has_cycle(self) -> bool:
        try:
            self.topological_order()
            return False
        except ValueError:
            return True

    def topological_order(self) -> list[str]:
        cached = self._struct_cache.get("topo")
        if cached is not None:
            return list(cached)
        indeg = {n: len(ps) for n, ps in self._parents.items()}
        q = deque(sorted(n for n, d in indeg.items() if d == 0))
        out: list[str] = []
        while q:
            n = q.popleft()
            out.append(n)
            for c in self._children[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    q.append(c)
        if len(out) != len(self._nodes):
            raise ValueError("graph has a cycle")
        self._struct_cache["topo"] = out
        return list(out)

    def ranks(self) -> dict[str, int]:
        """Breadth-first rank of each task set (paper Fig. 2/3 y-axis)."""
        cached = self._struct_cache.get("ranks")
        if cached is not None:
            return dict(cached)
        r: dict[str, int] = {}
        for n in self.topological_order():
            ps = self._parents[n]
            r[n] = 0 if not ps else 1 + max(r[p] for p in ps)
        self._struct_cache["ranks"] = r
        return dict(r)

    def rank_groups(self) -> list[list[str]]:
        """Task sets grouped by rank, rank-ascending (PST stages)."""
        cached = self._struct_cache.get("rank_groups")
        if cached is not None:
            return [list(g) for g in cached]
        r = self.ranks()
        depth = max(r.values(), default=-1) + 1
        groups: list[list[str]] = [[] for _ in range(depth)]
        for n in self.topological_order():
            groups[r[n]].append(n)
        self._struct_cache["rank_groups"] = groups
        return [list(g) for g in groups]

    # -- the paper's §5.1 -------------------------------------------------
    def _chains_and_union(self) -> tuple[list[list[str]], dict[str, int], list[int]]:
        """DFS branch discovery.

        Returns ``(chains, owner, uf)`` where ``chains`` are the maximal
        fork-opened chains, ``owner[name]`` the chain id a task set was
        discovered on, and ``uf`` a union-find over chain ids in which the
        chains of converging sub-paths (nodes with indeg > 1) have been
        merged — converging paths must synchronise at the join, so they are
        not *independent* branches in the paper's sense.
        """
        chains: list[list[str]] = []
        owner: dict[str, int] = {}
        uf: list[int] = []

        def find(x: int) -> int:
            while uf[x] != x:
                uf[x] = uf[uf[x]]
                x = uf[x]
            return x

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                uf[max(ra, rb)] = min(ra, rb)

        for n in self.topological_order():
            ps = self._parents[n]
            if not ps:
                owner[n] = len(chains)
                chains.append([n])
                uf.append(len(uf))
                continue
            first = ps[0]
            b = owner[first]
            if self._children[first].index(n) == 0 and chains[b][-1] == first:
                owner[n] = b
                chains[b].append(n)
            else:
                owner[n] = len(chains)
                chains.append([n])
                uf.append(len(uf))
            if len(ps) > 1:  # a join: converging branches collapse into one
                for p in ps:
                    union(owner[p], owner[n])
        # path-compress all
        for i in range(len(uf)):
            uf[i] = find(i)
        return chains, owner, uf

    def branches(self) -> list[list[str]]:
        """Maximal fork-opened chains (pre-join-merge); see `branch_ids`."""
        return self._chains_and_union()[0]

    def branch_ids(self) -> dict[str, int]:
        """Final independent-branch id per task set (joins merged)."""
        cached = self._struct_cache.get("branch_ids")
        if cached is not None:
            return dict(cached)
        _, owner, uf = self._chains_and_union()
        out = {n: uf[b] for n, b in owner.items()}
        self._struct_cache["branch_ids"] = out
        return dict(out)

    def num_branches(self) -> int:
        """Number of independent execution branches (see module docstring).

        Equals ``#sources + sum max(0, outdeg-1) - sum max(0, indeg-1)`` on
        graphs without redundant joins; computed robustly via union-find.
        """
        if not self._nodes:
            return 0
        return len(set(self.branch_ids().values()))

    def doa_dep(self) -> int:
        """Dependency-permitted degree of asynchronicity (paper §5.1)."""
        return max(0, self.num_branches() - 1)

    def critical_path_tx(self) -> float:
        """Lower bound on makespan: longest tx_mean-weighted path."""
        best: dict[str, float] = {}
        for n in self.topological_order():
            ps = self._parents[n]
            base = max((best[p] for p in ps), default=0.0)
            best[n] = base + self._nodes[n].tx_mean
        return max(best.values(), default=0.0)

    def total_tx(self) -> float:
        return sum(ts.tx_mean for ts in self._nodes.values())

    def validate(self) -> None:
        self.topological_order()
        for ts in self._nodes.values():
            if ts.num_tasks <= 0:
                raise ValueError(f"{ts.name}: num_tasks must be positive")
            if ts.tx_mean < 0:
                raise ValueError(f"{ts.name}: negative TX")
            if ts.cpus_per_task < 0 or ts.gpus_per_task < 0:
                raise ValueError(f"{ts.name}: negative resources")

    def copy(self) -> "DAG":
        g = DAG()
        for ts in self._nodes.values():
            g.add(ts)
        for u, v in self.edges():
            g.add_edge(u, v)
        return g

    def with_sequential_barriers(
            self, stage_groups: Sequence[Sequence[str]] | None = None) -> "DAG":
        """Return the BSP/sequential version of this DG: an edge from every
        task set in stage s to every task set in stage s+1 (PST stage
        barriers), which is how the paper's sequential mode executes.

        ``stage_groups`` overrides the default rank-per-stage mapping; the
        paper's c-DG workflows use one stage per task *type* group
        (T0 | {T1,T2} | {T3,T6} | {T4,T5} | T7), which is how their
        sequential TTX sums to ~2000 s.
        """
        g = self.copy()
        groups = [list(s) for s in (stage_groups or g.rank_groups())]
        for a, b in zip(groups, groups[1:]):
            for u in a:
                for v in b:
                    try:
                        g.add_edge(u, v)
                    except ValueError:
                        pass  # edge already implied; never cycles by stage order
        return g

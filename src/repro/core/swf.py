"""Standard Workload Format (SWF) trace replay: real cluster logs as
campaigns and arrival streams.

Every committed baseline runs the paper's three synthetic drivers; the
Parallel Workloads Archive's SWF traces (the accasim exemplar drives its
whole simulator from ``HPC2N-2002-2.2.1-cln.swf``) are how pilot-job
systems are validated against decades of real arrival processes.  This
module parses SWF and maps trace jobs onto the repo's source
abstractions so the six policies x admission x faults x elastic knobs
can be exercised on real workloads (``core/scenarios.py`` composes the
result into the scenario matrix).

SWF recap (v2.2): lines starting with ``;`` are header directives
(``; MaxNodes: 120``); every other non-blank line is one job of 18
whitespace-separated integer fields, with ``-1`` marking "unknown".
The fields this loader consumes, and where they land:

======  ==================  =============================================
field   SWF meaning         mapped to
======  ==================  =============================================
1       job number          ``WorkflowEntry`` name (``job<N>``)
2       submit time (s)     arrival (shifted so the first kept job
                            arrives at 0, then / ``time_scale``)
3       wait time (s)       optional per-job deadline slack
                            (``deadline_slack`` knob)
4       run time (s)        ``TaskSet.tx_mean`` — the TX prior the
                            policies / ``TxEstimator`` start from
5       allocated procs     task footprint over the target pool: procs
                            become CPU cores, split into node-bounded
                            tasks (``cpus_per_proc`` knob)
8       requested procs     fallback when field 5 is ``-1``/0
9       requested time      kept on :class:`SWFJob` (user's estimate)
11      status              ``keep_statuses`` filter (1 = completed,
                            0 = failed, 5 = cancelled, -1 = unknown)
======  ==================  =============================================

Degenerate jobs — zero/``-1`` runtimes (cancelled jobs), zero-width
footprints — are *clamped or dropped at load time*
(:attr:`SWFMapOptions.on_degenerate`): a replayed job can never reach
``TxEstimator`` / ``MakespanPredictor`` as a zero-TX or zero-width set
(``DAG.validate`` would reject it anyway; the loader enforces it with
trace-aware semantics instead of a crash deep in the engine).

Down-sampling is seeded and documented: with ``sample < 1`` each kept
job is an independent ``random.Random(seed)`` Bernoulli draw *in trace
order*, then ``max_jobs`` truncates — so a decades-long trace replays
in bounded wall time while two runs with the same options replay the
identical job subset.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import IO, Iterable, Sequence

from .dag import DAG, TaskSet
from .resources import Allocation, PoolSpec
from .stream import WorkflowStream
from .workflow import Campaign, WorkflowEntry

__all__ = ["SWFJob", "SWFTrace", "SWFMapOptions", "parse_swf", "load_swf",
           "swf_entries", "swf_campaign", "swf_stream"]

#: SWF job status codes (field 11)
SWF_COMPLETED = 1
SWF_FAILED = 0
SWF_CANCELLED = 5


@dataclasses.dataclass(frozen=True)
class SWFJob:
    """One parsed SWF trace record (raw field values, ``-1`` preserved)."""

    job_id: int
    submit: float
    wait: float
    run_time: float
    procs: int
    req_procs: int
    req_time: float
    status: int
    user: int
    group: int
    queue: int
    partition: int


@dataclasses.dataclass(frozen=True)
class SWFTrace:
    """A parsed trace: header directives + jobs, in file order."""

    header: "tuple[tuple[str, str], ...]"
    jobs: "tuple[SWFJob, ...]"

    def directive(self, key: str, default: "str | None" = None
                  ) -> "str | None":
        """Header directive value by (case-insensitive) key."""
        for k, v in self.header:
            if k.lower() == key.lower():
                return v
        return default

    def __len__(self) -> int:
        return len(self.jobs)


def _num(tok: str) -> float:
    try:
        return float(tok)
    except ValueError:
        return -1.0


def parse_swf(source: "Iterable[str] | IO[str]") -> SWFTrace:
    """Parse SWF lines: ``; Key: value`` headers, 18-field job records.

    Tolerant by design — archive traces carry short rows, stray comment
    styles and out-of-spec status codes: rows shorter than 18 fields are
    right-padded with ``-1``, non-numeric fields read as ``-1``, and
    nothing is filtered here (mapping applies ``SWFMapOptions``)."""
    header: list[tuple[str, str]] = []
    jobs: list[SWFJob] = []
    for line in source:
        line = line.strip()
        if not line:
            continue
        if line.startswith(";"):
            body = line.lstrip("; \t")
            if ":" in body:
                k, _, v = body.partition(":")
                if k.strip():
                    header.append((k.strip(), v.strip()))
            continue
        f = [_num(tok) for tok in line.split()]
        f += [-1.0] * (18 - len(f))
        jobs.append(SWFJob(
            job_id=int(f[0]), submit=f[1], wait=f[2], run_time=f[3],
            procs=int(f[4]), req_procs=int(f[7]), req_time=f[8],
            status=int(f[10]), user=int(f[11]), group=int(f[12]),
            queue=int(f[14]), partition=int(f[15])))
    return SWFTrace(header=tuple(header), jobs=tuple(jobs))


def load_swf(path: str) -> SWFTrace:
    """Parse the SWF trace file at ``path``."""
    with open(path) as fh:
        return parse_swf(fh)


@dataclasses.dataclass(frozen=True)
class SWFMapOptions:
    """Knobs of the trace-job -> workflow mapping (all seeded draws come
    from one ``random.Random(seed)``, so the mapping is a pure function
    of (trace, pool, options))."""

    #: seeded down-sampling: keep each job independently with this
    #: probability, drawn in trace order (1.0 = keep every job)
    sample: float = 1.0
    #: seed of the down-sampling / GPU-mix draws
    seed: int = 0
    #: keep at most this many jobs after thinning (None = no cap)
    max_jobs: "int | None" = None
    #: divide all trace times (submit offsets, runtimes, waits) by this
    #: factor — a months-long trace replays in bounded modelled time
    time_scale: float = 1.0
    #: SWF statuses to replay (None = all); default: completed jobs only
    keep_statuses: "tuple[int, ...] | None" = (SWF_COMPLETED,)
    #: degenerate jobs (runtime <= 0 or ``-1``, zero/``-1`` footprint):
    #: ``"clamp"`` repairs them (runtime -> ``min_runtime``, footprint ->
    #: 1 proc), ``"drop"`` skips them, ``"error"`` raises ``ValueError``
    on_degenerate: str = "clamp"
    #: clamp floor for degenerate runtimes, in trace seconds
    #: (pre-``time_scale``); must be > 0 — zero-TX sets are unmappable
    min_runtime: float = 1.0
    #: modelled CPU cores per trace processor (footprint coarsening)
    cpus_per_proc: float = 1.0
    #: seeded fraction of jobs replayed as GPU jobs (a hybrid AI-HPC mix
    #: on GPU pools): a GPU job's tasks also hold GPUs pro-rata to their
    #: node share.  Ignored on pools without GPUs.
    gpu_fraction: float = 0.0
    #: per-job SLO from the trace's own queueing behaviour: deadline =
    #: arrival + ``deadline_slack`` x (wait + run time) (None = no SLOs)
    deadline_slack: "float | None" = None

    def __post_init__(self) -> None:
        if not 0.0 < self.sample <= 1.0:
            raise ValueError(f"sample must be in (0, 1], got {self.sample}")
        if self.time_scale <= 0:
            raise ValueError("time_scale must be > 0")
        if self.on_degenerate not in ("clamp", "drop", "error"):
            raise ValueError(
                f"unknown on_degenerate {self.on_degenerate!r}; "
                f"known: 'clamp', 'drop', 'error'")
        if self.min_runtime <= 0:
            raise ValueError("min_runtime must be > 0 (zero-TX task sets "
                             "cannot be estimated or predicted)")
        if self.cpus_per_proc <= 0:
            raise ValueError("cpus_per_proc must be > 0")


def _target_pool(pool: "PoolSpec | Allocation") -> PoolSpec:
    if isinstance(pool, Allocation):
        # footprints are sized to the widest node so every job fits
        # somewhere; placement across the pools stays the engine's call
        return max(pool.pools, key=lambda p: p.node_cpu_capacity)
    return pool


def swf_entries(trace: SWFTrace, pool: "PoolSpec | Allocation",
                options: SWFMapOptions = SWFMapOptions()
                ) -> "list[WorkflowEntry]":
    """Map trace jobs to arrival-ordered single-set workflow entries.

    Each kept job becomes one ``WorkflowEntry`` named ``job<id>`` whose
    DAG holds a single ``TaskSet``: the job's processors become
    ``ceil(procs * cpus_per_proc)`` cores split into node-bounded tasks
    over ``pool``, and its runtime becomes the set's ``tx_mean`` — the
    TX prior every policy and the ``TxEstimator`` start from.  The
    loader guarantees every emitted set has ``tx_mean > 0``,
    ``num_tasks >= 1`` and ``cpus_per_task >= 1`` (degenerate trace
    rows are clamped/dropped per :attr:`SWFMapOptions.on_degenerate`)."""
    p = _target_pool(pool)
    cap = p.node_cpu_capacity
    if cap <= 0:
        raise ValueError(f"pool {p.name!r} has no usable cores per node")
    rng = random.Random(options.seed)
    kept: list[SWFJob] = []
    for job in trace.jobs:
        # one Bernoulli draw PER TRACE JOB, filtered or not: the replayed
        # subset at a given seed is stable under keep_statuses changes
        take = options.sample >= 1.0 or rng.random() < options.sample
        if (options.keep_statuses is not None
                and job.status not in options.keep_statuses):
            continue
        if take:
            kept.append(job)
    if options.max_jobs is not None:
        kept = kept[:options.max_jobs]
    if not kept:
        return []
    t0 = min(j.submit for j in kept if j.submit >= 0)
    entries: list[WorkflowEntry] = []
    for job in kept:
        run = job.run_time
        procs = job.procs if job.procs > 0 else job.req_procs
        if run <= 0 or procs <= 0:
            if options.on_degenerate == "error":
                raise ValueError(
                    f"degenerate SWF job {job.job_id}: run_time="
                    f"{job.run_time}, procs={job.procs} "
                    f"(req {job.req_procs}) — zero-TX / zero-width sets "
                    f"cannot be replayed (on_degenerate='error')")
            if options.on_degenerate == "drop":
                continue
            run = max(run, options.min_runtime)
            procs = max(procs, 1)
        cores = max(1, math.ceil(procs * options.cpus_per_proc))
        num_tasks = max(1, math.ceil(cores / cap))
        cpus_per_task = max(1, math.ceil(cores / num_tasks))
        gpus_per_task = 0
        if options.gpu_fraction > 0 and p.node.gpus > 0:
            if rng.random() < options.gpu_fraction:
                gpus_per_task = max(
                    1, round(cpus_per_task / cap * p.node.gpus))
        tx = run / options.time_scale
        arrival = max(0.0, (job.submit - t0)) / options.time_scale
        wait = max(0.0, job.wait) / options.time_scale
        deadline = None
        if options.deadline_slack is not None:
            deadline = arrival + options.deadline_slack * (wait + tx)
        g = DAG()
        g.add(TaskSet("job", num_tasks, cpus_per_task, gpus_per_task, tx,
                      kind="swf"))
        entries.append(WorkflowEntry(
            f"job{job.job_id}", g, arrival=arrival, deadline=deadline,
            reference_makespan=tx))
    entries.sort(key=lambda e: (e.arrival, e.name))
    return entries


def swf_campaign(trace: SWFTrace, pool: "PoolSpec | Allocation",
                 options: SWFMapOptions = SWFMapOptions(),
                 name: str = "swf") -> Campaign:
    """The trace as a *closed* campaign (arrival-gated, known up front)."""
    entries = swf_entries(trace, pool, options)
    if not entries:
        raise ValueError("no SWF jobs survived filtering/down-sampling")
    return Campaign(entries, name=name)


def swf_stream(trace: SWFTrace, pool: "PoolSpec | Allocation",
               options: SWFMapOptions = SWFMapOptions(),
               name: str = "swf") -> WorkflowStream:
    """The trace as an *open* arrival stream (consumed incrementally)."""
    entries = swf_entries(trace, pool, options)
    if not entries:
        raise ValueError("no SWF jobs survived filtering/down-sampling")
    return WorkflowStream(entries, name=name)

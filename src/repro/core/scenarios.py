"""Named workload scenarios: trace replay + adversarial generation.

The committed baselines exercise the paper's three synthetic drivers;
this module is the scenario-diversity multiplier the ROADMAP calls for.
A :class:`Scenario` is a *frozen, named, reproducible spec* — pure data,
no callables — and :class:`ScenarioGenerator` turns (spec, seed) into
everything a run needs: the pool, the workload (an open
``GeneratedStream``, an SWF replay, or a closed campaign), the simulator
physics (``SimOptions``), and the fault schedule.  Same spec + same seed
=> bit-identical workload and dispatch, across both substrates and
``RunConfig.incremental`` modes (pinned by ``tests/test_scenarios.py``).

Three scenario families:

- **replay** (``arrival="swf"``) — real cluster logs through
  ``core/swf.py``: the committed ``tests/data/hpc2n_head.swf`` fixture
  by default, any Parallel Workloads Archive trace via
  :attr:`Scenario.swf_path`.
- **service mixes** (``poisson`` / ``diurnal``) — the serving-fleet
  streams the streaming-tenancy PR introduced, as named specs.
- **adversarial** — seeded stress compositions aimed at the machinery's
  weak points: ``bursty-heavytail`` (burst arrival clumps x lognormal
  heavy-tail TX — straggler mitigation and prediction under fat tails),
  ``fragmenting-footprints`` (node-level GPU pool with widths chosen so
  greedy placement strands capacity — ``nodepack`` vs ``gpu_bestfit``),
  and ``failure-storm`` (a trace-driven burst of node losses mid-run on
  top of a stochastic hazard — priced recovery under correlated
  failures).

``benchmarks/bench_scenarios.py`` sweeps all six policies x admission x
feedback over :data:`SCENARIOS` and commits the policy-selection table
as the ninth gated baseline (``benchmarks/baseline/scenarios.json``).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Sequence

from .dag import DAG, TaskSet
from .estimator import FeedbackOptions
from .resources import NodeSpec, PoolSpec
from .runconfig import RunConfig
from .sched_engine import AdmissionOptions
from .simulator import SimOptions, SimResult, simulate
from .stream import GeneratedStream, StreamTemplate, WorkflowStream
from .swf import SWFMapOptions, load_swf, swf_campaign, swf_stream
from .workflow import Campaign
from ..runtime.fault import FaultOptions

__all__ = ["Scenario", "ScenarioGenerator", "SCENARIOS", "run_scenario"]

#: repo-relative default SWF fixture (the truncated HPC2N head committed
#: for tier-1; resolved against the repo root when cwd isn't it)
DEFAULT_SWF = os.path.join("tests", "data", "hpc2n_head.swf")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named, frozen, reproducible workload spec (pure data — every
    field is JSON-serializable, so a spec can be logged and replayed)."""

    name: str
    #: arrival process: ``poisson`` | ``diurnal`` | ``bursty`` (the
    #: ``GeneratedStream`` kinds) or ``swf`` (trace replay)
    arrival: str = "poisson"
    description: str = ""
    #: workload template palette for generated arrivals:
    #: ``serving`` | ``heavy_tail`` | ``fragmenting``
    palette: str = "serving"
    #: deliver the workload as a closed ``Campaign`` instead of an open
    #: stream (arrivals still gate dispatch; admission control applies)
    closed: bool = False
    # -- pool ------------------------------------------------------------
    #: pool shape: ``pool_nodes`` x (``node_cpus``, ``node_gpus``)
    pool_nodes: int = 6
    node_cpus: int = 32
    node_gpus: int = 4
    #: per-node placement + concrete node choice (``PoolSpec.node_level``)
    node_level: bool = False
    # -- generated arrivals ---------------------------------------------
    #: mean arrival rate (1/s) and stream horizon (modelled s)
    rate: float = 1.0 / 75.0
    horizon: float = 1500.0
    #: diurnal modulation (``GeneratedStream`` knobs)
    period: float = 1800.0
    peak_ratio: float = 4.0
    #: bursty clumping (``GeneratedStream`` knobs)
    burst_size: int = 4
    burst_spread: float = 30.0
    # -- task-duration physics (SimOptions) ------------------------------
    #: ``normal`` is the paper's N(mu, 0.05); ``lognormal`` has the heavy
    #: right tail (sigma_log = ``tail_sigma``) adversarial mixes want
    tx_distribution: str = "normal"
    tail_sigma: float = 0.0
    # -- fault composition (FaultOptions) --------------------------------
    #: trace-driven node-failure storm: ``storm_nodes`` losses starting
    #: at ``storm_at``, spaced ``storm_spacing`` s (None = no storm)
    storm_at: "float | None" = None
    storm_nodes: int = 2
    storm_spacing: float = 10.0
    #: modelled seconds a stormed node stays down
    storm_recovery: float = 300.0
    #: stochastic per-node-per-second hazard on top of the storm
    failure_rate: float = 0.0
    # -- SWF replay (arrival="swf") --------------------------------------
    #: trace path (None = the committed ``tests/data`` fixture)
    swf_path: "str | None" = None
    #: forwarded to ``SWFMapOptions``: seeded thinning probability,
    #: post-thinning cap, time compression, hybrid GPU-job fraction
    swf_sample: float = 1.0
    swf_max_jobs: "int | None" = None
    swf_time_scale: float = 1.0
    swf_gpu_fraction: float = 0.0

    def __post_init__(self) -> None:
        if self.arrival not in ("poisson", "diurnal", "bursty", "swf"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if self.palette not in ("serving", "heavy_tail", "fragmenting"):
            raise ValueError(f"unknown template palette {self.palette!r}")


# -- template palettes ------------------------------------------------------
def _two_set(name, n1, c1, g1, tx1, n2, c2, g2, tx2) -> DAG:
    g = DAG()
    g.add(TaskSet(f"{name}_a", n1, c1, g1, tx1))
    g.add(TaskSet(f"{name}_b", n2, c2, g2, tx2))
    g.add_edge(f"{name}_a", f"{name}_b")
    return g


def _one_set(name, n, c, gp, tx) -> DAG:
    g = DAG()
    g.add(TaskSet(name, n, c, gp, tx))
    return g


def _palette(scenario: Scenario) -> "list[StreamTemplate]":
    gpus = scenario.node_gpus > 0
    if scenario.palette == "serving":
        return [
            StreamTemplate("decode",
                           _two_set("dec", 6, 2, 1 if gpus else 0, 24.0,
                                    1, 2, 0, 8.0),
                           deadline_slack=420.0, reference_makespan=95.0,
                           share=3.0),
            StreamTemplate("embed", _one_set("emb", 4, 4, 0, 15.0),
                           reference_makespan=40.0, share=2.0),
            StreamTemplate("train",
                           _two_set("trn", 3, 4, 2 if gpus else 0, 110.0,
                                    1, 4, 0, 20.0),
                           priority=1, reference_makespan=260.0,
                           share=1.0),
        ]
    if scenario.palette == "heavy_tail":
        return [
            StreamTemplate("short", _one_set("sh", 3, 2, 0, 6.0),
                           reference_makespan=16.0, share=6.0),
            StreamTemplate("long",
                           _one_set("lg", 2, 8, 1 if gpus else 0, 180.0),
                           reference_makespan=220.0, share=1.0),
            StreamTemplate("wide",
                           _two_set("wd", 4, 6, 1 if gpus else 0, 45.0,
                                    1, 4, 0, 30.0),
                           reference_makespan=140.0, share=1.0),
        ]
    # fragmenting: widths chosen so greedy GPU placement strands
    # capacity on 6-GPU nodes (4-GPU residents leave 2-GPU holes no
    # 3-GPU task fits; 1-GPU fillers then pin the holes open)
    return [
        StreamTemplate("resident", _one_set("res", 1, 8, 4, 60.0),
                       reference_makespan=85.0, share=2.0),
        StreamTemplate("odd", _one_set("odd", 1, 6, 3, 45.0),
                        reference_makespan=65.0, share=2.0),
        StreamTemplate("filler", _two_set("fil", 2, 2, 1, 20.0,
                                          1, 2, 0, 6.0),
                       reference_makespan=55.0, share=3.0),
    ]


#: the named scenario matrix (benchmarks sweep exactly these)
SCENARIOS: "dict[str, Scenario]" = {s.name: s for s in (
    Scenario(
        name="steady-mix",
        description="memoryless serving mix at moderate load — the "
                    "sanity row every policy should handle"),
    Scenario(
        name="diurnal-serving", arrival="diurnal", rate=1.0 / 110.0,
        peak_ratio=5.0,
        description="day/night load swing over the serving palette — "
                    "the elastic-capacity / admission sweet spot"),
    Scenario(
        name="bursty-heavytail", arrival="bursty", palette="heavy_tail",
        pool_nodes=2, rate=1.0 / 12.0, burst_size=5, burst_spread=20.0,
        tx_distribution="lognormal", tail_sigma=0.9,
        description="adversarial: arrival clumps x lognormal TX tails on "
                    "a saturated 2-node slice — stragglers dominate, "
                    "size-based orders backfire, estimates mislead"),
    Scenario(
        name="fragmenting-footprints", palette="fragmenting",
        node_cpus=16, node_gpus=6, node_level=True, pool_nodes=2,
        rate=1.0 / 8.0, horizon=900.0,
        description="adversarial: widths that strand GPU holes on a "
                    "saturated node-level pool — placement policies "
                    "separate sharply"),
    Scenario(
        name="failure-storm", palette="serving", pool_nodes=3,
        rate=1.0 / 12.0, storm_at=400.0, storm_nodes=2,
        storm_recovery=400.0, failure_rate=2e-6,
        description="adversarial: correlated node losses mid-run on a "
                    "loaded slice, on top of a background hazard — "
                    "priced recovery vs rerun under queueing"),
    Scenario(
        name="swf-hpc2n", arrival="swf", closed=True,
        pool_nodes=8, node_cpus=32, node_gpus=0,
        swf_time_scale=20.0, swf_max_jobs=24,
        description="replay: the committed HPC2N trace head as a closed "
                    "campaign (real sizes, arrivals and runtimes)"),
)}


def _resolve_swf(path: "str | None") -> str:
    p = path or DEFAULT_SWF
    if os.path.isabs(p) or os.path.exists(p):
        return p
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, p)


class ScenarioGenerator:
    """Materialize a :class:`Scenario` at one seed.

    Every stochastic choice (arrival draws, template mix, SWF
    down-sampling, TX sampling, failure injection) is derived from
    ``seed`` through the respective component's own ``random.Random`` —
    the generator holds no hidden state, so two generators with equal
    (spec, seed) produce interchangeable workloads."""

    def __init__(self, scenario: "Scenario | str", seed: int = 0):
        if isinstance(scenario, str):
            scenario = SCENARIOS[scenario]
        self.scenario = scenario
        self.seed = seed

    def pool(self) -> PoolSpec:
        s = self.scenario
        return PoolSpec("sc", s.pool_nodes,
                        NodeSpec(cpus=s.node_cpus, gpus=s.node_gpus),
                        node_level=s.node_level)

    def workload(self) -> "WorkflowStream | Campaign":
        s = self.scenario
        if s.arrival == "swf":
            trace = load_swf(_resolve_swf(s.swf_path))
            opts = SWFMapOptions(
                sample=s.swf_sample, seed=self.seed,
                max_jobs=s.swf_max_jobs, time_scale=s.swf_time_scale,
                gpu_fraction=s.swf_gpu_fraction)
            make = swf_campaign if s.closed else swf_stream
            return make(trace, self.pool(), opts, name=s.name)
        stream = GeneratedStream(
            _palette(s), rate=s.rate, horizon=s.horizon, seed=self.seed,
            kind=s.arrival, period=s.period, peak_ratio=s.peak_ratio,
            burst_size=s.burst_size, burst_spread=s.burst_spread,
            name=s.name)
        if s.closed:
            return Campaign(stream.entries, name=s.name)
        return stream

    def sim_options(self) -> SimOptions:
        s = self.scenario
        if s.tx_distribution == "lognormal":
            return SimOptions(seed=self.seed, tx_distribution="lognormal",
                              lognormal_sigma=s.tail_sigma)
        return SimOptions(seed=self.seed)

    def faults(self) -> "FaultOptions | None":
        s = self.scenario
        if s.storm_at is None and s.failure_rate <= 0:
            return None
        trace = ()
        if s.storm_at is not None:
            trace = tuple(
                (s.storm_at + i * s.storm_spacing, "sc", i % s.pool_nodes)
                for i in range(s.storm_nodes))
        return FaultOptions(node_failure_rate=s.failure_rate,
                            node_failure_trace=trace,
                            node_recovery_time=s.storm_recovery,
                            seed=self.seed)

    def run_config(self, *, policy: str = "fifo", admission: bool = False,
                   feedback: bool = False, **over) -> RunConfig:
        return RunConfig(
            scheduling=policy,
            admission=AdmissionOptions() if admission else None,
            feedback=FeedbackOptions() if feedback else None,
            faults=self.faults(), **over)

    def run(self, *, policy: str = "fifo", admission: bool = False,
            feedback: bool = False, **over) -> SimResult:
        """One simulator run of the scenario at this seed."""
        return simulate(self.workload(), self.pool(),
                        options=self.sim_options(),
                        config=self.run_config(policy=policy,
                                               admission=admission,
                                               feedback=feedback, **over))


def run_scenario(name: "str | Scenario", *, policy: str = "fifo",
                 admission: bool = False, feedback: bool = False,
                 seed: int = 0, **over) -> SimResult:
    """Convenience one-liner: materialize and simulate a named scenario."""
    return ScenarioGenerator(name, seed).run(
        policy=policy, admission=admission, feedback=feedback, **over)

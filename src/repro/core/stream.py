"""Open-ended workflow arrival streams: the service-tenancy source.

A :class:`~repro.core.workflow.Campaign` is a *closed* set of workflows
known up front; a production service (the RHAPSODY service-ification of
the paper's execution model) faces an unbounded arrival process where
the scheduler never sees "the whole DAG".  :class:`WorkflowStream` is
the source abstraction both substrates consume *incrementally*: the
engine only ever holds the arrived prefix
(:meth:`~repro.core.sched_engine.SchedEngine.add_workflow` merges each
arrival into the live state), and admission / prediction / metrics all
operate on what has actually arrived.

Two concrete sources:

- :class:`GeneratedStream` — seeded arrival-process generators over
  *templated* workflows (:class:`StreamTemplate`): ``poisson`` (memoryless
  arrivals at a constant rate), ``diurnal`` (sinusoidal rate modulation
  via thinning — the day/night load swing elastic capacity follows), and
  ``bursty`` (Poisson burst epochs each spawning a clump of arrivals).
  Optional ``periodic`` templates emit fixed-cadence jobs (e.g. the
  recurring training runs of a serving fleet) on top of the stochastic
  process.  All arrivals are drawn eagerly at construction from one
  ``random.Random(seed)`` so a stream is reproducible and substrate
  independent.
- :class:`CampaignStream` — the adapter that wraps any closed
  ``Campaign`` as a stream, making existing callers a special case.
  Substrates detect :attr:`WorkflowStream.closed_campaign` and route to
  the closed-campaign path *verbatim*, so wrapping is bit-identical to
  passing the campaign directly (the closed path's predictions may peek
  at not-yet-arrived entries — that lookahead is exactly what an open
  stream forbids and what keeps the committed baselines byte-stable).

The driving workload is the repo's serving stack: `launch/serve.py` /
`examples/serve_batch.py` shape the inference templates
(`benchmarks/bench_streaming.py` models their batch-decode jobs), and
`examples/stream_tenancy.py` is the end-to-end quickstart.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Sequence

from .dag import DAG
from .workflow import Campaign, CampaignView, WorkflowEntry

__all__ = ["WorkflowStream", "CampaignStream", "GeneratedStream",
           "StreamTemplate", "prefix_view"]


@dataclasses.dataclass(frozen=True)
class StreamTemplate:
    """One workflow *shape* a :class:`GeneratedStream` instantiates.

    ``dag`` may be a DAG (shared by every instance — instances are
    namespaced by the campaign merge, the template DAG is never mutated)
    or a zero-argument factory returning one.  ``deadline_slack`` turns
    each arrival into an SLO: ``deadline = arrival + deadline_slack``.
    ``share`` weights the seeded template choice for stochastic
    arrivals."""

    name: str
    dag: "DAG | Callable[[], DAG]"
    priority: int = 0
    weight: float = 1.0
    #: per-arrival SLO: deadline = arrival + slack (None = no deadline)
    deadline_slack: "float | None" = None
    #: dedicated single-tenant makespan (slowdown denominator)
    reference_makespan: "float | None" = None
    #: relative frequency among the stream's stochastic templates
    share: float = 1.0

    def build_dag(self) -> DAG:
        return self.dag() if callable(self.dag) else self.dag

    def instantiate(self, k: int, arrival: float) -> WorkflowEntry:
        deadline = (arrival + self.deadline_slack
                    if self.deadline_slack is not None else None)
        return WorkflowEntry(
            f"{self.name}-{k:04d}", self.build_dag(),
            priority=self.priority, arrival=arrival, deadline=deadline,
            weight=self.weight, reference_makespan=self.reference_makespan)


class WorkflowStream:
    """Base class: an ordered source of :class:`WorkflowEntry` arrivals.

    Consumption protocol (both substrates):

    - :meth:`next_arrival` — the arrival time of the earliest
      *unconsumed* entry (``None`` when the stream is exhausted);
    - :meth:`take_until` — pop every entry with ``arrival <= t``, in
      arrival order (each entry is returned exactly once).

    :attr:`closed_campaign` is the adapter escape hatch: when it returns
    a ``Campaign``, substrates run the closed-campaign path unchanged
    instead of consuming incrementally."""

    name = "stream"

    def __init__(self, entries: Sequence[WorkflowEntry], name: str = "stream"):
        self.name = name
        self._entries = sorted(entries, key=lambda e: (e.arrival, e.name))
        self._next = 0

    @property
    def closed_campaign(self) -> "Campaign | None":
        """The wrapped closed campaign, or ``None`` for open streams."""
        return None

    @property
    def entries(self) -> "tuple[WorkflowEntry, ...]":
        """Every entry the stream will ever emit (generators draw their
        whole horizon eagerly), regardless of consumption state."""
        return tuple(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def next_arrival(self) -> "float | None":
        """Arrival time of the next unconsumed entry (None when
        drained).  Never consumes."""
        if self._next >= len(self._entries):
            return None
        return self._entries[self._next].arrival

    def take_until(self, t: float) -> "list[WorkflowEntry]":
        """Consume and return every entry with ``arrival <= t``.

        The boundary is INCLUSIVE, and consumers must honour it at
        exact timestamp collisions: a workflow arriving at time ``t``
        is schedulable in the *same* dispatch pass as any task
        completion at ``t`` — both substrates drain the stream up to
        ``now`` before allocating freed capacity (the collision
        regression in ``tests/test_streaming.py`` pins this for the
        simulator's re-pushed stream sentinel)."""
        out = []
        while (self._next < len(self._entries)
               and self._entries[self._next].arrival <= t):
            out.append(self._entries[self._next])
            self._next += 1
        return out

    def reset(self) -> None:
        """Rewind consumption (a stream object is otherwise single-use)."""
        self._next = 0


class CampaignStream(WorkflowStream):
    """A closed :class:`Campaign` viewed as a stream (finite, known up
    front).  Substrates short-circuit on :attr:`closed_campaign`, so
    running ``simulate(CampaignStream(c), ...)`` is bit-identical to
    ``simulate(c, ...)``; the incremental protocol is still implemented
    for generic stream consumers (tests, conservation checks)."""

    def __init__(self, campaign: Campaign):
        super().__init__(campaign.workflows, name=campaign.name)
        self._campaign = campaign

    @property
    def closed_campaign(self) -> Campaign:
        return self._campaign


class GeneratedStream(WorkflowStream):
    """Seeded arrival-process generator over workflow templates.

    ``kind``:

    - ``"poisson"`` — exponential inter-arrivals at ``rate`` (1/s);
    - ``"diurnal"`` — inhomogeneous Poisson by thinning: the rate swings
      sinusoidally between ``rate`` and ``rate * peak_ratio`` with
      period ``period`` (peak at t = period/4);
    - ``"bursty"`` — burst epochs arrive Poisson at ``rate /
      burst_size``; each epoch spawns ``burst_size`` arrivals spread by
      Exp(mean ``burst_spread``) offsets (mean arrival rate stays
      ``rate``).

    Stochastic arrivals pick a template by seeded weighted ``share``
    choice.  ``periodic`` adds deterministic fixed-cadence instances:
    each ``(template, every)`` pair emits at ``every, 2*every, ...`` up
    to the horizon.  All randomness comes from ``random.Random(seed)``
    at construction — the arrival schedule is a pure function of the
    arguments."""

    def __init__(self, templates: Sequence[StreamTemplate], rate: float,
                 horizon: float, *, seed: int = 0, kind: str = "poisson",
                 period: float = 1800.0, peak_ratio: float = 4.0,
                 burst_size: int = 4, burst_spread: float = 30.0,
                 periodic: "Sequence[tuple[StreamTemplate, float]]" = (),
                 name: str = "stream"):
        if kind not in ("poisson", "diurnal", "bursty"):
            raise ValueError(f"unknown arrival kind {kind!r}")
        if rate <= 0 and not periodic:
            raise ValueError("stream needs rate > 0 or periodic templates")
        rng = random.Random(seed)
        times: list[float] = []
        if rate > 0:
            if kind == "poisson":
                t = rng.expovariate(rate)
                while t < horizon:
                    times.append(t)
                    t += rng.expovariate(rate)
            elif kind == "diurnal":
                # thinning against the peak rate; the accepted process
                # has instantaneous rate lam(t)
                lam_max = rate * peak_ratio
                t = rng.expovariate(lam_max)
                while t < horizon:
                    phase = 0.5 * (1.0 + math.sin(2.0 * math.pi * t / period))
                    lam = rate * (1.0 + (peak_ratio - 1.0) * phase)
                    if rng.random() < lam / lam_max:
                        times.append(t)
                    t += rng.expovariate(lam_max)
            else:  # bursty
                t = rng.expovariate(rate / burst_size)
                while t < horizon:
                    for _ in range(burst_size):
                        a = t + rng.expovariate(1.0 / burst_spread)
                        if a < horizon:
                            times.append(a)
                    t += rng.expovariate(rate / burst_size)
                times.sort()
        shares = [max(0.0, tp.share) for tp in templates]
        entries: list[WorkflowEntry] = []
        counts: dict[str, int] = {}
        for t in times:
            tp = rng.choices(list(templates), weights=shares)[0]
            k = counts[tp.name] = counts.get(tp.name, 0) + 1
            entries.append(tp.instantiate(k - 1, t))
        for tp, every in periodic:
            if every <= 0:
                raise ValueError(f"{tp.name}: periodic cadence must be > 0")
            t = every
            while t < horizon:
                k = counts[tp.name] = counts.get(tp.name, 0) + 1
                entries.append(tp.instantiate(k - 1, t))
                t += every
        super().__init__(entries, name=name)
        self.kind = kind
        self.horizon = horizon


def prefix_view(entries: Sequence[WorkflowEntry],
                name: str = "stream") -> CampaignView:
    """The merged engine-facing view of an arrived prefix.  Identical to
    ``Campaign(entries).view()`` but tolerates an *empty* prefix (an
    open stream may start with nothing arrived at t = 0)."""
    if entries:
        return Campaign(entries, name=name).view()
    return CampaignView(name, DAG(), {}, {}, {}, {}, ())

"""Shared scheduling engine: one ready-queue + dependency + resource
bookkeeping core consumed by BOTH the discrete-event simulator
(`repro.core.simulator`) and the real thread-level executor
(`repro.core.executor`), so the two substrates cannot drift.

This mirrors the separation RADICAL-Pilot makes between the *scheduler*
(which task goes where, when) and the *execution substrate* (how it runs):
the engine owns

- the per-set ready queues and the set-/task-level dependency counters;
- per-pool resource accounting over a heterogeneous
  :class:`~repro.core.resources.Allocation` (GPU nodes + CPU-only nodes,
  each with its own oversubscription flags and placement constraints);
- the pluggable :class:`SchedulingPolicy` deciding (a) the order in which
  ready task sets are offered resources and (b) on which pool each task is
  placed.

The substrates only decide *when* completions happen (simulated clock vs
wall clock) and feed them back via :meth:`SchedEngine.complete`.

Policies (registry: ``SCHEDULING_POLICIES``; table mirrored in DESIGN.md)
--------
``fifo``         rank/topo FIFO with backfilling — the behaviour both
                 substrates hard-coded before this engine existed, and the
                 closest analogue of the paper's EnTK/RP agent scheduler.
``lpt``          largest-TX-first (longest processing time): ready sets with
                 the largest mean task duration are offered resources first,
                 the classic makespan heuristic for malleable bags of tasks.
                 Consults the *observed* TX estimate when runtime feedback
                 is enabled.
``gpu_bestfit``  GPU-aware best fit: GPU task sets are placed first on the
                 pool whose free GPUs they fill tightest; CPU-only tasks are
                 packed *around* them, preferring GPU-less pools so GPU-node
                 cores stay available for GPU-task co-scheduling.
``locality``     data-movement-aware placement: each task scores every
                 eligible pool by the cost of pulling its parents' outputs
                 there (the allocation's ``transfer_cost`` matrix) plus a
                 queue-depth penalty, and waits for its cheapest pool unless
                 an idling pool may *steal* it (bounded steals per dispatch
                 pass).
``nodepack``     NVLink-aware packing for node-level pools
                 (``PoolSpec.node_level``): multi-GPU sets first, each task
                 into the tightest NVLink group that fits it, candidate
                 pools scored by fragmentation (largest contiguous free GPU
                 block) — preserving whole nodes/groups for wide tasks.
                 Other policies on node-level pools keep the RM-default
                 *spread* node choice, which fragments under mixed widths.
``priority``     workflow-priority-first ordering for multi-tenant
                 campaigns: higher-priority workflows' sets are offered
                 resources first, ties broken by arrival time then
                 rank/topo (fifo within one workflow).  Degenerates to
                 ``fifo`` outside a campaign (every set has priority 0).

Multi-workflow tenancy + prediction-driven admission
----------------------------------------------------
Constructed with a :class:`~repro.core.workflow.CampaignView` the engine
schedules several concurrent workflows over one allocation: a set may not
start before its workflow's *arrival* time (both substrates pass their
clock into :meth:`SchedEngine.startable`), and with
``admission=AdmissionOptions(...)`` an admission controller decides, per
scheduling pass, which newly-ready task sets join the dispatch frontier:

- sets of the highest-priority workflow still in flight always admit;
- *narrow* sets backfill into fragmentation holes (one task fits the
  current ``largest_free_block`` and the set's remaining strict demand is
  a small fraction of the free capacity);
- wide lower-priority sets are *priced* with the online predictor
  (``core/predictor.py``): three snapshots bound the admitted
  workflows' remaining work alone, the candidate's alone, and both
  combined under cross-workflow contention; ``i_adm = 1 - combined /
  (admitted + alone)`` is Eqn. 5 at admission granularity.  When it
  collapses below ``i_floor`` AND the candidate's task TX exceeds
  ``hold_ratio`` x the admitted work's largest task TX (non-preemptible
  head-of-line blocking), the set is deferred and re-priced on every
  later pass;
- deferred work is never lost: when nothing is running and no admitted
  set can start, the best deferred set is admitted unconditionally, and
  ``max_defer_time`` optionally ages any deferral into an admission.

Streaming tenancy (``core/stream.py``)
--------------------------------------
With an open :class:`~repro.core.stream.WorkflowStream` the engine never
sees "the whole DAG": substrates merge each arrival into the live state
through :meth:`SchedEngine.add_workflow` (dependency counters, ready
queues, priority order, incremental indexes, predictor snapshots — all
extended in place), and admission prices only the arrived prefix.  Three
extensions serve SLOs:

- *deadline-aware admission* (``AdmissionOptions.deadline_aware``): a
  priced defer is overridden once the candidate's dedicated residual no
  longer fits before its ``WorkflowEntry.deadline`` plus margin;
- *preemptive revocation* (``AdmissionOptions.revoke``): such a deadline
  admit may un-admit one not-yet-started lower-priority workflow
  (:meth:`SchedEngine.revoke_workflow`; started workflows are never
  revoked, revoked work re-enters the deferred pool);
- *elastic capacity* (``elastic=ElasticOptions(...)``): one node-level
  pool grows by whole-node leases while queued strict demand outruns its
  usable free capacity and shrinks at lease expiry — idle nodes retire
  at once, busy ones drain and retire on their last release, so expiry
  never strands a placed task (:meth:`SchedEngine.elastic_pass`; the
  aggregate-counter/index invariants hold across every resize and are
  asserted by :meth:`SchedEngine.check_index_integrity`).

:meth:`SchedEngine.stream_accounting` reports the conservation partition
(arrived == finished + admitted + deferred + queued) the invariant suite
drives random streams against.

Admission-deferred sets are also *preempted ahead of running-task
migration* in the arbiter's cost model: their queued tasks do not count
as slot pressure (deferral already absorbed them), so the arbiter
prefers the free speculative duplicate over paying migration costs when
the only queued work is deferred.

Node-level topology (``core/resources.py``)
-------------------------------------------
Pools with ``node_level=True`` are accounted node-granularly
(:class:`~repro.core.resources.NodeState`): a task must fit on ONE node
(an aggregate-only co-fit is honestly rejected — fragmentation), every
placement carries a concrete node id (``SchedEngine.node_placement``,
``TaskRecord.node``), and straggler migration/speculation land on
concrete nodes too — including same-pool cross-node migration, priced by
the topology distances of :meth:`~repro.core.resources.Allocation.transfer`
(same NVLink group <= same node <= intra-pool <= cross-pool).  The
aggregate ``free_cpus``/``free_gpus`` counters remain a derived view, so
aggregate pools behave bit-identically.

Runtime feedback (``core/estimator.py``)
----------------------------------------
Constructed with ``feedback=FeedbackOptions(...)``, the engine keeps a
per-set (and, with ``per_pool``, per-(set, pool)) online TX estimate
(EWMA mean + variance over completions fed in via
:meth:`SchedEngine.observe`); :meth:`SchedEngine.tx_estimate` serves
policies the observed mean once a set has ``min_samples`` completions and
the static ``tx_mean`` prior before that, and the set priority order is
recomputed whenever estimates move.  :meth:`SchedEngine.stragglers` flags
running tasks whose runtime exceeds ``mean + k*sigma`` of the running
estimate (the task's *pool* estimate when armed, so a uniformly slow pool
is not mass-flagged), and two mitigations compete:

- :meth:`SchedEngine.try_migrate` preempts + requeues the task onto a
  different pool — releasing the source pool's resources, charging
  ``migration_base_cost + transfer_cost[src][dst]``;
- :meth:`SchedEngine.try_speculate` launches a duplicate attempt on a
  pool with a *free* slot (the original keeps running; first finisher
  wins, the loser is cancelled and its slot freed).

Both no-op when the cost exceeds the expected benefit (``max_cost_ratio``
x estimated TX), no pool fits, or the task hit its per-task cap.  With
both enabled, :meth:`SchedEngine.arbitrate` picks per straggler by the
predictor's marginal-makespan delta (``core/predictor.py``): each
action's ``cost + fresh rerun TX`` against the straggler's expected
remaining runtime if left alone; ties prefer migration (it frees the
straggler's slot, speculation spends an extra one).

Predictive control plane (``core/predictor.py``)
------------------------------------------------
With feedback enabled the engine owns a :class:`MakespanPredictor`;
:meth:`SchedEngine.repredict` re-evaluates the paper's Eqns. 2-6 on the
live estimates at every scheduling pass and appends to
``SchedEngine.predictions`` (surfaced as ``SimResult.predictions`` /
``ExecResult.predictions``).

Incremental pass structures (default; ``incremental=False`` restores the
brute-force scans)
-------------------
Pass cost is proportional to *what changed*, not to cluster size:

- every (pool, footprint-class) pair — a footprint class is one distinct
  strict ``(need_cpus, need_gpus)`` demand — keeps the set of nodes that
  currently fit it, updated in O(#classes) whenever a node's occupancy
  changes (``_acquire``/``_release``/``complete``), so ``_candidates`` is
  O(#eligible pools) per task instead of O(#nodes);
- ``largest_free_block`` reads a bucket-counted maximum over per-node
  free-block sizes (O(1) query, O(block width) update);
- the default *spread* node choice pops a lazy per-pool max-heap keyed by
  ``(-free_gpus, -free_cpus, node)`` instead of scanning every node
  (policies overriding ``choose_node`` still receive the indexed —
  sorted, hence bit-identical — fitting-node list);
- sets whose last offer found no candidate pool are *blocked* and skipped
  by ``startable`` until an occupancy release flips one of their
  footprint classes back to fitting (event-driven dirty tracking).

Every structure agrees with a brute-force recount at all times
(:meth:`SchedEngine.check_index_integrity`; property-tested in
``tests/test_invariants.py``), and the dispatch sequence is bit-identical
to the ``incremental=False`` scans — ``benchmarks/bench_engine_scale.py``
asserts both, and gates decisions/sec at 10^4-10^5 tasks on 10^2-10^3
nodes.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Sequence

from ..runtime.fault import FaultOptions
from .dag import DAG, TaskSet
from .estimator import FeedbackOptions, TxEstimator
from .predictor import MakespanPrediction, MakespanPredictor
from .resources import (Allocation, ElasticOptions, NodeState, PoolSpec,
                        as_allocation, node_states)
from .workflow import WORKFLOW_SEP, CampaignView, WorkflowEntry


@dataclasses.dataclass(frozen=True)
class AdmissionOptions:
    """Knobs of the prediction-driven admission controller (campaign runs
    only; see the module docstring for the decision order)."""

    #: defer a wide lower-priority set when admitting it would leave the
    #: predicted degree of asynchronicity of the combined work (Eqn. 5
    #: over the candidate-next-to-admitted vs candidate-after-admitted
    #: residuals) below this floor...
    i_floor: float = 0.05
    #: ... and the set's tasks, once started, would pin their devices
    #: across many of the admitted work's scheduling rounds: estimated
    #: candidate TX > ``hold_ratio`` x the admitted work's largest task
    #: TX (tasks are not preemptible — a long wide task admitted into a
    #: ragged wave tail blocks the next waves of everything above it).
    hold_ratio: float = 3.0
    #: a set is narrow (backfills unconditionally) when its remaining
    #: strict demand fits in this fraction of the free capacity and one
    #: task fits the current largest free GPU block.
    backfill_fraction: float = 0.5
    #: age any deferral into an admission after this long (``inf`` = only
    #: the idle-admission conservation guard ends a deferral).
    max_defer_time: float = math.inf
    #: price SLOs into the defer decision: a priced-path defer is
    #: overridden when the candidate workflow's *deadline* no longer fits
    #: its dedicated residual (plus margin) — deferring would turn a
    #: likely miss into a certain one.  Off by default so deadline-blind
    #: runs (every committed baseline) stay bit-identical.
    deadline_aware: bool = False
    #: safety margin of the miss test, as a fraction of the candidate's
    #: dedicated residual: admit on deadline when
    #: ``deadline - now - alone.remaining <= margin * alone.remaining``.
    deadline_margin: float = 0.25
    #: with ``deadline_aware``: a deadline-driven admission may *revoke*
    #: (un-admit, back to deferred) one strictly-lower-priority admitted
    #: workflow none of whose tasks have started, freeing the frontier
    #: for the urgent arrival.  Started workflows are never revoked.
    revoke: bool = False


@dataclasses.dataclass(frozen=True)
class SetInfo:
    """The static per-task-set facts a policy may order by."""

    name: str
    rank: int
    topo: int
    num_tasks: int
    cpus: int
    gpus: int
    tx_mean: float
    kind: str
    #: workflow admission priority (campaign runs; 0 otherwise)
    priority: int = 0
    #: workflow arrival time (campaign runs; 0.0 otherwise)
    arrival: float = 0.0


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """What a :meth:`SchedEngine.fail_node` / :meth:`~SchedEngine.fail_task`
    call did, for the substrate to mirror onto its attempt bookkeeping:

    - ``failed`` — attempts whose slots were released and whose tasks were
      re-enqueued (the substrate invalidates their in-flight events);
    - ``promoted`` — tasks whose primary attempt died but whose replica /
      speculative duplicate survives on another node: the duplicate's slot
      became the primary allocation, the task is NOT re-enqueued, and the
      substrate re-labels the duplicate's completion as the primary's;
    - ``cancelled`` — tasks whose *duplicate* died while the primary keeps
      running: the duplicate's slot was released, nothing re-enqueued.
    """

    kind: str  # "node" | "task"
    pool: int = -1
    node: int = -1
    failed: tuple = ()
    promoted: tuple = ()
    cancelled: tuple = ()


class SchedulingPolicy:
    """Strategy interface: set priority + per-task pool placement.

    ``order_sets`` fixes the priority in which ready sets are offered free
    resources (backfilling walks this order and starts whatever fits).
    ``choose_pool`` picks among the pools that can start one task of ``ts``
    right now; it is only consulted when more than one pool fits.  A policy
    may return ``None`` to *defer* the task (hold it for a pool that is
    currently busy — see ``locality``); the engine re-offers it on the next
    dispatch pass.  ``begin_pass`` is called once at the start of every
    :meth:`SchedEngine.startable` pass (for per-pass budgets).

    When runtime feedback is enabled the ``SetInfo.tx_mean`` values passed
    to ``order_sets`` are the engine's *observed* estimates
    (:meth:`SchedEngine.tx_estimate`), not the static priors.
    """

    name = "base"
    #: True when ``order_sets`` reads ``SetInfo.tx_mean`` — only such
    #: policies need their priority rebuilt as TX observations arrive.
    uses_tx = False

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        raise NotImplementedError

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> "int | None":
        return candidates[0]

    def choose_node(self, ts: TaskSet, pool_idx: int,
                    nodes: Sequence[int],
                    engine: "SchedEngine") -> int:
        """Node choice within a ``node_level`` pool, among the nodes that
        can start one task of ``ts`` right now.  The default *spreads*
        (most free GPUs, then most free cores — the load-balancing
        behaviour typical resource managers default to); ``nodepack``
        overrides it to pack.  Only consulted for node-level pools."""
        states = engine.node_states[pool_idx]
        return min(nodes, key=lambda n: (-states[n].free_gpus,
                                         -states[n].free_cpus, n))

    def begin_pass(self, engine: "SchedEngine") -> None:
        pass


class FifoBackfill(SchedulingPolicy):
    """Rank/topo FIFO with backfilling (the pre-engine behaviour)."""

    name = "fifo"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in sorted(sets, key=lambda s: (s.rank, s.topo))]


class LargestTxFirst(SchedulingPolicy):
    """LPT: among ready sets, largest mean task duration first."""

    name = "lpt"
    uses_tx = True

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (-s.tx_mean, s.rank, s.topo))]


class GpuAwareBestFit(SchedulingPolicy):
    """GPU sets first (widest footprint first), best-fit pool placement;
    CPU-only tasks pack around GPU tasks on GPU-less pools when possible."""

    name = "gpu_bestfit"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (s.gpus == 0, -s.gpus,
                                            s.rank, s.topo))]

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> int:
        if ts.gpus_per_task > 0:
            # tightest GPU fit: least free GPUs left after placement
            return min(candidates,
                       key=lambda k: (engine.free_gpus[k] - ts.gpus_per_task,
                                      engine.free_cpus[k]))
        # CPU-only: prefer pools without GPUs, then tightest CPU fit
        return min(candidates,
                   key=lambda k: (engine.pools[k].total.gpus > 0,
                                  engine.free_cpus[k] - ts.cpus_per_task))


class LocalityAware(SchedulingPolicy):
    """Data-movement-aware placement with bounded work stealing.

    Each task scores every eligible pool by ``data_cost + queue_weight x
    running-task count``, where ``data_cost`` is the mean cost of pulling
    the task's parent outputs to that pool (the allocation's
    ``transfer_cost`` matrix weighted by where the parent tasks actually
    ran — see :meth:`SchedEngine.data_cost`).  On a ``node_level`` pool
    the score is node-granular: the best-achievable
    :meth:`~repro.core.resources.Allocation.transfer` topology distance
    over the pool's nodes (same NVLink group <= same node <= intra-pool),
    and the node choice itself minimises the same distance — instead of
    reading only the pool-level ``transfer_cost`` matrix, which prices
    every same-pool placement at zero.  If the cheapest pool has
    free capacity the task is placed there; otherwise an *idling* pool
    (free capacity, higher data cost) may steal it, but only
    ``steal_budget`` times per dispatch pass — beyond that the task waits
    for its data-local pool.  With no ``transfer_cost`` matrix the score
    degenerates to queue depth, i.e. pure load balancing."""

    name = "locality"

    def __init__(self, queue_weight: float = 0.1, steal_budget: int = 4):
        self.queue_weight = queue_weight
        self.steal_budget = steal_budget
        self._steals_left = steal_budget

    def begin_pass(self, engine: "SchedEngine") -> None:
        self._steals_left = self.steal_budget

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in sorted(sets, key=lambda s: (s.rank, s.topo))]

    def _score(self, ts: TaskSet, k: int, engine: "SchedEngine") -> float:
        return (engine.best_data_cost(ts.name, k)
                + self.queue_weight * engine.running_per_pool[k])

    def choose_node(self, ts: TaskSet, pool_idx: int,
                    nodes: Sequence[int],
                    engine: "SchedEngine") -> int:
        """Data-local node choice: the fitting node with the cheapest
        node-granular parent-output pull, spread tie-break."""
        states = engine.node_states[pool_idx]
        return min(nodes, key=lambda n: (engine.data_cost(ts.name, pool_idx,
                                                          node=n),
                                         -states[n].free_gpus,
                                         -states[n].free_cpus, n))

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> "int | None":
        eligible = [k for k, p in enumerate(engine.pools) if p.accepts(ts)]
        best = min(eligible, key=lambda k: (self._score(ts, k, engine), k))
        if best in candidates:
            return best
        # the data-local pool is busy: steal onto an idling pool if the
        # per-pass budget allows, else hold the task for the local pool
        if self._steals_left > 0:
            self._steals_left -= 1
            return min(candidates, key=lambda k: (self._score(ts, k, engine),
                                                  k))
        return None


class NodePackTopology(SchedulingPolicy):
    """Topology-aware packing for ``node_level`` pools (``nodepack``).

    Ordering is ``gpu_bestfit``'s (GPU sets first, widest first) so
    multi-GPU tasks claim contiguous blocks before narrow fillers scatter.
    Placement packs: a task lands in the *tightest* NVLink group that
    fits it (single-node, single-group when possible), and candidate
    pools are scored by fragmentation — prefer a single-group fit with
    the least leftover, then the pool whose largest contiguous free GPU
    block is smallest (placing there preserves the other pools' big
    blocks for wider tasks).  On aggregate pools it degenerates to
    ``gpu_bestfit`` placement."""

    name = "nodepack"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (s.gpus == 0, -s.gpus,
                                            s.rank, s.topo))]

    @staticmethod
    def _node_key(ts: TaskSet, states: "Sequence[NodeState]", n: int,
                  engine: "SchedEngine", k: int) -> tuple:
        ns = states[n]
        need_c, need_g = engine._needs(k, ts)
        if need_g:
            gi = ns.best_group(need_g)
            if gi is not None:  # single NVLink group: leftover = shrink
                return (0, ns.group_free[gi] - need_g, ns.free_gpus, n)
            return (1, ns.free_gpus - need_g, ns.free_gpus, n)
        return (0, ns.free_cpus - need_c, 0, n)

    def choose_node(self, ts: TaskSet, pool_idx: int,
                    nodes: Sequence[int],
                    engine: "SchedEngine") -> int:
        states = engine.node_states[pool_idx]
        return min(nodes, key=lambda n: self._node_key(ts, states, n,
                                                       engine, pool_idx))

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> int:
        def key(k: int) -> tuple:
            states = engine.node_states[k]
            if states is None:  # aggregate pool: gpu_bestfit placement
                if ts.gpus_per_task > 0:
                    return (2, engine.free_gpus[k] - ts.gpus_per_task,
                            engine.free_cpus[k], k)
                # CPU-only: prefer GPU-less pools, then tightest CPU fit
                return (2, engine.pools[k].total.gpus > 0,
                        engine.free_cpus[k] - ts.cpus_per_task, k)
            nodes = engine.fitting_nodes(k, ts)
            best = min(self._node_key(ts, states, n, engine, k)
                       for n in nodes)
            return (best[0], best[1], engine.largest_free_block(k), k)
        return min(candidates, key=key)


class CampaignPriority(SchedulingPolicy):
    """Workflow-priority-first ordering for campaigns (``priority``):
    higher-priority workflows' sets are offered resources first, ties
    broken by arrival time then rank/topo — fifo within one workflow.
    Outside a campaign every set carries priority 0 / arrival 0, so the
    order degenerates to ``fifo``."""

    name = "priority"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (-s.priority, s.arrival,
                                            s.rank, s.topo))]


SCHEDULING_POLICIES: dict[str, type[SchedulingPolicy]] = {
    FifoBackfill.name: FifoBackfill,
    LargestTxFirst.name: LargestTxFirst,
    GpuAwareBestFit.name: GpuAwareBestFit,
    LocalityAware.name: LocalityAware,
    NodePackTopology.name: NodePackTopology,
    CampaignPriority.name: CampaignPriority,
}


def get_scheduling_policy(
        policy: "str | SchedulingPolicy") -> SchedulingPolicy:
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return SCHEDULING_POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; "
            f"known: {sorted(SCHEDULING_POLICIES)}") from None


@dataclasses.dataclass
class _FitClass:
    """Incremental fit state of one (pool, footprint-class) pair.

    A footprint class is one distinct strict ``(need_cpus, need_gpus)``
    demand on the pool (after oversubscription zeroing), shared by every
    task set with that demand.  ``nodes`` is the live set of node indexes
    that fit the class (``None`` on aggregate pools, where an O(1)
    counter check replaces it); ``fits`` tracks the aggregate-counter fit
    so a release can detect the unfit -> fit transition that unblocks the
    class's waiting sets."""

    need_c: int
    need_g: int
    fits: bool = True
    #: node indexes currently fitting (node-level pools; None = aggregate)
    nodes: "set[int] | None" = None
    #: names of the task sets with this footprint on this pool
    sets: list = dataclasses.field(default_factory=list)
    #: the subset of ``sets`` currently parked in the engine's blocked
    #: set — what an unfit -> fit transition actually has to wake, so
    #: the unblock stays O(blocked-on-this-class) instead of re-scanning
    #: every set the class ever held (``sets`` only ever grows)
    blocked: set = dataclasses.field(default_factory=set)


@dataclasses.dataclass(frozen=True)
class PredictOptions:
    """Prediction-epoch throttling of :meth:`SchedEngine.repredict`
    (``RunConfig.predict``).

    By default the substrates re-evaluate the paper's Eqns. 2-6 on
    nearly every heap event; at trace scale the prediction becomes the
    hot loop.  These knobs gate re-evaluation on *material* state
    change instead: ``dirty_only`` skips when the engine's prediction
    stamp (the admission-pricing epoch plus every counter a prediction
    input can move through — launches, migrations, speculations,
    failures, recoveries, leases) is unchanged since the last evaluated
    prediction, and ``min_interval`` adds a modelled-seconds floor
    between evaluations.  A throttled call returns the last prediction
    *without* appending to the trace.  Throttling is placement-neutral:
    the trace is only read by result construction, the mitigation
    arbiter prices from the estimator's statistics, and admission
    pricing predicts through its own epoch cache — the invariant suite
    pins dispatch-sequence identity across policies and pool modes."""

    #: modelled-seconds floor between evaluated predictions (0 = none)
    min_interval: float = 0.0
    #: skip re-evaluation while the prediction stamp is unchanged
    dirty_only: bool = True


class SchedEngine:
    """Ready-queue, dependency and multi-pool resource bookkeeping.

    Drive it with::

        engine = SchedEngine(g, pool, policy="fifo", task_level=False)
        for name, i, pool_idx in engine.startable():   # resources acquired
            ... launch task i of set name on pools[pool_idx] ...
        # when a launched task finishes:
        pool_idx = engine.complete(name, i)            # resources released,
        ...                                            # children made ready

    ``g`` must already carry the execution mode's edges (callers apply
    :meth:`DAG.with_sequential_barriers` for sequential mode first).
    Dependency granularity matches the paper: set-level barriers by default,
    ``task_level=True`` for the adaptive (future-work) semantics.
    """

    def __init__(self, g: DAG, pool: "PoolSpec | Allocation", *,
                 policy: "str | SchedulingPolicy" = "fifo",
                 task_level: bool = False,
                 feedback: "FeedbackOptions | None" = None,
                 estimator: "TxEstimator | None" = None,
                 campaign: "CampaignView | None" = None,
                 admission: "AdmissionOptions | None" = None,
                 faults: "FaultOptions | None" = None,
                 elastic: "ElasticOptions | None" = None,
                 incremental: bool = True,
                 predict: "PredictOptions | None" = None):
        self.g = g
        self.alloc = as_allocation(pool)
        # -- fault tolerance (runtime/fault.py) ----------------------------
        # disabled options are normalized to None so every faults-off code
        # path is the exact pre-fault path (bit-identity)
        if faults is not None and not faults.enabled:
            faults = None
        self.faults = faults
        # -- multi-workflow tenancy (core/workflow.py) ---------------------
        if admission is not None and campaign is None:
            raise ValueError("admission control requires a campaign "
                             "(single workflows are always admitted)")
        self.campaign = campaign
        self.workflow_of: dict[str, str] = (
            dict(campaign.workflow_of) if campaign else {})
        self.arrival_of: dict[str, float] = (
            dict(campaign.arrival_of) if campaign else {})
        self.wf_priority: dict[str, int] = (
            dict(campaign.priority_of) if campaign else {})
        #: set -> its workflow's SLO deadline (None = best-effort)
        self.wf_deadline: dict[str, "float | None"] = (
            dict(campaign.deadline_of) if campaign else {})
        self.admission = admission
        #: sets the admission controller let onto the dispatch frontier
        #: (sticky); with admission off every set is implicitly admitted
        self.admitted: set[str] = set()
        #: deferred set -> time of its first deferral (re-priced per pass)
        self.deferred: dict[str, float] = {}
        #: sets ever deferred at least once
        self.admission_deferrals = 0
        #: admission trace: (now, set, decision) tuples
        self.admission_log: list[tuple[float, str, str]] = []
        #: workflows with at least one launched task (never revocable)
        self._wf_started: set[str] = set()
        #: workflows un-admitted by a deadline-driven revocation
        self.admission_revocations = 0
        #: admission-pricing epoch: bumped whenever an input of the priced
        #: predictions may have moved (completions, TX observations,
        #: admissions, revocations, arrivals, leases); cached prices are
        #: reused within one epoch — see :meth:`_admission_price`
        self._adm_epoch = 0
        self._adm_price_cache: dict[str, tuple] = {}
        self._adm_base_cache: "tuple[int, MakespanPrediction] | None" = None
        #: last scheduling-pass clock (supplied by the substrates)
        self._now = 0.0
        self.pools: tuple[PoolSpec, ...] = self.alloc.pools
        self.free_cpus = [p.total.cpus for p in self.pools]
        self.free_gpus = [p.total.gpus for p in self.pools]
        #: live capacity view: the static totals minus nodes currently
        #: down to a failure (== totals whenever faults are off)
        self.cap_cpus = [p.total.cpus for p in self.pools]
        self.cap_gpus = [p.total.gpus for p in self.pools]
        #: per-node occupancy for ``node_level`` pools (None = aggregate
        #: accounting); the aggregate counters above stay a derived view
        self.node_states: list["list[NodeState] | None"] = [
            node_states(p) if p.node_level else None for p in self.pools]
        self._node_level_any = any(p.node_level for p in self.pools)
        # -- elastic capacity (leases; core/resources.py) ------------------
        if elastic is not None and not elastic.enabled:
            elastic = None
        if elastic is not None and self.faults is not None:
            raise ValueError(
                "elastic leases cannot be combined with fault injection "
                "(retired lease nodes and failed nodes share NodeState.down)")
        self.elastic = elastic
        #: index of the elasticized pool (-1 with elasticity off)
        self._lease_pool = -1
        if elastic is not None:
            if elastic.pool is None:
                lk = next((i for i, p in enumerate(self.pools)
                           if p.node_level), -1)
            else:
                lk = next((i for i, p in enumerate(self.pools)
                           if p.name == elastic.pool), -1)
            if lk < 0 or not self.pools[lk].node_level:
                raise ValueError(
                    "elastic leases need a node_level pool "
                    f"(got pool={elastic.pool!r})")
            self._lease_pool = lk
        #: leased node index -> lease expiry time (modelled clock)
        self._lease_expiry: dict[int, float] = {}
        #: retired (down) lease nodes, recycled by later grants
        self._lease_retired: list[int] = []
        #: lease nodes draining towards retirement (expired while busy)
        self._draining: set[int] = set()
        self.leases_granted = 0
        self.leases_expired = 0
        #: lease trace: (now, event, node) tuples
        self.lease_log: list[tuple[float, str, int]] = []
        #: (set, index) -> (node, per-group GPU takes) of the primary
        #: attempt on a node-level pool (absent on aggregate pools)
        self._node_alloc: dict[tuple[str, int],
                               tuple[int, list[tuple[int, int]]]] = {}
        #: same, for the racing speculative duplicate's slot
        self._spec_node_alloc: dict[tuple[str, int],
                                    tuple[int, list[tuple[int, int]]]] = {}
        self.policy = get_scheduling_policy(policy)
        #: True while the policy keeps the base-class *spread* node choice
        #: — only then may the engine serve it from the spread heap
        #: (overriding policies get the indexed fitting-node list instead)
        self._policy_spreads = (type(self.policy).choose_node
                                is SchedulingPolicy.choose_node)
        self.task_level = task_level

        # -- runtime feedback (core/estimator.py) --------------------------
        if estimator is not None and feedback is None:
            feedback = FeedbackOptions(migrate=False)
        self.feedback = feedback
        if feedback is not None and estimator is None:
            estimator = TxEstimator(
                alpha=feedback.ewma_alpha,
                prior={n: g.node(n).tx_mean for n in g.nodes})
        self.estimator = estimator
        self._priority_dirty = False
        self.running_per_pool = [0] * len(self.pools)
        self.migrations = 0
        self._migrations_of: dict[tuple[str, int], int] = {}
        self._data_cost_cache: dict[tuple[str, int, int], float] = {}
        #: speculative duplicates: (set, index) -> pool holding the
        #: duplicate's slot while both attempts race
        self._spec_pool: dict[tuple[str, int], int] = {}
        self._speculations_of: dict[tuple[str, int], int] = {}
        self.speculations = 0
        #: online makespan re-prediction (core/predictor.py); node-level
        #: occupancy unlocks the cross-set GPU contention term, a campaign
        #: the cross-workflow one — and the admission controller needs the
        #: predictor even without runtime feedback
        self.predictor = (MakespanPredictor(
            g, self.alloc, contention=self._node_level_any,
            workflow_of=self.workflow_of or None, cache=True)
            if feedback is not None or admission is not None
            or faults is not None else None)
        self.predictions: list[MakespanPrediction] = []
        # -- prediction epochs (trace-scale hot loop) ----------------------
        self.predict_opts = predict
        #: launches so far — part of the prediction stamp (len(launched)
        #: alone can stay equal across a simultaneous finish + start)
        self._starts = 0
        #: predictions actually evaluated (throttled/deduped calls excluded)
        self._pred_evals = 0
        self._last_pred_key: "tuple | None" = None
        self._last_pred: "MakespanPrediction | None" = None
        self._last_pred_now = float("-inf")
        if (predict is not None and self.predictor is not None
                and admission is None):
            # throttled runs also stop re-deriving the whole topological
            # order per arrival (arrivals are dependency-disconnected, so
            # appending preserves topological validity).  NOT with
            # admission control: the appended order changes the float
            # summation order inside ``predictor.predict``, and admission
            # *decisions* read those floats (``_admission_price``) — an
            # ulp there could move a placement, which would break the
            # throttle's placement-neutrality guarantee.  Without
            # admission, predictor floats only reach the prediction
            # trace, never a decision.
            self.predictor.incremental_order = True

        # -- fault-tolerance state (all dormant when ``faults is None``) ---
        #: failure-site count for the empirical hazard estimate
        self._fault_sites = max(1, sum(p.num_nodes for p in self.pools))
        self.node_failures = 0
        self.task_failures = 0
        self.recoveries_restart = 0
        self.recoveries_rerun = 0
        self.replications = 0
        #: failure trace: (now, kind, detail...) tuples
        self.fault_log: list[tuple] = []
        #: (set, index) -> failed-attempt count (the attempt number the
        #: substrates key the seeded per-attempt failure draws on)
        self._failures_of: dict[tuple[str, int], int] = {}
        #: (set, index) -> (pool, node) the running attempt writes its
        #: checkpoints from (present only while checkpointing the attempt)
        self._ckpt_run: dict[tuple[str, int], tuple[int, int]] = {}
        #: (set, index) -> (saved progress, writer pool, writer node) of a
        #: restart-from-checkpoint decision, consumed at re-dispatch
        self._recovery: dict[tuple[str, int], tuple[float, int, int]] = {}
        #: aggregate pools: (pool, node) -> (cpus, gpus) removed while the
        #: conceptual node is down (node-level pools track ``NodeState.down``)
        self._agg_lost: dict[tuple[int, int], tuple[int, int]] = {}

        order = g.topological_order()
        ranks = g.ranks()
        self.order = order
        self._infos = [SetInfo(n, ranks[n], k, g.node(n).num_tasks,
                               g.node(n).cpus_per_task, g.node(n).gpus_per_task,
                               g.node(n).tx_mean, g.node(n).kind,
                               self.wf_priority.get(n, 0),
                               self.arrival_of.get(n, 0.0))
                       for k, n in enumerate(order)]
        self.priority = list(self.policy.order_sets(self._infos))
        if sorted(self.priority) != sorted(order):
            raise ValueError(
                f"policy {self.policy.name!r} returned an invalid set order")

        for n in order:
            ts = g.node(n)
            if not any(p.accepts(ts) for p in self.pools):
                raise ValueError(
                    f"task set {n!r} (cpus={ts.cpus_per_task}, "
                    f"gpus={ts.gpus_per_task}, kind={ts.kind!r}) fits no "
                    f"pool of allocation {self.alloc.name!r}")

        # -- dependency counters (identical semantics in both substrates) --
        self._remaining: dict[tuple[str, int], int] = {}
        self._set_remaining = {n: g.node(n).num_tasks for n in order}
        self._child_waiters: dict[tuple[str, int],
                                  list[tuple[str, int]]] = {}
        if task_level:
            # task i of a child set depends on task j of each parent set
            # with j mapped proportionally (i * np // nc); one parent task
            # may unlock several child tasks.
            for name in order:
                nc = g.node(name).num_tasks
                for i in range(nc):
                    cnt = 0
                    for p in g.parents(name):
                        np_ = g.node(p).num_tasks
                        self._child_waiters.setdefault(
                            (p, i * np_ // nc), []).append((name, i))
                        cnt += 1
                    self._remaining[(name, i)] = cnt
        else:
            # set-level: every task of a child set waits for *all* tasks of
            # all parent sets (the paper's stage semantics).
            for name in order:
                cnt = sum(g.node(p).num_tasks for p in g.parents(name))
                for i in range(g.node(name).num_tasks):
                    self._remaining[(name, i)] = cnt

        self.ready: dict[str, deque] = {n: deque() for n in order}
        #: sets with tasks still outstanding (``_set_remaining > 0``) —
        #: the iteration domain of every whole-state scan whose result is
        #: order-independent (repredict pending maps, admission pricing,
        #: elastic pressure); finished sets only ever contribute zeros
        #: there, so scans stay proportional to the *live* frontier on
        #: long streams instead of everything that ever arrived
        self._live: set[str] = set(order)
        #: finished sets not yet compacted out of ``_infos``/``priority``
        self._retired_sets = 0
        self.launched: set[tuple[str, int]] = set()
        self.finished: set[tuple[str, int]] = set()
        self.pool_of: dict[tuple[str, int], int] = {}
        #: node the task's primary attempt was placed on (-1 on aggregate
        #: pools); unlike ``_node_alloc`` this survives completion, so
        #: node-granular data costs can price finished parents' outputs
        self.node_of: dict[tuple[str, int], int] = {}
        self._n_total = sum(g.node(n).num_tasks for n in order)
        self._n_done = 0
        for n in order:
            if not g.parents(n):
                for i in range(g.node(n).num_tasks):
                    self.ready[n].append(i)

        # -- incremental pass structures (module docstring section) --------
        #: False restores the brute-force scans (the pre-index engine) —
        #: kept for the scale benchmark's comparison arm and for the
        #: index-vs-recount invariant suite
        self.incremental = incremental
        #: ready sets whose last offer found no candidate pool; skipped by
        #: ``startable`` until a release unblocks one of their classes
        self._blocked: set[str] = set()
        if incremental:
            self._build_indexes()

    # -- streaming arrivals (core/stream.py) --------------------------------
    def add_workflow(self, entry: "WorkflowEntry", now: float = 0.0
                     ) -> list[str]:
        """Merge a newly-arrived workflow into the live engine state (the
        open-stream consumption path: the engine only ever holds the
        arrived prefix).  Namespaces the entry's sets exactly as
        :meth:`~repro.core.workflow.Campaign.view` does, extends every
        dependency / resource / index structure, and returns the merged
        set names in the entry's topological order — the substrates
        sample task durations for them in that order.  Arriving workflows
        are dependency-disconnected from everything already merged, so
        existing entries (including the predictor's snapshots, via
        :meth:`~repro.core.predictor.MakespanPredictor.add_sets`) stay
        valid."""
        g = self.g
        sub = entry.dag
        sub_order = sub.topological_order()
        sub_ranks = sub.ranks()
        names: list[str] = []
        for n in sub_order:
            merged = f"{entry.name}{WORKFLOW_SEP}{n}"
            if merged in g:
                raise ValueError(f"workflow {entry.name!r} already merged "
                                 f"(set {merged!r} exists)")
            g.add(sub.node(n).with_(name=merged))
            names.append(merged)
        for n in sub_order:
            for p in sub.parents(n):
                g.add_edge(f"{entry.name}{WORKFLOW_SEP}{p}",
                           f"{entry.name}{WORKFLOW_SEP}{n}")
        for m in names:
            ts = g.node(m)
            if not any(p.accepts(ts) for p in self.pools):
                raise ValueError(
                    f"arrived task set {m!r} (cpus={ts.cpus_per_task}, "
                    f"gpus={ts.gpus_per_task}, kind={ts.kind!r}) fits no "
                    f"pool of allocation {self.alloc.name!r}")
            self.workflow_of[m] = entry.name
            self.arrival_of[m] = entry.arrival
            self.wf_priority[m] = entry.priority
            self.wf_deadline[m] = entry.deadline
        # dependency counters + ready queues (same semantics as __init__;
        # all parents are entry-local — no cross-workflow edges exist)
        base_topo = len(self.order)
        for j, n in enumerate(sub_order):
            m = names[j]
            ts = g.node(m)
            self.order.append(m)
            self._infos.append(SetInfo(
                m, sub_ranks[n], base_topo + j, ts.num_tasks,
                ts.cpus_per_task, ts.gpus_per_task, ts.tx_mean, ts.kind,
                entry.priority, entry.arrival))
            self._set_remaining[m] = ts.num_tasks
            self.ready[m] = deque()
            self._n_total += ts.num_tasks
            if self.task_level:
                nc = ts.num_tasks
                for i in range(nc):
                    cnt = 0
                    for p in g.parents(m):
                        np_ = g.node(p).num_tasks
                        self._child_waiters.setdefault(
                            (p, i * np_ // nc), []).append((m, i))
                        cnt += 1
                    self._remaining[(m, i)] = cnt
            else:
                cnt = sum(g.node(p).num_tasks for p in g.parents(m))
                for i in range(ts.num_tasks):
                    self._remaining[(m, i)] = cnt
            if not g.parents(m):
                for i in range(ts.num_tasks):
                    self.ready[m].append(i)
        self.priority = list(self.policy.order_sets(self._infos))
        if self.policy.uses_tx:
            self._priority_dirty = True
        if self.estimator is not None:
            for m in names:
                self.estimator.prior.setdefault(m, g.node(m).tx_mean)
        if self.incremental:
            for m in names:
                ts = g.node(m)
                entries = []
                for k, p in enumerate(self.pools):
                    if (p.only_kinds is not None
                            and ts.kind not in p.only_kinds):
                        continue
                    cls = self._needs(k, ts)
                    ent = self._classes[k].get(cls)
                    if ent is None:
                        ent = self._classes[k][cls] = _FitClass(*cls)
                        states = self.node_states[k]
                        if states is not None:
                            ent.nodes = {n for n, ns in enumerate(states)
                                         if ns.fits(ent.need_c, ent.need_g)}
                            ent.fits = bool(ent.nodes)
                        else:
                            ent.fits = (ent.need_c <= self.free_cpus[k]
                                        and ent.need_g <= self.free_gpus[k])
                    ent.sets.append(m)
                    entries.append((k, ent))
                self._set_pools[m] = entries
        if self.predictor is not None:
            self.predictor.add_sets(names, {m: entry.name for m in names})
        self._live.update(names)
        self._adm_epoch += 1
        self._now = max(self._now, now)
        return names

    # -- incremental indexes (dirty tracking; module docstring section) -----
    def _build_indexes(self) -> None:
        """Build the per-(pool, footprint-class) fit indexes, the per-pool
        free-block bucket counters and the lazy spread heaps from the
        current occupancy (all free at construction)."""
        n_pools = len(self.pools)
        #: per pool: footprint class -> :class:`_FitClass`
        self._classes: list[dict[tuple[int, int], _FitClass]] = [
            {} for _ in range(n_pools)]
        #: set name -> [(pool, class key, class entry)] over the pools the
        #: set may be placed on (kind-eligible), ascending pool index —
        #: the iteration order ``_candidates`` must reproduce
        self._set_pools: dict[str, list] = {}
        for n in self.order:
            ts = self.g.node(n)
            entries = []
            for k, p in enumerate(self.pools):
                if p.only_kinds is not None and ts.kind not in p.only_kinds:
                    continue
                cls = self._needs(k, ts)
                ent = self._classes[k].get(cls)
                if ent is None:
                    ent = self._classes[k][cls] = _FitClass(*cls)
                ent.sets.append(n)
                entries.append((k, ent))
            self._set_pools[n] = entries
        #: per (node-level) pool: cached per-node largest_block values,
        #: their bucket counts, and the running maximum
        self._node_block: list["list[int] | None"] = [None] * n_pools
        self._block_buckets: list["list[int] | None"] = [None] * n_pools
        self._block_max: list[int] = [0] * n_pools
        #: per (node-level) pool: lazy min-heap of (-free_gpus, -free_cpus,
        #: node, version) — the default spread key; stale entries (version
        #: mismatch) are dropped at query time
        self._spread_heap: list["list | None"] = [None] * n_pools
        self._node_ver: list["list[int] | None"] = [None] * n_pools
        for k, states in enumerate(self.node_states):
            if states is None:
                for ent in self._classes[k].values():
                    ent.fits = (ent.need_c <= self.free_cpus[k]
                                and ent.need_g <= self.free_gpus[k])
                continue
            blocks = [ns.largest_block() for ns in states]
            self._node_block[k] = blocks
            buckets = [0] * (max(blocks, default=0) + 1)
            for b in blocks:
                buckets[b] += 1
            self._block_buckets[k] = buckets
            self._block_max[k] = max(blocks, default=0)
            self._node_ver[k] = [0] * len(states)
            heap = [(-ns.free_gpus, -ns.free_cpus, n, 0)
                    for n, ns in enumerate(states)]
            heapq.heapify(heap)
            self._spread_heap[k] = heap
            for ent in self._classes[k].values():
                ent.nodes = {n for n, ns in enumerate(states)
                             if ns.fits(ent.need_c, ent.need_g)}
                ent.fits = bool(ent.nodes)

    def _node_changed(self, k: int, node: int) -> None:
        """One node of pool ``k`` changed occupancy: refresh its free-block
        bucket, push its new spread-heap key, and move it in/out of every
        footprint class's fit set — unblocking the class's waiting sets on
        an empty -> non-empty transition."""
        ns = self.node_states[k][node]
        blocks = self._node_block[k]
        b_new = ns.largest_block()
        b_old = blocks[node]
        if b_new != b_old:
            buckets = self._block_buckets[k]
            buckets[b_old] -= 1
            buckets[b_new] += 1
            blocks[node] = b_new
            if b_new > self._block_max[k]:
                self._block_max[k] = b_new
            elif b_old == self._block_max[k] and not buckets[b_old]:
                m = b_old
                while m > 0 and not buckets[m]:
                    m -= 1
                self._block_max[k] = m
        ver = self._node_ver[k]
        ver[node] += 1
        heapq.heappush(self._spread_heap[k],
                       (-ns.free_gpus, -ns.free_cpus, node, ver[node]))
        for ent in self._classes[k].values():
            if ns.fits(ent.need_c, ent.need_g):
                if node not in ent.nodes:
                    if not ent.nodes and ent.blocked:
                        self._blocked.difference_update(ent.blocked)
                        ent.blocked.clear()
                    ent.nodes.add(node)
                    ent.fits = True
            elif node in ent.nodes:
                ent.nodes.discard(node)
                ent.fits = bool(ent.nodes)

    def _agg_freed(self, k: int) -> None:
        """Aggregate pool ``k``'s free counters grew: flip any footprint
        class that fits again and unblock its waiting sets.  (Node-level
        pools are handled by :meth:`_node_changed` — a node fit implies
        the aggregate fit there.)"""
        fc, fg = self.free_cpus[k], self.free_gpus[k]
        for ent in self._classes[k].values():
            if not ent.fits and ent.need_c <= fc and ent.need_g <= fg:
                ent.fits = True
                if ent.blocked:
                    self._blocked.difference_update(ent.blocked)
                    ent.blocked.clear()

    def _mark_blocked(self, name: str) -> None:
        """Record that set ``name`` found no candidate pool: sync its
        aggregate classes' ``fits`` flags to the current (necessarily
        unfitting) counters so the next release detects the unfit -> fit
        transition, then skip the set until one fires."""
        for k, ent in self._set_pools[name]:
            if ent.nodes is None:
                ent.fits = (ent.need_c <= self.free_cpus[k]
                            and ent.need_g <= self.free_gpus[k])
            ent.blocked.add(name)
        self._blocked.add(name)

    def _spread_choose(self, k: int, need_c: int, need_g: int,
                       exclude: int = -1) -> int:
        """The default *spread* node choice served from the lazy per-pool
        heap: the first live entry (in ``(-free_gpus, -free_cpus, node)``
        order) whose node fits — identical to ``min`` over the fitting
        nodes without scanning them all.  Returns -1 when nothing fits."""
        states = self.node_states[k]
        heap = self._spread_heap[k]
        ver = self._node_ver[k]
        popped = []
        chosen = -1
        while heap:
            entry = heap[0]
            n = entry[2]
            if entry[3] != ver[n]:
                heapq.heappop(heap)  # superseded by a newer occupancy key
                continue
            if n != exclude and states[n].fits(need_c, need_g):
                chosen = n
                break
            popped.append(heapq.heappop(heap))  # live, but not eligible
        for entry in popped:
            heapq.heappush(heap, entry)
        if len(heap) > 64 and len(heap) > 4 * len(states):
            # compact away accumulated stale entries
            heap[:] = [(-ns.free_gpus, -ns.free_cpus, n, ver[n])
                       for n, ns in enumerate(states)]
            heapq.heapify(heap)
        return chosen

    def check_index_integrity(self) -> None:
        """Assert every incremental structure equals a brute-force recount
        of the live occupancy (the invariant the property suite drives
        random engine operation against).  Raises ``AssertionError`` with
        the first divergence; no-op side-effect-wise."""
        if not self.incremental:
            raise AssertionError("index integrity needs incremental=True")
        for k, states in enumerate(self.node_states):
            if states is None:
                fc, fg = self.free_cpus[k], self.free_gpus[k]
                for cls, ent in self._classes[k].items():
                    want = ent.need_c <= fc and ent.need_g <= fg
                    if ent.fits and not want:
                        # stale True is only legal while no blocked set
                        # relies on the transition (synced at block time)
                        if any(n in self._blocked for n in ent.sets):
                            raise AssertionError(
                                f"pool {k} class {cls}: fits=True with "
                                f"blocked waiters but counters disagree")
                    elif not ent.fits and want:
                        raise AssertionError(
                            f"pool {k} class {cls}: fits=False but "
                            f"counters fit (missed unblock)")
                continue
            # the aggregate counters stay a derived view of the node table
            # — the invariant elastic grow/drain/retire must preserve
            if self.free_cpus[k] != sum(ns.free_cpus for ns in states):
                raise AssertionError(
                    f"pool {k}: free_cpus {self.free_cpus[k]} != node sum "
                    f"{sum(ns.free_cpus for ns in states)}")
            if self.free_gpus[k] != sum(ns.free_gpus for ns in states):
                raise AssertionError(
                    f"pool {k}: free_gpus {self.free_gpus[k]} != node sum "
                    f"{sum(ns.free_gpus for ns in states)}")
            if self.cap_cpus[k] != sum(ns.cpus for ns in states
                                       if not ns.down):
                raise AssertionError(
                    f"pool {k}: cap_cpus {self.cap_cpus[k]} != live node "
                    f"capacity")
            if self.cap_gpus[k] != sum(ns.spec.gpus for ns in states
                                       if not ns.down):
                raise AssertionError(
                    f"pool {k}: cap_gpus {self.cap_gpus[k]} != live node "
                    f"capacity")
            drain = {n for n, ns in enumerate(states) if ns.draining}
            if drain != (self._draining if k == self._lease_pool else set()):
                raise AssertionError(
                    f"pool {k}: draining flags {drain} != lease-drain set "
                    f"{self._draining}")
            blocks = [ns.largest_block() for ns in states]
            if self._node_block[k] != blocks:
                raise AssertionError(
                    f"pool {k}: cached node blocks {self._node_block[k]} "
                    f"!= recount {blocks}")
            if self._block_max[k] != max(blocks, default=0):
                raise AssertionError(
                    f"pool {k}: block max {self._block_max[k]} != "
                    f"{max(blocks, default=0)}")
            buckets = [0] * len(self._block_buckets[k])
            for b in blocks:
                buckets[b] += 1
            if self._block_buckets[k] != buckets:
                raise AssertionError(
                    f"pool {k}: block buckets {self._block_buckets[k]} "
                    f"!= recount {buckets}")
            for cls, ent in self._classes[k].items():
                fit = {n for n, ns in enumerate(states)
                       if ns.fits(ent.need_c, ent.need_g)}
                if ent.nodes != fit:
                    raise AssertionError(
                        f"pool {k} class {cls}: fit index {ent.nodes} "
                        f"!= recount {fit}")
                if ent.fits != bool(fit):
                    raise AssertionError(
                        f"pool {k} class {cls}: fits={ent.fits} but "
                        f"recount says {bool(fit)}")
                want = (min(fit, key=lambda n: (-states[n].free_gpus,
                                                -states[n].free_cpus, n))
                        if fit else -1)
                got = self._spread_choose(k, ent.need_c, ent.need_g)
                if got != want:
                    raise AssertionError(
                        f"pool {k} class {cls}: spread heap chose {got}, "
                        f"brute force {want}")
        for name in self._blocked:
            cands = self._candidates_scan(self.g.node(name))
            if cands:
                raise AssertionError(
                    f"set {name!r} is blocked but pools {cands} fit it")

    # -- elastic capacity (leases; ElasticOptions) --------------------------
    def elastic_pass(self, now: float) -> bool:
        """One elasticity control step (both substrates drive it every
        ``ElasticOptions.check_interval`` modelled seconds): expire any
        lease past its term (idle nodes retire immediately, busy ones
        drain), then grant at most one new lease while queued strict
        demand outruns the pool's usable free capacity.  Returns True
        when capacity changed, so the caller re-runs dispatch."""
        if self.elastic is None:
            return False
        self._now = max(self._now, now)
        changed = self._expire_leases(now)
        if self._should_grow():
            changed = self._grant_lease(now) or changed
        return changed

    def _should_grow(self) -> bool:
        opts = self.elastic
        k = self._lease_pool
        if len(self._lease_expiry) + len(self._draining) \
                >= opts.max_lease_nodes:
            return False
        queued_c = queued_g = tasks = 0
        for n in self._live:
            q = self.ready[n]
            if not q or not self._dispatchable(n):
                continue
            ts = self.g.node(n)
            if not self.pools[k].accepts(ts):
                continue
            need_c, need_g = self._needs(k, ts)
            queued_c += len(q) * need_c
            queued_g += len(q) * need_g
            tasks += len(q)
        if tasks < opts.min_queue_tasks:
            return False
        # usable free capacity: a draining node's free slots accept no
        # new placements, so they are not headroom
        states = self.node_states[k]
        free_c, free_g = self.free_cpus[k], self.free_gpus[k]
        for node in self._draining:
            free_c -= states[node].free_cpus
            free_g -= states[node].free_gpus
        return ((queued_g > 0 and queued_g > opts.grow_threshold * free_g)
                or (queued_c > 0 and queued_c > opts.grow_threshold * free_c))

    def _grant_lease(self, now: float) -> bool:
        k = self._lease_pool
        p = self.pools[k]
        states = self.node_states[k]
        if self._lease_retired:  # recycle a retired node's slot
            node = self._lease_retired.pop(0)
            ns = states[node]
            c, g = ns.restore()
            self.free_cpus[k] += c
            self.free_gpus[k] += g
            self.cap_cpus[k] += c
            self.cap_gpus[k] += g
            if self.incremental:
                self._node_changed(k, node)
        else:
            # a fresh lease node carries the same per-node reserved-core
            # share as the pool's static nodes
            ns = NodeState(p.node,
                           p.node.cpus - p.reserved_cpus // p.num_nodes)
            node = len(states)
            states.append(ns)
            self.free_cpus[k] += ns.cpus
            self.free_gpus[k] += ns.spec.gpus
            self.cap_cpus[k] += ns.cpus
            self.cap_gpus[k] += ns.spec.gpus
            if self.incremental:
                self._index_add_node(k, node)
        self._lease_expiry[node] = now + self.elastic.lease_term
        self.leases_granted += 1
        self.lease_log.append((now, "grant", node))
        self._adm_epoch += 1
        if self.predictor is not None:
            self.predictor.invalidate()
        return True

    def _expire_leases(self, now: float) -> bool:
        changed = False
        k = self._lease_pool
        states = self.node_states[k]
        for node in sorted(self._lease_expiry):
            if self._lease_expiry[node] > now:
                continue
            del self._lease_expiry[node]
            if states[node].idle:
                self._retire_lease_node(k, node, now)
                changed = True
            else:
                # drain: no new placements, running tasks finish; the
                # last release retires the node (_maybe_retire) — lease
                # expiry never strands a placed task
                states[node].draining = True
                self._draining.add(node)
                self.lease_log.append((now, "drain", node))
                if self.incremental:
                    self._node_changed(k, node)
        return changed

    def _retire_lease_node(self, k: int, node: int, now: float) -> None:
        ns = self.node_states[k][node]
        c, g = ns.fail()  # idle, so free == capacity leaves with it
        self.free_cpus[k] -= c
        self.free_gpus[k] -= g
        self.cap_cpus[k] -= ns.cpus
        self.cap_gpus[k] -= ns.spec.gpus
        self._draining.discard(node)
        self._lease_retired.append(node)
        if self.incremental:
            self._node_changed(k, node)
        self.leases_expired += 1
        self.lease_log.append((now, "expire", node))
        self._adm_epoch += 1
        if self.predictor is not None:
            self.predictor.invalidate()

    def _maybe_retire(self, k: int, node: int) -> None:
        """Release hook: a draining lease node retires on its last
        release (it just went idle)."""
        if (self.elastic is not None and k == self._lease_pool
                and node in self._draining
                and self.node_states[k][node].idle):
            self._retire_lease_node(k, node, self._now)

    def _index_add_node(self, k: int, node: int) -> None:
        """Register a freshly-appended (leased) node with every
        incremental structure of pool ``k`` — the grow counterpart of
        :meth:`_node_changed`, which assumes the node already has index
        entries."""
        ns = self.node_states[k][node]
        b = ns.largest_block()
        blocks = self._node_block[k]
        blocks.append(b)
        buckets = self._block_buckets[k]
        while len(buckets) <= b:
            buckets.append(0)
        buckets[b] += 1
        if b > self._block_max[k]:
            self._block_max[k] = b
        self._node_ver[k].append(0)
        heapq.heappush(self._spread_heap[k],
                       (-ns.free_gpus, -ns.free_cpus, node, 0))
        for ent in self._classes[k].values():
            if ns.fits(ent.need_c, ent.need_g):
                if not ent.nodes and ent.blocked:
                    self._blocked.difference_update(ent.blocked)
                    ent.blocked.clear()
                ent.nodes.add(node)
                ent.fits = True

    def stream_accounting(self) -> dict:
        """Conservation partition over every workflow the engine has seen
        (the arrived prefix): ``arrived == finished + admitted + deferred
        + queued`` always holds — a revoked workflow re-enters
        ``deferred`` (``revoked`` counts revocation *events*, not a
        disjoint state).  ``admitted`` means in flight: some set on the
        dispatch frontier, remaining work > 0."""
        sets_of: dict[str, list[str]] = {}
        for n, wf in self.workflow_of.items():
            sets_of.setdefault(wf, []).append(n)
        finished = admitted = deferred = queued = 0
        for wf, ns in sets_of.items():
            if all(self._set_remaining[n] == 0 for n in ns):
                finished += 1
            elif (self.admission is None
                  or any(n in self.admitted for n in ns)):
                admitted += 1
            elif any(n in self.deferred for n in ns):
                deferred += 1
            else:
                queued += 1
        return dict(arrived=len(sets_of), finished=finished,
                    admitted=admitted, deferred=deferred, queued=queued,
                    revoked=self.admission_revocations)

    # -- state queries ------------------------------------------------------
    def done(self) -> bool:
        return self._n_done >= self._n_total

    @property
    def tasks_total(self) -> int:
        return self._n_total

    def pool_name(self, pool_idx: int) -> str:
        return self.pools[pool_idx].name

    # -- node-level topology ------------------------------------------------
    def fitting_nodes(self, k: int, ts: TaskSet) -> list[int]:
        """Nodes of pool ``k`` that can start one task of ``ts`` now
        (empty for aggregate pools).  Served from the footprint-class fit
        index (sorted, so the order matches the brute-force scan) when
        ``incremental``."""
        states = self.node_states[k]
        if states is None:
            return []
        need_c, need_g = self._needs(k, ts)
        if self.incremental:
            ent = self._classes[k].get((need_c, need_g))
            if ent is not None:
                return sorted(ent.nodes)
        return [n for n, ns in enumerate(states) if ns.fits(need_c, need_g)]

    def largest_free_block(self, k: int) -> int:
        """Largest contiguous free GPU block of pool ``k`` — for a
        node-level pool the widest free NVLink group across its nodes
        (``nodepack``'s fragmentation score); for an aggregate pool the
        free GPU count (one conceptual block).  O(1) off the bucket
        counters when ``incremental``."""
        states = self.node_states[k]
        if states is None:
            return self.free_gpus[k]
        if self.incremental:
            return self._block_max[k]
        return max((ns.largest_block() for ns in states), default=0)

    def node_placement(self, name: str, i: int) -> int:
        """Node index the task's primary attempt occupies (-1 on
        aggregate pools or before launch)."""
        alloc = self._node_alloc.get((name, i))
        return alloc[0] if alloc is not None else -1

    def spec_node(self, name: str, i: int) -> int:
        """Node index of the racing speculative duplicate (-1 if none or
        on an aggregate pool)."""
        alloc = self._spec_node_alloc.get((name, i))
        return alloc[0] if alloc is not None else -1

    def node_occupancy(self) -> "dict[str, list[dict] | None]":
        """Live per-node occupancy per pool (None = aggregate pool):
        ``{pool: [{node, free_cpus, free_gpus, group_free}, ...]}``."""
        out: "dict[str, list[dict] | None]" = {}
        for k, p in enumerate(self.pools):
            states = self.node_states[k]
            if states is None:
                out[p.name] = None
            else:
                out[p.name] = [dict(node=n, free_cpus=ns.free_cpus,
                                    free_gpus=ns.free_gpus,
                                    group_free=list(ns.group_free))
                               for n, ns in enumerate(states)]
        return out

    def _choose_node(self, k: int, ts: TaskSet,
                     exclude: int = -1) -> int:
        """Pick the node of pool ``k`` the task lands on (policy hook;
        ``exclude`` bars the straggler's own node for migrations).

        Returns -1 when no node fits — the policy is never handed an
        empty candidate list (every ``choose_node`` implementation is a
        ``min`` over the nodes and would raise on ``[]``, which used to
        crash straggler migration when ``exclude`` removed the only
        fitting node); callers treat -1 as "no placement" and no-op."""
        if self.incremental and self._policy_spreads:
            need_c, need_g = self._needs(k, ts)
            return self._spread_choose(k, need_c, need_g, exclude)
        nodes = self.fitting_nodes(k, ts)
        if exclude >= 0:
            nodes = [n for n in nodes if n != exclude]
        if not nodes:
            return -1
        return self.policy.choose_node(ts, k, nodes, self)

    def _acquire(self, k: int, ts: TaskSet,
                 node: int = -1) -> "tuple[int, list[tuple[int, int]]] | None":
        """Take one task's resources on pool ``k`` (node-granular when the
        pool is node-level; ``node`` pins the choice).  Returns the node
        allocation to store for release, or ``None`` for aggregate."""
        need_c, need_g = self._needs(k, ts)
        self.free_cpus[k] -= need_c
        self.free_gpus[k] -= need_g
        self.running_per_pool[k] += 1
        states = self.node_states[k]
        if states is None:
            return None
        if node < 0 or not states[node].fits(need_c, need_g):
            node = self._choose_node(k, ts)
        if node < 0:
            raise RuntimeError(
                f"no node of pool {self.pools[k].name!r} fits "
                f"({need_c} cpus, {need_g} gpus) — caller skipped the "
                f"candidate check")
        takes = states[node].acquire(need_c, need_g)
        if self.incremental:
            self._node_changed(k, node)
        return node, takes

    def _release(self, k: int, ts: TaskSet,
                 node_alloc: "tuple[int, list[tuple[int, int]]] | None",
                 ) -> None:
        need_c, need_g = self._needs(k, ts)
        self.free_cpus[k] += need_c
        self.free_gpus[k] += need_g
        self.running_per_pool[k] -= 1
        if node_alloc is not None:
            node, takes = node_alloc
            self.node_states[k][node].release(need_c, takes)
            if self.incremental:
                self._node_changed(k, node)
            self._maybe_retire(k, node)
        elif self.incremental and self.node_states[k] is None:
            self._agg_freed(k)

    # -- runtime feedback ---------------------------------------------------
    def tx_estimate(self, name: str, pool: "int | None" = None) -> float:
        """The mean TX a policy should reason with: the observed EWMA once
        the set has ``min_samples`` completions, the static ``tx_mean``
        prior before that (or always, without feedback).  With ``pool``
        given (an index) and ``per_pool`` feedback on, the (set, pool)
        split is preferred once it is armed — so placement and mitigation
        decisions price each pool's own speed."""
        fb = self.feedback
        if self.estimator is not None and fb is not None:
            if pool is not None and fb.per_pool:
                pname = self.pools[pool].name
                if self.estimator.count(name, pool=pname) >= fb.min_samples:
                    return self.estimator.mean(name, pool=pname)
            if self.estimator.count(name) >= fb.min_samples:
                return self.estimator.mean(name)
        return self.g.node(name).tx_mean

    def observe(self, name: str, duration: float,
                pool: "int | None" = None) -> None:
        """Feed one completed task's duration into the online estimator
        (both substrates call this right after :meth:`complete`, tagging
        the pool the task ran on).  Straggler durations are winsorized at
        ``winsorize_ratio`` x the running mean so they cannot contaminate
        the very estimate they are detected against.  Marks the priority
        order dirty so the next dispatch pass re-ranks ready sets by
        observed TX."""
        if self.estimator is None:
            return
        fb = self.feedback
        raw = duration  # pre-winsorize, for online tail calibration
        pname = (self.pools[pool].name
                 if pool is not None and fb is not None and fb.per_pool
                 else None)
        if fb is not None and fb.winsorize_ratio > 0:
            # clip against the pool split's own mean once it is armed — a
            # genuinely slow pool must not have its observations capped at
            # a multiple of the faster cross-pool blend, or its estimate
            # saturates low and its tasks read as permanent stragglers.
            # A non-positive armed mean (all-zero durations) must not
            # clip, or every later observation is pinned to zero forever
            if (pname is not None and
                    self.estimator.count(name, pool=pname)
                    >= fb.min_samples):
                m = self.estimator.mean(name, pool=pname)
                if m > 0:
                    duration = min(duration, fb.winsorize_ratio * m)
            elif self.estimator.count(name) >= fb.min_samples:
                m = self.estimator.mean(name)
                if m > 0:
                    duration = min(duration, fb.winsorize_ratio * m)
        self.estimator.observe(name, duration, pool=pname, raw=raw)
        self._adm_epoch += 1  # TX estimates are admission-pricing inputs
        if self.predictor is not None:
            # explicit cache invalidation: this set's live TX moved, so
            # its memoized residual terms and the whole-workflow Eqn. 2-5
            # snapshot must be re-priced on the next prediction
            self.predictor.invalidate(name)
        # only TX-ordering policies need the priority rebuilt; fifo/
        # gpu_bestfit/locality orderings cannot change with estimates
        if self.policy.uses_tx:
            self._priority_dirty = True

    def stragglers(self, running: "dict[tuple[str, int], float]",
                   now: float) -> list[tuple[str, int]]:
        """Running tasks whose runtime exceeds ``mean + k*sigma`` of their
        set's running estimate (armed after ``min_samples`` completions;
        the task's *pool* estimate when that split is armed).  ``running``
        maps (set, index) -> start time on the caller's clock; the
        estimator must have been fed durations on the same clock.  Tasks
        with a speculative duplicate already racing are skipped."""
        fb = self.feedback
        if fb is None or self.estimator is None:
            return []
        out = []
        for (name, i), start in running.items():
            if (name, i) in self.finished:
                continue  # completed at the detection tick
            if (name, i) in self._spec_pool:
                continue  # a duplicate is already racing it
            pname = None
            if fb.per_pool and (name, i) in self.pool_of:
                pname = self.pools[self.pool_of[(name, i)]].name
            if self.estimator.is_straggler(name, now - start, fb,
                                           pool=pname):
                out.append((name, i))
        return out

    # -- straggler mitigation: migration, speculation, arbitration ----------
    def _migration_candidate(self, name: str,
                             i: int) -> "tuple[int, float, int] | None":
        """``(dst, cost, node)`` migration would use, or ``None`` — pure
        (no state change), so the arbiter can price it before committing.
        On node-level pools the straggler may also migrate *within* its
        own pool onto a different node (priced at the topology's
        intra-pool distance); the landing node is chosen here so the cost
        the arbiter sees matches the placement that gets applied."""
        fb = self.feedback
        if fb is None or not fb.migrate:
            return None
        if (name, i) in self.finished or (name, i) not in self.launched:
            return None
        if (self._migrations_of.get((name, i), 0)
                >= fb.max_migrations_per_task):
            return None
        src = self.pool_of[(name, i)]
        src_node = self.node_placement(name, i)
        ts = self.g.node(name)
        best: "tuple[float, int, int] | None" = None
        for k in self._candidates(ts):
            if k == src:
                # same-pool migration: only onto a DIFFERENT node of a
                # node-level pool (moving within one node is a no-op).
                # ``exclude`` may leave no fitting node at all — then the
                # migration is a priced no-op, not a policy crash
                if self.node_states[k] is None:
                    continue
                node = self._choose_node(k, ts, exclude=src_node)
                if node < 0:
                    continue
                cost = self.alloc.transfer(src, k, src_node, node)
            else:
                node = (self._choose_node(k, ts)
                        if self.node_states[k] is not None else -1)
                cost = self.alloc.transfer(src, k)
            if best is None or (cost, k) < (best[0], best[1]):
                best = (cost, k, node)
        if best is None:
            return None  # no eligible target with free capacity
        cost, dst, node = best
        cost += fb.migration_base_cost
        if cost > fb.max_cost_ratio * self.tx_estimate(name):
            return None  # moving the data costs more than the rerun saves
        return dst, cost, node

    def _apply_migration(self, name: str, i: int, dst: int, cost: float,
                         node: int = -1) -> tuple[int, float]:
        src = self.pool_of[(name, i)]
        ts = self.g.node(name)
        self._release(src, ts, self._node_alloc.pop((name, i), None))
        node_alloc = self._acquire(dst, ts, node)
        if node_alloc is not None:
            self._node_alloc[(name, i)] = node_alloc
        self.node_of[(name, i)] = (node_alloc[0]
                                   if node_alloc is not None else -1)
        self.pool_of[(name, i)] = dst
        self._migrations_of[(name, i)] = (
            self._migrations_of.get((name, i), 0) + 1)
        self.migrations += 1
        return dst, cost

    def try_migrate(self, name: str, i: int) -> "tuple[int, float] | None":
        """Preempt straggler ``(name, i)`` and requeue it onto a different
        pool: release the source pool's resources, acquire the cheapest
        (by ``transfer_cost``) eligible target's, and return ``(new_pool,
        migration_cost)``.  No-ops (returns ``None``) when the task already
        finished or never launched, no other pool fits right now, the
        data-movement cost exceeds ``max_cost_ratio`` x the set's estimated
        TX, or the task hit ``max_migrations_per_task``.  The caller owns
        cancelling the old attempt and scheduling the new one."""
        cand = self._migration_candidate(name, i)
        if cand is None:
            return None
        return self._apply_migration(name, i, *cand)

    def _speculation_candidate(self, name: str,
                               i: int) -> "tuple[int, float, int] | None":
        """``(dst, cost, node)`` a speculative duplicate would use, or
        ``None`` — pure (no state change).  Unlike migration the source
        pool's slot stays held (the original keeps running), so a *free*
        slot must exist; the source pool itself is eligible (a same-pool
        duplicate moves data over the cheap intra-pool topology hops)."""
        fb = self.feedback
        if fb is None or not fb.speculate:
            return None
        if (name, i) in self.finished or (name, i) not in self.launched:
            return None
        if (name, i) in self._spec_pool:
            return None  # one duplicate at a time
        if (self._speculations_of.get((name, i), 0)
                >= fb.max_speculations_per_task):
            return None
        src = self.pool_of[(name, i)]
        src_node = self.node_placement(name, i)
        ts = self.g.node(name)
        best: "tuple[float, int, int] | None" = None
        for k in self._candidates(ts):
            if self.node_states[k] is not None:
                node = self._choose_node(k, ts)
                cost = (self.alloc.transfer(src, k, src_node, node)
                        if k == src else self.alloc.transfer(src, k))
            else:
                node, cost = -1, self.alloc.transfer(src, k)
            if best is None or (cost, k) < (best[0], best[1]):
                best = (cost, k, node)
        if best is None:
            return None  # no free duplicate slot anywhere
        cost, dst, node = best
        if dst != src:
            cost += fb.migration_base_cost
        if cost > fb.max_cost_ratio * self.tx_estimate(name):
            return None
        return dst, cost, node

    def _apply_speculation(self, name: str, i: int, dst: int, cost: float,
                           node: int = -1) -> tuple[int, float]:
        ts = self.g.node(name)
        node_alloc = self._acquire(dst, ts, node)
        if node_alloc is not None:
            self._spec_node_alloc[(name, i)] = node_alloc
        self._spec_pool[(name, i)] = dst
        self._speculations_of[(name, i)] = (
            self._speculations_of.get((name, i), 0) + 1)
        self.speculations += 1
        return dst, cost

    def try_speculate(self, name: str, i: int) -> "tuple[int, float] | None":
        """Launch a speculative duplicate of straggler ``(name, i)``:
        acquire a free slot on the cheapest eligible pool (the source pool
        included — a same-pool duplicate moves no data) and return
        ``(dup_pool, data_cost)``.  The original attempt keeps its slot
        and keeps running; first finisher wins and :meth:`complete` frees
        *both* slots (the loser is cancelled by the substrate).  No-ops
        when the task finished, a duplicate is already racing, the cap
        ``max_speculations_per_task`` is hit, no free slot exists, or the
        data cost exceeds ``max_cost_ratio`` x the estimated TX."""
        cand = self._speculation_candidate(name, i)
        if cand is None:
            return None
        return self._apply_speculation(name, i, *cand)

    def speculation_pool(self, name: str, i: int) -> "int | None":
        """Pool index of the task's racing duplicate, if any."""
        return self._spec_pool.get((name, i))

    def arbitrate(self, name: str, i: int,
                  elapsed: float) -> "tuple[str, int, float] | None":
        """Pick and apply the better mitigation for straggler ``(name,
        i)``: ``("migrate" | "speculate", dst_pool, cost)`` or ``None``.

        With only one mechanism enabled this degenerates to that mechanism
        (the always-migrate / always-speculate arms).  With both, each
        candidate is priced by the predictor's marginal-makespan delta —
        ``cost + fresh rerun TX on the candidate pool`` against the
        straggler's expected remaining runtime if left alone
        (``straggler_tail_ratio``) — and the action only happens when it
        is predicted to finish the task sooner; ties prefer migration
        (it frees the straggler's slot, speculation spends an extra one).
        """
        fb = self.feedback
        if fb is None:
            return None
        mig = self._migration_candidate(name, i)
        spec = self._speculation_candidate(name, i)
        if mig is None and spec is None:
            return None
        arbitrated = fb.migrate and fb.speculate
        if not arbitrated:
            # pure arms (always-migrate / always-speculate): PR-2
            # semantics, no cost-model gate beyond the candidates' own
            if spec is None:
                dst, cost = self._apply_migration(name, i, *mig)
                return "migrate", dst, cost
            dst, cost = self._apply_speculation(name, i, *spec)
            return "speculate", dst, cost
        pred = self.predictor
        src = self.pool_of[(name, i)]
        base = pred.straggler_baseline(self.tx_estimate(name, pool=src),
                                       elapsed, self.tail_ratio(name))
        # queued work turns the duplicate's slot into displaced work; at
        # the tail (nothing queued) speculation races for free.  Only
        # *dispatchable* work counts: admission-deferred sets are held
        # back ahead of migrating running tasks, so their queues are free
        pressure = any(self.ready[n] and self._dispatchable(n)
                       for n in self._live)
        d_mig = (pred.mitigation_delta(self.tx_estimate(name, pool=mig[0]),
                                       mig[1], base)
                 if mig is not None else None)
        d_spec = (pred.speculation_delta(
            self.tx_estimate(name, pool=spec[0]), spec[1], base, pressure)
            if spec is not None else None)
        # the arbiter declines whenever the action is predicted to finish
        # the task strictly LATER than letting it run (delta > 0) —
        # including when a cap or saturation left just one candidate
        # standing.  At exactly zero it still acts: the baseline is an
        # expectation, and a pressure-free duplicate races for free,
        # keeping the chance of finishing sooner
        if mig is None:
            if d_spec > 0:
                return None
            dst, cost = self._apply_speculation(name, i, *spec)
            return "speculate", dst, cost
        if spec is None:
            if d_mig > 0:
                return None
            dst, cost = self._apply_migration(name, i, *mig)
            return "migrate", dst, cost
        if d_mig > 0 and d_spec > 0:
            return None  # neither beats letting the straggler run
        # tie-break: under slot pressure migration wins (it frees the
        # straggler's slot; the duplicate would spend an extra one) —
        # without pressure speculation wins (the original races for free,
        # keeping its chance of finishing first)
        if d_mig < d_spec or (d_mig == d_spec and pressure):
            dst, cost = self._apply_migration(name, i, *mig)
            return "migrate", dst, cost
        dst, cost = self._apply_speculation(name, i, *spec)
        return "speculate", dst, cost

    # -- fault tolerance: failure events + priced recovery -------------------
    def hazard_rate(self) -> float:
        """Per-node-per-second failure hazard the recovery arbiter and the
        predictor price against: the configured stochastic rate, or — when
        the *observed* node-failure rate exceeds it (trace-driven runs
        configure no rate but suffer real failures) — the empirical
        ``failures / (sites x elapsed)`` estimate."""
        f = self.faults
        if f is None:
            return 0.0
        lam = f.node_failure_rate
        if self.node_failures and self._now > 0:
            lam = max(lam, self.node_failures
                      / (self._fault_sites * self._now))
        return lam

    def attempt_number(self, name: str, i: int) -> int:
        """How many attempts of (name, i) have failed so far — the attempt
        index the substrates key the seeded per-attempt failure draws on."""
        return self._failures_of.get((name, i), 0)

    def _ckpt_enabled(self, name: str) -> bool:
        """Does set ``name`` checkpoint its running attempts?  Forced by
        the pure ``recovery`` arms; under ``"arbitrated"`` priced per set:
        checkpoint iff the expected work a failure would destroy (hazard x
        TX x half the attempt, less what a restart still re-pays) exceeds
        the write overhead the set's every task pays up front."""
        f = self.faults
        if f is None or f.checkpoint_interval <= 0:
            return False
        if f.recovery == "rerun":
            return False
        if f.recovery == "restart":
            return True
        t = self.tx_estimate(name)
        if t <= 0:
            return False
        c, w, r = (f.checkpoint_interval, f.checkpoint_write_cost,
                   f.checkpoint_read_cost)
        n_writes = math.floor(t / c)
        if n_writes <= 0:
            return False  # the task finishes before its first snapshot
        # per-second hazard of losing the attempt: node loss + software
        # failure (one expected per-attempt draw spread over the TX)
        lam = self.hazard_rate() + f.task_failure_prob / t
        if lam <= 0:
            return False
        loss_per_failure = t / 2 - (c / 2 + r + self.alloc.intra_pool_cost)
        return lam * t * max(0.0, loss_per_failure) > n_writes * w

    def checkpoint_params(self, name: str) -> "tuple[float, float, float] | None":
        """(interval, write cost, read cost) when set ``name`` checkpoints,
        else None — the predictor's hazard term reads this."""
        if not self._ckpt_enabled(name):
            return None
        f = self.faults
        return (f.checkpoint_interval, f.checkpoint_write_cost,
                f.checkpoint_read_cost)

    def dispatch_duration(self, name: str, i: int, d: float,
                          k: int) -> float:
        """Adjust a freshly dispatched attempt's duration for recovery and
        checkpoint overheads (the substrates call this at every dispatch
        while faults are on).  A restart-from-checkpoint decision resumes
        from the saved progress and pays the checkpoint read over the
        topology distance from the writer's placement
        (:meth:`Allocation.transfer`); a checkpointing set pays one write
        per completed interval."""
        f = self.faults
        if f is None:
            return d
        rec = self._recovery.pop((name, i), None)
        if rec is not None:
            saved, sp, sn = rec
            d = max(0.0, d - saved)
            d += f.checkpoint_read_cost + self.alloc.transfer(
                sp, k, sn, self.node_of.get((name, i), -1))
        if self._ckpt_enabled(name):
            d += math.floor(d / f.checkpoint_interval) \
                * f.checkpoint_write_cost
            self._ckpt_run[(name, i)] = (k, self.node_of.get((name, i), -1))
        else:
            self._ckpt_run.pop((name, i), None)
        return d

    def _promote_duplicate(self, key: tuple[str, int]) -> None:
        """The primary attempt died but its duplicate lives: the
        duplicate's slot becomes the primary allocation (the task stays
        launched, nothing is re-enqueued, no work is lost)."""
        name, i = key
        dst = self._spec_pool.pop(key)
        dup_alloc = self._spec_node_alloc.pop(key, None)
        if dup_alloc is not None:
            self._node_alloc[key] = dup_alloc
        self.pool_of[key] = dst
        self.node_of[key] = dup_alloc[0] if dup_alloc is not None else -1
        if key in self._ckpt_run:
            self._ckpt_run[key] = (dst, self.node_of[key])

    def _record_failure(self, name: str, i: int, elapsed: float) -> None:
        """Plain-fail bookkeeping shared by node and task failures: count
        the attempt, feed the estimator's empirical failure rate, decide
        the recovery arm (restart-from-checkpoint when the saved progress
        beats the estimated read-back, or when forced), and re-enqueue."""
        key = (name, i)
        self._failures_of[key] = self._failures_of.get(key, 0) + 1
        if self.estimator is not None:
            self.estimator.record_failure(name)
        f = self.faults
        ck = self._ckpt_run.pop(key, None)
        plan = "rerun"
        if ck is not None and elapsed > 0 and f.recovery != "rerun":
            c, w = f.checkpoint_interval, f.checkpoint_write_cost
            saved = math.floor(elapsed / (c + w)) * c
            if saved > 0:
                read_est = (f.checkpoint_read_cost
                            + self.alloc.intra_pool_cost)
                if f.recovery == "restart" or saved > read_est:
                    self._recovery[key] = (saved, ck[0], ck[1])
                    plan = "restart"
        if plan == "restart":
            self.recoveries_restart += 1
        else:
            self.recoveries_rerun += 1
        self.launched.discard(key)
        self.pool_of.pop(key, None)
        self.node_of.pop(key, None)

    def _requeue_failed(self, failed: "list[tuple[str, int]]") -> None:
        """Failed tasks retry at the head of their ready queue, ascending
        index order preserved."""
        for name, i in sorted(failed, reverse=True):
            self.ready[name].appendleft(i)

    def _placeable_without(self, k: int, node: int) -> bool:
        """Conservation guard: would every unfinished set still have SOME
        possible placement (full-capacity fit on a surviving node / pool)
        if (pool k, node) went down?  A failure that strands work is
        refused — failed must never become lost."""
        for n in self._live:
            if self._set_remaining[n] <= 0:
                continue
            ts = self.g.node(n)
            ok = False
            for j, p in enumerate(self.pools):
                if not p.accepts(ts):
                    continue
                need_c, need_g = self._needs(j, ts)
                states = self.node_states[j]
                if states is not None:
                    ok = any(not ns.down and ns.cpus >= need_c
                             and ns.spec.gpus >= need_g
                             for m, ns in enumerate(states)
                             if not (j == k and m == node))
                else:
                    cc, cg = self.cap_cpus[j], self.cap_gpus[j]
                    if j == k:
                        cc -= min(p.node.cpus, cc)
                        cg -= min(p.node.gpus, cg)
                    ok = cc >= need_c and cg >= need_g
                if ok:
                    break
            if not ok:
                return False
        return True

    def fail_node(self, k: int, node: int, now: float = 0.0,
                  started: "dict[tuple[str, int], float] | None" = None,
                  ) -> "FailureEvent | None":
        """Node ``node`` of pool ``k`` fails at ``now``: every attempt
        placed there is released and its task re-enqueued (or its replica
        promoted), the node's remaining slots leave the free/capacity
        counters, and the incremental indexes are updated.  ``started``
        maps in-flight attempts to their start times on the substrate's
        clock — the recovery arbiter prices saved checkpoint progress off
        it.  Returns the :class:`FailureEvent` applied, or ``None`` when
        the failure is refused (unknown/already-down node, or the
        conservation guard: taking the node down would leave some
        unfinished set with no possible placement anywhere)."""
        if self.faults is None:
            return None
        self._now = max(self._now, now)
        states = self.node_states[k]
        if states is not None:
            if node < 0 or node >= len(states) or states[node].down:
                return None
        else:
            if (node < 0 or node >= self.pools[k].num_nodes
                    or (k, node) in self._agg_lost):
                return None
        if not self._placeable_without(k, node):
            return None
        started = started or {}
        failed: list[tuple[str, int]] = []
        promoted: list[tuple[str, int]] = []
        cancelled: list[tuple[str, int]] = []

        def fail_primary(key):
            name, i = key
            ts = self.g.node(name)
            self._release(self.pool_of[key], ts,
                          self._node_alloc.pop(key, None))
            dst = self._spec_pool.get(key)
            if dst is not None:
                dup_alloc = self._spec_node_alloc.get(key)
                dup_dead = (dst == k and dup_alloc is not None
                            and dup_alloc[0] == node)
                if not dup_dead:
                    self._promote_duplicate(key)
                    promoted.append(key)
                    return
                self._release(dst, ts, self._spec_node_alloc.pop(key, None))
                self._spec_pool.pop(key)
            self._record_failure(name, i,
                                 now - started.get(key, now))
            failed.append(key)

        def cancel_duplicate(key):
            name, i = key
            self._release(self._spec_pool.pop(key), self.g.node(name),
                          self._spec_node_alloc.pop(key, None))
            cancelled.append(key)

        if states is not None:
            for key in sorted(key for key, na in self._node_alloc.items()
                              if self.pool_of.get(key) == k
                              and na[0] == node):
                fail_primary(key)
            for key in sorted(key for key, na
                              in self._spec_node_alloc.items()
                              if self._spec_pool.get(key) == k
                              and na[0] == node):
                cancel_duplicate(key)
            lost_c, lost_g = states[node].fail()
            self.free_cpus[k] -= lost_c
            self.free_gpus[k] -= lost_g
            self.cap_cpus[k] -= states[node].cpus
            self.cap_gpus[k] -= states[node].spec.gpus
            if self.incremental:
                self._node_changed(k, node)
        else:
            p = self.pools[k]
            lost_c = min(p.node.cpus, self.cap_cpus[k])
            lost_g = min(p.node.gpus, self.cap_gpus[k])
            self.free_cpus[k] -= lost_c
            self.free_gpus[k] -= lost_g
            self.cap_cpus[k] -= lost_c
            self.cap_gpus[k] -= lost_g
            self._agg_lost[(k, node)] = (lost_c, lost_g)
            # an aggregate pool has no node placements: the tasks "on the
            # dead node" are the latest-launched attempts on the pool,
            # failed until what survivors hold fits the shrunk capacity
            victims = sorted(
                [key for key in self.launched
                 if key not in self.finished
                 and self.pool_of.get(key) == k],
                reverse=True)
            dups = sorted((key for key, j in self._spec_pool.items()
                           if j == k and key not in self.finished),
                          reverse=True)
            while ((self.free_cpus[k] < 0 or self.free_gpus[k] < 0)
                   and (victims or dups)):
                if dups:
                    cancel_duplicate(dups.pop(0))
                    continue
                fail_primary(victims.pop(0))
        self._requeue_failed(failed)
        self.node_failures += 1
        if self.predictor is not None:
            self.predictor.invalidate()
        # an aggregate loss may cancel a duplicate AND then fail its
        # primary in the same sweep: the cancel entry is moot (there is
        # no surviving attempt whose event the substrate should re-push)
        cancelled = [c for c in cancelled if c not in failed]
        ev = FailureEvent("node", pool=k, node=node, failed=tuple(failed),
                          promoted=tuple(promoted),
                          cancelled=tuple(cancelled))
        self.fault_log.append((now, "node_failure", self.pools[k].name,
                               node, len(failed), len(promoted),
                               len(cancelled)))
        return ev

    def recover_node(self, k: int, node: int, now: float = 0.0) -> bool:
        """A failed node rejoins, fully idle: restore its capacity to the
        free/capacity counters and the incremental indexes."""
        if self.faults is None:
            return False
        states = self.node_states[k]
        if states is not None:
            if node < 0 or node >= len(states) or not states[node].down:
                return False
            c, g = states[node].restore()
            self.free_cpus[k] += c
            self.free_gpus[k] += g
            self.cap_cpus[k] += c
            self.cap_gpus[k] += g
            if self.incremental:
                self._node_changed(k, node)
        else:
            lost = self._agg_lost.pop((k, node), None)
            if lost is None:
                return False
            self.free_cpus[k] += lost[0]
            self.free_gpus[k] += lost[1]
            self.cap_cpus[k] += lost[0]
            self.cap_gpus[k] += lost[1]
            if self.incremental:
                self._agg_freed(k)
        self.fault_log.append((now, "node_recovery",
                               self.pools[k].name, node))
        return True

    def fail_task(self, name: str, i: int, now: float = 0.0,
                  elapsed: float = 0.0) -> "FailureEvent | None":
        """The running primary attempt of (name, i) fails (software
        fault): release its slot and re-enqueue the task — unless a
        replica / speculative duplicate is racing, which is promoted to
        primary instead (a software crash of one attempt does not touch
        the other).  No-op on tasks not currently in flight."""
        if self.faults is None:
            return None
        self._now = max(self._now, now)
        key = (name, i)
        if key in self.finished or key not in self.launched:
            return None
        ts = self.g.node(name)
        self._release(self.pool_of[key], ts, self._node_alloc.pop(key, None))
        self.task_failures += 1
        if key in self._spec_pool:
            self._promote_duplicate(key)
            ev = FailureEvent("task", promoted=(key,))
        else:
            self._record_failure(name, i, elapsed)
            self._requeue_failed([key])
            ev = FailureEvent("task", failed=(key,))
        if self.predictor is not None:
            self.predictor.invalidate()
        self.fault_log.append((now, "task_failure", name, i,
                               "promoted" if ev.promoted else "requeued"))
        return ev

    def at_risk(self, running: "dict[tuple[str, int], float]",
                now: float) -> list[tuple[str, int]]:
        """Running tasks worth proactively replicating: probability of
        losing the attempt's node before it finishes (``1 - exp(-hazard x
        expected remaining)``) at or above ``replicate_risk``, no
        duplicate racing yet."""
        f = self.faults
        if f is None or not f.replicate:
            return []
        lam = self.hazard_rate()
        if lam <= 0:
            return []
        out = []
        for (name, i), start in running.items():
            key = (name, i)
            if (key in self.finished or key in self._spec_pool
                    or key not in self.launched):
                continue
            rem = self.tx_estimate(name, pool=self.pool_of.get(key)) \
                - (now - start)
            if rem <= 0:
                continue  # about to finish: nothing left to protect
            if 1.0 - math.exp(-lam * rem) >= f.replicate_risk:
                out.append(key)
        return out

    def try_replicate(self, name: str, i: int) -> "tuple[int, float] | None":
        """Proactive replication of an at-risk task: launch a duplicate on
        a *different* node (one node loss must never take both attempts)
        through the speculation slot machinery; when the primary's node
        later dies the replica is promoted and no work is lost.  The risk
        gate lives in :meth:`at_risk`; here only a free slot is needed."""
        f = self.faults
        if f is None or not f.replicate:
            return None
        key = (name, i)
        if (key in self.finished or key not in self.launched
                or key in self._spec_pool):
            return None
        if self._speculations_of.get(key, 0) >= 2:
            return None  # replica churn guard (re-replication after loss)
        src = self.pool_of[key]
        src_node = self.node_placement(name, i)
        ts = self.g.node(name)
        best: "tuple[float, int, int] | None" = None
        for k in self._candidates(ts):
            if self.node_states[k] is not None:
                node = self._choose_node(
                    k, ts, exclude=src_node if k == src else -1)
                if node < 0:
                    continue
                cost = self.alloc.transfer(src, k, src_node, node)
            else:
                node, cost = -1, self.alloc.transfer(src, k)
            if best is None or (cost, k) < (best[0], best[1]):
                best = (cost, k, node)
        if best is None:
            return None
        cost, dst, node = best
        self._apply_speculation(name, i, dst, cost, node)
        self.replications += 1
        return dst, cost

    # -- online makespan re-prediction (core/predictor.py) ------------------
    def predict_stamp(self) -> tuple:
        """Monotonic fingerprint of every engine-side input a prediction
        can move through: the admission epoch (completions, TX
        observations, arrivals, admissions, leases) plus the counters it
        does not cover (launches change ``running``/``gpu_held``;
        migrations/speculations/failures/recoveries move placements and
        the hazard estimate).  An unchanged stamp at an unchanged clock
        means :meth:`repredict` would recompute the same snapshot."""
        return (self._adm_epoch, self._starts, self.migrations,
                self.speculations, self.node_failures, self.task_failures,
                self.replications, self.recoveries_restart,
                self.recoveries_rerun, self.leases_granted,
                self.leases_expired)

    def repredict(self, now: float,
                  running: "dict[tuple[str, int], float]"
                  ) -> "MakespanPrediction | None":
        """Re-evaluate the analytic model (Eqns. 2-6) on the live TX
        estimates and the current progress; appends to (and returns the
        newest entry of) ``self.predictions``.  ``running`` maps (set,
        index) -> start time on the caller's clock, exactly as for
        :meth:`stragglers`.

        Two fast paths guard the evaluation.  *Dedupe* (always on): a
        call at the same clock (event ``now`` and scheduling-pass
        ``_now`` — the hazard estimate reads the latter) with an
        unchanged :meth:`predict_stamp` would recompute the identical
        snapshot, so the previous prediction object is re-appended — the
        trace keeps its length and values bit-identical while the
        recomputation is skipped (the back-to-back same-timestamp pass
        the substrates' event loops otherwise pay twice).  *Throttle*
        (``PredictOptions``): skips the evaluation entirely — nothing is
        appended and the last prediction is returned — while the stamp
        is clean (``dirty_only``) or the modelled-seconds floor
        (``min_interval``) has not elapsed; the first call always
        evaluates."""
        if self.predictor is None:
            return None
        stamp = self.predict_stamp()
        # the scheduling-pass clock ``_now`` reaches the prediction only
        # through the hazard estimate, which is dead without faults — so
        # it only disambiguates the key on fault runs (otherwise a
        # same-instant sentinel pair, e.g. arrival + watchdog, would
        # never dedupe: the pass between them moves ``_now``)
        key = (now, self._now if self.faults is not None else 0.0, stamp)
        last_key = self._last_pred_key
        opts = self.predict_opts
        if opts is not None and last_key is not None:
            if opts.dirty_only and stamp == last_key[2]:
                return self._last_pred
            if now - self._last_pred_now < opts.min_interval:
                return self._last_pred
        if last_key is not None and last_key == key:
            self.predictions.append(self._last_pred)
            return self._last_pred
        elapsed = {k: now - start for k, start in running.items()
                   if k not in self.finished}
        run_per_set: dict[str, int] = {}
        for (n, _i) in elapsed:
            run_per_set[n] = run_per_set.get(n, 0) + 1
        # the live frontier only: finished sets contribute exact zeros to
        # every term the predictor derives from ``pending``
        pending = {n: max(0, self._set_remaining[n] - run_per_set.get(n, 0))
                   for n in self._live}
        # live GPU holdings per set (speculative duplicates included):
        # what the node-level occupancy accounting actually charged, so
        # the contention term prices the GPUs concurrent sets truly hold
        gpu_held: dict[str, int] = {}
        for (n, i) in elapsed:
            k = self.pool_of.get((n, i))
            if k is not None:
                gpu_held[n] = (gpu_held.get(n, 0)
                               + self._needs(k, self.g.node(n))[1])
        for (n, i), k in self._spec_pool.items():
            if (n, i) not in self.finished:
                gpu_held[n] = (gpu_held.get(n, 0)
                               + self._needs(k, self.g.node(n))[1])
        if self.faults is not None:
            self.predictor.set_hazard(
                self.hazard_rate() if self.faults.hazard_aware else 0.0,
                self.checkpoint_params)
        p = self.predictor.predict(
            self.tx_estimate, now, pending, elapsed,
            done_fraction=self._n_done / max(1, self._n_total),
            tx_std=self.tx_std_estimate, gpu_held=gpu_held)
        if self.admission is not None and self.workflow_of:
            # per-workflow Eqn. 2-5 snapshots for the prediction trace —
            # batched through BatchEqns once enough workflows are in
            # flight for the one-matrix evaluation to beat scalar loops
            wfs = {self.workflow_of[n] for n in self._live
                   if self._set_remaining[n] > 0 and n in self.workflow_of}
            if len(wfs) >= 4:
                p = dataclasses.replace(
                    p, wf_models=self.predictor.workflow_models(
                        self.tx_estimate, wfs))
        self._last_pred_key = key
        self._last_pred = p
        self._last_pred_now = now
        self._pred_evals += 1
        self.predictions.append(p)
        return p

    def tx_std_estimate(self, name: str) -> float:
        """Live dispersion of the set's observed TX (0 before feedback or
        before the variance estimate has samples)."""
        if self.estimator is None:
            return 0.0
        return self.estimator.std(name)

    def tail_ratio(self, name: str) -> float:
        """The arbiter's straggler-left-alone tail ratio: the static
        ``FeedbackOptions.straggler_tail_ratio`` by default, or — with
        ``calibrate_tail`` on — the set's *observed* tail quantile over
        its running mean (un-winsorized durations), once enough
        completions accumulated.  Never below ``straggler_min_ratio``
        (a flagged straggler is by definition past that)."""
        fb = self.feedback
        if fb is None:
            return 4.0
        if fb.calibrate_tail and self.estimator is not None:
            r = self.estimator.tail_ratio(name, q=fb.tail_quantile,
                                          min_count=fb.min_samples)
            if r is not None:
                return max(r, fb.straggler_min_ratio)
        return fb.straggler_tail_ratio

    def data_cost(self, name: str, k: int, node: int = -1) -> float:
        """Mean data-movement cost of pulling set ``name``'s parent outputs
        to pool ``k``: the allocation's ``transfer_cost`` weighted by where
        the parent tasks actually ran.  With ``node`` given (node-level
        pools) same-pool pulls are priced at the node-granular topology
        distances of :meth:`~repro.core.resources.Allocation.transfer`
        (same NVLink group <= same node <= intra-pool) instead of the flat
        pool-level zero.  Cached once every parent set has finished
        (placements are final from then on)."""
        key = (name, k, node)
        cached = self._data_cost_cache.get(key)
        if cached is not None:
            return cached
        parents = self.g.parents(name)
        total, n = 0.0, 0
        for p in parents:
            for i in range(self.g.node(p).num_tasks):
                j = self.pool_of.get((p, i))
                if j is None:
                    continue
                total += self.alloc.transfer(j, k,
                                             self.node_of.get((p, i), -1),
                                             node)
                n += 1
        cost = total / n if n else 0.0
        if not parents or all(self._set_remaining[p] == 0 for p in parents):
            self._data_cost_cache[key] = cost
        return cost

    def best_data_cost(self, name: str, k: int) -> float:
        """Best-achievable data cost of placing one task of ``name`` on
        pool ``k``: for a ``node_level`` pool the minimum node-granular
        cost over its nodes (the pool's score must not pretend every
        same-pool pull is free), for an aggregate pool the pool-level
        matrix cost."""
        states = self.node_states[k]
        if states is None:
            return self.data_cost(name, k)
        return min(self.data_cost(name, k, node=n)
                   for n in range(len(states)))

    def _needs(self, k: int, ts: TaskSet) -> tuple[int, int]:
        p = self.pools[k]
        return (0 if p.oversubscribe_cpus else ts.cpus_per_task,
                0 if p.oversubscribe_gpus else ts.gpus_per_task)

    def _candidates(self, ts: TaskSet) -> list[int]:
        """Pools that can start one task of ``ts`` right now.  The
        incremental path reads the footprint-class indexes — O(#eligible
        pools) with no node scan; the node fit implies the aggregate fit
        (a node's free counters are bounded by the pool's)."""
        if not self.incremental:
            return self._candidates_scan(ts)
        out = []
        for k, ent in self._set_pools[ts.name]:
            if ent.nodes is not None:
                if not ent.nodes:
                    continue
            elif (ent.need_c > self.free_cpus[k]
                    or ent.need_g > self.free_gpus[k]):
                continue
            out.append(k)
        return out

    def _candidates_scan(self, ts: TaskSet) -> list[int]:
        """Brute-force candidate scan (the pre-index implementation; the
        integrity checker's and scale benchmark's reference)."""
        out = []
        for k, p in enumerate(self.pools):
            if p.only_kinds is not None and ts.kind not in p.only_kinds:
                continue
            need_c, need_g = self._needs(k, ts)
            if need_c > self.free_cpus[k] or need_g > self.free_gpus[k]:
                continue
            # fragmentation honesty: a node-level pool must have ONE node
            # that fits the task — aggregate co-fit alone is not placement
            states = self.node_states[k]
            if states is not None and not any(
                    ns.fits(need_c, need_g) for ns in states):
                continue
            out.append(k)
        return out

    # -- admission control (campaign runs) ----------------------------------
    def _dispatchable(self, name: str) -> bool:
        """Ready work that could actually use a free slot right now:
        arrived, and (with admission on) admitted.  Admission-deferred
        sets are held back in preference to disturbing running tasks, so
        their queued work is *not* slot pressure for the arbiter."""
        if self.arrival_of.get(name, 0.0) > self._now:
            return False
        return self.admission is None or name in self.admitted

    def _active_priority(self) -> "int | None":
        """Highest workflow priority among admitted sets with remaining
        work (``None`` when nothing admitted is still in flight)."""
        out = None
        for m in self.admitted:
            if self._set_remaining[m] <= 0:
                continue
            p = self.wf_priority.get(m, 0)
            out = p if out is None or p > out else out
        return out

    def _is_narrow(self, name: str) -> bool:
        """Backfill test: one task fits the current largest free GPU
        block (:meth:`largest_free_block`) and the set's remaining strict
        demand stays within ``backfill_fraction`` of the free capacity
        LEFT ONCE the admitted frontier claims its share — such a set
        fills fragmentation holes without displacing the admitted work's
        waves (the admission pass runs before dispatch, so raw free
        counters would overstate what is genuinely spare)."""
        opts = self.admission
        ts = self.g.node(name)
        remaining = self._set_remaining[name]
        free_c = free_g = block = 0
        strict_c = strict_g = False
        for k, p in enumerate(self.pools):
            if not p.accepts(ts):
                continue
            need_c, need_g = self._needs(k, ts)
            if need_g:
                strict_g = True
                free_g += self.free_gpus[k]
                block = max(block, self.largest_free_block(k))
            if need_c:
                strict_c = True
                free_c += self.free_cpus[k]
        # the admitted sets' ready tasks will claim their strict
        # footprints this very pass — only what remains is backfillable
        for m in self.admitted:
            if not self.ready[m]:
                continue
            mts = self.g.node(m)
            needs = [self._needs(k, mts) for k, p in enumerate(self.pools)
                     if p.accepts(mts)]
            claim_c = max((c for c, _g in needs), default=0)
            claim_g = max((g for _c, g in needs), default=0)
            free_c -= len(self.ready[m]) * claim_c
            free_g -= len(self.ready[m]) * claim_g
        free_c, free_g = max(0, free_c), max(0, free_g)
        if strict_g:
            return (ts.gpus_per_task <= min(block, free_g)
                    and remaining * ts.gpus_per_task
                    <= opts.backfill_fraction * free_g)
        if strict_c:
            return (remaining * ts.cpus_per_task
                    <= opts.backfill_fraction * free_c)
        return True  # fully oversubscribed: consumes no bounded resource

    def _admission_price(self, name: str, now: float
                         ) -> tuple[MakespanPrediction, MakespanPrediction,
                                    MakespanPrediction]:
        """Price admitting ``name``'s workflow next to the admitted work:
        predictor snapshots of (a) the admitted workflows' remaining work
        alone, (b) combined with the candidate workflow's (the cross-
        workflow contention term shrinks everyone's slots by demand
        share), and (c) the candidate workflow's alone (its dedicated
        residual, i.e. what deferring until the admitted work drains
        would cost it).  Running tasks are priced as pending (the engine
        has no per-task clocks; the bound is conservative by at most one
        in-flight wave).

        Prices are *epoch-cached*: every input of these predictions (set
        remainders, TX estimates, the admitted set, arrivals) only moves
        when an engine event bumps ``_adm_epoch``, so a candidate
        re-priced on a later pass within the same epoch reuses its cached
        triple, and the admitted-work ``base`` snapshot — identical for
        every candidate priced in one epoch — is hoisted across them.
        Decisions read only the now-independent ``remaining`` fields, so
        caching is decision-bit-identical to re-predicting (a cached
        prediction's ``now``/``total`` may be stale)."""
        cached = self._adm_price_cache.get(name)
        if cached is not None and cached[0] == self._adm_epoch:
            return cached[1]
        wf = self.workflow_of.get(name)
        active = {self.workflow_of.get(m) for m in self.admitted
                  if self._set_remaining[m] > 0}
        base_pending = {m: self._set_remaining[m] for m in self._live
                        if self._set_remaining[m] > 0
                        and self.workflow_of.get(m) in active}
        cand_pending = {m: self._set_remaining[m] for m in self._live
                        if self._set_remaining[m] > 0
                        and self.workflow_of.get(m) == wf}
        with_pending = dict(base_pending)
        with_pending.update(cand_pending)
        predict = self.predictor.predict
        bc = self._adm_base_cache
        if bc is not None and bc[0] == self._adm_epoch:
            base = bc[1]
        else:
            base = predict(self.tx_estimate, now, base_pending, {},
                           tx_std=self.tx_std_estimate)
            self._adm_base_cache = (self._adm_epoch, base)
        with_ = predict(self.tx_estimate, now, with_pending, {},
                        tx_std=self.tx_std_estimate)
        alone = predict(self.tx_estimate, now, cand_pending, {},
                        tx_std=self.tx_std_estimate)
        out = (base, with_, alone)
        self._adm_price_cache[name] = (self._adm_epoch, out)
        return out

    def _admit_decision(self, name: str, now: float) -> tuple[bool, str]:
        opts = self.admission
        pri = self.wf_priority.get(name, 0)
        active = self._active_priority()
        if active is None or pri >= active:
            return True, "priority"  # nothing more important in flight
        since = self.deferred.get(name)
        if since is not None and now - since >= opts.max_defer_time:
            return True, "aged"
        if self._is_narrow(name):
            return True, "backfill"
        base, with_, alone = self._admission_price(name, now)
        # Eqn. 5 at admission granularity: t_seq = run the candidate's
        # workflow AFTER the admitted work drains, t_async = run them
        # combined (contention-priced).  When the predicted improvement
        # collapses below the floor, admitting now buys ~no overlap (the
        # workflows fight for the same devices) — AND the candidate's
        # tasks would pin those devices across many of the admitted
        # work's scheduling rounds (tasks are not preemptible): that is
        # head-of-line blocking with no masking upside, so the set
        # defers.  A candidate of comparable task granularity interleaves
        # harmlessly under priority ordering and is admitted even when
        # the predicted overlap is poor.
        serial = base.remaining + alone.remaining
        i_adm = (1.0 - with_.remaining / serial) if serial > 0 else 1.0
        active_tx = max((self.tx_estimate(m) for m in self.admitted
                         if self._set_remaining[m] > 0), default=0.0)
        if (i_adm < opts.i_floor and active_tx > 0
                and self.tx_estimate(name) > opts.hold_ratio * active_tx):
            if opts.deadline_aware:
                # SLO override: the candidate's dedicated residual no
                # longer fits before its workflow deadline (plus margin)
                # — defer would turn the likely miss into a certain one
                dl = self.wf_deadline.get(name)
                if (dl is not None and dl - now - alone.remaining
                        <= opts.deadline_margin * alone.remaining):
                    return True, "deadline"
            return False, "defer"
        return True, "priced"

    def _admit(self, name: str, now: float, why: str) -> None:
        self.admitted.add(name)
        self.deferred.pop(name, None)
        self.admission_log.append((now, name, why))
        self._adm_epoch += 1  # the admitted frontier is a pricing input

    def revoke_workflow(self, wf: str, now: float) -> bool:
        """Preemptive revocation: un-admit every admitted set of workflow
        ``wf``, returning them to the deferred pool (re-priced on later
        passes, still covered by the idle conservation guard — revoked is
        never lost).  Refuses (False) once any of the workflow's tasks
        has launched: revocation never kills a started workflow."""
        if wf in self._wf_started:
            return False
        sets = [m for m in self.admitted
                if self.workflow_of.get(m) == wf]
        if not sets:
            return False
        for m in sorted(sets):
            self.admitted.discard(m)
            self.deferred.setdefault(m, now)
        self.admission_revocations += 1
        self.admission_log.append((now, wf, "revoke"))
        self._adm_epoch += 1
        return True

    def _revoke_for(self, urgent: str, now: float) -> None:
        """A deadline-driven admission may displace ONE admitted
        workflow: strictly lower priority than the urgent set's, not yet
        started, with remaining work — lowest priority first, then the
        latest arrival (the cheapest commitment to walk back)."""
        upri = self.wf_priority.get(urgent, 0)
        uwf = self.workflow_of.get(urgent)
        cands: dict[str, tuple[int, float]] = {}
        for m in self.admitted:
            wf = self.workflow_of.get(m)
            if (wf is None or wf == uwf or wf in self._wf_started
                    or self._set_remaining[m] <= 0):
                continue
            pri = self.wf_priority.get(m, 0)
            if pri >= upri:
                continue
            cands[wf] = (pri, -self.arrival_of.get(m, 0.0))
        if cands:
            victim = min(cands, key=lambda w: (*cands[w], w))
            self.revoke_workflow(victim, now)

    def _admission_pass(self, now: float) -> None:
        cand = [n for n in self.priority
                if n not in self.admitted and self.ready[n]
                and self.arrival_of.get(n, 0.0) <= now]
        if cand:
            # most-important first; Python's stable sort keeps the
            # policy's own set order within (priority, arrival) ties
            cand.sort(key=lambda n: (-self.wf_priority.get(n, 0),
                                     self.arrival_of.get(n, 0.0)))
            for n in cand:
                ok, why = self._admit_decision(n, now)
                if ok:
                    self._admit(n, now, why)
                    if why == "deadline" and self.admission.revoke:
                        self._revoke_for(n, now)
                elif n not in self.deferred:
                    self.deferred[n] = now
                    self.admission_deferrals += 1
                    self.admission_log.append((now, n, "defer"))
        # conservation guard: deferred != lost.  When nothing runs and no
        # admitted set can start, admit the best deferred set outright.
        if (self.deferred and not any(self.running_per_pool)
                and not any(self.ready[m] for m in self.admitted)):
            n = min(self.deferred, key=lambda m: (
                -self.wf_priority.get(m, 0), self.deferred[m], m))
            self._admit(n, now, "idle")

    # -- scheduling ---------------------------------------------------------
    def startable(self, now: float = 0.0) -> list[tuple[str, int, int]]:
        """Backfill pass: pop every ready task that fits somewhere *now*,
        acquire its resources and return ``(set, index, pool_idx)`` triples
        in launch order.  Walks sets in policy priority order (re-ranked by
        observed TX first when feedback marked it dirty).  A policy may
        defer a task (``choose_pool`` -> ``None``) to hold it for a busy
        pool; deferred tasks stay at the head of their ready queue.

        ``now`` is the substrate's scheduling clock: campaign sets whose
        workflow has not arrived yet are skipped, and with admission
        control on, the admission pass runs first — only admitted sets
        dispatch."""
        self._now = now
        if self._priority_dirty:
            infos = [dataclasses.replace(si, tx_mean=self.tx_estimate(si.name))
                     for si in self._infos]
            self.priority = list(self.policy.order_sets(infos))
            self._priority_dirty = False
        self.policy.begin_pass(self)
        if self.admission is not None:
            self._admission_pass(now)
        out: list[tuple[str, int, int]] = []
        for name in self.priority:
            q = self.ready[name]
            if not q:
                continue
            if self.arrival_of and self.arrival_of.get(name, 0.0) > now:
                continue  # workflow not arrived yet
            if self.admission is not None and name not in self.admitted:
                continue  # admission-deferred (re-priced next pass)
            if name in self._blocked:
                # nothing was released towards any of this set's footprint
                # classes since its last no-candidate offer — re-scanning
                # would find nothing (event-driven dirty tracking)
                continue
            ts = self.g.node(name)
            while q:
                cands = self._candidates(ts)
                if not cands:
                    if self.incremental:
                        self._mark_blocked(name)
                    break
                i = q.popleft()
                if (name, i) in self.finished or (name, i) in self.launched:
                    continue
                k = self.policy.choose_pool(ts, cands, self)
                if k is None:  # policy defers: wait for the preferred pool
                    q.appendleft(i)
                    break
                node_alloc = self._acquire(k, ts)
                if node_alloc is not None:
                    self._node_alloc[(name, i)] = node_alloc
                self.node_of[(name, i)] = (node_alloc[0]
                                           if node_alloc is not None else -1)
                self.launched.add((name, i))
                self._starts += 1
                self.pool_of[(name, i)] = k
                wf = self.workflow_of.get(name)
                if wf is not None:
                    self._wf_started.add(wf)  # now beyond revocation
                out.append((name, i, k))
        return out

    def complete(self, name: str, i: int, *, spec_won: bool = False) -> int:
        """Mark task ``(name, i)`` finished: release its pool's resources,
        decrement dependency counters, enqueue newly-ready tasks.  Returns
        the pool index of the *winning* attempt — the original's, or the
        speculative duplicate's when the caller passes ``spec_won=True``
        (both attempts' slots are released either way; the loser is
        cancelled by the substrate).  With ``spec_won`` the engine also
        records the duplicate's pool/node as the task's final placement
        (``pool_of``/``node_of``), so children's node-granular data costs
        price pulls from where the output actually lives instead of from
        the cancelled original's node.  Idempotent per task (duplicate
        completions — straggler mitigation — are no-ops)."""
        if (name, i) in self.finished:
            return self.pool_of.get((name, i), 0)
        if self.faults is not None and (name, i) not in self.launched:
            # stale completion of a failed attempt: the failure path
            # already released every slot and re-enqueued the task, so
            # freeing again here would double-credit the pool
            return self.pool_of.get((name, i), 0)
        k = self.pool_of.get((name, i), 0)
        ts = self.g.node(name)
        need_c, need_g = self._needs(k, ts)
        self.free_cpus[k] += need_c
        self.free_gpus[k] += need_g
        if (name, i) in self.launched:
            self.running_per_pool[k] -= 1
        node_alloc = self._node_alloc.pop((name, i), None)
        if node_alloc is not None:
            node, takes = node_alloc
            self.node_states[k][node].release(need_c, takes)
            if self.incremental:
                self._node_changed(k, node)
            self._maybe_retire(k, node)
        elif self.incremental and self.node_states[k] is None:
            self._agg_freed(k)
        spec = self._spec_pool.pop((name, i), None)
        spec_node_alloc = self._spec_node_alloc.pop((name, i), None)
        if spec is not None:  # the losing attempt's slot is freed with it
            self._release(spec, ts, spec_node_alloc)
            if spec_won:
                # the duplicate finished first: its placement is where the
                # task's output lives — without this the cancelled
                # original's stale entry mispriced the children's pulls
                self.pool_of[(name, i)] = k = spec
                self.node_of[(name, i)] = (spec_node_alloc[0]
                                           if spec_node_alloc is not None
                                           else -1)
        if self.faults is not None:
            self._ckpt_run.pop((name, i), None)
            self._recovery.pop((name, i), None)
        self.finished.add((name, i))
        self._n_done += 1
        self._set_remaining[name] -= 1
        self._adm_epoch += 1  # set remainders are admission-pricing inputs
        if self._set_remaining[name] == 0:
            # the set is drained: drop it from every live-frontier scan.
            # Set-level retirement in the predictor is exact (a finished
            # set's residual, work and DP contributions are all 0.0, and
            # every ancestor of a finished set is finished — task-level
            # children can outrun parents, so that mode keeps the full
            # order).  The policy walk compacts lazily once half of
            # ``_infos`` is retired: a stable re-sort of the live subset
            # equals the live subsequence of the full sort, so pruning
            # never reorders dispatch.
            self._live.discard(name)
            if self.predictor is not None and not self.task_level:
                self.predictor.retire(name)
            if self.incremental:
                self._retired_sets += 1
                if self._retired_sets * 2 >= len(self._infos):
                    self._infos = [si for si in self._infos
                                   if self._set_remaining[si.name] > 0]
                    self.priority = [n for n in self.priority
                                     if self._set_remaining[n] > 0]
                    self._retired_sets = 0
        if self.task_level:
            for (cn, ci) in self._child_waiters.get((name, i), ()):
                self._remaining[(cn, ci)] -= 1
                if self._remaining[(cn, ci)] == 0:
                    self.ready[cn].append(ci)
        elif self._set_remaining[name] == 0:
            nt = ts.num_tasks
            for c in self.g.children(name):
                for j in range(self.g.node(c).num_tasks):
                    self._remaining[(c, j)] -= nt
                    if self._remaining[(c, j)] == 0:
                        self.ready[c].append(j)
        return k

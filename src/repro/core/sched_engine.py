"""Shared scheduling engine: one ready-queue + dependency + resource
bookkeeping core consumed by BOTH the discrete-event simulator
(`repro.core.simulator`) and the real thread-level executor
(`repro.core.executor`), so the two substrates cannot drift.

This mirrors the separation RADICAL-Pilot makes between the *scheduler*
(which task goes where, when) and the *execution substrate* (how it runs):
the engine owns

- the per-set ready queues and the set-/task-level dependency counters;
- per-pool resource accounting over a heterogeneous
  :class:`~repro.core.resources.Allocation` (GPU nodes + CPU-only nodes,
  each with its own oversubscription flags and placement constraints);
- the pluggable :class:`SchedulingPolicy` deciding (a) the order in which
  ready task sets are offered resources and (b) on which pool each task is
  placed.

The substrates only decide *when* completions happen (simulated clock vs
wall clock) and feed them back via :meth:`SchedEngine.complete`.

Policies
--------
``fifo``         rank/topo FIFO with backfilling — the behaviour both
                 substrates hard-coded before this engine existed, and the
                 closest analogue of the paper's EnTK/RP agent scheduler.
``lpt``          largest-TX-first (longest processing time): ready sets with
                 the largest mean task duration are offered resources first,
                 the classic makespan heuristic for malleable bags of tasks.
``gpu_bestfit``  GPU-aware best fit: GPU task sets are placed first on the
                 pool whose free GPUs they fill tightest; CPU-only tasks are
                 packed *around* them, preferring GPU-less pools so GPU-node
                 cores stay available for GPU-task co-scheduling.

Scheduling stays O(#ready sets x #pools) per dispatch round — all tasks of
a set share one footprint — so the engine sustains the simulator's 10^5-task
workloads unchanged.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

from .dag import DAG, TaskSet
from .resources import Allocation, PoolSpec, as_allocation


@dataclasses.dataclass(frozen=True)
class SetInfo:
    """The static per-task-set facts a policy may order by."""

    name: str
    rank: int
    topo: int
    num_tasks: int
    cpus: int
    gpus: int
    tx_mean: float
    kind: str


class SchedulingPolicy:
    """Strategy interface: set priority + per-task pool placement.

    ``order_sets`` fixes the priority in which ready sets are offered free
    resources (backfilling walks this order and starts whatever fits).
    ``choose_pool`` picks among the pools that can start one task of ``ts``
    right now; it is only consulted when more than one pool fits.
    """

    name = "base"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        raise NotImplementedError

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> int:
        return candidates[0]


class FifoBackfill(SchedulingPolicy):
    """Rank/topo FIFO with backfilling (the pre-engine behaviour)."""

    name = "fifo"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in sorted(sets, key=lambda s: (s.rank, s.topo))]


class LargestTxFirst(SchedulingPolicy):
    """LPT: among ready sets, largest mean task duration first."""

    name = "lpt"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (-s.tx_mean, s.rank, s.topo))]


class GpuAwareBestFit(SchedulingPolicy):
    """GPU sets first (widest footprint first), best-fit pool placement;
    CPU-only tasks pack around GPU tasks on GPU-less pools when possible."""

    name = "gpu_bestfit"

    def order_sets(self, sets: Sequence[SetInfo]) -> list[str]:
        return [s.name for s in
                sorted(sets, key=lambda s: (s.gpus == 0, -s.gpus,
                                            s.rank, s.topo))]

    def choose_pool(self, ts: TaskSet, candidates: Sequence[int],
                    engine: "SchedEngine") -> int:
        if ts.gpus_per_task > 0:
            # tightest GPU fit: least free GPUs left after placement
            return min(candidates,
                       key=lambda k: (engine.free_gpus[k] - ts.gpus_per_task,
                                      engine.free_cpus[k]))
        # CPU-only: prefer pools without GPUs, then tightest CPU fit
        return min(candidates,
                   key=lambda k: (engine.pools[k].total.gpus > 0,
                                  engine.free_cpus[k] - ts.cpus_per_task))


SCHEDULING_POLICIES: dict[str, type[SchedulingPolicy]] = {
    FifoBackfill.name: FifoBackfill,
    LargestTxFirst.name: LargestTxFirst,
    GpuAwareBestFit.name: GpuAwareBestFit,
}


def get_scheduling_policy(
        policy: "str | SchedulingPolicy") -> SchedulingPolicy:
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return SCHEDULING_POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; "
            f"known: {sorted(SCHEDULING_POLICIES)}") from None


class SchedEngine:
    """Ready-queue, dependency and multi-pool resource bookkeeping.

    Drive it with::

        engine = SchedEngine(g, pool, policy="fifo", task_level=False)
        for name, i, pool_idx in engine.startable():   # resources acquired
            ... launch task i of set name on pools[pool_idx] ...
        # when a launched task finishes:
        pool_idx = engine.complete(name, i)            # resources released,
        ...                                            # children made ready

    ``g`` must already carry the execution mode's edges (callers apply
    :meth:`DAG.with_sequential_barriers` for sequential mode first).
    Dependency granularity matches the paper: set-level barriers by default,
    ``task_level=True`` for the adaptive (future-work) semantics.
    """

    def __init__(self, g: DAG, pool: "PoolSpec | Allocation", *,
                 policy: "str | SchedulingPolicy" = "fifo",
                 task_level: bool = False):
        self.g = g
        self.alloc = as_allocation(pool)
        self.pools: tuple[PoolSpec, ...] = self.alloc.pools
        self.free_cpus = [p.total.cpus for p in self.pools]
        self.free_gpus = [p.total.gpus for p in self.pools]
        self.policy = get_scheduling_policy(policy)
        self.task_level = task_level

        order = g.topological_order()
        ranks = g.ranks()
        self.order = order
        infos = [SetInfo(n, ranks[n], k, g.node(n).num_tasks,
                         g.node(n).cpus_per_task, g.node(n).gpus_per_task,
                         g.node(n).tx_mean, g.node(n).kind)
                 for k, n in enumerate(order)]
        self.priority = list(self.policy.order_sets(infos))
        if sorted(self.priority) != sorted(order):
            raise ValueError(
                f"policy {self.policy.name!r} returned an invalid set order")

        for n in order:
            ts = g.node(n)
            if not any(p.accepts(ts) for p in self.pools):
                raise ValueError(
                    f"task set {n!r} (cpus={ts.cpus_per_task}, "
                    f"gpus={ts.gpus_per_task}, kind={ts.kind!r}) fits no "
                    f"pool of allocation {self.alloc.name!r}")

        # -- dependency counters (identical semantics in both substrates) --
        self._remaining: dict[tuple[str, int], int] = {}
        self._set_remaining = {n: g.node(n).num_tasks for n in order}
        self._child_waiters: dict[tuple[str, int],
                                  list[tuple[str, int]]] = {}
        if task_level:
            # task i of a child set depends on task j of each parent set
            # with j mapped proportionally (i * np // nc); one parent task
            # may unlock several child tasks.
            for name in order:
                nc = g.node(name).num_tasks
                for i in range(nc):
                    cnt = 0
                    for p in g.parents(name):
                        np_ = g.node(p).num_tasks
                        self._child_waiters.setdefault(
                            (p, i * np_ // nc), []).append((name, i))
                        cnt += 1
                    self._remaining[(name, i)] = cnt
        else:
            # set-level: every task of a child set waits for *all* tasks of
            # all parent sets (the paper's stage semantics).
            for name in order:
                cnt = sum(g.node(p).num_tasks for p in g.parents(name))
                for i in range(g.node(name).num_tasks):
                    self._remaining[(name, i)] = cnt

        self.ready: dict[str, deque] = {n: deque() for n in order}
        self.launched: set[tuple[str, int]] = set()
        self.finished: set[tuple[str, int]] = set()
        self.pool_of: dict[tuple[str, int], int] = {}
        self._n_total = sum(g.node(n).num_tasks for n in order)
        self._n_done = 0
        for n in order:
            if not g.parents(n):
                for i in range(g.node(n).num_tasks):
                    self.ready[n].append(i)

    # -- state queries ------------------------------------------------------
    def done(self) -> bool:
        return self._n_done >= self._n_total

    @property
    def tasks_total(self) -> int:
        return self._n_total

    def pool_name(self, pool_idx: int) -> str:
        return self.pools[pool_idx].name

    def _needs(self, k: int, ts: TaskSet) -> tuple[int, int]:
        p = self.pools[k]
        return (0 if p.oversubscribe_cpus else ts.cpus_per_task,
                0 if p.oversubscribe_gpus else ts.gpus_per_task)

    def _candidates(self, ts: TaskSet) -> list[int]:
        out = []
        for k, p in enumerate(self.pools):
            if p.only_kinds is not None and ts.kind not in p.only_kinds:
                continue
            need_c, need_g = self._needs(k, ts)
            if need_c <= self.free_cpus[k] and need_g <= self.free_gpus[k]:
                out.append(k)
        return out

    # -- scheduling ---------------------------------------------------------
    def startable(self) -> list[tuple[str, int, int]]:
        """Backfill pass: pop every ready task that fits somewhere *now*,
        acquire its resources and return ``(set, index, pool_idx)`` triples
        in launch order.  Walks sets in policy priority order."""
        out: list[tuple[str, int, int]] = []
        for name in self.priority:
            q = self.ready[name]
            if not q:
                continue
            ts = self.g.node(name)
            while q:
                cands = self._candidates(ts)
                if not cands:
                    break
                i = q.popleft()
                if (name, i) in self.finished or (name, i) in self.launched:
                    continue
                k = (cands[0] if len(cands) == 1
                     else self.policy.choose_pool(ts, cands, self))
                need_c, need_g = self._needs(k, ts)
                self.free_cpus[k] -= need_c
                self.free_gpus[k] -= need_g
                self.launched.add((name, i))
                self.pool_of[(name, i)] = k
                out.append((name, i, k))
        return out

    def complete(self, name: str, i: int) -> int:
        """Mark task ``(name, i)`` finished: release its pool's resources,
        decrement dependency counters, enqueue newly-ready tasks.  Returns
        the pool index the task ran on.  Idempotent per task (duplicate
        completions — straggler mitigation — are no-ops)."""
        if (name, i) in self.finished:
            return self.pool_of.get((name, i), 0)
        k = self.pool_of.get((name, i), 0)
        ts = self.g.node(name)
        need_c, need_g = self._needs(k, ts)
        self.free_cpus[k] += need_c
        self.free_gpus[k] += need_g
        self.finished.add((name, i))
        self._n_done += 1
        self._set_remaining[name] -= 1
        if self.task_level:
            for (cn, ci) in self._child_waiters.get((name, i), ()):
                self._remaining[(cn, ci)] -= 1
                if self._remaining[(cn, ci)] == 0:
                    self.ready[cn].append(ci)
        elif self._set_remaining[name] == 0:
            nt = ts.num_tasks
            for c in self.g.children(name):
                for j in range(self.g.node(c).num_tasks):
                    self._remaining[(c, j)] -= nt
                    if self._remaining[(c, j)] == 0:
                        self.ready[c].append(j)
        return k

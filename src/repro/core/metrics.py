"""Streaming metric accumulators for trace-scale runs.

``RunResult``'s steady-state metrics (``slo_attainment``,
``slowdown_percentile``, ``window_stats``) are computed from the full
per-workflow stats dict, which grows with the stream: a million-arrival
replay keeps a million ``WorkflowStats`` (and a million task records)
alive just to answer "what was the P99".  This module is the bounded
alternative behind ``RunConfig.record_policy="summary"``: the simulator
folds each workflow into a :class:`StreamMetrics` the moment it
finishes and drops the per-task trace, so memory stays O(sketch size +
windows) and every metric query is O(1)-amortized in the record count.

:class:`QuantileSketch` is a weighted online quantile summary with an
exact small-population fallback: below ``2 * max_points`` entries it
stores the raw ``(value, weight)`` points and its ``query`` walk is
*bit-identical* to ``RunResult.slowdown_percentile`` over the same
population (the exact-fallback tests pin this).  Past that it compacts
by merging adjacent sorted pairs into weighted-mean centroids, always
keeping the extreme points exact — so ``q=0``/``q=1`` stay the true
min/max, and the quantile *rank* error is bounded by the largest
centroid's weight share of the total mass (``<= 2/max_points`` of the
mass under uniform weights, since a centroid never absorbs more than
two points per compaction round against a doubling population).
"""

from __future__ import annotations

import bisect

__all__ = ["QuantileSketch", "StreamMetrics"]


class QuantileSketch:
    """Weighted online quantile summary (adjacent-pair compaction).

    ``add(value, weight)`` streams points in; ``query(q)`` returns the
    smallest value at which the cumulative weight reaches ``q`` of the
    total — the same weight-respecting definition as
    ``RunResult.slowdown_percentile``.  Exact until ``2 * max_points``
    points are held; bounded-error beyond (module docstring)."""

    def __init__(self, max_points: int = 512):
        if max_points < 2:
            raise ValueError("max_points must be >= 2")
        self.max_points = max_points
        #: (value, weight) points / centroids (unordered between queries)
        self._pts: list[tuple[float, float]] = []
        #: memoized (sorted points, cumulative weights) query view
        self._view: "tuple[list, list] | None" = None
        self.compactions = 0
        self.n_added = 0

    def __len__(self) -> int:
        return len(self._pts)

    @property
    def exact(self) -> bool:
        """True while no compaction has happened: every query is exact."""
        return self.compactions == 0

    def total_weight(self) -> float:
        return sum(w for _v, w in self._pts)

    def add(self, value: float, weight: float = 1.0) -> None:
        if weight <= 0:
            return
        self._pts.append((value, weight))
        self._view = None
        self.n_added += 1
        if len(self._pts) >= 2 * self.max_points:
            self._compact()

    def _compact(self) -> None:
        """Halve the population: sort, keep both extremes exact, merge
        the interior in adjacent pairs into weight-preserving centroids
        (a centroid sits between its parents, so the result is sorted)."""
        pts = sorted(self._pts)
        interior = pts[1:-1]
        out = [pts[0]]
        for j in range(0, len(interior) - 1, 2):
            (v1, w1), (v2, w2) = interior[j], interior[j + 1]
            w = w1 + w2
            out.append(((v1 * w1 + v2 * w2) / w, w))
        if len(interior) % 2:
            out.append(interior[-1])
        out.append(pts[-1])
        self._pts = out
        self._view = None
        self.compactions += 1

    def _query_view(self) -> "tuple[list, list]":
        view = self._view
        if view is None:
            pts = sorted(self._pts)
            cum: list[float] = []
            acc = 0.0
            for _v, w in pts:
                acc += w
                cum.append(acc)
            view = self._view = (pts, cum)
        return view

    def query(self, q: float) -> "float | None":
        """Smallest value whose cumulative weight reaches ``q * total``
        (None when empty).  Mirrors ``RunResult.slowdown_percentile``'s
        walk — including its ``1e-12`` cumulative-mass tolerance — so
        the exact fallback agrees bit-for-bit."""
        pts, cum = self._query_view()
        if not pts:
            return None
        idx = bisect.bisect_left(cum, q * cum[-1] - 1e-12)
        if idx >= len(pts):
            return pts[-1][0]
        return pts[idx][0]


class _WindowAcc:
    """One finish-time window's incremental accumulators."""

    __slots__ = ("finished", "slo_total", "slo_met", "sketch")

    def __init__(self, max_points: int):
        self.finished = 0
        self.slo_total = 0
        self.slo_met = 0
        self.sketch = QuantileSketch(max_points)


class StreamMetrics:
    """Incremental replacement for the per-workflow stats dict.

    Feed each finished workflow's ``WorkflowStats`` (duck-typed: any
    object with ``weight`` / ``deadline`` / ``met_deadline`` /
    ``slowdown`` / ``tasks`` / ``finish``) through
    :meth:`observe_workflow`; query the same steady-state surface
    ``RunResult`` exposes.  The sliding-window width is fixed at
    construction (``RunConfig.slo_window``) — summary mode cannot
    re-bucket after the fact, that is the memory trade."""

    def __init__(self, window: float = 900.0, max_points: int = 512):
        if window <= 0:
            raise ValueError("window must be > 0")
        self.window = window
        self.max_points = max_points
        self.workflows = 0
        self.slo_total = 0
        self.slo_met = 0
        self._ws_num = 0.0
        self._ws_den = 0.0
        self.sketch = QuantileSketch(max_points)
        self._windows: dict[int, _WindowAcc] = {}
        #: memoized ``window_stats`` list, dropped on the next observation
        #: — repeated queries stay O(1) even with thousands of windows
        self._window_view: "list[dict] | None" = None

    def observe_workflow(self, w) -> None:
        """Fold one workflow's final stats in (call once per workflow)."""
        self.workflows += 1
        self._window_view = None
        met = None
        if w.deadline is not None:
            met = w.met_deadline
            self.slo_total += 1
            if met:
                self.slo_met += 1
        sd = w.slowdown
        if sd is not None:
            self._ws_num += w.weight * sd
            self._ws_den += w.weight
            if w.weight > 0:
                self.sketch.add(sd, w.weight)
        if w.tasks <= 0:
            return  # never started — window_stats skips those too
        acc = self._windows.get(int(w.finish // self.window))
        if acc is None:
            acc = self._windows[int(w.finish // self.window)] = \
                _WindowAcc(self.max_points)
        acc.finished += 1
        if w.deadline is not None:
            acc.slo_total += 1
            if met:
                acc.slo_met += 1
        if sd is not None and w.weight > 0:
            acc.sketch.add(sd, w.weight)

    # -- the RunResult metric surface, O(1)-amortized -----------------------
    def slo_attainment(self) -> "float | None":
        if not self.slo_total:
            return None
        return self.slo_met / self.slo_total

    def weighted_slowdown(self) -> "float | None":
        if not self._ws_den:
            return None
        return self._ws_num / self._ws_den

    def slowdown_percentile(self, q: float) -> "float | None":
        return self.sketch.query(q)

    def window_stats(self) -> "list[dict]":
        if self._window_view is not None:
            return self._window_view
        out = []
        for b in sorted(self._windows):
            acc = self._windows[b]
            out.append(dict(
                t0=b * self.window, t1=(b + 1) * self.window,
                finished=acc.finished,
                slo_attainment=(acc.slo_met / acc.slo_total
                                if acc.slo_total else None),
                p50_slowdown=acc.sketch.query(0.50),
                p99_slowdown=acc.sketch.query(0.99)))
        self._window_view = out
        return out

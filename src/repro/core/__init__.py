"""Core of the reproduction: asynchronous execution of heterogeneous tasks
(Pascuzzi et al., 2022) — DG model, DOA/WLA metrics, makespan model,
discrete-event simulator, and a real asynchronous executor."""

from .dag import DAG, TaskSet
from .estimator import FeedbackOptions, SetEstimate, TxEstimator
from .resources import (Allocation, NodeSpec, NodeState, PoolSpec, Resources,
                        as_allocation, doa_res, hybrid_pool, node_states,
                        summit_pool, tpu_pod_pool, wla)
from .sched_engine import (SCHEDULING_POLICIES, AdmissionOptions,
                           CampaignPriority, FailureEvent, FifoBackfill,
                           GpuAwareBestFit, LargestTxFirst, LocalityAware,
                           NodePackTopology, PredictOptions, SchedEngine,
                           SchedulingPolicy, SetInfo, get_scheduling_policy)
from ..runtime.fault import FailureSchedule, FaultOptions
from .model import (ENTK_OVERHEAD, ASYNC_OVERHEAD, Prediction, async_ttx,
                    maskable_stages, predict, relative_improvement,
                    sequential_ttx, sequential_ttx_grouped,
                    staggered_async_ttx, tx_lookup_fn)
from .model_batch import (BatchEqns, jax_available,
                          staggered_async_ttx_batch)
from .metrics import QuantileSketch, StreamMetrics
from .predictor import MakespanPrediction, MakespanPredictor
from .results import PerfCounters, RunResult, per_pool_task_counts
from .runconfig import (RunConfig, reset_legacy_warnings,
                        resolve_run_config)
from .simulator import SimOptions, SimResult, TaskRecord, simulate
from .swf import (SWFJob, SWFMapOptions, SWFTrace, load_swf, parse_swf,
                  swf_campaign, swf_entries, swf_stream)
from .scenarios import (SCENARIOS, Scenario, ScenarioGenerator,
                        run_scenario)
from .executor import ExecResult, RealExecutor
from .scheduler import (ExecutionPolicy, adaptive_observed_policy,
                        adaptive_policy, arbitrated_policy, async_policy,
                        gpu_bestfit_policy, locality_policy, lpt_policy,
                        nodepack_policy, priority_policy, sequential_policy)
from .adaptive import PolicyComparison, compare_policies
from .stream import (CampaignStream, GeneratedStream, StreamTemplate,
                     WorkflowStream, prefix_view)
from .workflow import (CDG_SEQUENTIAL_GROUPS, CDG_TABLE2, DDMD_TABLE1,
                       Campaign, CampaignView, Pipeline, Stage, WorkflowEntry,
                       WorkflowStats, campaign_stats, cdg_dag,
                       cdg_sequential_stage_tx, ddmd_sequential_stage_groups,
                       ddmd_stage_tx, deepdrivemd_dag, fig2a_chain,
                       fig2b_fork, fig2b_with_paper_tx, fig2d_independent,
                       pipelines_to_dag, weighted_slowdown)
from .resources import ElasticOptions

#: the curated public surface — what ``from repro.core import *`` gives
#: and what ``tests/test_public_api.py`` snapshots.  Additions are
#: deliberate API growth; removals are breaking changes.
__all__ = [
    # structure + workloads
    "DAG", "TaskSet", "Pipeline", "Stage", "pipelines_to_dag",
    "fig2a_chain", "fig2b_fork", "fig2b_with_paper_tx", "fig2d_independent",
    "deepdrivemd_dag", "cdg_dag", "ddmd_stage_tx", "cdg_sequential_stage_tx",
    "ddmd_sequential_stage_groups", "DDMD_TABLE1", "CDG_TABLE2",
    "CDG_SEQUENTIAL_GROUPS",
    # resources
    "Resources", "NodeSpec", "NodeState", "PoolSpec", "Allocation",
    "ElasticOptions", "as_allocation", "node_states", "summit_pool",
    "hybrid_pool", "tpu_pod_pool", "doa_res", "wla",
    # analytic model + prediction
    "ENTK_OVERHEAD", "ASYNC_OVERHEAD", "Prediction", "predict",
    "async_ttx", "sequential_ttx", "sequential_ttx_grouped",
    "staggered_async_ttx", "relative_improvement", "maskable_stages",
    "tx_lookup_fn", "BatchEqns", "jax_available",
    "staggered_async_ttx_batch", "MakespanPrediction", "MakespanPredictor",
    # scheduling engine
    "SchedEngine", "SchedulingPolicy", "SCHEDULING_POLICIES",
    "get_scheduling_policy", "SetInfo", "FifoBackfill", "LargestTxFirst",
    "GpuAwareBestFit", "LocalityAware", "NodePackTopology",
    "CampaignPriority", "AdmissionOptions", "FailureEvent", "PredictOptions",
    # estimator / feedback
    "TxEstimator", "SetEstimate", "FeedbackOptions",
    # faults
    "FaultOptions", "FailureSchedule",
    # tenancy: campaigns + streams
    "Campaign", "CampaignView", "WorkflowEntry", "WorkflowStats",
    "campaign_stats", "weighted_slowdown", "WorkflowStream",
    "CampaignStream", "GeneratedStream", "StreamTemplate", "prefix_view",
    # trace replay + scenario engine
    "SWFJob", "SWFTrace", "SWFMapOptions", "parse_swf", "load_swf",
    "swf_entries", "swf_campaign", "swf_stream", "Scenario",
    "ScenarioGenerator", "SCENARIOS", "run_scenario",
    # run API (both substrates)
    "RunConfig", "resolve_run_config", "reset_legacy_warnings",
    "RunResult", "TaskRecord",
    "per_pool_task_counts", "simulate", "SimOptions", "SimResult",
    "RealExecutor", "ExecResult", "PerfCounters",
    # streaming metric sketches (bounded-memory summaries)
    "QuantileSketch", "StreamMetrics",
    # execution policies / comparison
    "ExecutionPolicy", "async_policy", "sequential_policy",
    "adaptive_policy", "adaptive_observed_policy", "arbitrated_policy",
    "priority_policy", "lpt_policy", "gpu_bestfit_policy",
    "locality_policy", "nodepack_policy", "PolicyComparison",
    "compare_policies",
]

"""Core of the reproduction: asynchronous execution of heterogeneous tasks
(Pascuzzi et al., 2022) — DG model, DOA/WLA metrics, makespan model,
discrete-event simulator, and a real asynchronous executor."""

from .dag import DAG, TaskSet
from .estimator import FeedbackOptions, SetEstimate, TxEstimator
from .resources import (Allocation, NodeSpec, NodeState, PoolSpec, Resources,
                        as_allocation, doa_res, hybrid_pool, node_states,
                        summit_pool, tpu_pod_pool, wla)
from .sched_engine import (SCHEDULING_POLICIES, AdmissionOptions,
                           CampaignPriority, FailureEvent, FifoBackfill,
                           GpuAwareBestFit, LargestTxFirst, LocalityAware,
                           NodePackTopology, SchedEngine, SchedulingPolicy,
                           SetInfo, get_scheduling_policy)
from ..runtime.fault import FailureSchedule, FaultOptions
from .model import (ENTK_OVERHEAD, ASYNC_OVERHEAD, Prediction, async_ttx,
                    maskable_stages, predict, relative_improvement,
                    sequential_ttx, sequential_ttx_grouped,
                    staggered_async_ttx, tx_lookup_fn)
from .model_batch import (BatchEqns, jax_available,
                          staggered_async_ttx_batch)
from .predictor import MakespanPrediction, MakespanPredictor
from .simulator import SimOptions, SimResult, TaskRecord, simulate
from .executor import ExecResult, RealExecutor
from .scheduler import (ExecutionPolicy, adaptive_observed_policy,
                        adaptive_policy, arbitrated_policy, async_policy,
                        gpu_bestfit_policy, locality_policy, lpt_policy,
                        nodepack_policy, priority_policy, sequential_policy)
from .adaptive import PolicyComparison, compare_policies
from .workflow import (CDG_SEQUENTIAL_GROUPS, CDG_TABLE2, DDMD_TABLE1,
                       Campaign, CampaignView, Pipeline, Stage, WorkflowEntry,
                       WorkflowStats, campaign_stats, cdg_dag,
                       cdg_sequential_stage_tx, ddmd_sequential_stage_groups,
                       ddmd_stage_tx, deepdrivemd_dag, fig2a_chain,
                       fig2b_fork, fig2b_with_paper_tx, fig2d_independent,
                       pipelines_to_dag, weighted_slowdown)

__all__ = [s for s in dir() if not s.startswith("_")]

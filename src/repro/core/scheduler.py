"""Execution policies: named bundles of (mode, dependency granularity,
stage grouping, scheduling policy, runtime feedback) consumed by both the
simulator and the real executor.

The ``mode``/``task_level`` axes pick the paper's execution semantics
(sequential / asynchronous / adaptive); ``scheduling`` picks the shared
engine's placement policy (``fifo`` / ``lpt`` / ``gpu_bestfit`` /
``locality``, see ``sched_engine.SCHEDULING_POLICIES``); ``feedback``
enables the runtime-feedback loop (observed-TX estimation, straggler
migration and/or speculative duplicates — cost-arbitrated when both are
on — and online makespan re-prediction; see ``estimator.FeedbackOptions``
and ``core/predictor.py``).  The axes compose freely.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .dag import DAG
from .estimator import FeedbackOptions
from .executor import ExecResult, RealExecutor
from .resources import Allocation, PoolSpec
from .sched_engine import SchedulingPolicy
from .simulator import Mode, SimOptions, SimResult, simulate


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a workflow DG is scheduled onto an allocation."""

    mode: Mode = "async"
    task_level: bool = False
    sequential_stage_groups: Sequence[Sequence[str]] | None = None
    name: str = ""
    #: shared-engine scheduling policy name (or a SchedulingPolicy instance)
    scheduling: "str | SchedulingPolicy" = "fifo"
    #: runtime feedback: None = static TXs (the paper's assumption)
    feedback: FeedbackOptions | None = None

    def simulate(self, dag: DAG, pool: "PoolSpec | Allocation",
                 options: SimOptions = SimOptions()) -> SimResult:
        return simulate(
            dag, pool, self.mode, options=options,
            task_level=self.task_level,
            sequential_stage_groups=self.sequential_stage_groups,
            scheduling=self.scheduling, feedback=self.feedback)

    def execute(self, dag: DAG, executor: RealExecutor) -> ExecResult:
        """Run the same policy on the real executor (shared engine)."""
        return executor.run(
            dag, self.mode, task_level=self.task_level,
            sequential_stage_groups=self.sequential_stage_groups,
            scheduling=self.scheduling, feedback=self.feedback)

    def with_scheduling(self, scheduling: "str | SchedulingPolicy"
                        ) -> "ExecutionPolicy":
        sched_name = (scheduling if isinstance(scheduling, str)
                      else scheduling.name)
        return dataclasses.replace(
            self, scheduling=scheduling,
            name=f"{self.name}+{sched_name}" if self.name else sched_name)

    def with_feedback(self, feedback: FeedbackOptions = FeedbackOptions()
                      ) -> "ExecutionPolicy":
        return dataclasses.replace(
            self, feedback=feedback,
            name=f"{self.name}+observed" if self.name else "observed")


def sequential_policy(stage_groups=None) -> ExecutionPolicy:
    """The paper's BSP/sequential mode (PST stage barriers)."""
    return ExecutionPolicy("sequential", False, stage_groups, "sequential")


def async_policy() -> ExecutionPolicy:
    """The paper's asynchronous mode (set-level dependencies only)."""
    return ExecutionPolicy("async", False, None, "async")


def adaptive_policy() -> ExecutionPolicy:
    """Task-level asynchronicity (the paper's future work; see adaptive.py)."""
    return ExecutionPolicy("async", True, None, "adaptive")


def lpt_policy() -> ExecutionPolicy:
    """Asynchronous mode with largest-TX-first dispatch order."""
    return ExecutionPolicy("async", False, None, "lpt", scheduling="lpt")


def gpu_bestfit_policy() -> ExecutionPolicy:
    """Asynchronous mode with GPU-aware best-fit multi-pool placement."""
    return ExecutionPolicy("async", False, None, "gpu_bestfit",
                           scheduling="gpu_bestfit")


def locality_policy() -> ExecutionPolicy:
    """Asynchronous mode with data-movement-aware placement + bounded
    work stealing (uses the allocation's ``transfer_cost`` matrix)."""
    return ExecutionPolicy("async", False, None, "locality",
                           scheduling="locality")


def nodepack_policy() -> ExecutionPolicy:
    """Asynchronous mode with NVLink-aware node packing (for node-level
    pools, ``PoolSpec.node_level``): multi-GPU tasks onto single
    nodes/NVLink groups, candidates scored by fragmentation."""
    return ExecutionPolicy("async", False, None, "nodepack",
                           scheduling="nodepack")


def priority_policy() -> ExecutionPolicy:
    """Asynchronous mode with workflow-priority-first ordering — the
    natural dispatch order for multi-tenant campaigns (higher-priority
    workflows' sets offered resources first; fifo within one workflow)."""
    return ExecutionPolicy("async", False, None, "priority",
                           scheduling="priority")


def adaptive_observed_policy(
        feedback: FeedbackOptions = FeedbackOptions()) -> ExecutionPolicy:
    """Task-level asynchronicity driven by OBSERVED runtime TX instead of
    static ``tx_mean``, with straggler preemption + migration — the
    ROADMAP's adaptive-scheduling follow-up to the paper's future work."""
    return ExecutionPolicy("async", True, None, "adaptive_observed",
                           scheduling="lpt", feedback=feedback)


def arbitrated_policy(
        feedback: "FeedbackOptions | None" = None) -> ExecutionPolicy:
    """Asynchronous mode with the full predictive control plane: observed
    TX, online makespan re-prediction, and per-straggler arbitration
    between preemptive migration and speculative duplicates (both
    mitigations enabled; ``SchedEngine.arbitrate`` picks by the
    predictor's marginal-makespan delta)."""
    if feedback is None:
        feedback = FeedbackOptions(speculate=True)
    return ExecutionPolicy("async", False, None, "arbitrated",
                           scheduling="lpt", feedback=feedback)

"""Execution policies: named bundles of (mode, dependency granularity,
stage grouping) consumed by both the simulator and the real executor."""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .dag import DAG
from .resources import PoolSpec
from .simulator import Mode, SimOptions, SimResult, simulate


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a workflow DG is scheduled onto an allocation."""

    mode: Mode = "async"
    task_level: bool = False
    sequential_stage_groups: Sequence[Sequence[str]] | None = None
    name: str = ""

    def simulate(self, dag: DAG, pool: PoolSpec,
                 options: SimOptions = SimOptions()) -> SimResult:
        return simulate(
            dag, pool, self.mode, options=options,
            task_level=self.task_level,
            sequential_stage_groups=self.sequential_stage_groups)


def sequential_policy(stage_groups=None) -> ExecutionPolicy:
    """The paper's BSP/sequential mode (PST stage barriers)."""
    return ExecutionPolicy("sequential", False, stage_groups, "sequential")


def async_policy() -> ExecutionPolicy:
    """The paper's asynchronous mode (set-level dependencies only)."""
    return ExecutionPolicy("async", False, None, "async")


def adaptive_policy() -> ExecutionPolicy:
    """Task-level asynchronicity (the paper's future work; see adaptive.py)."""
    return ExecutionPolicy("async", True, None, "adaptive")

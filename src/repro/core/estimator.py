"""Online task-execution-time (TX) estimation — the runtime-feedback half
of the scheduling engine.

The paper's asynchronicity model (Eqn. 5) and its EnTK experiments assume
*static* mean task execution times (``TaskSet.tx_mean``).  Real ML-driven
HPC workflows have heavy-tailed, drifting durations, so this module gives
the engine an *observed* view of TX:

``TxEstimator``
    Per-task-set exponentially weighted moving average (EWMA) mean and
    variance over completed task durations.  Policies consult it (through
    :meth:`~repro.core.sched_engine.SchedEngine.tx_estimate`) instead of
    the static ``tx_mean`` once a set has ``min_samples`` completions;
    before that the static value is the prior.  Observations tagged with a
    ``pool`` additionally feed a per-(set, pool) estimate, so a slow pool
    raises only its own estimate instead of masquerading as set-wide
    drift; pool-aware queries (``mean(name, pool=...)``) fall back
    set-level -> prior when the pool split is not yet armed.

``FeedbackOptions``
    The knobs of the feedback loop: EWMA decay, straggler detection
    threshold (runtime > mean + k*sigma above the set's running estimate,
    evaluated against the task's *pool* estimate when armed), the
    migration cost model (base data-movement cost + the allocation's
    per-pool-pair ``transfer_cost`` matrix, no-op'd when the cost exceeds
    the expected benefit), and speculative duplicates (``speculate``) —
    when both mitigations are enabled the engine's cost-model arbiter
    picks per straggler using the predictor's marginal-makespan delta
    (see ``core/predictor.py`` and ``SchedEngine.arbitrate``).

Both execution substrates (``simulate()`` and ``RealExecutor.run()``) feed
completions back via ``SchedEngine.observe``; see DESIGN.md
("Runtime-feedback layer") for the estimator -> policy -> engine loop and
the straggler/migration state machine.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Iterable, Mapping


@dataclasses.dataclass
class SetEstimate:
    """Running EWMA statistics for one task set."""

    mean: float = 0.0
    var: float = 0.0
    count: int = 0

    @property
    def std(self) -> float:
        return math.sqrt(max(0.0, self.var))


@dataclasses.dataclass(frozen=True)
class FeedbackOptions:
    """Configuration of the runtime-feedback loop (estimator + straggler
    mitigation).  ``FeedbackOptions()`` enables observed-TX estimation and
    preemption + migration with conservative defaults; ``migrate=False``
    keeps the estimator but never moves a running task."""

    #: EWMA decay: weight of the newest observation (0 < alpha <= 1).
    ewma_alpha: float = 0.25
    #: completions of a set required before its observed estimate replaces
    #: the static ``tx_mean`` prior (and before straggler detection arms).
    min_samples: int = 3
    #: a running task is a straggler when
    #: ``runtime > mean + straggler_k * sigma`` ...
    straggler_k: float = 3.0
    #: ... and ``runtime > straggler_min_ratio * mean`` (guards task sets
    #: whose observed sigma collapsed to ~0).
    straggler_min_ratio: float = 1.5
    #: master switch for preemption + migration (estimation always runs).
    migrate: bool = True
    #: launch speculative duplicates of stragglers (first finisher wins,
    #: the loser is cancelled and its slot freed).  With ``migrate`` also
    #: on, the engine's arbiter picks per straggler by predicted marginal
    #: makespan (see ``SchedEngine.arbitrate``); off by default so plain
    #: ``FeedbackOptions()`` keeps the PR-2 always-migrate behaviour.
    speculate: bool = False
    #: speculative duplicates allowed per task.
    max_speculations_per_task: int = 1
    #: the arbiter's model of a flagged straggler left alone: its expected
    #: remaining runtime is ``max(mean, tail_mean_ratio * mean - elapsed)``
    #: — heavy-tailed durations stay heavy once past the detection
    #: threshold, so the default assumes ~4x the set mean in total.
    straggler_tail_ratio: float = 4.0
    #: calibrate the tail ratio online from each set's OBSERVED tail
    #: quantile (un-winsorized durations / running mean) instead of the
    #: fixed default above; arms per set after ``min_samples`` raw
    #: observations.  Off by default (keeps prior behaviour bit-identical).
    calibrate_tail: bool = False
    #: the quantile the online calibration reads as "the tail".
    tail_quantile: float = 0.95
    #: maintain + consult per-(set, pool) TX estimates so a slow pool does
    #: not pollute its siblings' estimates or straggler thresholds.
    per_pool: bool = True
    #: fixed data-movement cost charged on every migration (seconds),
    #: added to the allocation's ``transfer_cost[src][dst]``.
    migration_base_cost: float = 0.0
    #: no-op the migration when its total cost exceeds this multiple of
    #: the set's estimated mean TX (cost would exceed the benefit).
    max_cost_ratio: float = 1.0
    #: migrations allowed per task (prevents pool ping-ponging).
    max_migrations_per_task: int = 1
    #: winsorize observations at this multiple of the running mean before
    #: they enter the EWMA, so straggler durations cannot contaminate the
    #: estimate they are detected against (0 disables clipping).
    winsorize_ratio: float = 4.0
    #: simulator straggler-watchdog period (s).  0 = auto (half the
    #: smallest positive set mean).  Completions also trigger scans; the
    #: periodic watchdog exists so a lone tail straggler — with no other
    #: completions left to piggyback on — is still detected.  The real
    #: executor's watchdog runs on its dispatcher wakeups instead.
    watchdog_interval: float = 0.0


class TxEstimator:
    """Per-set EWMA mean + variance over observed task durations.

    The update is the standard exponentially weighted mean/variance pair
    (West 1979): with ``d = x - mean``::

        mean <- mean + alpha * d
        var  <- (1 - alpha) * (var + alpha * d^2)

    The first observation initialises ``mean = x, var = 0``.  ``alpha``
    close to 1 tracks drift aggressively; close to 0 averages long-term.

    Observations carrying a ``pool`` tag also update a per-(set, pool)
    estimate.  Pool-aware queries prefer that split once it has
    observations, falling back to the set-level blend, then the prior —
    so a slow pool's durations raise only that pool's estimate instead of
    reading as set-wide drift on its siblings.
    """

    #: raw (un-winsorized) durations kept per set for online tail-quantile
    #: calibration; bounded so memory stays O(sets)
    RAW_WINDOW = 128

    def __init__(self, alpha: float = 0.25,
                 prior: "Mapping[str, float] | None" = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        #: static fallback means (typically ``TaskSet.tx_mean``), returned
        #: by :meth:`mean` until a set has observations.
        self.prior: dict[str, float] = dict(prior or {})
        self._est: dict[str, SetEstimate] = {}
        self._pool_est: dict[tuple[str, str], SetEstimate] = {}
        self._raw: dict[str, deque] = {}
        self._failures: dict[str, int] = {}

    # -- updates -----------------------------------------------------------
    def _fold(self, est: "dict", key, duration: float) -> SetEstimate:
        e = est.get(key)
        if e is None:
            e = est[key] = SetEstimate(mean=float(duration))
        else:
            d = duration - e.mean
            e.mean += self.alpha * d
            e.var = (1.0 - self.alpha) * (e.var + self.alpha * d * d)
        e.count += 1
        return e

    def observe(self, name: str, duration: float,
                pool: "str | None" = None,
                raw: "float | None" = None) -> SetEstimate:
        """Fold one completed task's duration into the set's estimate (and
        into the per-(set, pool) estimate when ``pool`` is given).
        ``raw`` is the pre-winsorize duration, recorded for online tail
        calibration (:meth:`tail_ratio`) — stragglers must count there
        even though clipping keeps them out of the EWMA."""
        if raw is None:
            raw = duration
        self._raw.setdefault(
            name, deque(maxlen=self.RAW_WINDOW)).append(float(raw))
        if pool is not None:
            self._fold(self._pool_est, (name, pool), duration)
        return self._fold(self._est, name, duration)

    def observe_many(self, name: str, durations: Iterable[float],
                     pool: "str | None" = None) -> None:
        for d in durations:
            self.observe(name, d, pool=pool)

    def record_failure(self, name: str) -> None:
        """Count one failed attempt of ``name`` (node loss or software
        fault).  Failed attempts never reach :meth:`observe` — their
        truncated durations would bias the EWMA — so failures are tracked
        separately and surfaced via :meth:`failure_rate`."""
        self._failures[name] = self._failures.get(name, 0) + 1

    def failures(self, name: str) -> int:
        return self._failures.get(name, 0)

    def failure_rate(self, name: str) -> float:
        """Observed per-attempt failure fraction: failures over total
        attempts (failures + successful completions).  0.0 before any
        attempt of the set has been seen."""
        f = self._failures.get(name, 0)
        e = self._est.get(name)
        n = f + (e.count if e is not None else 0)
        return f / n if n > 0 else 0.0

    # -- queries -----------------------------------------------------------
    def _lookup(self, name: str,
                pool: "str | None") -> "SetEstimate | None":
        if pool is not None:
            e = self._pool_est.get((name, pool))
            if e is not None and e.count > 0:
                return e
        return self._est.get(name)

    def count(self, name: str, pool: "str | None" = None) -> int:
        if pool is not None:
            e = self._pool_est.get((name, pool))
            return e.count if e else 0
        e = self._est.get(name)
        return e.count if e else 0

    def mean(self, name: str, default: float = 0.0,
             pool: "str | None" = None) -> float:
        """Observed EWMA mean — the (set, pool) split when armed, else the
        set-level blend — falling back to the prior, then ``default``."""
        e = self._lookup(name, pool)
        if e is not None and e.count > 0:
            return e.mean
        return self.prior.get(name, default)

    def std(self, name: str, default: float = 0.0,
            pool: "str | None" = None) -> float:
        e = self._lookup(name, pool)
        if e is not None and e.count > 1:
            return e.std
        return default

    def tail_ratio(self, name: str, q: float = 0.95,
                   min_count: int = 3) -> "float | None":
        """The set's observed tail: the ``q``-quantile of its raw
        (un-winsorized) durations over its running EWMA mean, or ``None``
        before ``min_count`` raw observations.  Clamped to >= 1 (a tail
        can not be shorter than the mean for mitigation purposes)."""
        raw = self._raw.get(name)
        if raw is None or len(raw) < max(min_count, 2):
            return None
        mean = self.mean(name)
        if mean <= 0:
            return None
        xs = sorted(raw)
        # round the index UP: the tail estimate must not ignore a lone
        # outlier merely because the window is small
        idx = min(len(xs) - 1, math.ceil(q * (len(xs) - 1)))
        return max(1.0, xs[idx] / mean)

    def is_straggler(self, name: str, runtime: float, fb: FeedbackOptions,
                     pool: "str | None" = None) -> bool:
        """Straggler test against the set's *running* estimate: armed only
        after ``min_samples`` completions of the set.  With ``pool`` given
        and its split armed, the test uses the pool's own estimate — tasks
        on a uniformly slow pool are then not mass-flagged merely for
        running there."""
        e = None
        if pool is not None:
            pe = self._pool_est.get((name, pool))
            if pe is not None and pe.count >= fb.min_samples:
                e = pe
        if e is None:
            e = self._est.get(name)
        if e is None or e.count < fb.min_samples:
            return False
        return (runtime > e.mean + fb.straggler_k * e.std
                and runtime > fb.straggler_min_ratio * e.mean)

    def snapshot(self) -> dict[str, SetEstimate]:
        """A copy of every per-set estimate (for reporting/benchmarks)."""
        return {n: dataclasses.replace(e) for n, e in self._est.items()}

    def pool_snapshot(self) -> dict[tuple[str, str], SetEstimate]:
        """A copy of every per-(set, pool) estimate."""
        return {k: dataclasses.replace(e)
                for k, e in self._pool_est.items()}

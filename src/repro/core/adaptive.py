"""Adaptive (task-level) asynchronicity — the paper's stated future work
(§6.1, §8), implemented here as a first-class scheduling policy.

The paper's asynchronous mode still groups tasks into *sets* with set-level
barriers (a child set starts only when the whole parent set finished).
Adaptive execution relaxes this to task-level dependencies: each task is
released as soon as the parent tasks it actually consumes are done, so

1. tasks from different non-converging branches execute fully
   asynchronously (e.g. Fig. 3a: ``Aggr_0`` and ``Train_1`` co-run); and
2. tasks from converging branches still execute asynchronously as long as
   they have no pairwise dependencies (Fig. 3b: ``T1`` and ``T5``).

`compare_policies` quantifies the additional improvement adaptive
execution yields on top of the paper's set-level asynchronicity, and —
since the runtime-feedback layer — the further gain of driving the
adaptive scheduler by OBSERVED runtime TX (online EWMA estimates,
straggler preemption + migration) instead of static ``tx_mean``
(the ``adaptive_observed`` arm).  ``arbitrate=True`` upgrades that arm to
the predictive control plane: speculation enabled next to migration, the
engine's cost-model arbiter choosing per straggler, and the mid-run
makespan re-predictions exposed on ``adaptive_observed.predictions``
(see ``core/predictor.py``).
"""

from __future__ import annotations

import dataclasses

from .dag import DAG
from .estimator import FeedbackOptions
from .model import relative_improvement
from .resources import PoolSpec
from .simulator import SimOptions, SimResult, simulate


@dataclasses.dataclass(frozen=True)
class PolicyComparison:
    sequential: SimResult
    asynchronous: SimResult
    adaptive: SimResult
    #: adaptive + runtime feedback (observed TX, straggler migration)
    adaptive_observed: SimResult

    @property
    def improvement_async(self) -> float:
        """The paper's I (Eqn. 5), sequential -> asynchronous."""
        return relative_improvement(self.sequential.makespan,
                                    self.asynchronous.makespan)

    @property
    def improvement_adaptive(self) -> float:
        """Sequential -> adaptive (beyond-paper)."""
        return relative_improvement(self.sequential.makespan,
                                    self.adaptive.makespan)

    @property
    def adaptive_gain_over_async(self) -> float:
        return relative_improvement(self.asynchronous.makespan,
                                    self.adaptive.makespan)

    @property
    def improvement_observed(self) -> float:
        """Sequential -> adaptive with runtime feedback."""
        return relative_improvement(self.sequential.makespan,
                                    self.adaptive_observed.makespan)

    @property
    def observed_gain_over_adaptive(self) -> float:
        """What the runtime-feedback layer adds on top of static-TX
        adaptive scheduling (positive when feedback helps)."""
        return relative_improvement(self.adaptive.makespan,
                                    self.adaptive_observed.makespan)


def compare_policies(dag: DAG, pool: PoolSpec, *,
                     options: SimOptions = SimOptions(),
                     sequential_stage_groups=None,
                     feedback: FeedbackOptions = FeedbackOptions(),
                     observed_scheduling: str = "fifo",
                     arbitrate: bool = False) -> PolicyComparison:
    """Simulate the four execution policies on one workflow DG.

    The ``adaptive_observed`` arm shares the adaptive arm's task-level
    dependencies and ``observed_scheduling`` ordering (fifo by default, so
    the delta to ``adaptive`` isolates the feedback layer; pass "lpt" to
    also re-rank sets by observed TX).  ``arbitrate=True`` additionally
    enables speculative duplicates on that arm, so the engine's cost-model
    arbiter picks migration vs speculation per straggler."""
    if arbitrate:
        feedback = dataclasses.replace(feedback, speculate=True)
    return PolicyComparison(
        sequential=simulate(dag, pool, "sequential", options=options,
                            sequential_stage_groups=sequential_stage_groups),
        asynchronous=simulate(dag, pool, "async", options=options),
        adaptive=simulate(dag, pool, "async", options=options,
                          task_level=True),
        adaptive_observed=simulate(dag, pool, "async", options=options,
                                   task_level=True,
                                   scheduling=observed_scheduling,
                                   feedback=feedback),
    )

"""Vectorized batch evaluation of the analytic model (Eqns. 2-6).

``core/model.py`` prices ONE workflow from one TX vector per call — fine
for a single offline prediction, linear-in-batch for everything else.
The prediction-driven subsystems want the same equations over *arrays*
of TX vectors at once:

- the admission controller's what-if probes (price K candidate
  workflows against the live estimator snapshot),
- bootstrap/sensitivity sweeps (price thousands of perturbed TX draws
  to put error bars on I = 1 - t_async / t_seq),
- the scaling benchmark's model-evaluation arm.

``BatchEqns`` compiles a DG's *structure* once — stage segments, the
sequential trunk prefix, (stage, branch) pair segments, the pair ->
branch incidence — into index arrays, then evaluates Eqns. 2-5 for a
whole ``(batch, n_sets)`` TX matrix with a handful of segment reductions
and no per-row Python.  This is a jax_pallas codebase: the NumPy path is
the deterministic default, and ``backend="jax"`` runs the identical
index program under ``jax.jit`` so the analytic model executes on the
substrate it schedules (CPU/GPU/TPU alike — the arrays are tiny, the
win is batching and fusion, not kernels).

Semantics are bit-identical to the scalar evaluators by construction:
the column order interleaves nothing — each stage (and each non-trunk
(stage, branch) pair) occupies one contiguous column segment, so
``np.maximum.reduceat`` computes exactly the ``max`` the scalar loop
takes, and the trunk/branch split is the same static prefix rule
``async_ttx`` applies (branch structure does not depend on TX values).
``tests/test_model_batch.py`` cross-checks every workflow in the repo's
zoo against the scalar implementations.  The NumPy backend is exact
(same float64 ops in the same order); the jax backend runs at jax's
configured precision (float32 unless ``jax_enable_x64``), so compare it
with a float32-scale tolerance.
"""

from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np

from .dag import DAG
from .model import tx_lookup_fn

__all__ = ["BatchEqns", "staggered_async_ttx_batch", "jax_available"]


def jax_available() -> bool:
    """True when ``import jax`` succeeds (the container may gate it)."""
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def _segment_starts(seg_sizes: Sequence[int]) -> np.ndarray:
    """``reduceat`` start offsets for contiguous segments of given sizes."""
    return np.concatenate(([0], np.cumsum(seg_sizes)[:-1])).astype(np.int64)


class BatchEqns:
    """Eqns. 2-5 for one DG, batched over TX vectors.

    Column order (``self.names``) is rank-group order with non-trunk
    groups sub-sorted by branch id, so both stage maxima and (stage,
    branch) pair maxima are contiguous segment reductions.  ``pack``
    builds the ``(batch, n_sets)`` TX matrix from per-row lookups.

    ``backend``: ``"numpy"`` (default; deterministic reference),
    ``"jax"`` (jit-compiled; requires jax), or ``"auto"`` (jax when
    importable, else numpy).
    """

    def __init__(self, dag: DAG, backend: str = "numpy"):
        if backend == "auto":
            backend = "jax" if jax_available() else "numpy"
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.g = dag

        groups = dag.rank_groups()
        branch_of = dag.branch_ids()
        self.n_branches = len(set(branch_of.values()))

        # -- trunk prefix: same static rule as model.async_ttx ------------
        first_branch = (branch_of[groups[0][0]] if groups else 0)
        trunk_groups: list[list[str]] = []
        fork_groups: list[list[str]] = []
        forked = False
        for group in groups:
            ids = {branch_of[n] for n in group}
            if not forked and ids == {first_branch}:
                trunk_groups.append(group)
            else:
                forked = True
                fork_groups.append(sorted(group, key=lambda n: branch_of[n]))

        # -- column order: trunk stages, then branch-sorted fork stages ---
        self.names: list[str] = [n for g in trunk_groups for n in g]
        self.names += [n for g in fork_groups for n in g]
        self._col = {n: j for j, n in enumerate(self.names)}

        # -- Eqn. 2: per-stage contiguous segments -------------------------
        stage_sizes = [len(g) for g in trunk_groups + fork_groups]
        self._stage_starts = _segment_starts(stage_sizes)
        self._n_stages = len(stage_sizes)
        self._n_trunk_stages = len(trunk_groups)
        self._n_trunk_cols = sum(len(g) for g in trunk_groups)

        # -- Eqn. 3/4: (stage, branch) pair segments + pair->branch sums ---
        pair_sizes: list[int] = []
        pair_branch: list[int] = []
        for group in fork_groups:
            j = 0
            while j < len(group):
                b = branch_of[group[j]]
                k = j
                while k < len(group) and branch_of[group[k]] == b:
                    k += 1
                pair_sizes.append(k - j)
                pair_branch.append(b)
                j = k
        branch_ids = sorted(set(pair_branch))
        b_idx = {b: i for i, b in enumerate(branch_ids)}
        self._n_pairs = len(pair_sizes)
        self._n_tail_branches = len(branch_ids)
        self._pair_starts = (
            self._n_trunk_cols + _segment_starts(pair_sizes)
            if pair_sizes else np.zeros(0, dtype=np.int64))
        #: 0/1 incidence (n_pairs, n_tail_branches): branch_tail = pairs @ M
        self._pair2branch = np.zeros(
            (self._n_pairs, self._n_tail_branches))
        for p, b in enumerate(pair_branch):
            self._pair2branch[p, b_idx[b]] = 1.0

        self._jit_eval = None
        if backend == "jax":
            self._jit_eval = self._compile_jax()

    # -- input marshalling -------------------------------------------------
    def pack(
        self,
        txs: "Sequence[Mapping[str, float] | Callable[[str], float] | None]",
    ) -> np.ndarray:
        """Stack per-row TX lookups (mapping / callable / ``None`` for the
        DG's static ``tx_mean`` priors) into a ``(batch, n_sets)`` matrix
        in :attr:`names` column order."""
        rows = []
        for tx in txs:
            fn = tx_lookup_fn(self.g, tx)
            rows.append([fn(n) for n in self.names])
        return np.asarray(rows, dtype=np.float64)

    # -- numpy reference path ----------------------------------------------
    def _eval_numpy(self, txs: np.ndarray,
                    overhead_c: float) -> tuple[np.ndarray, np.ndarray]:
        stage_max = np.maximum.reduceat(txs, self._stage_starts, axis=1)
        t_seq = stage_max.sum(axis=1) + overhead_c
        if self.n_branches <= 1 or self._n_pairs == 0:
            return t_seq, t_seq.copy()
        trunk = stage_max[:, :self._n_trunk_stages].sum(axis=1)
        pair_max = np.maximum.reduceat(txs, self._pair_starts, axis=1)
        branch_tail = pair_max @ self._pair2branch
        t_async = trunk + branch_tail.max(axis=1) + overhead_c
        return t_seq, t_async

    # -- jax path: identical index program, jitted -------------------------
    def _compile_jax(self):
        import jax
        import jax.numpy as jnp

        # segment ids replace reduceat (which jax lacks): column -> stage,
        # fork-suffix column -> (stage, branch) pair
        stage_sizes = np.diff(np.concatenate(
            (self._stage_starts, [len(self.names)]))).astype(np.int64)
        stage_ids = jnp.asarray(np.repeat(
            np.arange(self._n_stages), stage_sizes))
        pair2branch = jnp.asarray(self._pair2branch)
        n_trunk_cols = self._n_trunk_cols
        n_trunk_stages = self._n_trunk_stages
        n_stages, n_pairs = self._n_stages, self._n_pairs
        single = self.n_branches <= 1 or n_pairs == 0
        if not single:
            pair_sizes = np.diff(np.concatenate(
                (self._pair_starts, [len(self.names)]))).astype(np.int64)
            pair_ids = jnp.asarray(np.repeat(
                np.arange(n_pairs), pair_sizes))

        @jax.jit
        def run(txs, overhead_c):
            stage_max = jax.ops.segment_max(
                txs.T, stage_ids, num_segments=n_stages).T
            t_seq = stage_max.sum(axis=1) + overhead_c
            if single:
                return t_seq, t_seq
            trunk = stage_max[:, :n_trunk_stages].sum(axis=1)
            pair_max = jax.ops.segment_max(
                txs[:, n_trunk_cols:].T, pair_ids,
                num_segments=n_pairs).T
            branch_tail = pair_max @ pair2branch
            t_async = trunk + branch_tail.max(axis=1) + overhead_c
            return t_seq, t_async

        return run

    # -- public evaluators --------------------------------------------------
    def sequential_ttx(self, txs: np.ndarray, overhead_c: float = 0.0,
                       n_iterations: int = 1) -> np.ndarray:
        """Eqn. 2 per batch row: ``n_iterations * sum_stage max + C``."""
        txs = np.asarray(txs, dtype=np.float64)
        if self.backend == "jax":
            t_seq, _ = self._jit_eval(txs, 0.0)
            t_seq = np.asarray(t_seq)
        else:
            stage_max = np.maximum.reduceat(txs, self._stage_starts, axis=1)
            t_seq = stage_max.sum(axis=1)
        return n_iterations * t_seq + overhead_c

    def async_ttx(self, txs: np.ndarray,
                  overhead_c: float = 0.0) -> np.ndarray:
        """Eqn. 3 per batch row (single-branch DGs fall back to Eqn. 2,
        matching the scalar evaluator)."""
        return self.evaluate(txs, overhead_c)[1]

    def evaluate(self, txs: np.ndarray, overhead_c: float = 0.0,
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(t_seq, t_async, improvement)`` arrays for a TX matrix —
        one fused pass over the compiled structure (Eqns. 2-5)."""
        txs = np.asarray(txs, dtype=np.float64)
        if txs.ndim != 2 or txs.shape[1] != len(self.names):
            raise ValueError(
                f"expected (batch, {len(self.names)}) TX matrix, "
                f"got {txs.shape}")
        if self.backend == "jax":
            t_seq, t_async = self._jit_eval(txs, overhead_c)
            t_seq, t_async = np.asarray(t_seq), np.asarray(t_async)
        else:
            t_seq, t_async = self._eval_numpy(txs, overhead_c)
        with np.errstate(divide="ignore", invalid="ignore"):
            improvement = 1.0 - t_async / t_seq
        return t_seq, t_async, improvement


def staggered_async_ttx_batch(stage_tx: np.ndarray, n: int,
                              maskable: Sequence[bool],
                              overhead_c: float = 0.0) -> np.ndarray:
    """Eqns. 6/7 batched: ``stage_tx`` is ``(batch, n_stages)``; per row,
    ``n * t_seq_one - sum_{maskable k >= 1} max(0, n - k) * t_k`` — the
    closed form ``model.staggered_async_ttx`` computes per call, as one
    matrix-vector product."""
    stage_tx = np.asarray(stage_tx, dtype=np.float64)
    mask = np.asarray(maskable, dtype=bool)
    if stage_tx.ndim != 2 or mask.shape[0] != stage_tx.shape[1]:
        raise ValueError("maskable mask must match stage axis")
    k = np.arange(stage_tx.shape[1])
    coef = np.where(mask & (k >= 1), np.maximum(0, n - k), 0).astype(
        np.float64)
    return n * stage_tx.sum(axis=1) - stage_tx @ coef + overhead_c

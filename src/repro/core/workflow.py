"""Workflow construction: the Pipeline-Stage-Task (PST) API (EnTK
analogue, Ref. [3] of the paper) and builders for every DG the paper uses:

- the Fig. 2 abstract DGs (chain / fork / arbitrary / fully independent);
- the DeepDriveMD workflow (Table 1 task sets, Fig. 3a staggered DG);
- the abstract DG of Fig. 3b with the c-DG1 / c-DG2 concrete assignments
  (Table 2).

Multi-workflow tenancy (:class:`Campaign`): the paper's model assumes one
workflow owns the allocation, but the middleware it motivates
(RADICAL-Pilot / RHAPSODY hybrid AI-HPC campaigns) multiplexes many
concurrent workflows over one pilot.  A :class:`Campaign` names a list of
workflows with per-workflow priorities, arrival times, deadlines and
fairness weights; :meth:`Campaign.view` merges them into one namespaced DG
(set ``T0`` of workflow ``ddmd`` becomes ``ddmd/T0``) plus the
set -> workflow maps the scheduling engine's admission controller and the
substrates' per-workflow accounting read.  ``simulate()`` and
``RealExecutor.run()`` both accept a ``Campaign`` in place of a DAG and
then report per-workflow traces and makespan / wait / weighted-slowdown
metrics (:class:`WorkflowStats`, :func:`campaign_stats`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence

from .dag import DAG, TaskSet


# ---------------------------------------------------------------------------
# PST (Pipeline / Stage / Task) — the EnTK programming model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Stage:
    """One PST stage: task sets that execute under a common barrier."""

    task_sets: list[TaskSet]
    name: str = ""


@dataclasses.dataclass
class Pipeline:
    """A sequence of stages with barrier semantics between them."""

    stages: list[Stage]
    name: str = "pipeline"

    def to_dag(self) -> DAG:
        g = DAG()
        prev: list[str] = []
        for s in self.stages:
            cur = []
            for ts in s.task_sets:
                g.add(ts)
                cur.append(ts.name)
            for u in prev:
                for v in cur:
                    g.add_edge(u, v)
            prev = cur
        return g


def pipelines_to_dag(pipelines: Sequence[Pipeline]) -> DAG:
    """Independent pipelines side by side (workflow-level asynchronicity)."""
    g = DAG()
    for p in pipelines:
        sub = p.to_dag()
        for ts in sub.nodes.values():
            g.add(ts)
        for u, v in sub.edges():
            g.add_edge(u, v)
    return g


# ---------------------------------------------------------------------------
# Fig. 2 abstract DGs
# ---------------------------------------------------------------------------

def _ts(name: str, tx: float = 100.0, **kw) -> TaskSet:
    kw.setdefault("num_tasks", 1)
    kw.setdefault("cpus_per_task", 1)
    kw.setdefault("gpus_per_task", 0)
    return TaskSet(name=name, tx_mean=tx, **kw)


def fig2a_chain(n: int = 4) -> DAG:
    """Linear chain: DOA_dep = 0."""
    g = DAG()
    prev = None
    for i in range(n):
        g.add(_ts(f"T{i}"))
        if prev is not None:
            g.add_edge(prev, f"T{i}")
        prev = f"T{i}"
    return g


def fig2b_fork() -> DAG:
    """T0 forks into chains {T1,T3,T5} and {T2,T4}: DOA_dep = 1."""
    g = DAG()
    for i in range(6):
        g.add(_ts(f"T{i}"))
    for u, v in [("T0", "T1"), ("T0", "T2"), ("T1", "T3"), ("T2", "T4"),
                 ("T3", "T5")]:
        g.add_edge(u, v)
    return g


def fig2b_with_paper_tx() -> DAG:
    """Fig. 2b with the §5.3 masking example TXs:
    t0=500, t1=t2=1000, t3=t5=2000, t4=4000 -> t_seq=7500, t_async=5500."""
    g = fig2b_fork()
    for name, tx in [("T0", 500.0), ("T1", 1000.0), ("T2", 1000.0),
                     ("T3", 2000.0), ("T4", 4000.0), ("T5", 2000.0)]:
        g.replace(name, tx_mean=tx)
    return g


def fig2d_independent(n: int = 5) -> DAG:
    """n+1 fully independent task sets: DOA_dep = n."""
    g = DAG()
    for i in range(n + 1):
        g.add(_ts(f"T{i}"))
    return g


# ---------------------------------------------------------------------------
# DeepDriveMD (Table 1, Fig. 3a)
# ---------------------------------------------------------------------------

#: Table 1 of the paper (TXs already scaled down by 4 as published).
DDMD_TABLE1 = dict(
    simulation=dict(cpus=4, gpus=1, n=96, tx=340.0),
    aggregation=dict(cpus=32, gpus=0, n=16, tx=85.0),
    training=dict(cpus=4, gpus=1, n=1, tx=63.0),
    inference=dict(cpus=16, gpus=1, n=96, tx=38.0),
)

DDMD_STAGE_ORDER = ("simulation", "aggregation", "training", "inference")


def ddmd_task_sets(iteration: int, table: dict = DDMD_TABLE1,
                   payloads: dict[str, Callable[[int], object]] | None = None,
                   ) -> dict[str, TaskSet]:
    payloads = payloads or {}
    out = {}
    for kind in DDMD_STAGE_ORDER:
        p = table[kind]
        out[kind] = TaskSet(
            name=f"{kind[:5]}{iteration}", num_tasks=p["n"],
            cpus_per_task=p["cpus"], gpus_per_task=p["gpus"],
            tx_mean=p["tx"], kind=kind, payload=payloads.get(kind))
    return out


def deepdrivemd_dag(n_iterations: int = 3, table: dict = DDMD_TABLE1,
                    payloads: dict[str, Callable[[int], object]] | None = None,
                    ) -> DAG:
    """Fig. 3a: staggered iterations.

    Iteration i's Simulation forks the chain Aggregation_i -> Training_i ->
    Inference_i *and* paces Simulation_{i+1}; with three iterations the DG
    has three independent chains beginning at rank 1 -> DOA_dep = 2.
    """
    g = DAG()
    sets = [ddmd_task_sets(i, table, payloads) for i in range(n_iterations)]
    for s in sets:
        for ts in s.values():
            g.add(ts)
    for i, s in enumerate(sets):
        g.add_edge(s["simulation"].name, s["aggregation"].name)
        g.add_edge(s["aggregation"].name, s["training"].name)
        g.add_edge(s["training"].name, s["inference"].name)
        if i + 1 < n_iterations:
            g.add_edge(s["simulation"].name, sets[i + 1]["simulation"].name)
    return g


def ddmd_sequential_stage_groups(n_iterations: int = 3) -> list[list[str]]:
    """Sequential mode runs iterations back to back, one stage per task set."""
    groups = []
    for i in range(n_iterations):
        for kind in DDMD_STAGE_ORDER:
            groups.append([f"{kind[:5]}{i}"])
    return groups


def ddmd_stage_tx(table: dict = DDMD_TABLE1) -> list[float]:
    return [table[k]["tx"] for k in DDMD_STAGE_ORDER]


# ---------------------------------------------------------------------------
# Abstract DG of Fig. 3b + concrete c-DG1 / c-DG2 (Table 2)
# ---------------------------------------------------------------------------

#: Table 2.  "Mean TTX Fraction" x 2000 s gives each group's task TX.
CDG_TABLE2 = {
    "c-DG1": dict(
        T0=dict(cpus=16, gpus=1, n=96, frac=0.38),
        T12=dict(cpus=40, gpus=0, n=32, frac=0.11),
        T36=dict(cpus=4, gpus=0, n=16, frac=0.06),
        T45=dict(cpus=32, gpus=1, n=16, frac=0.08),
        T7=dict(cpus=4, gpus=1, n=96, frac=0.36),
    ),
    "c-DG2": dict(
        T0=dict(cpus=16, gpus=1, n=96, frac=0.19),
        T12=dict(cpus=40, gpus=0, n=32, frac=0.08),
        T36=dict(cpus=4, gpus=1, n=96, frac=0.38),
        T45=dict(cpus=32, gpus=1, n=16, frac=0.12),
        T7=dict(cpus=4, gpus=0, n=16, frac=0.23),
    ),
}

#: Fig. 3b edge set (see DESIGN.md): T0 forks to T1/T2; T1 -> {T3, T5};
#: T2 -> {T4, T6}; T4 and T5 converge on T7.  Ranks: T0 | T1 T2 |
#: T3 T4 T5 T6 | T7 (breadth-first indices as in the paper).
CDG_EDGES = [("T0", "T1"), ("T0", "T2"), ("T1", "T3"), ("T1", "T5"),
             ("T2", "T4"), ("T2", "T6"), ("T4", "T7"), ("T5", "T7")]

CDG_GROUP_OF = {"T0": "T0", "T1": "T12", "T2": "T12", "T3": "T36",
                "T6": "T36", "T4": "T45", "T5": "T45", "T7": "T7"}

#: the paper's sequential mode runs one stage per task-type group.
CDG_SEQUENTIAL_GROUPS = [["T0"], ["T1", "T2"], ["T3", "T6"], ["T4", "T5"],
                         ["T7"]]


def cdg_dag(which: str = "c-DG2", total_ttx: float = 2000.0,
            payloads: dict[str, Callable[[int], object]] | None = None) -> DAG:
    """Table 2's ``# Tasks`` column counts tasks per *group* ("their
    respective task sets are grouped within braces"), so a two-set group
    splits its count across both sets — e.g. c-DG2's {T3, T6} has 96 tasks
    total = 48 per set, which is exactly what makes the five-stage
    sequential execution fit the 96-GPU allocation in single waves."""
    table = CDG_TABLE2[which]
    payloads = payloads or {}
    group_sizes: dict[str, int] = {}
    for name, group in CDG_GROUP_OF.items():
        group_sizes[group] = group_sizes.get(group, 0) + 1
    g = DAG()
    for name, group in CDG_GROUP_OF.items():
        p = table[group]
        g.add(TaskSet(name=name, num_tasks=max(1, p["n"] // group_sizes[group]),
                      cpus_per_task=p["cpus"], gpus_per_task=p["gpus"],
                      tx_mean=p["frac"] * total_ttx,
                      kind=group, payload=payloads.get(name)))
    for u, v in CDG_EDGES:
        g.add_edge(u, v)
    return g


def cdg_sequential_stage_tx(which: str, total_ttx: float = 2000.0) -> list[float]:
    table = CDG_TABLE2[which]
    return [table[g]["frac"] * total_ttx
            for g in ("T0", "T12", "T36", "T45", "T7")]


# ---------------------------------------------------------------------------
# Multi-workflow tenancy: Campaign
# ---------------------------------------------------------------------------

#: separator between workflow name and set name in a merged campaign DG
WORKFLOW_SEP = "/"


@dataclasses.dataclass(frozen=True)
class WorkflowEntry:
    """One named workflow of a :class:`Campaign`.

    ``priority`` orders workflows for admission (higher = admitted ahead
    of lower); ``arrival`` is the modelled time the workflow's tasks
    become eligible to start; ``weight`` is the fairness weight used by
    weighted-slowdown reporting; ``reference_makespan`` is the workflow's
    dedicated single-tenant makespan (when known), the denominator of its
    slowdown — ``None`` leaves slowdown unreported."""

    name: str
    dag: DAG
    priority: int = 0
    arrival: float = 0.0
    deadline: "float | None" = None
    weight: float = 1.0
    reference_makespan: "float | None" = None

    def __post_init__(self):
        if WORKFLOW_SEP in self.name:
            raise ValueError(
                f"workflow name {self.name!r} may not contain "
                f"{WORKFLOW_SEP!r} (reserved for set namespacing)")
        if self.arrival < 0:
            raise ValueError(f"{self.name}: negative arrival time")
        if self.weight <= 0:
            raise ValueError(f"{self.name}: weight must be positive")
        if self.deadline is not None and self.deadline <= self.arrival:
            raise ValueError(
                f"{self.name}: deadline {self.deadline} must be after "
                f"arrival {self.arrival}")
        if self.reference_makespan is not None \
                and self.reference_makespan <= 0:
            raise ValueError(
                f"{self.name}: reference_makespan must be positive "
                f"(got {self.reference_makespan})")


@dataclasses.dataclass(frozen=True)
class CampaignView:
    """The merged, engine-facing form of a :class:`Campaign`: one DG with
    namespaced set names plus the per-set workflow / arrival / priority
    maps the scheduling engine and the substrates consume."""

    name: str
    dag: DAG
    #: merged set name -> workflow name
    workflow_of: "dict[str, str]"
    #: merged set name -> the workflow's arrival time
    arrival_of: "dict[str, float]"
    #: merged set name -> the workflow's admission priority
    priority_of: "dict[str, int]"
    #: merged set name -> the workflow's fairness weight
    weight_of: "dict[str, float]"
    entries: "tuple[WorkflowEntry, ...]"
    #: merged set name -> the workflow's deadline (None = no SLO); a
    #: late field with a default so positional constructions predating
    #: deadline-aware admission keep working
    deadline_of: "dict[str, float | None]" = (
        dataclasses.field(default_factory=dict))


class Campaign:
    """A set of concurrent workflows multiplexed over one allocation."""

    def __init__(self, entries: "Iterable[WorkflowEntry]" = (),
                 name: str = "campaign"):
        self.name = name
        self.workflows: list[WorkflowEntry] = []
        for e in entries:
            self._append(e)

    def _append(self, e: WorkflowEntry) -> WorkflowEntry:
        if any(w.name == e.name for w in self.workflows):
            raise ValueError(f"duplicate workflow name {e.name!r}")
        self.workflows.append(e)
        return e

    def add(self, name: str, dag: DAG, *, priority: int = 0,
            arrival: float = 0.0, deadline: "float | None" = None,
            weight: float = 1.0,
            reference_makespan: "float | None" = None) -> WorkflowEntry:
        return self._append(WorkflowEntry(
            name, dag, priority=priority, arrival=arrival, deadline=deadline,
            weight=weight, reference_makespan=reference_makespan))

    def __len__(self) -> int:
        return len(self.workflows)

    def entry(self, name: str) -> WorkflowEntry:
        for w in self.workflows:
            if w.name == name:
                return w
        raise KeyError(name)

    def view(self) -> CampaignView:
        """Merge the workflows into one namespaced DG (``wf/set``) + maps."""
        if not self.workflows:
            raise ValueError("campaign has no workflows")
        g = DAG()
        workflow_of: dict[str, str] = {}
        arrival_of: dict[str, float] = {}
        priority_of: dict[str, int] = {}
        weight_of: dict[str, float] = {}
        deadline_of: "dict[str, float | None]" = {}
        for w in self.workflows:
            for ts in w.dag.nodes.values():
                merged = f"{w.name}{WORKFLOW_SEP}{ts.name}"
                g.add(ts.with_(name=merged))
                workflow_of[merged] = w.name
                arrival_of[merged] = w.arrival
                priority_of[merged] = w.priority
                weight_of[merged] = w.weight
                deadline_of[merged] = w.deadline
            for u, v in w.dag.edges():
                g.add_edge(f"{w.name}{WORKFLOW_SEP}{u}",
                           f"{w.name}{WORKFLOW_SEP}{v}")
        return CampaignView(self.name, g, workflow_of, arrival_of,
                            priority_of, weight_of, tuple(self.workflows),
                            deadline_of)


@dataclasses.dataclass(frozen=True)
class WorkflowStats:
    """Per-workflow metrics of one campaign execution."""

    name: str
    arrival: float
    #: first task start / last task end on the execution clock
    start: float
    finish: float
    tasks: int
    priority: int = 0
    weight: float = 1.0
    deadline: "float | None" = None
    reference_makespan: "float | None" = None

    @property
    def makespan(self) -> float:
        """Span from the workflow's first task start to its last end."""
        return self.finish - self.start

    @property
    def wait(self) -> float:
        """Admission + queueing delay: arrival -> first task start."""
        return self.start - self.arrival

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival

    @property
    def slowdown(self) -> "float | None":
        """Turnaround over the dedicated single-tenant makespan (``None``
        when no ``reference_makespan`` was supplied)."""
        if not self.reference_makespan:
            return None
        return self.turnaround / self.reference_makespan

    @property
    def met_deadline(self) -> "bool | None":
        if self.deadline is None:
            return None
        return self.finish <= self.deadline


def campaign_stats(view: CampaignView,
                   records: "Sequence") -> "dict[str, WorkflowStats]":
    """Fold an execution trace (``TaskRecord``-like objects) into
    per-workflow :class:`WorkflowStats`, keyed by workflow name."""
    start: dict[str, float] = {}
    finish: dict[str, float] = {}
    tasks: dict[str, int] = {}
    for r in records:
        wf = view.workflow_of[r.set_name]
        start[wf] = min(start.get(wf, math.inf), r.start)
        finish[wf] = max(finish.get(wf, 0.0), r.end)
        tasks[wf] = tasks.get(wf, 0) + 1
    out = {}
    for w in view.entries:
        out[w.name] = WorkflowStats(
            name=w.name, arrival=w.arrival,
            start=start.get(w.name, w.arrival),
            finish=finish.get(w.name, w.arrival),
            tasks=tasks.get(w.name, 0), priority=w.priority,
            weight=w.weight, deadline=w.deadline,
            reference_makespan=w.reference_makespan)
    return out


def weighted_slowdown(stats: "dict[str, WorkflowStats]") -> "float | None":
    """Fairness-weighted mean slowdown over the workflows that carry a
    ``reference_makespan`` (``None`` when none do)."""
    num = den = 0.0
    for s in stats.values():
        sd = s.slowdown
        if sd is None:
            continue
        num += s.weight * sd
        den += s.weight
    return num / den if den else None

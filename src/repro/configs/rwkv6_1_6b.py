"""rwkv6-1.6b "Finch" [arXiv:2404.05892]: attention-free RWKV6 with
data-dependent decay; head size 64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", rwkv=True,
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536,
)

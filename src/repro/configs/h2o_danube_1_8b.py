"""h2o-danube-1.8b [arXiv:2401.16818]: llama+mistral mix with sliding-
window attention (the released 1.8b uses a 4096 local window)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0,
)

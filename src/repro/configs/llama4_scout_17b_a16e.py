"""llama4-scout-17b-16e [hf:meta-llama/Llama-4-Scout-17B-16E]: MoE 16
experts top-1 + shared expert; iRoPE: chunked-local attention (8192) with
a global NoPE layer every 4th layer.  Early-fusion vision path is out of
scope (text backbone per assignment)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    num_experts=16, experts_per_token=1, moe_d_ff=8192, shared_expert=True,
    chunk_size=8192, global_every=4, rope_theta=500_000.0,
)

"""whisper-tiny [arXiv:2212.04356]: encoder-decoder; conv frontend is a
stub (input_specs provides precomputed 1500-frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_seq=1500, encoder_d_ff=1536,
    frontend_stub=True, tie_embeddings=True,
)

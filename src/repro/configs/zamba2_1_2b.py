"""zamba2-1.2b [arXiv:2411.15242]: Mamba2 backbone + one shared-weight
attention block (invoked every 6th layer) with per-invocation LoRA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, shared_attn_every=6,
)

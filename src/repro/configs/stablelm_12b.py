"""stablelm-12b [hf:stabilityai/stablelm-2-12b]: dense GQA with partial
rotary (25%) and per-head qk layernorm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=13824, vocab_size=100352,
    rope_pct=0.25, qk_norm=True, rope_theta=10_000.0,
)

"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 128 experts top-8 with
normalised top-k routing, GQA kv=4, head_dim 128, per-head qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=6144, vocab_size=151936,
    num_experts=128, experts_per_token=8, moe_d_ff=768, norm_topk=True,
    qk_norm=True, rope_theta=1_000_000.0,
)

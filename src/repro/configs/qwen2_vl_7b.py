"""qwen2-vl-7b [arXiv:2409.12191]: dense backbone with M-RoPE (temporal/
height/width sections); vision frontend is a stub (input_specs provides
patch embeddings / position ids)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
)

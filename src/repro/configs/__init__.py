"""Architecture registry: ``--arch <id>`` -> exact published config.

Also defines the four assigned input shapes and the per-(arch x shape)
applicability rules (long_500k needs sub-quadratic attention; see
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "minicpm-2b": "minicpm_2b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "stablelm-12b": "stablelm_12b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-tiny": "whisper_tiny",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = tuple(SHAPES)


def cell_status(cfg: ModelConfig, shape: str) -> str:
    """'run' or a documented skip reason for one (arch x shape) cell."""
    sp = SHAPES[shape]
    if sp.name == "long_500k" and not cfg.sub_quadratic:
        return "skipped_full_attention"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    """Every (arch, shape, status) — 40 cells."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPE_IDS:
            out.append((a, s, cell_status(cfg, s)))
    return out

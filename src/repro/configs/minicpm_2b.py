"""minicpm-2b [arXiv:2404.06395]: llama-like dense with muP-style scaling
(depth-scaled residuals, scaled embeddings/logits) trained under WSD."""
import math
from repro.models.config import ModelConfig

_L, _D = 40, 2304
CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=_L, d_model=_D, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    residual_scale=1.4 / math.sqrt(_L),    # depth scaling (paper §4)
    embed_scale=12.0, logit_scale=1.0 / (_D / 256),
    tie_embeddings=True, rope_theta=10_000.0,
    # WSD learning-rate schedule is configured in optim (schedule="wsd")
)

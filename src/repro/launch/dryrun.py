import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell:
  lower the step (train_step for train_4k; prefill for prefill_32k;
  serve/decode step for decode_32k / long_500k) with ShapeDtypeStruct
  stand-ins (no allocation), ``.compile()`` it for the production mesh,
  and record memory_analysis / cost_analysis / collective traffic into
  ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` — the roofline
  benchmark (benchmarks/roofline.py) reads these artifacts.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --skip-existing
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_status, get_config
from repro.launch.hlo_analysis import (collective_stats, count_op,
                                       roofline_terms,
                                       weighted_collective_stats)
from repro.launch.mesh import TPU_V5E, make_production_mesh
from repro.models.api import build_model
from repro.models.params import count_params, abstract_params
from repro.runtime import ShardingRules
from repro.runtime.steps import (TrainOptions, abstract_train_state,
                                 batch_shardings, build_decode_step,
                                 build_prefill_step, build_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _with_shardings(specs: dict, shardings: dict) -> dict:
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                    sharding=shardings[k])
            for k, v in specs.items()}


def lower_cell(arch: str, shape_name: str, mesh, rules: ShardingRules,
               opts: TrainOptions | None = None,
               flags: dict | None = None):
    """Returns (lowered, meta) for one cell."""
    cfg = get_config(arch)
    model = build_model(cfg)
    sp = SHAPES[shape_name]
    opts = opts or TrainOptions()

    if sp.mode == "train":
        step, _ = build_train_step(model, mesh, rules, opts, flags)
        state = abstract_train_state(model)
        # place shardings on the state stand-ins so .lower() is fully
        # specified even though in_shardings also carry them
        bsh = batch_shardings(
            model, model.input_specs(batch=sp.global_batch, seq=sp.seq_len,
                                     mode="train"), mesh, rules)
        batch = _with_shardings(
            model.input_specs(batch=sp.global_batch, seq=sp.seq_len,
                              mode="train"), bsh)
        lowered = step.lower(state, batch)
    elif sp.mode == "prefill":
        step, _ = build_prefill_step(model, mesh, rules, flags)
        params = abstract_params(model.specs())
        bsh = batch_shardings(
            model, model.input_specs(batch=sp.global_batch, seq=sp.seq_len,
                                     mode="prefill"), mesh, rules)
        batch = _with_shardings(
            model.input_specs(batch=sp.global_batch, seq=sp.seq_len,
                              mode="prefill"), bsh)
        lowered = step.lower(params, batch)
    else:  # decode
        step, (ps, cs) = build_decode_step(
            model, mesh, rules, batch=sp.global_batch, s_max=sp.seq_len,
            flags=flags)
        params = abstract_params(model.specs())
        cache = abstract_params(model.cache_specs(sp.global_batch,
                                                  sp.seq_len))
        dec = model.input_specs(batch=sp.global_batch, seq=sp.seq_len,
                                mode="decode")
        lowered = step.lower(params, cache, dec["tokens"], dec["pos"])

    meta = dict(arch=arch, shape=shape_name, mode=sp.mode,
                seq_len=sp.seq_len, global_batch=sp.global_batch,
                params=count_params(model.specs()),
                active_params=cfg.active_param_count_estimate())
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules: ShardingRules | None = None, out_dir: str = OUT_DIR,
             verbose: bool = True, tag: str = "", flags: dict | None = None,
             mesh_shape: tuple[int, int] | None = None):
    """``mesh_shape`` overrides the single-pod (data, model) aspect ratio —
    a §Perf hillclimb knob (the chip count stays 256)."""
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    cell_dir = os.path.join(out_dir, mesh_name)
    os.makedirs(cell_dir, exist_ok=True)
    path = os.path.join(cell_dir, f"{arch}__{shape_name}{tag}.json")

    cfg = get_config(arch)
    status = cell_status(cfg, shape_name)
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, status=status)
    if status != "run":
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name}: "
                  f"{status}")
        return rec

    if mesh_shape is not None:
        assert not multi_pod
        import jax as _jax
        import math as _math
        n = int(_math.prod(mesh_shape))
        mesh = _jax.make_mesh(mesh_shape, ("data", "model"),
                              devices=_jax.devices()[:n])
        rec["mesh_override"] = list(mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ShardingRules()
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, rules,
                                   flags=flags)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_info = dict(
                bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
                argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                output_bytes=getattr(mem, "output_size_in_bytes", None),
                peak_bytes=getattr(
                    mem, "peak_memory_in_bytes",
                    getattr(mem, "temp_size_in_bytes", None)))
        except Exception as e:  # CPU backend may not implement it
            mem_info = {"unavailable": str(e)}

        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        # loop-weighted: a while body's collectives count x trip_count
        coll_w = weighted_collective_stats(hlo)
        terms = roofline_terms(cost, coll_w, TPU_V5E)

        n_dev = mesh.size
        rec.update(
            meta,
            mesh_shape={k: int(v) for k, v in mesh.shape.items()},
            devices=n_dev,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            cost=dict(flops=cost.get("flops"),
                      bytes_accessed=cost.get("bytes accessed"),
                      transcendentals=cost.get("transcendentals")),
            memory=mem_info,
            collectives=coll.as_dict(),
            collectives_weighted=coll_w.as_dict(),
            roofline=terms.as_dict(),
            hlo_ops=dict(
                fusion=count_op(hlo, "fusion"),
                while_=count_op(hlo, "while"),
                dot=count_op(hlo, "dot"),
            ),
        )
        if verbose:
            print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name}: OK  "
                  f"flops/dev={terms.flops:.3g} coll={coll.total_bytes:.3g}B "
                  f"dominant={terms.dominant} "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name}: "
                  f"FAIL {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    results = []
    for multi in meshes:
        for a in archs:
            for s in shapes:
                mesh_name = ("multi_pod_2x16x16" if multi
                             else "single_pod_16x16")
                path = os.path.join(args.out, mesh_name, f"{a}__{s}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("run", "skipped_full_attention"
                                              ) and "error" not in prev:
                        print(f"[dryrun] skip existing {a} {s} {mesh_name}")
                        continue
                results.append(run_cell(a, s, multi_pod=multi,
                                        out_dir=args.out))
    bad = [r for r in results if r.get("status") == "error"]
    print(f"\n[dryrun] {len(results)} cells run, {len(bad)} failures")
    if bad:
        for r in bad:
            print("  FAIL:", r["arch"], r["shape"], r["mesh"],
                  r.get("error"))
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Serving launcher: batched prefill + decode against a KV cache.

``python -m repro.launch.serve --arch qwen2-0.5b --requests 8``

Implements the production serving loop in miniature: a request queue is
batched, prefilled (one sharded forward over the prompt), then decoded
step-by-step with a persistent sharded cache.  On TPU the same loop runs
the full config on the production mesh.

At fleet scale these serving jobs are the arrival process of the
streaming-tenancy scheduler: `core/stream.py` models an open stream of
them (`examples/stream_tenancy.py`, `benchmarks/bench_streaming.py`)
with per-arrival SLOs, deadline-aware admission, and elastic capacity.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model
from repro.models.params import init_params
from repro.runtime import ShardingRules
from repro.runtime.steps import build_decode_step, build_prefill_step


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif len(jax.devices()) > 1:
        mesh = make_host_mesh()
    else:
        mesh = None
    rules = ShardingRules()

    params = init_params(model.specs(), jax.random.PRNGKey(0))
    b = args.requests

    # ---- prefill ----------------------------------------------------------
    prefill, _ = build_prefill_step(model, mesh, rules)
    batch = model.make_batch(jax.random.PRNGKey(1), batch=b,
                             seq=args.prompt_len, mode="prefill")
    batch.pop("labels", None)
    t0 = time.perf_counter()
    last_logits = prefill(params, batch)
    next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {b} x {args.prompt_len} tokens in {t_prefill:.3f}s")

    # ---- decode -----------------------------------------------------------
    decode, _ = build_decode_step(model, mesh, rules, batch=b,
                                  s_max=args.cache_len)
    cache = init_params(model.cache_specs(b, args.cache_len),
                        jax.random.PRNGKey(2))
    pos = jnp.full((b,), args.prompt_len, jnp.int32)
    toks = [np.asarray(next_tok)]
    t0 = time.perf_counter()
    for i in range(args.gen_len):
        out = decode(params, cache, next_tok[:, None], pos + i)
        if len(out) == 3:
            next_tok, _, cache = out
        else:
            next_tok, cache = out
        toks.append(np.asarray(next_tok))
    dt = time.perf_counter() - t0
    gen = np.stack(toks, axis=1)
    print(f"decode: {args.gen_len} steps x {b} requests in {dt:.3f}s "
          f"({b * args.gen_len / dt:.1f} tok/s)")
    print("generated ids (first request):", gen[0][:12], "...")
    return gen


if __name__ == "__main__":
    serve()

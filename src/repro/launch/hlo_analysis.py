"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
bytes; we parse the partitioned HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Shapes in the post-partitioning module are PER-DEVICE, so all derived
terms are per-device seconds; the roofline denominator is then a single
chip's peak (no further division by chip count).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
#: op keyword with its opening paren (op NAMES like %all-reduce.696 or
#: operand references never match because they lack the trailing "(").
_KIND_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op ('-start' variants
    counted once, '-done' skipped).

    Parsed procedurally per line: the output type is everything between
    the '=' and the op keyword — large tuple types embed ``/*index=N*/``
    comments (which contain '='), so a pure-regex prefix match silently
    drops exactly the big fused gradient all-reduces."""
    bytes_by: dict[str, int] = {}
    count_by: dict[str, int] = {}
    for line in hlo_text.splitlines():
        eq = line.find("=")
        if eq < 0:
            continue
        rest = line[eq + 1:]
        m = _KIND_RE.search(rest)
        if not m or m.group(2) == "-done":
            continue
        b = _shape_bytes(rest[:m.start()])
        kind = m.group(1)
        bytes_by[kind] = bytes_by.get(kind, 0) + b
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"= *\S* {opname}\(", hlo_text))


# ---------------------------------------------------------------------------
# Loop-aware (weighted) accounting.
#
# XLA's cost analysis and a flat text scan both count a while-loop body
# ONCE; a layer scan with trip count L therefore under-counts collectives
# by ~L x.  We rebuild the computation call graph, propagate multiplicity
# through `body=`/`to_apply=`/`calls=`/`condition=` edges (while bodies
# weighted by their `known_trip_count` backend config), and weight each
# computation's collective bytes by its total multiplicity.
# ---------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_REF_RE = re.compile(
    r"(body|condition|to_apply|calls)=\{?%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def _split_computations(hlo_text: str):
    """-> (entry_name, {name: [lines]})."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: str | None = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                if line.strip().endswith("}"):  # one-liner
                    cur = None
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return entry, comps


def _comp_edges(lines: list[str]):
    """[(callee, weight)] for one computation's body."""
    edges: list[tuple[str, int]] = []
    for line in lines:
        is_while = re.search(r"\bwhile\(", line) is not None
        trip = 1
        if is_while:
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else 1
        for kind, name in _REF_RE.findall(line):
            w = trip if (is_while and kind == "body") else 1
            edges.append((name, w))
        bm = _BRANCH_RE.search(line)
        if bm:
            for name in bm.group(1).split(","):
                edges.append((name.strip().lstrip("%"), 1))
    return edges


def computation_multiplicities(hlo_text: str) -> dict[str, int]:
    """Total execution count of each computation (entry = 1; while bodies
    x trip count; summed over call sites).  The graph is a DAG."""
    entry, comps = _split_computations(hlo_text)
    edges = {name: [(c, w) for c, w in _comp_edges(lines) if c in comps]
             for name, lines in comps.items()}
    if entry is None:
        return {name: 1 for name in comps}
    # Kahn topological order over the call DAG
    indeg = {name: 0 for name in comps}
    for es in edges.values():
        for c, _ in es:
            indeg[c] += 1
    queue = [n for n, d in indeg.items() if d == 0]
    order = []
    while queue:
        n = queue.pop()
        order.append(n)
        for c, _ in edges[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                queue.append(c)
    mult = {name: 0 for name in comps}
    mult[entry] = 1
    for name in order:
        for callee, w in edges[name]:
            mult[callee] += mult[name] * max(w, 1)
    return mult


def weighted_collective_stats(hlo_text: str) -> CollectiveStats:
    entry, comps = _split_computations(hlo_text)
    mult = computation_multiplicities(hlo_text)
    bytes_by: dict[str, int] = {}
    count_by: dict[str, int] = {}
    for name, lines in comps.items():
        m = max(mult.get(name, 1), 1)
        sub = collective_stats("\n".join(lines))
        for k, v in sub.bytes_by_kind.items():
            bytes_by[k] = bytes_by.get(k, 0) + v * m
        for k, v in sub.count_by_kind.items():
            count_by[k] = count_by.get(k, 0) + v * m
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineTerms:
    """All terms in seconds (per device, one step)."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the step the MXU is the binding constraint —
        (compute term / max term); 1.0 == compute-bound at roofline."""
        return self.compute_s / max(self.step_time_s, 1e-30)

    def as_dict(self):
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "step_time_s": self.step_time_s,
                "compute_fraction": self.compute_fraction}


def roofline_terms(cost: dict, coll: CollectiveStats, hw: dict,
                   ) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    # XLA:CPU reports utilization-style bytes under 'bytes accessed{...}'
    hbm = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.total_bytes)
    return RooflineTerms(
        compute_s=flops / hw["peak_bf16_flops"],
        memory_s=hbm / hw["hbm_bytes_per_s"],
        collective_s=cb / hw["ici_bytes_per_s"],
        flops=flops, hbm_bytes=hbm, collective_bytes=cb)

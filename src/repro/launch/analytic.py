"""Analytic per-step FLOP and HBM-byte accounting per (arch x shape).

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts a ``while`` body once,
so any layer-scanned model under-reports FLOPs by ~L x; and XLA:CPU's
'bytes accessed' counts every fusion operand read, over-reporting TPU HBM
traffic.  The roofline's compute and memory terms therefore come from
this structural model (matmul dims are fully determined by the config);
the collective term still comes from the compiled HLO (loop-weighted).

Conventions:
- FLOPs are 2 x MACs; attention kv-extent uses the true masked average.
- train = 3 x forward matmul FLOPs (bwd = 2x; dot results are saved by
  the remat policy, so recompute adds only elementwise work).
- decode counts the full cache extent (the dense decode path scores every
  slot and masks).
"""

from __future__ import annotations

import dataclasses

from repro.configs import ShapeSpec
from repro.models.config import ModelConfig

CAPACITY_FACTOR = 1.25


def _avg_kv(cfg: ModelConfig, s: int, layer_frac_global: float = 0.0) -> float:
    """Average attended kv extent per query token under the arch's mask."""
    if cfg.sliding_window:
        w = cfg.sliding_window
        # causal within a window: ramp up to w then flat
        if s <= w:
            return (s + 1) / 2
        return (w * (w + 1) / 2 + (s - w) * w) / s
    if cfg.chunk_size and cfg.global_every:
        local = (cfg.chunk_size + 1) / 2 if s >= cfg.chunk_size else (s + 1) / 2
        glob = (s + 1) / 2
        f = 1.0 / cfg.global_every
        return (1 - f) * local + f * glob
    return (s + 1) / 2


def _attn_flops_per_layer(cfg: ModelConfig, b: int, s: int) -> float:
    """QKV/out projections + score/value contractions for one layer."""
    t = b * s
    proj = (2 * t * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
            + 2 * t * cfg.q_dim * cfg.d_model)
    sc = 4 * t * _avg_kv(cfg, s) * cfg.q_dim
    return proj + sc


def _mlp_flops_per_layer(cfg: ModelConfig, tokens: float) -> float:
    if cfg.family == "moe":
        f = (6 * tokens * cfg.experts_per_token * cfg.d_model
             * cfg.moe_d_ff * CAPACITY_FACTOR)
        f += 2 * tokens * cfg.d_model * cfg.num_experts          # router
        if cfg.shared_expert:
            f += 6 * tokens * cfg.d_model * cfg.d_ff
        return f
    return 6 * tokens * cfg.d_model * cfg.d_ff


def _rwkv_flops_per_layer(cfg: ModelConfig, tokens: float,
                          chunk: int = 128) -> float:
    d = cfg.d_model
    k = 64
    # 5 square projections (r,k,v,g,o) + ddlerp/decay loras + channel mix
    proj = 2 * tokens * d * d * 5 + 2 * tokens * d * (5 * 32 + 64) * 2
    cm = 2 * tokens * d * cfg.d_ff * 2 + 2 * tokens * d * d
    # chunked scan per token per head: scores row (C*K) + o_intra (C*K)
    # + inter/state (4*K*K)
    h = d // k
    scan = tokens * h * (2 * chunk * k + 2 * chunk * k + 4 * k * k)
    return proj + cm + scan


def _ssd_flops_per_layer(cfg: ModelConfig, tokens: float,
                         chunk: int = 128) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n, p = cfg.ssm_state, 64
    h = d_in // p
    proj = 2 * tokens * d * (2 * d_in + 2 * n + h) + 2 * tokens * d_in * d
    conv = 2 * tokens * (d_in + 2 * n) * cfg.ssm_conv
    scan = tokens * h * (2 * chunk * n + 2 * chunk * p + 4 * n * p)
    return proj + conv + scan


def forward_flops(cfg: ModelConfig, b: int, s: int) -> float:
    """One forward pass, full logits."""
    t = b * s
    unembed = 2 * t * cfg.d_model * cfg.vocab_size
    if cfg.family == "encdec":
        enc_t = b * cfg.encoder_seq
        enc = cfg.encoder_layers * (
            2 * enc_t * cfg.d_model * 4 * cfg.d_model
            + 4 * enc_t * cfg.encoder_seq * cfg.q_dim
            + 4 * enc_t * cfg.d_model * (cfg.encoder_d_ff or cfg.d_ff))
        dec = cfg.num_layers * (
            _attn_flops_per_layer(cfg, b, s)                      # self
            + 2 * t * cfg.d_model * 2 * cfg.d_model               # cross qo
            + 2 * enc_t * cfg.d_model * 2 * cfg.d_model           # cross kv
            + 4 * t * cfg.encoder_seq * cfg.q_dim                  # cross sc
            + 4 * t * cfg.d_model * cfg.d_ff)
        return enc + dec + unembed
    if cfg.rwkv:
        return cfg.num_layers * _rwkv_flops_per_layer(cfg, t) + unembed
    if cfg.family in ("ssm", "hybrid"):
        body = cfg.num_layers * _ssd_flops_per_layer(cfg, t)
        if cfg.shared_attn_every:
            n_inv = cfg.num_layers // cfg.shared_attn_every
            dd = 2 * cfg.d_model
            per = (2 * t * dd * (cfg.q_dim + cfg.kv_dim)           # q,k w/ 2D in
                   + 2 * t * dd * cfg.kv_dim + 2 * t * cfg.q_dim * cfg.d_model
                   + 4 * t * (s + 1) / 2 * cfg.q_dim
                   + 6 * t * cfg.d_model * cfg.d_ff)
            body += n_inv * per
        return body + unembed
    per_layer = (_attn_flops_per_layer(cfg, b, s)
                 + _mlp_flops_per_layer(cfg, t))
    return cfg.num_layers * per_layer + unembed


def decode_flops(cfg: ModelConfig, b: int, s_cache: int) -> float:
    """One decode step for a batch of b, cache extent s_cache."""
    t = b
    unembed = 2 * t * cfg.d_model * cfg.vocab_size
    if cfg.rwkv:
        d, k = cfg.d_model, 64
        h = d // k
        per = (2 * d * d * 5 + 4 * h * k * k * 2 + 2 * d * cfg.d_ff * 2
               + 2 * d * d)
        return cfg.num_layers * t * per + unembed
    if cfg.family in ("ssm", "hybrid"):
        d = cfg.d_model
        d_in = cfg.ssm_expand * d
        n, p = cfg.ssm_state, 64
        h = d_in // p
        per = 2 * d * (2 * d_in + 2 * n + h) + 2 * d_in * d + 4 * h * n * p
        body = cfg.num_layers * t * per
        if cfg.shared_attn_every:
            n_inv = cfg.num_layers // cfg.shared_attn_every
            w = min(s_cache, 4096)
            body += n_inv * t * (2 * 2 * d * (cfg.q_dim + 2 * cfg.kv_dim)
                                 + 4 * w * cfg.q_dim
                                 + 6 * d * cfg.d_ff)
        return body + unembed
    kv = min(s_cache, cfg.sliding_window or s_cache)
    per = (2 * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
           + 2 * cfg.q_dim * cfg.d_model + 4 * kv * cfg.q_dim)
    if cfg.family == "moe":
        mlp = 6 * cfg.experts_per_token * cfg.d_model * cfg.moe_d_ff
        if cfg.shared_expert:
            mlp += 6 * cfg.d_model * cfg.d_ff
        mlp += 2 * cfg.d_model * cfg.num_experts
    else:
        mlp = 6 * cfg.d_model * cfg.d_ff
    out = cfg.num_layers * t * (per + mlp) + unembed
    if cfg.family == "encdec":
        out += cfg.num_layers * t * (2 * cfg.d_model * 2 * cfg.d_model
                                     + 4 * cfg.encoder_seq * cfg.q_dim)
    return out


@dataclasses.dataclass(frozen=True)
class AnalyticCell:
    flops_global: float          # whole step, all devices
    hbm_bytes_global: float      # structural HBM traffic floor
    model_flops: float           # 6*N_active*D (train) / 2*N*D (serve)

    def per_device(self, n: int):
        return (self.flops_global / n, self.hbm_bytes_global / n,
                self.model_flops / n)


def analyse_cell(cfg: ModelConfig, shape: ShapeSpec, n_params: int,
                 n_active: int, batch_axes_size: int) -> AnalyticCell:
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s
    if shape.mode == "train":
        fwd = forward_flops(cfg, b, s)
        flops = 3.0 * fwd
        model = 6.0 * n_active * tokens
        # params+opt fp32 read/write + bf16 grads + saved activations x2
        act = 6 * tokens * cfg.d_model * cfg.num_layers * 2 * 2
        hbm = n_params * 28.0 + act
    elif shape.mode == "prefill":
        # prefill unembeds only the final position (runtime slices first)
        flops = (forward_flops(cfg, b, s)
                 - 2 * (tokens - b) * cfg.d_model * cfg.vocab_size)
        model = 2.0 * n_active * tokens
        act = 4 * tokens * cfg.d_model * cfg.num_layers * 2
        hbm = n_params * 4.0 + act
    else:
        flops = decode_flops(cfg, b, s)
        model = 2.0 * n_active * b
        cache = cache_bytes(cfg, b, s)
        hbm = n_params * 4.0 + cache
    return AnalyticCell(flops, hbm, model)


def cache_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    if cfg.rwkv:
        h = cfg.d_model // 64
        return cfg.num_layers * b * (h * 64 * 64 * 4 + 2 * cfg.d_model * 2)
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model
        h = d_in // 64
        out = cfg.num_layers * b * (h * cfg.ssm_state * 64 * 4
                                    + (cfg.ssm_conv - 1)
                                    * (d_in + 2 * cfg.ssm_state) * 2)
        if cfg.shared_attn_every:
            n_inv = cfg.num_layers // cfg.shared_attn_every
            out += n_inv * b * min(s, 4096) * cfg.kv_dim * 2 * 2
        return out
    kv_len = min(s, cfg.sliding_window or s)
    out = cfg.num_layers * b * kv_len * cfg.kv_dim * 2 * 2
    if cfg.family == "encdec":
        out += cfg.num_layers * b * cfg.encoder_seq * cfg.q_dim * 2 * 2
    return out

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run (and only the dry-run) forces 512 host platform
devices before any jax import.

Mesh layout decisions (see DESIGN.md §4):
- single pod: (16, 16) ('data', 'model') — FSDP/DP over rows, TP/EP/SP
  over columns (a v5e pod's 16x16 torus maps model-parallel traffic onto
  single-hop ICI rings).
- multi-pod: (2, 16, 16) ('pod', 'data', 'model') — the pod axis composes
  with 'data' for batch sharding; the only steady-state cross-pod
  collective is the gradient all-reduce over 'pod' (optionally top-k
  compressed), which rides the slower inter-pod links.
"""

from __future__ import annotations

import jax
import numpy as np

TPU_V5E = dict(
    name="tpu_v5e",
    peak_bf16_flops=197e12,      # per chip
    hbm_bytes_per_s=819e9,       # per chip
    ici_bytes_per_s=5.0e10,      # ~50 GB/s per link
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dryrun.py must set XLA_FLAGS before importing jax)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_axis: int = 1):
    """Whatever this host has, as ('data','model') — for examples/tests."""
    devs = jax.devices()
    rows = max(1, len(devs) // model_axis)
    mesh_devs = np.asarray(devs[: rows * model_axis]).reshape(
        rows, model_axis)
    from jax.sharding import Mesh
    return Mesh(mesh_devs, ("data", "model"))

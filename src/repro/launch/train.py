"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs a real (CPU-scale by default) training loop with the full production
substrate: sharded data pipeline, AdamW + schedule, gradient accumulation,
async checkpointing, failure injection + elastic restart, straggler
monitoring.  On a TPU slice the same launcher runs the full config on the
production mesh (``--production-mesh``).
"""

from __future__ import annotations

import argparse
import logging
import time

import jax

from repro.checkpoint import CheckpointManager, latest_step, restore_pytree
from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticTokenDataset, make_global_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model
from repro.runtime import (FailureInjector, ShardingRules, StragglerMonitor,
                           TrainOptions)
from repro.runtime.steps import build_train_step, make_train_state

log = logging.getLogger("repro.train")


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-sized config (CPU default)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=20)
    ap.add_argument("--failure-rate", type=float, default=0.0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # minicpm trains under WSD per its paper
    schedule = "wsd" if args.arch == "minicpm-2b" else args.schedule
    model = build_model(cfg)

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif len(jax.devices()) > 1:
        mesh = make_host_mesh()
    else:
        mesh = None
    rules = ShardingRules()
    opts = TrainOptions(peak_lr=args.lr, warmup=max(2, args.steps // 20),
                        total_steps=args.steps, schedule=schedule,
                        microbatches=args.microbatches)
    step_fn, shardings = build_train_step(model, mesh, rules, opts)

    state = make_train_state(model, jax.random.PRNGKey(0))
    start = 0
    mgr = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    if args.resume and (ls := latest_step(args.ckpt_dir)) is not None:
        state = restore_pytree(state, args.ckpt_dir, ls,
                               shardings if mesh is not None else None)
        start = ls + 1
        log.info("resumed from step %d", ls)

    ds = SyntheticTokenDataset(cfg.vocab_size, args.seq, args.batch)
    injector = FailureInjector(rate=args.failure_rate, seed=1)
    monitor = StragglerMonitor()

    losses = []
    s = start
    while s < args.steps:
        try:
            injector.check(s)
        except Exception:
            # elastic restart: reload latest checkpoint, continue
            mgr.wait()
            ls = latest_step(args.ckpt_dir)
            if ls is not None:
                state = restore_pytree(state, args.ckpt_dir, ls)
                s = ls + 1
            log.warning("injected failure; restarted at step %d", s)
            continue
        if mesh is not None:
            batch = make_global_batch(ds, s, mesh)
        else:
            hb = ds.host_batch(s)
            batch = {k: jax.numpy.asarray(v) for k, v in hb.items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        monitor.observe(time.perf_counter() - t0)
        losses.append(loss)
        mgr.maybe_save(state, s)
        if s % args.log_every == 0:
            print(f"step {s:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")
        s += 1
    mgr.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"stragglers flagged: {monitor.flagged}")
    return losses


if __name__ == "__main__":
    logging.basicConfig(level=logging.INFO)
    train()

"""Step builders: jit-compiled, mesh-sharded train / prefill / decode steps.

``build_train_step`` returns (step_fn, state_shardings, input_shardings)
where step_fn: (TrainState, batch) -> (TrainState, metrics).  All sharding
comes from the logical-axis rules (runtime/sharding.py); the same builder
serves the real trainer, the smoke tests (mesh=None) and the dry-run
(ShapeDtypeStructs via .lower()).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.models.params import (abstract_params, init_params,
                                 param_shardings)
from repro.optim import (AdamWState, GradAccumulator, adamw_init,
                         adamw_update, clip_by_global_norm, make_schedule)
from .sharding import ShardingRules, use_sharding


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"
    max_grad_norm: float = 1.0
    weight_decay: float = 0.1
    microbatches: int = 1
    remat: bool = True


def _remat_loss(model: Model):
    """Activation-checkpoint the loss at layer-scan granularity: the scan
    body is the natural remat unit, so `jax.checkpoint` with a
    dots-saveable policy keeps matmul outputs and recomputes the rest."""
    policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(model.loss, policy=policy)


def make_train_state(model: Model, key=None):
    specs = model.specs()
    params = init_params(specs, key if key is not None
                         else jax.random.PRNGKey(0))
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=adamw_init(params))


def abstract_train_state(model: Model) -> TrainState:
    specs = model.specs()
    p = abstract_params(specs)
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=p,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       mu=f32, nu=jax.tree.map(lambda x: x, f32)))


def state_shardings(model: Model, mesh, rules: ShardingRules) -> TrainState:
    ps = param_shardings(model.specs(), mesh, rules)
    rep = NamedSharding(mesh, P())
    return TrainState(
        step=rep, params=ps,
        opt=AdamWState(step=rep, mu=jax.tree.map(lambda s: s, ps),
                       nu=jax.tree.map(lambda s: s, ps)))


def _batch_axes(rules: ShardingRules, mesh, batch_size: int):
    """Largest prefix of the configured batch axes that divides the batch
    (long_500k has global_batch=1 -> fully replicated)."""
    axes: list[str] = []
    size = 1
    for a in rules.mesh_axes_for("batch", mesh):
        if batch_size % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    return tuple(axes)


def _batch_entry(axes):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_shardings(model: Model, batch_specs, mesh, rules: ShardingRules):
    """Data inputs: leading batch dim over ('pod','data') when divisible;
    special cases (positions [3,B,S]) spelled out by name."""
    def sh(name, s):
        bdim = s.shape[1] if name == "positions" else s.shape[0]
        spec_b = _batch_entry(_batch_axes(rules, mesh, bdim))
        if name == "positions":
            return NamedSharding(mesh, P(None, spec_b))
        return NamedSharding(mesh, P(spec_b))

    return {k: sh(k, v) for k, v in batch_specs.items()}


def build_train_step(model: Model, mesh=None,
                     rules: ShardingRules | None = None,
                     opts: TrainOptions = TrainOptions(),
                     flags: dict | None = None):
    """Returns (train_step, shardings) — train_step is NOT yet jitted with
    shardings when mesh is None (smoke path uses plain jit)."""
    rules = rules or ShardingRules()
    sched = make_schedule(
        opts.schedule, peak_lr=opts.peak_lr, warmup=opts.warmup,
        total=opts.total_steps)
    accum = GradAccumulator(opts.microbatches)
    loss_fn = _remat_loss(model) if opts.remat else model.loss

    def train_step(state: TrainState, batch):
        with use_sharding(mesh, rules, flags):
            loss, grads = accum.grads(loss_fn, state.params, batch)
            grads, gnorm = clip_by_global_norm(grads, opts.max_grad_norm)
            lr = sched(state.step)
            params, opt = adamw_update(
                grads, state.opt, state.params, lr=lr,
                weight_decay=opts.weight_decay)
        new = TrainState(step=state.step + 1, params=params, opt=opt)
        return new, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    if mesh is None:
        return jax.jit(train_step), None

    shardings = state_shardings(model, mesh, rules)
    rep = NamedSharding(mesh, P())
    jitted = jax.jit(
        train_step,
        in_shardings=(shardings, None),      # batch sharding via data layer
        out_shardings=(shardings,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
        donate_argnums=(0,),
    )
    return jitted, shardings


def build_prefill_step(model: Model, mesh=None,
                       rules: ShardingRules | None = None,
                       flags: dict | None = None):
    rules = rules or ShardingRules()

    def prefill(params, batch):
        with use_sharding(mesh, rules, flags):
            # the hidden state is sliced to the final position BEFORE the
            # unembedding matmul: one next-token distribution per request,
            # not a [B, S, V] logits tensor
            logits, _ = model.forward(params, batch, last_only=True)
            return logits[:, -1].astype(jnp.float32)

    if mesh is None:
        return jax.jit(prefill), None
    ps = param_shardings(model.specs(), mesh, rules)
    out_sh = NamedSharding(
        mesh, rules.spec_for(("batch", "act_vocab"), (1, 1), mesh))
    return jax.jit(prefill, in_shardings=(ps, None),
                   out_shardings=out_sh), ps


def cache_shardings(model: Model, batch: int, s_max: int, mesh,
                    rules: ShardingRules):
    return param_shardings(model.cache_specs(batch, s_max), mesh, rules)


def build_decode_step(model: Model, mesh=None,
                      rules: ShardingRules | None = None, *,
                      batch: int, s_max: int, flags: dict | None = None):
    """One new token against a KV cache of ``s_max``.  Returns
    (decode_step, (param_shardings, cache_shardings))."""
    rules = rules or ShardingRules()

    def decode(params, cache, tokens, pos):
        with use_sharding(mesh, rules, flags):
            logits, cache = model.decode_step(params, cache, tokens, pos)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, logits.astype(jnp.float32), cache

    if mesh is None:
        return jax.jit(decode), None
    ps = param_shardings(model.specs(), mesh, rules)
    cs = cache_shardings(model, batch, s_max, mesh, rules)
    spec_b = _batch_entry(_batch_axes(rules, mesh, batch))
    tok_sh = NamedSharding(mesh, P(spec_b))
    jitted = jax.jit(
        decode,
        in_shardings=(ps, cs, tok_sh, tok_sh),
        out_shardings=(tok_sh, NamedSharding(mesh, P(spec_b)), cs),
        donate_argnums=(1,),
    )
    return jitted, (ps, cs)
